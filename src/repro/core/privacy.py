"""Differential privacy for the FedGenGMM uplink (paper §4.4, left as
future work there — implemented here as a beyond-paper feature).

The one-shot structure is DP-friendly: the WHOLE privacy budget is spent on
a single release of the local GMM parameters (vs. iterative methods that
split epsilon across rounds — the depletion problem of Huang et al. '23
cited in the paper).

Mechanism: per-client Gaussian mechanism on the sufficient-statistic view
of the GMM. Features are normalized to [0,1]^d (§5.1), so per-sample
sensitivity of the (clipped) statistics is bounded:

    weights  : histogram release, L2 sensitivity sqrt(2)/|D_c|
    means    : each coordinate in [0,1]; sensitivity <= sqrt(d)/n_k
    variances: each coordinate in [0,1]; sensitivity <= sqrt(d)/n_k

We use the analytic Gaussian mechanism calibration sigma =
sqrt(2 ln(1.25/delta)) * sensitivity / epsilon (composition across the
three releases by simple epsilon-splitting). Variances are re-clipped to
stay positive; weights are re-projected to the simplex.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.gmm import GMM


class DPConfig(NamedTuple):
    epsilon: float = 1.0
    delta: float = 1e-5
    min_count: float = 8.0   # floor on per-component effective counts


def gaussian_sigma(sensitivity: float, epsilon: float, delta: float) -> float:
    return math.sqrt(2.0 * math.log(1.25 / delta)) * sensitivity / epsilon


def privatize_gmm(key: jax.Array, gmm: GMM, n_samples: float,
                  dp: DPConfig) -> GMM:
    """Release a (epsilon, delta)-DP view of one client's GMM parameters.

    Assumes diagonal covariance and features in [0,1]^d."""
    assert gmm.is_diagonal, "DP release supports diagonal covariance"
    k, d = gmm.means.shape
    eps_each = dp.epsilon / 3.0
    kw, km, kv = jax.random.split(key, 3)

    # effective per-component counts (for sensitivity of means/vars)
    counts = jnp.maximum(gmm.weights * n_samples, dp.min_count)

    # weights: histogram of proportions
    sig_w = gaussian_sigma(math.sqrt(2.0) / max(n_samples, 1.0), eps_each,
                           dp.delta)
    w = gmm.weights + sig_w * jax.random.normal(kw, (k,))
    w = jnp.maximum(w, 1e-4)
    w = w / jnp.sum(w)

    # means: coordinates bounded by [0,1]
    sig_m = gaussian_sigma(math.sqrt(d), eps_each, dp.delta)
    mu = gmm.means + (sig_m / counts[:, None]) * \
        jax.random.normal(km, (k, d))
    mu = jnp.clip(mu, 0.0, 1.0)

    # variances: bounded by [0, 1/4] coordinate-wise for [0,1] data
    sig_v = gaussian_sigma(math.sqrt(d) / 4.0, eps_each, dp.delta)
    var = gmm.covs + (sig_v / counts[:, None]) * \
        jax.random.normal(kv, (k, d))
    var = jnp.clip(var, 1e-5, 0.25)

    return GMM(w, mu, var)


def privatize_clients(key: jax.Array, gmms: list[GMM], sizes,
                      dp: DPConfig) -> list[GMM]:
    return [privatize_gmm(jax.random.fold_in(key, i), g, float(n), dp)
            for i, (g, n) in enumerate(zip(gmms, sizes))]
