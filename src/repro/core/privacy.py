"""Differential privacy for the FedGenGMM uplink (paper §4.4, left as
future work there — implemented here as a beyond-paper feature).

The one-shot structure is DP-friendly: the WHOLE privacy budget is spent on
a single release of the local GMM parameters (vs. iterative methods that
split epsilon across rounds — the depletion problem of Huang et al. '23
cited in the paper).

Mechanism: per-client Gaussian mechanism on the sufficient-statistic view
of the GMM. Features are normalized to [0,1]^d (§5.1), so per-sample
sensitivity of the (clipped) statistics is bounded:

    weights  : histogram release, L2 sensitivity sqrt(2)/|D_c|
    means    : each coordinate in [0,1]; sensitivity <= sqrt(d)/n_k
    variances: each coordinate in [0,1]; sensitivity <= sqrt(d)/n_k

We use the analytic Gaussian mechanism calibration sigma =
sqrt(2 ln(1.25/delta)) * sensitivity / epsilon (composition across the
three releases by simple epsilon-splitting). Variances are re-clipped to
stay positive; weights are re-projected to the simplex.

Since §11 the mechanism itself lives in ``repro.fed.transforms.
GaussianDP`` — the uplink-transform seam every strategy shares — and the
entry points here are the thin GMM-parameter spellings kept for direct
use: :func:`privatize_gmm` / :func:`privatize_clients` release one
client's (or every client's) fitted parameters under a :class:`DPConfig`
budget, exactly as before the seam existed.
"""
from __future__ import annotations

import dataclasses
import math

import jax

from repro.core.gmm import GMM
from repro.fed.transforms import GaussianDP


@dataclasses.dataclass(frozen=True)
class DPConfig:
    """The (epsilon, delta) budget of one DP release, validated at
    construction (FitConfig-style): ``epsilon > 0``, ``delta`` in
    (0, 1), ``min_count > 0`` — the floor on per-component effective
    counts that bounds the mean/variance sensitivities."""

    epsilon: float = 1.0
    delta: float = 1e-5
    min_count: float = 8.0   # floor on per-component effective counts

    def __post_init__(self):
        if not float(self.epsilon) > 0.0:
            raise ValueError(
                f"DPConfig.epsilon must be > 0, got {self.epsilon}")
        if not 0.0 < float(self.delta) < 1.0:
            raise ValueError(
                f"DPConfig.delta must be in (0, 1), got {self.delta}")
        if not float(self.min_count) > 0.0:
            raise ValueError(
                f"DPConfig.min_count must be > 0, got {self.min_count}")


def gaussian_sigma(sensitivity: float, epsilon: float, delta: float) -> float:
    """Analytic Gaussian mechanism calibration (host-side closed form):
    ``sigma = sqrt(2 ln(1.25/delta)) * sensitivity / epsilon``."""
    return math.sqrt(2.0 * math.log(1.25 / delta)) * sensitivity / epsilon


def _transform(dp: DPConfig) -> GaussianDP:
    """One-shot (rounds=1) transform carrying this budget."""
    return GaussianDP(epsilon=float(dp.epsilon), delta=float(dp.delta),
                      rounds=1, min_count=float(dp.min_count))


def privatize_gmm(key: jax.Array, gmm: GMM, n_samples: float,
                  dp: DPConfig) -> GMM:
    """Release a (epsilon, delta)-DP view of one client's GMM parameters.

    Assumes diagonal covariance (a full covariance raises ValueError)
    and features in [0,1]^d. Delegates to the §11 transform
    (:class:`repro.fed.transforms.GaussianDP`) — the same mechanism the
    runtime applies when ``run_rounds(transform=...)`` is installed."""
    if not gmm.is_diagonal:
        raise ValueError(
            f"DP release supports diagonal covariance; this GMM carries "
            f"a 'full' covariance (covs shape {tuple(gmm.covs.shape)})")
    t = _transform(dp)
    released, _ = t.apply(key, t.traced(), (gmm, n_samples), 0, None)
    return released


def privatize_clients(key: jax.Array, gmms: list[GMM], sizes,
                      dp: DPConfig) -> list[GMM]:
    """Per-client DP release of a list of fitted GMMs (one budget each;
    client ``i`` draws from ``fold_in(key, i)``)."""
    return [privatize_gmm(jax.random.fold_in(key, i), g, float(n), dp)
            for i, (g, n) in enumerate(zip(gmms, sizes))]
