"""Gaussian Mixture Model primitives.

A GMM is a pytree of (weights, means, covs):
  weights : (K,)        mixing weights, sum to 1
  means   : (K, d)
  covs    : (K, d)      diagonal covariance (variances), or
            (K, d, d)   full covariance

All log-density math uses the matmul identity (see DESIGN.md §3/§5) so the
E-step maps onto the MXU on TPU; the Pallas kernel in
``repro.kernels.gmm_logpdf`` implements the same contraction with explicit
VMEM tiling, and this module is its reference semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

LOG_2PI = 1.8378770664093453


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class GMM:
    """Gaussian mixture parameters (a pytree)."""

    weights: jax.Array  # (K,)
    means: jax.Array    # (K, d)
    covs: jax.Array     # (K, d) diagonal variances or (K, d, d) full

    def tree_flatten(self):
        return (self.weights, self.means, self.covs), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n_components(self) -> int:
        return self.weights.shape[0]

    @property
    def n_features(self) -> int:
        return self.means.shape[1]

    @property
    def is_diagonal(self) -> bool:
        return self.covs.ndim == 2

    # ------------------------------------------------------------------
    def component_log_prob(self, x: jax.Array) -> jax.Array:
        """Per-component Gaussian log density. x: (N, d) -> (N, K)."""
        if self.is_diagonal:
            return _diag_component_log_prob(x, self.means, self.covs)
        return _full_component_log_prob(x, self.means, self.covs)

    def log_prob(self, x: jax.Array) -> jax.Array:
        """Mixture log density. x: (N, d) -> (N,)."""
        lp = self.component_log_prob(x) + jnp.log(self.weights)[None, :]
        return jax.scipy.special.logsumexp(lp, axis=1)

    def responsibilities(self, x: jax.Array) -> jax.Array:
        """Posterior component responsibilities. x: (N, d) -> (N, K)."""
        lp = self.component_log_prob(x) + jnp.log(self.weights)[None, :]
        return jax.nn.softmax(lp, axis=1)

    def score(self, x: jax.Array, sample_weight: Optional[jax.Array] = None) -> jax.Array:
        """Average log-likelihood (the paper's fitness score, Eq. 2)."""
        lp = self.log_prob(x)
        if sample_weight is None:
            return jnp.mean(lp)
        w = sample_weight
        return jnp.sum(lp * w) / jnp.maximum(jnp.sum(w), 1e-12)

    def sample(self, key: jax.Array, n: int) -> jax.Array:
        """Draw n samples from the mixture -> (n, d)."""
        k_comp, k_noise = jax.random.split(key)
        comp = jax.random.categorical(k_comp, jnp.log(self.weights), shape=(n,))
        mu = self.means[comp]  # (n, d)
        if self.is_diagonal:
            std = jnp.sqrt(self.covs[comp])
            eps = jax.random.normal(k_noise, mu.shape, dtype=mu.dtype)
            return mu + std * eps
        chol = jnp.linalg.cholesky(self.covs)[comp]  # (n, d, d)
        eps = jax.random.normal(k_noise, mu.shape, dtype=mu.dtype)
        return mu + jnp.einsum("nij,nj->ni", chol, eps)

    # ------------------------------------------------------------------
    def n_free_params(self) -> int:
        """Number of free parameters (for BIC)."""
        k, d = self.means.shape
        cov_params = k * d if self.is_diagonal else k * d * (d + 1) // 2
        return (k - 1) + k * d + cov_params

    def bic(self, x: jax.Array, sample_weight: Optional[jax.Array] = None) -> jax.Array:
        """Bayesian Information Criterion (lower is better)."""
        if sample_weight is None:
            n = x.shape[0]
            total_ll = jnp.sum(self.log_prob(x))
        else:
            n = jnp.sum(sample_weight)
            total_ll = jnp.sum(self.log_prob(x) * sample_weight)
        return self.n_free_params() * jnp.log(n) - 2.0 * total_ll


# ----------------------------------------------------------------------
# Log-density kernels (pure jnp; mirrored by repro/kernels/gmm_logpdf)
# ----------------------------------------------------------------------

def _diag_component_log_prob(x: jax.Array, means: jax.Array, variances: jax.Array) -> jax.Array:
    """log N(x | mu_k, diag(var_k)) for all k, via two matmuls.

    -2 log N = (x - mu)^T var^{-1} (x - mu) + sum(log var) + d log 2pi
             = x^2 @ (1/var)^T  - 2 x @ (mu/var)^T + sum(mu^2/var)
               + sum(log var) + d log 2pi
    """
    d = x.shape[-1]
    inv_var = 1.0 / variances                      # (K, d)
    a = x * x @ inv_var.T                          # (N, K)
    b = x @ (means * inv_var).T                    # (N, K)
    c = jnp.sum(means * means * inv_var + jnp.log(variances), axis=-1)  # (K,)
    return -0.5 * (a - 2.0 * b + c[None, :] + d * LOG_2PI)


def _full_component_log_prob(x: jax.Array, means: jax.Array, covs: jax.Array) -> jax.Array:
    """log N(x | mu_k, Sigma_k) for all k via Cholesky. x: (N,d) -> (N,K)."""
    d = x.shape[-1]
    chol = jnp.linalg.cholesky(covs)               # (K, d, d)
    diff = x[:, None, :] - means[None, :, :]       # (N, K, d)
    # Solve L y = diff for each component.
    y = jax.vmap(
        lambda L, v: jax.scipy.linalg.solve_triangular(L, v.T, lower=True).T,
        in_axes=(0, 1), out_axes=1,
    )(chol, diff)                                  # (N, K, d)
    maha = jnp.sum(y * y, axis=-1)                 # (N, K)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(chol, axis1=-2, axis2=-1)), axis=-1)  # (K,)
    return -0.5 * (maha + logdet[None, :] + d * LOG_2PI)


# ----------------------------------------------------------------------
# Construction / merging helpers
# ----------------------------------------------------------------------

def merge_gmms(gmms: list[GMM], dataset_sizes: jax.Array) -> GMM:
    """FedGenGMM server-side merge (Algorithm 4.1 lines 21-29).

    Re-weights each client's component weights by |D_c| / |D| and
    concatenates all components into a single mixture, then normalizes.
    Clients may have different numbers of components.
    """
    sizes = jnp.asarray(dataset_sizes, dtype=jnp.float32)
    total = jnp.sum(sizes)
    ws, mus, covs = [], [], []
    for g, s in zip(gmms, sizes):
        ws.append(g.weights * (s / total))
        mus.append(g.means)
        covs.append(g.covs)
    w = jnp.concatenate(ws)
    w = w / jnp.sum(w)
    return GMM(w, jnp.concatenate(mus, axis=0), jnp.concatenate(covs, axis=0))


def merge_gmms_stacked(weights: jax.Array, means: jax.Array, covs: jax.Array,
                       dataset_sizes: jax.Array) -> GMM:
    """Vectorized merge for stacked client params (C, K, ...) — the form the
    one-shot all_gather produces in the distributed runtime."""
    sizes = jnp.asarray(dataset_sizes, dtype=weights.dtype)
    w = weights * (sizes / jnp.sum(sizes))[:, None]       # (C, K)
    w = w.reshape(-1)
    w = w / jnp.sum(w)
    k = means.shape[0] * means.shape[1]
    return GMM(w, means.reshape(k, -1), covs.reshape((k,) + covs.shape[2:]))
