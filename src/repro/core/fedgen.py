"""FedGenGMM (Algorithm 4.1): one-shot federated GMM learning.

Pipeline:
  1. local EM per client (vmap'd over padded client datasets, or a python
     loop with per-client BIC selection when K_c is heterogeneous),
  2. single communication round: clients ship (r, mu, Sigma, |D_c|),
  3. server merge: re-weight by |D_c|/|D|, concatenate, normalize,
  4. server samples |S| = H * sum_c K_c synthetic points from the merged
     mixture and trains the global GMM on S.

The sharded (shard_map) variant lives in ``repro.distributed.fed``; this
module is its single-process semantics and is what the paper benchmarks use.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.em import EMResult, fit_gmm, fit_gmm_bic
from repro.core.gmm import GMM, merge_gmms
from repro.core.partition import ClientSplit
from repro.data.sources import DataSource, SyntheticGMMSource


class CommStats(NamedTuple):
    """Communication accounting for one federated training run."""
    rounds: int
    uplink_floats: int       # client -> server payload (total floats)
    downlink_floats: int     # server -> client payload (total floats)


class FedGenResult(NamedTuple):
    global_gmm: GMM
    local_gmms: list[GMM]
    synthetic: jax.Array       # the server-side dataset S: an (|S|, d)
    #                            array, or a SyntheticGMMSource when the
    #                            refit ran out-of-core (synthetic="source")
    comm: CommStats
    local_results: list[EMResult]


def payload_floats(gmm: GMM) -> int:
    """Uplink size of one local model: weights + means + covariances."""
    k, d = gmm.means.shape
    cov = k * d if gmm.is_diagonal else k * d * d
    return k + k * d + cov


# ----------------------------------------------------------------------
# Local training
# ----------------------------------------------------------------------

@partial(jax.jit, static_argnames=("k", "max_iter", "covariance_type",
                                   "estep_backend", "chunk_size"))
def train_locals(key: jax.Array, data: jax.Array, mask: jax.Array, k: int,
                 max_iter: int = 200, tol: float = 1e-3,
                 reg_covar: float = 1e-6,
                 covariance_type: str = "diag",
                 estep_backend: str = "auto",
                 chunk_size: Optional[int] = None) -> tuple[GMM, jax.Array,
                                                            jax.Array]:
    """vmap'd local EM, fixed K_c = k for all clients.

    data: (C, N, d) padded, mask: (C, N). Returns stacked GMM with leaves
    of leading dim C, plus (C,) final logliks and iteration counts.
    """
    c = data.shape[0]
    keys = jax.random.split(key, c)

    def one(key, x, w):
        res = fit_gmm(key, x, k, sample_weight=w,
                      covariance_type=covariance_type, max_iter=max_iter,
                      tol=tol, reg_covar=reg_covar,
                      estep_backend=estep_backend, chunk_size=chunk_size)
        return res.gmm, res.log_likelihood, res.n_iter

    return jax.vmap(one)(keys, data, mask)


def train_locals_bic(key: jax.Array, split: ClientSplit,
                     k_candidates: Sequence[int],
                     max_iter: int = 200, tol: float = 1e-3,
                     reg_covar: float = 1e-6,
                     covariance_type: str = "diag",
                     estep_backend: str = "auto",
                     chunk_size: Optional[int] = None) -> list[EMResult]:
    """Per-client TrainGMM with BIC selection — heterogeneous K_c."""
    results = []
    for i in range(split.data.shape[0]):
        n = int(split.sizes[i])
        x = jnp.asarray(split.data[i, :n])
        res, _ = fit_gmm_bic(jax.random.fold_in(key, i), x, k_candidates,
                             covariance_type=covariance_type,
                             max_iter=max_iter, tol=tol, reg_covar=reg_covar,
                             estep_backend=estep_backend,
                             chunk_size=chunk_size)
        results.append(res)
    return results


# ----------------------------------------------------------------------
# Server-side aggregation
# ----------------------------------------------------------------------

def aggregate(key: jax.Array, local_gmms: list[GMM], sizes,
              h: int = 100,
              k_global: Optional[int] = None,
              k_candidates: Optional[Sequence[int]] = None,
              max_iter: int = 200, tol: float = 1e-3,
              reg_covar: float = 1e-6,
              covariance_type: str = "diag",
              estep_backend: str = "auto",
              chunk_size: Optional[int] = None,
              synthetic: str = "resident") -> tuple[EMResult, jax.Array]:
    """Algorithm 4.1 lines 21-31: merge, sample S, train global model.

    The synthetic set S = H * sum_c K_c points is the largest dataset in
    the pipeline, so ``chunk_size`` matters most here: it bounds the whole
    refit — the k-means init's Lloyd sweeps and label statistics, every
    E-step, and (on the ``k_candidates`` path) the per-candidate BIC
    scoring — at an O(chunk_size·K) working set (DESIGN.md §6).

    ``synthetic="source"`` goes one step further: S is never materialized
    at all. The refit consumes a :class:`SyntheticGMMSource` that
    regenerates seeded blocks on every pass (DESIGN.md §7), so the server's
    peak memory is independent of H and of the number of clients — the
    replay set can be arbitrarily large. Returned ``synthetic`` is then the
    source object instead of an array.
    """
    if synthetic not in ("resident", "source"):
        raise ValueError(f"synthetic must be 'resident' or 'source', "
                         f"got {synthetic!r}")
    merged = merge_gmms(local_gmms, jnp.asarray(sizes))
    n_synth = h * sum(g.n_components for g in local_gmms)
    k_sample, k_fit = jax.random.split(key)
    if synthetic == "source":
        synthetic = SyntheticGMMSource(merged, n_synth, k_sample)
    else:
        synthetic = merged.sample(k_sample, n_synth)
    if k_global is not None:
        res = fit_gmm(k_fit, synthetic, k_global,
                      covariance_type=covariance_type, max_iter=max_iter,
                      tol=tol, reg_covar=reg_covar,
                      estep_backend=estep_backend, chunk_size=chunk_size)
    else:
        assert k_candidates is not None, "need k_global or k_candidates"
        res, _ = fit_gmm_bic(k_fit, synthetic, k_candidates,
                             covariance_type=covariance_type,
                             max_iter=max_iter, tol=tol,
                             reg_covar=reg_covar,
                             estep_backend=estep_backend,
                             chunk_size=chunk_size)
    return res, synthetic


# ----------------------------------------------------------------------
# End-to-end FedGenGMM
# ----------------------------------------------------------------------

def fedgengmm(key: jax.Array, split: ClientSplit,
              k_clients: Optional[int] = None,
              k_global: Optional[int] = None,
              k_candidates: Optional[Sequence[int]] = None,
              h: int = 100,
              max_iter: int = 200, tol: float = 1e-3,
              reg_covar: float = 1e-6,
              covariance_type: str = "diag",
              estep_backend: str = "auto",
              chunk_size: Optional[int] = None,
              synthetic: str = "resident") -> FedGenResult:
    """Run the full one-shot pipeline on a partitioned dataset.

    Either fix ``k_clients`` (paper's main experiments, K_c = K) or pass
    ``k_candidates`` for per-client BIC selection (heterogeneous models).
    ``estep_backend``/``chunk_size`` select the E-step engine for both the
    local fits and the server refit (DESIGN.md §6);
    ``synthetic="source"`` runs the server refit out-of-core (see
    :func:`aggregate`).
    """
    k_local_train, k_agg = jax.random.split(key)
    if k_clients is not None:
        stacked, lls, iters = train_locals(
            k_local_train, jnp.asarray(split.data), jnp.asarray(split.mask),
            k_clients, max_iter=max_iter, tol=tol, reg_covar=reg_covar,
            covariance_type=covariance_type, estep_backend=estep_backend,
            chunk_size=chunk_size)
        local_gmms = [
            GMM(stacked.weights[i], stacked.means[i], stacked.covs[i])
            for i in range(split.data.shape[0])]
        local_results = [
            EMResult(g, lls[i], iters[i], jnp.array(True))
            for i, g in enumerate(local_gmms)]
    else:
        assert k_candidates is not None, "need k_clients or k_candidates"
        local_results = train_locals_bic(
            k_local_train, split, k_candidates, max_iter=max_iter, tol=tol,
            reg_covar=reg_covar, covariance_type=covariance_type,
            estep_backend=estep_backend, chunk_size=chunk_size)
        local_gmms = [r.gmm for r in local_results]

    res, synth = aggregate(
        k_agg, local_gmms, split.sizes, h=h, k_global=k_global,
        k_candidates=k_candidates, max_iter=max_iter, tol=tol,
        reg_covar=reg_covar, covariance_type=covariance_type,
        estep_backend=estep_backend, chunk_size=chunk_size,
        synthetic=synthetic)

    uplink = sum(payload_floats(g) + 1 for g in local_gmms)  # +1: |D_c|
    down = payload_floats(res.gmm) * len(local_gmms)          # broadcast of G
    comm = CommStats(rounds=1, uplink_floats=uplink, downlink_floats=down)
    return FedGenResult(res.gmm, local_gmms, synth, comm, local_results)


# ----------------------------------------------------------------------
# Out-of-core clients: per-client DataSource training (DESIGN.md §7)
# ----------------------------------------------------------------------

def train_locals_from_sources(key: jax.Array,
                              sources: Sequence[DataSource],
                              k: Optional[int] = None,
                              k_candidates: Optional[Sequence[int]] = None,
                              max_iter: int = 200, tol: float = 1e-3,
                              reg_covar: float = 1e-6,
                              covariance_type: str = "diag",
                              estep_backend: str = "auto",
                              chunk_size: Optional[int] = None
                              ) -> list[EMResult]:
    """Local TrainGMM per client, each over its own :class:`DataSource` —
    the edge-device regime the paper targets: a client's dataset never has
    to fit in memory, only one block at a time. Fixed ``k`` or per-client
    BIC selection over ``k_candidates``. Sources are ragged by nature, so
    no padding, masks or sample weights appear anywhere on this path.
    """
    results = []
    for i, src in enumerate(sources):
        sub = jax.random.fold_in(key, i)
        if k is not None:
            res = fit_gmm(sub, src, k, covariance_type=covariance_type,
                          max_iter=max_iter, tol=tol, reg_covar=reg_covar,
                          estep_backend=estep_backend, chunk_size=chunk_size)
        else:
            assert k_candidates is not None, "need k or k_candidates"
            res, _ = fit_gmm_bic(sub, src, k_candidates,
                                 covariance_type=covariance_type,
                                 max_iter=max_iter, tol=tol,
                                 reg_covar=reg_covar,
                                 estep_backend=estep_backend,
                                 chunk_size=chunk_size)
        results.append(res)
    return results


def fedgengmm_from_sources(key: jax.Array,
                           sources: Sequence[DataSource],
                           k_clients: Optional[int] = None,
                           k_global: Optional[int] = None,
                           k_candidates: Optional[Sequence[int]] = None,
                           h: int = 100,
                           max_iter: int = 200, tol: float = 1e-3,
                           reg_covar: float = 1e-6,
                           covariance_type: str = "diag",
                           estep_backend: str = "auto",
                           chunk_size: Optional[int] = None,
                           synthetic: str = "source") -> FedGenResult:
    """The full one-shot pipeline with every dataset out-of-core: each
    client streams its local fit from its own :class:`DataSource`, the
    single communication round ships only (K, 2d+1) parameter blocks, and
    the server refit (``synthetic="source"`` by default) replays the merged
    mixture block-by-block — end to end, no stage holds O(N) rows.
    Mirrors :func:`fedgengmm` semantics otherwise.
    """
    k_local_train, k_agg = jax.random.split(key)
    local_results = train_locals_from_sources(
        k_local_train, sources, k=k_clients, k_candidates=k_candidates,
        max_iter=max_iter, tol=tol, reg_covar=reg_covar,
        covariance_type=covariance_type, estep_backend=estep_backend,
        chunk_size=chunk_size)
    local_gmms = [r.gmm for r in local_results]
    sizes = [src.num_rows for src in sources]

    res, synth = aggregate(
        k_agg, local_gmms, sizes, h=h, k_global=k_global,
        k_candidates=k_candidates, max_iter=max_iter, tol=tol,
        reg_covar=reg_covar, covariance_type=covariance_type,
        estep_backend=estep_backend, chunk_size=chunk_size,
        synthetic=synthetic)

    uplink = sum(payload_floats(g) + 1 for g in local_gmms)  # +1: |D_c|
    down = payload_floats(res.gmm) * len(local_gmms)          # broadcast of G
    comm = CommStats(rounds=1, uplink_floats=uplink, downlink_floats=down)
    return FedGenResult(res.gmm, local_gmms, synth, comm, local_results)
