"""FedGenGMM (Algorithm 4.1): one-shot federated GMM learning.

Pipeline:
  1. local EM per client (vmap'd over padded client datasets, or a python
     loop with per-client BIC selection when K_c is heterogeneous),
  2. single communication round: clients ship (r, mu, Sigma, |D_c|),
  3. server merge: re-weight by |D_c|/|D|, concatenate, normalize,
  4. server samples |S| = H * sum_c K_c synthetic points from the merged
     mixture and trains the global GMM on S.

Clients arrive either as a padded :class:`ClientSplit` (resident arrays +
masks) or as a list of per-client :class:`DataSource` streams (out-of-core,
DESIGN.md §7); :func:`fedgengmm_cfg` dispatches on that input type with one
validated :class:`FitConfig`, and is what ``repro.api.FedGenGMM`` runs.

The sharded (shard_map) variant lives in ``repro.distributed.fed``; this
module is its single-process semantics and is what the paper benchmarks use.
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.config import FitConfig, is_source_list
from repro.core.em import (EMResult, fit_gmm_bic_cfg, fit_gmm_cfg)
from repro.core.gmm import GMM, merge_gmms
from repro.core.partition import ClientSplit
from repro.data.sources import DataSource, SyntheticGMMSource
# CommStats / payload_floats historically lived here; the one copy of the
# communication accounting is now the federation ledger (DESIGN.md §9) and
# these re-exports keep the long-standing import path working.
from repro.fed.ledger import (CommStats, RoundPayload, dtype_itemsize,
                              payload_floats)
from repro.fed.runtime import run_rounds


class FedGenResult(NamedTuple):
    global_gmm: GMM
    local_gmms: list[GMM]
    synthetic: jax.Array       # the server-side dataset S: an (|S|, d)
    #                            array, or a SyntheticGMMSource when the
    #                            refit ran out-of-core (synthetic="source")
    comm: CommStats
    local_results: list[EMResult]


# ----------------------------------------------------------------------
# Local training
# ----------------------------------------------------------------------

@partial(jax.jit, static_argnames=("k", "config"))
def _train_locals_jit(key: jax.Array, data: jax.Array, mask: jax.Array,
                      k: int, config: FitConfig):
    c = data.shape[0]
    keys = jax.random.split(key, c)

    def one(key, x, w):
        res = fit_gmm_cfg(key, x, k, config, sample_weight=w)
        return res.gmm, res.log_likelihood, res.n_iter

    return jax.vmap(one)(keys, data, mask)


def train_locals_cfg(key: jax.Array, data: jax.Array, mask: jax.Array,
                     k: int, config: FitConfig) -> tuple[GMM, jax.Array,
                                                         jax.Array]:
    """vmap'd local EM, fixed K_c = k for all clients — the cfg-core behind
    :func:`train_locals` (the frozen :class:`FitConfig` rides through jit
    as a static argument, so the whole knob set is one hashable value).
    ``config.seed`` and ``config.init`` only feed the facade's key
    derivation / init-strategy naming and never the traced computation
    (local fits always use the k-means init), so both are normalized out
    of the static cache key — sweeping them must not recompile identical
    graphs.

    data: (C, N, d) padded, mask: (C, N). Returns stacked GMM with leaves
    of leading dim C, plus (C,) final logliks and iteration counts.
    tol/max_iter are normalized to their resolved EM values for the same
    reason seed/init are normalized out: a ``tol="auto"`` config and its
    concrete legacy twin describe the identical graph and must share one
    cache entry.
    """
    return _train_locals_jit(key, data, mask, k,
                             config.resolved_for("em").replace(seed=0,
                                                               init="auto"))


def train_locals(key: jax.Array, data: jax.Array, mask: jax.Array, k: int,
                 max_iter: int = 200, tol: float = 1e-3,
                 reg_covar: float = 1e-6,
                 covariance_type: str = "diag",
                 estep_backend: str = "auto",
                 chunk_size: Optional[int] = None) -> tuple[GMM, jax.Array,
                                                            jax.Array]:
    """Legacy keyword surface of :func:`train_locals_cfg` (internal)."""
    cfg = FitConfig.from_legacy(
        backend=estep_backend, chunk_size=chunk_size,
        covariance_type=covariance_type, reg_covar=reg_covar, tol=tol,
        max_iter=max_iter)
    return train_locals_cfg(key, data, mask, k, cfg)


def train_locals_bic_cfg(key: jax.Array, split: ClientSplit,
                         k_candidates: Sequence[int],
                         config: FitConfig) -> list[EMResult]:
    """Per-client TrainGMM with BIC selection — heterogeneous K_c."""
    results = []
    for i in range(split.data.shape[0]):
        n = int(split.sizes[i])
        x = jnp.asarray(split.data[i, :n])
        res, _ = fit_gmm_bic_cfg(jax.random.fold_in(key, i), x, k_candidates,
                                 config)
        results.append(res)
    return results


def train_locals_bic(key: jax.Array, split: ClientSplit,
                     k_candidates: Sequence[int],
                     max_iter: int = 200, tol: float = 1e-3,
                     reg_covar: float = 1e-6,
                     covariance_type: str = "diag",
                     estep_backend: str = "auto",
                     chunk_size: Optional[int] = None) -> list[EMResult]:
    """Legacy keyword surface of :func:`train_locals_bic_cfg` (internal)."""
    cfg = FitConfig.from_legacy(
        backend=estep_backend, chunk_size=chunk_size,
        covariance_type=covariance_type, reg_covar=reg_covar, tol=tol,
        max_iter=max_iter)
    return train_locals_bic_cfg(key, split, k_candidates, cfg)


def train_locals_sources_cfg(key: jax.Array,
                             sources: Sequence[DataSource],
                             config: FitConfig,
                             k: Optional[int] = None,
                             k_candidates: Optional[Sequence[int]] = None
                             ) -> list[EMResult]:
    """Local TrainGMM per client, each over its own :class:`DataSource` —
    the edge-device regime the paper targets: a client's dataset never has
    to fit in memory, only one block at a time. Fixed ``k`` or per-client
    BIC selection over ``k_candidates``. Sources are ragged by nature, so
    no padding, masks or sample weights appear anywhere on this path.
    """
    results = []
    for i, src in enumerate(sources):
        sub = jax.random.fold_in(key, i)
        if k is not None:
            res = fit_gmm_cfg(sub, src, k, config)
        else:
            assert k_candidates is not None, "need k or k_candidates"
            res, _ = fit_gmm_bic_cfg(sub, src, k_candidates, config)
        results.append(res)
    return results


def train_locals_from_sources(key: jax.Array,
                              sources: Sequence[DataSource],
                              k: Optional[int] = None,
                              k_candidates: Optional[Sequence[int]] = None,
                              max_iter: int = 200, tol: float = 1e-3,
                              reg_covar: float = 1e-6,
                              covariance_type: str = "diag",
                              estep_backend: str = "auto",
                              chunk_size: Optional[int] = None
                              ) -> list[EMResult]:
    """Deprecated: the per-client out-of-core local fits are the source arm
    of :func:`train_locals_sources_cfg`, which ``repro.api.FedGenGMM``
    drives. This shim forwards (bit-identical results) and will be
    removed."""
    warnings.warn(
        "train_locals_from_sources is deprecated; use "
        "repro.api.FedGenGMM(...).run(sources) for the full pipeline or "
        "train_locals_sources_cfg with a FitConfig — same engine, same bits",
        DeprecationWarning, stacklevel=2)
    cfg = FitConfig.from_legacy(
        backend=estep_backend, chunk_size=chunk_size,
        covariance_type=covariance_type, reg_covar=reg_covar, tol=tol,
        max_iter=max_iter)
    return train_locals_sources_cfg(key, sources, cfg, k=k,
                                    k_candidates=k_candidates)


# ----------------------------------------------------------------------
# Server-side aggregation
# ----------------------------------------------------------------------

def aggregate_cfg(key: jax.Array, local_gmms: list[GMM], sizes,
                  config: FitConfig, h: int = 100,
                  k_global: Optional[int] = None,
                  k_candidates: Optional[Sequence[int]] = None,
                  synthetic: str = "resident") -> tuple[EMResult, jax.Array]:
    """Algorithm 4.1 lines 21-31: merge, sample S, train global model.

    The synthetic set S = H * sum_c K_c points is the largest dataset in
    the pipeline, so an integer ``config.chunk_size`` matters most here:
    it bounds the whole refit — the k-means init's Lloyd sweeps and label
    statistics, every E-step, and (on the ``k_candidates`` path) the
    per-candidate BIC scoring — at an O(chunk·K) working set (DESIGN.md
    §6).

    ``synthetic="source"`` goes one step further: S is never materialized
    at all. The refit consumes a :class:`SyntheticGMMSource` that
    regenerates seeded blocks on every pass (DESIGN.md §7), so the server's
    peak memory is independent of H and of the number of clients — the
    replay set can be arbitrarily large. Returned ``synthetic`` is then the
    source object instead of an array.
    """
    if synthetic not in ("resident", "source"):
        raise ValueError(f"synthetic must be 'resident' or 'source', "
                         f"got {synthetic!r}")
    merged = merge_gmms(local_gmms, jnp.asarray(sizes))
    n_synth = h * sum(g.n_components for g in local_gmms)
    k_sample, k_fit = jax.random.split(key)
    if synthetic == "source":
        synthetic = SyntheticGMMSource(merged, n_synth, k_sample)
    else:
        synthetic = merged.sample(k_sample, n_synth)
    if k_global is not None:
        res = fit_gmm_cfg(k_fit, synthetic, k_global, config)
    else:
        assert k_candidates is not None, "need k_global or k_candidates"
        res, _ = fit_gmm_bic_cfg(k_fit, synthetic, k_candidates, config)
    return res, synthetic


def aggregate(key: jax.Array, local_gmms: list[GMM], sizes,
              h: int = 100,
              k_global: Optional[int] = None,
              k_candidates: Optional[Sequence[int]] = None,
              max_iter: int = 200, tol: float = 1e-3,
              reg_covar: float = 1e-6,
              covariance_type: str = "diag",
              estep_backend: str = "auto",
              chunk_size: Optional[int] = None,
              synthetic: str = "resident") -> tuple[EMResult, jax.Array]:
    """Legacy keyword surface of :func:`aggregate_cfg` (internal)."""
    cfg = FitConfig.from_legacy(
        backend=estep_backend, chunk_size=chunk_size,
        covariance_type=covariance_type, reg_covar=reg_covar, tol=tol,
        max_iter=max_iter)
    return aggregate_cfg(key, local_gmms, sizes, cfg, h=h, k_global=k_global,
                         k_candidates=k_candidates, synthetic=synthetic)


# ----------------------------------------------------------------------
# End-to-end FedGenGMM: the one-shot strategy on the federation runtime
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FedGenStrategy:
    """Algorithm 4.1 as a one-shot :class:`~repro.fed.runtime.
    FederationStrategy`: the single round runs host-side (``run_once``) —
    local TrainGMM per client (vmap'd for a padded split, streamed for
    source clients, Python-level when per-client BIC selection makes K_c
    heterogeneous), then the server-side merge -> sample -> refit
    (:func:`aggregate_cfg`). The runtime contributes what every strategy
    shares: input-type dispatch and the communication ledger — uplink is
    each client's (K, 2d+1) parameter block + |D_c|, downlink the global
    broadcast, ``rounds=1`` by construction."""

    config: FitConfig
    k_clients: Optional[int] = None
    k_global: Optional[int] = None
    k_candidates: Optional[tuple] = None
    h: int = 100
    synthetic: str = "resident"

    one_shot = True
    name = "fedgen"

    def init_state(self, key: jax.Array, backend) -> dict:
        k_local_train, k_agg = jax.random.split(key)
        return {"k_local": k_local_train, "k_agg": k_agg}

    def run_once(self, state: dict, backend, transform=None, tparams=None,
                 tkey=None) -> dict:
        """The single communication round. With an uplink ``transform``
        installed (``run_rounds(transform=...)``, §11) every client's
        parameter-block payload ``(gmm, n_c)`` is transformed before the
        server sees it — for :class:`~repro.fed.transforms.GaussianDP`
        that is the paper-§4.4 one-shot DP release, the whole budget
        spent in this one round."""
        if backend.kind == "sources":
            local_results = train_locals_sources_cfg(
                state["k_local"], backend.sources, self.config,
                k=self.k_clients, k_candidates=self.k_candidates)
            local_gmms = [r.gmm for r in local_results]
            sizes = backend.sizes
        elif backend.kind == "split":
            split = backend.split
            sizes = split.sizes
            if self.k_clients is not None:
                stacked, lls, iters = train_locals_cfg(
                    state["k_local"], backend.data, backend.mask,
                    self.k_clients, self.config)
                local_gmms = [
                    GMM(stacked.weights[i], stacked.means[i], stacked.covs[i])
                    for i in range(split.data.shape[0])]
                local_results = [
                    EMResult(g, lls[i], iters[i], jnp.array(True))
                    for i, g in enumerate(local_gmms)]
            else:
                assert self.k_candidates is not None, \
                    "need k_clients or k_candidates"
                local_results = train_locals_bic_cfg(
                    state["k_local"], split, self.k_candidates, self.config)
                local_gmms = [r.gmm for r in local_results]
        else:
            raise TypeError(
                "FedGenStrategy runs ClientSplit or source-list clients; "
                "the mesh variant is repro.distributed.fedgen_sharded")

        if transform is not None:
            # the uplink seam for the one-shot round: each client's
            # (gmm, n_c) block is transformed under the same shared
            # round key the iterative driver hands out (round 0); the
            # transform derives its per-client streams itself
            members = jnp.arange(len(local_gmms))
            rkey = jax.random.fold_in(tkey, 0)
            sizes_list = [float(n) for n in list(sizes)]
            released = []
            for i, (g, n) in enumerate(zip(local_gmms, sizes_list)):
                wire = transform.apply(rkey, tparams, (g, n), i, members)
                released.append(transform.finish(wire)[0])
            local_gmms = released

        res, synth = aggregate_cfg(
            state["k_agg"], local_gmms, sizes, self.config, h=self.h,
            k_global=self.k_global, k_candidates=self.k_candidates,
            synthetic=self.synthetic)
        return {"res": res, "synth": synth, "local_gmms": local_gmms,
                "local_results": local_results}

    def round_payload(self, backend, state) -> RoundPayload:
        local_gmms = state["local_gmms"]
        uplink = sum(payload_floats(g) + 1 for g in local_gmms)  # +1: |D_c|
        down = payload_floats(state["res"].gmm) * len(local_gmms)
        return RoundPayload(
            uplink_floats=uplink, downlink_floats=down,
            itemsize=dtype_itemsize(state["res"].gmm.means.dtype))

    def finalize(self, state, n_rounds, converged,
                 comm: CommStats) -> FedGenResult:
        return FedGenResult(state["res"].gmm, state["local_gmms"],
                            state["synth"], comm, state["local_results"])


def fedgengmm_cfg(key: jax.Array, clients, config: FitConfig,
                  k_clients: Optional[int] = None,
                  k_global: Optional[int] = None,
                  k_candidates: Optional[Sequence[int]] = None,
                  h: int = 100,
                  synthetic: str = "auto",
                  transform=None) -> FedGenResult:
    """Run the full one-shot pipeline — the cfg-core behind
    ``repro.api.FedGenGMM``, a thin wrapper building a
    :class:`FedGenStrategy` and handing it to the federation runtime
    (bit-identical to the pre-runtime pipeline; pinned in
    ``tests/test_fed_runtime.py``). Dispatch on the client input type:

    * a padded :class:`ClientSplit`: vmap'd local EM (fixed ``k_clients``)
      or per-client BIC selection (``k_candidates``), resident arrays;
    * a list/tuple of :class:`DataSource`: every client streams its local
      fit out-of-core, the single communication round ships only
      (K, 2d+1) parameter blocks, and (with ``synthetic="source"``) the
      server refit replays the merged mixture block-by-block — end to end,
      no stage holds O(N) rows.

    ``synthetic="auto"`` keeps the historical defaults per input type:
    a resident S for split clients, the seeded replay source for source
    clients.
    """
    sources = is_source_list(clients)
    if not sources and not isinstance(clients, ClientSplit):
        raise TypeError(
            f"fedgengmm clients must be a ClientSplit or a list of "
            f"DataSources, got {type(clients).__name__}")
    if synthetic == "auto":
        synthetic = "source" if sources else "resident"
    strategy = FedGenStrategy(
        config=config, k_clients=k_clients, k_global=k_global,
        k_candidates=None if k_candidates is None else tuple(k_candidates),
        h=h, synthetic=synthetic)
    return run_rounds(strategy, clients, key=key, max_rounds=1,
                      transform=transform)


def fedgengmm(key: jax.Array, split: ClientSplit,
              k_clients: Optional[int] = None,
              k_global: Optional[int] = None,
              k_candidates: Optional[Sequence[int]] = None,
              h: int = 100,
              max_iter: int = 200, tol: float = 1e-3,
              reg_covar: float = 1e-6,
              covariance_type: str = "diag",
              estep_backend: str = "auto",
              chunk_size: Optional[int] = None,
              synthetic: str = "resident") -> FedGenResult:
    """Legacy keyword surface of :func:`fedgengmm_cfg` (internal; prefer
    ``repro.api.FedGenGMM``). Either fix ``k_clients`` (paper's main
    experiments, K_c = K) or pass ``k_candidates`` for per-client BIC
    selection (heterogeneous models)."""
    cfg = FitConfig.from_legacy(
        backend=estep_backend, chunk_size=chunk_size,
        covariance_type=covariance_type, reg_covar=reg_covar, tol=tol,
        max_iter=max_iter)
    return fedgengmm_cfg(key, split, cfg, k_clients=k_clients,
                         k_global=k_global, k_candidates=k_candidates, h=h,
                         synthetic=synthetic)


def fedgengmm_from_sources(key: jax.Array,
                           sources: Sequence[DataSource],
                           k_clients: Optional[int] = None,
                           k_global: Optional[int] = None,
                           k_candidates: Optional[Sequence[int]] = None,
                           h: int = 100,
                           max_iter: int = 200, tol: float = 1e-3,
                           reg_covar: float = 1e-6,
                           covariance_type: str = "diag",
                           estep_backend: str = "auto",
                           chunk_size: Optional[int] = None,
                           synthetic: str = "source") -> FedGenResult:
    """Deprecated: ``repro.api.FedGenGMM(...).run(sources)`` dispatches on
    the input type, so the separate ``_from_sources`` spelling is obsolete.
    This shim forwards to the facade (bit-identical result) and will be
    removed."""
    warnings.warn(
        "fedgengmm_from_sources is deprecated; use "
        "repro.api.FedGenGMM(k_clients=..., k_global=...).run(sources) — "
        "same engine, same bits",
        DeprecationWarning, stacklevel=2)
    from repro.api import FedGenGMM  # facade sits above core; lazy
    fed = FedGenGMM(k_clients=k_clients, k_global=k_global,
                    k_candidates=k_candidates, h=h, synthetic=synthetic,
                    config=FitConfig.from_legacy(
                        backend=estep_backend, chunk_size=chunk_size,
                        covariance_type=covariance_type, reg_covar=reg_covar,
                        tol=tol, max_iter=max_iter))
    return fed.run(list(sources), key=key)
