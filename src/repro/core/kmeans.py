"""K-means in JAX: k-means++ seeding, weighted Lloyd iterations, and the
one-shot federated k-means of Dennis et al. '21 (paper ref [7]) used both
standalone and as DEM init 3.

Lloyd sweeps run on the streaming-statistics engine (``repro.core.em``,
DESIGN.md §6): each sweep reduces (counts, sums, inertia) sufficient
statistics over row blocks — never an (N, K) one-hot — and with
``chunk_size`` set the distance block itself shrinks to (chunk_size, K).
Per-block assignment dispatches through the ``kmeans_assign`` Pallas kernel
on TPU (``assign_backend="auto"``) and the matmul-identity reference
elsewhere.

Out-of-core data runs through the source twins (DESIGN.md §7):
``kmeans_plusplus_streaming`` (Gumbel-max seeding over blocks),
``kmeans_source``/``kmeans_multi_source`` (host-driven Lloyd loops) and
``federated_kmeans_from_sources`` — none of which ever hold an (N, ·)
array.
"""
from __future__ import annotations

import warnings
from functools import partial
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.config import (FitConfig, is_source_list,
                               require_array_weights, resolve_backend,
                               resolve_source_chunk)
from repro.core.em import (SufficientStats, reduce_rows,
                           streaming_map_reduce, streaming_reduce)
from repro.data.sources import DataSource, prefetch_blocks

# Rows the k-means++ seeding pass works from when the dataset is larger:
# seeding is O(k · N_pool · d) with a k-round categorical over an
# (N_pool,)-logit vector, and a uniform subsample this size seeds planted
# mixtures indistinguishably from the full pass at a fraction of the cost
# (the Lloyd iterations that follow see every row regardless).
SEED_ROWS = 16384

# Lockstep Lloyd sweeps every restart runs before kmeans_multi prunes to
# the best seed (see kmeans_multi): enough for inertia to separate good
# seedings from bad on anything EM-initializable, while bad restarts never
# get to drag a vmapped while_loop through dozens of straggler iterations.
PILOT_ITERS = 3

# Full-data Lloyd budget for kmeans_multi's refine stage beyond SEED_ROWS
# rows: the winner first converges on the seed subsample (cheap sweeps),
# then polishes on the full data — at 100k rows a full sweep costs ~9ms
# on the 1-core CPU backend, so an unbounded full-data while_loop is what
# made init_from_kmeans a 6.3s outlier.
REFINE_ITERS = 10


class KMeansResult(NamedTuple):
    centers: jax.Array        # (K, d)
    assignments: jax.Array    # (N,); None on out-of-core (DataSource) runs
    inertia: jax.Array        # ()
    n_iter: jax.Array         # ()
    cluster_sizes: jax.Array  # (K,) sum of sample weights per cluster


def _sq_dists(x: jax.Array, centers: jax.Array) -> jax.Array:
    """Squared euclidean distances (N, K) via the matmul identity."""
    x2 = jnp.sum(x * x, axis=1, keepdims=True)           # (N, 1)
    c2 = jnp.sum(centers * centers, axis=1)[None, :]     # (1, K)
    return jnp.maximum(x2 - 2.0 * (x @ centers.T) + c2, 0.0)


def _assign_block(xb: jax.Array, centers: jax.Array,
                  backend: str) -> tuple[jax.Array, jax.Array]:
    """Nearest-center assignment of one row block -> ((B,) int32, (B,) d2).
    ``fused`` routes through the Pallas ``kmeans_assign`` kernel, reference
    through the matmul identity; both share the §3 contraction."""
    if backend == "fused":
        from repro.kernels import ops  # local import: kernels are optional
        return ops.kmeans_assign(xb, centers)
    dists = _sq_dists(xb, centers)
    return (jnp.argmin(dists, axis=1).astype(jnp.int32),
            jnp.min(dists, axis=1))


def _labels_onehot(idx: jax.Array, k: int, wb: jax.Array,
                   dtype) -> jax.Array:
    """Weighted one-hot (B, K) of an assignment vector. Per-cluster sums
    then become matmuls (``oh.T @ xb``) instead of ``segment_sum`` scatter
    adds — the scatter path costs ~13ms per 100k-row sweep on a 1-core
    CPU backend, the matmul path ~1ms, and Lloyd runs one sweep per
    iteration (this was most of the 6.3s init outlier)."""
    cols = jnp.arange(k, dtype=idx.dtype)[None, :]
    return (idx[:, None] == cols).astype(dtype) * wb[:, None]


def _sweep_block(xb: jax.Array, wb: jax.Array, centers: jax.Array,
                 backend: str):
    """Weighted Lloyd-sweep sufficient statistics of one block:
    (counts (K,), sums (K, d), inertia ())."""
    k = centers.shape[0]
    idx, d2 = _assign_block(xb, centers, backend)
    oh = _labels_onehot(idx, k, wb, xb.dtype)
    return jnp.sum(oh, axis=0), oh.T @ xb, jnp.sum(d2 * wb)


def kmeans_plusplus(key: jax.Array, x: jax.Array, k: int,
                    sample_weight: Optional[jax.Array] = None) -> jax.Array:
    """k-means++ seeding -> (k, d). Supports zero-weighted (padded) rows."""
    n = x.shape[0]
    w = jnp.ones(n, x.dtype) if sample_weight is None else sample_weight
    key, sub = jax.random.split(key)
    first = jax.random.categorical(sub, jnp.log(jnp.maximum(w, 1e-30)))
    centers0 = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(x[first])
    min_d0 = jnp.sum((x - x[first]) ** 2, axis=1)

    def body(i, carry):
        centers, min_d, key = carry
        key, sub = jax.random.split(key)
        probs = jnp.maximum(min_d * w, 1e-30)
        idx = jax.random.categorical(sub, jnp.log(probs))
        c = x[idx]
        centers = centers.at[i].set(c)
        min_d = jnp.minimum(min_d, jnp.sum((x - c) ** 2, axis=1))
        return centers, min_d, key

    centers, _, _ = jax.lax.fori_loop(1, k, body, (centers0, min_d0, key))
    return centers


def _seed_centers(key: jax.Array, x: jax.Array, k: int, w: jax.Array,
                  seed_rows: int) -> jax.Array:
    """k-means++ over a uniform row subsample once N exceeds ``seed_rows``
    (sampled rows keep their weights); the full pass below that. Seeding
    was measured at >100ms per restart on a 100k-row batch — almost all of
    it the k categorical draws over (N,) logits — and the Lloyd iterations
    wash out any subsampling noise in the seed."""
    n = x.shape[0]
    if n <= seed_rows:
        return kmeans_plusplus(key, x, k, w)
    key, sub = jax.random.split(key)
    idx = jax.random.randint(sub, (seed_rows,), 0, n)
    return kmeans_plusplus(key, x[idx], k, w[idx])


@partial(jax.jit, static_argnames=("k", "max_iter", "chunk_size",
                                   "assign_backend", "seed_rows"))
def kmeans(key: jax.Array, x: jax.Array, k: int,
           sample_weight: Optional[jax.Array] = None,
           max_iter: int = 100, tol: float = 1e-4,
           chunk_size: Optional[int] = None,
           assign_backend: str = "auto",
           init_centers: Optional[jax.Array] = None,
           seed_rows: int = SEED_ROWS) -> KMeansResult:
    """Weighted Lloyd's algorithm with k-means++ init.

    Every sweep accumulates (counts (K,), sums (K, d), inertia) sufficient
    statistics per assignment block — no (N, K) one-hot. ``chunk_size=None``
    assigns the whole batch at once (one (N, K) distance block on the
    reference backend); an integer scans (chunk_size, d) slices so the peak
    working set is O(chunk_size·K). The returned assignments, inertia and
    cluster sizes are recomputed against the *returned* centers (a final
    sweep), not the pre-update centers of the last Lloyd iteration.

    Beyond ``seed_rows`` rows the k-means++ pass seeds from a uniform row
    subsample (weights ride along) — the Lloyd sweeps still see every row.
    ``init_centers`` skips seeding entirely and starts Lloyd from the given
    (k, d) centers (how :func:`kmeans_multi` resumes its pruned winner).
    """
    n, d = x.shape
    w = jnp.ones(n, x.dtype) if sample_weight is None else sample_weight
    backend = resolve_backend(assign_backend)
    if init_centers is not None:
        centers0 = init_centers
    else:
        centers0 = _seed_centers(key, x, k, w, seed_rows)

    def block_stats(xb, wb, centers):
        idx, d2 = _assign_block(xb, centers, backend)
        oh = _labels_onehot(idx, k, wb, xb.dtype)
        return (jnp.sum(oh, axis=0), oh.T @ xb, jnp.sum(d2 * wb)), idx

    def sweep(centers):
        """One assignment pass -> ((counts, sums, inertia), assignments)."""
        if chunk_size is None:
            return block_stats(x, w, centers)
        return streaming_map_reduce(
            lambda xb, wb: block_stats(xb, wb, centers), (x, w), chunk_size)

    def update_block(xb, wb, centers):
        """counts/sums only — the Lloyd loop never reads inertia, so the
        assignment reduces to ``argmax(x·c - ||c||²/2)``: one matmul per
        block, no per-row ``x²`` term or min-distance pass (both are
        assignment-invariant constants per row)."""
        if backend == "fused":
            idx, _ = _assign_block(xb, centers, backend)
        else:
            score = xb @ centers.T - 0.5 * jnp.sum(
                centers * centers, axis=1)[None, :]
            idx = jnp.argmax(score, axis=1).astype(jnp.int32)
        oh = _labels_onehot(idx, k, wb, xb.dtype)
        return jnp.sum(oh, axis=0), oh.T @ xb

    def sweep_stats(centers):
        """Reduce-only sweep for the Lloyd loop (assignments not collected)."""
        return reduce_rows(lambda xb, wb: update_block(xb, wb, centers),
                           (x, w), chunk_size)

    def cond(state):
        _, it, shift = state
        return jnp.logical_and(it < max_iter, shift > tol)

    def body(state):
        centers, it, _ = state
        counts, sums = sweep_stats(centers)
        new_centers = jnp.where(
            counts[:, None] > 0,
            sums / jnp.maximum(counts[:, None], 1e-12), centers)
        shift = jnp.sum((new_centers - centers) ** 2)
        return new_centers, it + 1, shift

    state = (centers0, jnp.array(0), jnp.array(jnp.inf, x.dtype))
    centers, n_iter, _ = jax.lax.while_loop(cond, body, state)
    # Final sweep against the returned centers: the loop body scores the
    # pre-update centers, which used to skew kmeans_multi's restart pick.
    (counts, _, inertia), assign = sweep(centers)
    return KMeansResult(centers, assign, inertia, n_iter, counts)


@partial(jax.jit, static_argnames=("k", "max_iter", "n_init", "chunk_size",
                                   "assign_backend", "pilot_iters",
                                   "seed_rows"))
def kmeans_multi(key: jax.Array, x: jax.Array, k: int,
                 sample_weight: Optional[jax.Array] = None,
                 max_iter: int = 100, tol: float = 1e-4,
                 n_init: int = 4,
                 chunk_size: Optional[int] = None,
                 assign_backend: str = "auto",
                 pilot_iters: int = PILOT_ITERS,
                 seed_rows: int = SEED_ROWS) -> KMeansResult:
    """Best of ``n_init`` k-means restarts (lowest inertia) — sklearn-style
    robustness against bad seeding, which matters for small local client
    datasets.

    Restarts are **pilot-pruned**: every seed runs ``pilot_iters`` fixed
    Lloyd sweeps under one vmap, the seed with the lowest pilot inertia
    wins, and only the winner iterates to convergence. The previous
    vmap-of-while_loop design ran ALL restarts in lockstep until the
    slowest straggler converged — one bad seed spinning 38 iterations at
    n_init-wide cost was the committed 6.3s ``init_from_kmeans_chunked``
    outlier. Beyond ``seed_rows`` rows the pilot (and the winner's
    convergence run) operate on one shared uniform row subsample, with a
    bounded :data:`REFINE_ITERS` full-data polish at the end — so the
    full data is swept O(1) times, not O(iterations). The winner's
    returned stats are always recomputed against its final centers on the
    full data (see :func:`kmeans`), so restart selection quality is
    judged on real inertia downstream.
    """
    if n_init == 1:
        return kmeans(key, x, k, sample_weight, max_iter, tol, chunk_size,
                      assign_backend, seed_rows=seed_rows)
    n = x.shape[0]
    w = (jnp.ones(n, x.dtype) if sample_weight is None else sample_weight)
    backend = resolve_backend(assign_backend)
    # The pilot's only job is picking a seed, so beyond ``seed_rows`` rows
    # its sweeps run on one shared uniform subsample (weights ride along,
    # full-batch — the subsample working set is O(seed_rows·d) by
    # construction). Only the pruned winner ever sweeps the full data.
    if n > seed_rows:
        key, sub = jax.random.split(key)
        sidx = jax.random.randint(sub, (seed_rows,), 0, n)
        xs, ws, pilot_chunk = x[sidx], w[sidx], None
    else:
        xs, ws, pilot_chunk = x, w, chunk_size
    keys = jax.random.split(key, n_init)

    def sweep_stats(centers):
        return reduce_rows(
            lambda xb, wb: _sweep_block(xb, wb, centers, backend),
            (xs, ws), pilot_chunk)

    def pilot(kk):
        centers = kmeans_plusplus(kk, xs, k, ws)

        def body(_, carry):
            centers, _ = carry
            counts, sums, inertia = sweep_stats(centers)
            new_centers = jnp.where(
                counts[:, None] > 0,
                sums / jnp.maximum(counts[:, None], 1e-12), centers)
            return new_centers, inertia

        return jax.lax.fori_loop(
            0, pilot_iters, body, (centers, jnp.array(jnp.inf, x.dtype)))

    pilot_centers, pilot_inertia = jax.vmap(pilot)(keys)
    best = jnp.argmin(pilot_inertia)
    if n > seed_rows:
        # Coreset-style finish: converge the winner on the subsample
        # (sweeps are ~n/seed_rows cheaper), then a bounded full-data
        # refine — the returned assignments/inertia/sizes all come from
        # the final full-data sweeps.
        sub = kmeans(key, xs, k, sample_weight=ws, max_iter=max_iter,
                     tol=tol, assign_backend=assign_backend,
                     init_centers=pilot_centers[best])
        res = kmeans(key, x, k, sample_weight, min(max_iter, REFINE_ITERS),
                     tol, chunk_size, assign_backend,
                     init_centers=sub.centers)
        return res._replace(n_iter=res.n_iter + sub.n_iter + pilot_iters)
    res = kmeans(key, x, k, sample_weight, max_iter, tol, chunk_size,
                 assign_backend, init_centers=pilot_centers[best])
    return res._replace(n_iter=res.n_iter + pilot_iters)


def kmeans_fit_cfg(key: jax.Array, x, k: int, config: FitConfig,
                   sample_weight: Optional[jax.Array] = None,
                   n_init: int = 1) -> KMeansResult:
    """The cfg-core k-means trainer behind ``repro.api.KMeansEstimator``:
    one validated :class:`FitConfig`, one dispatch — resident arrays run
    the jitted Lloyd loops (:func:`kmeans` / :func:`kmeans_multi`), a
    :class:`DataSource` runs the host-driven out-of-core twins. ``n_init``
    > 1 keeps the best restart by final-center inertia. ``tol`` and
    ``max_iter`` resolve through the "kmeans" algorithm defaults
    (1e-4 / 100), so a default config matches the legacy ``kmeans`` entry
    point without callers pinning the knobs."""
    backend = config.backend
    tol = config.resolve_tol("kmeans")
    max_iter = config.resolve_max_iter("kmeans")
    if isinstance(x, DataSource):
        require_array_weights(sample_weight, "k-means over a DataSource")
        cs = config.resolve_chunk(source=True)
        if n_init == 1:
            return kmeans_source(key, x, k, max_iter=max_iter,
                                 tol=tol, chunk_size=cs,
                                 assign_backend=backend)
        return kmeans_multi_source(key, x, k, max_iter=max_iter,
                                   tol=tol, n_init=n_init,
                                   chunk_size=cs, assign_backend=backend)
    cs = config.resolve_chunk(source=False)
    if n_init == 1:
        return kmeans(key, x, k, sample_weight=sample_weight,
                      max_iter=max_iter, tol=tol,
                      chunk_size=cs, assign_backend=backend)
    return kmeans_multi(key, x, k, sample_weight=sample_weight,
                        max_iter=max_iter, tol=tol,
                        n_init=n_init, chunk_size=cs, assign_backend=backend)


def federated_kmeans(key: jax.Array, client_data, k_global: int,
                     k_local: Optional[int] = None,
                     client_weights: Optional[jax.Array] = None,
                     max_iter: int = 100,
                     chunk_size: Optional[int] = None,
                     assign_backend: str = "auto") -> jax.Array:
    """One-shot federated k-means (Dennis et al. '21).

    Each client runs local k-means; the server clusters the (weighted) local
    centers to produce global centers. ``chunk_size``/``assign_backend``
    select the Lloyd-sweep engine for the client-side runs (the server-side
    run is over C·K_local centers — already tiny).

    client_data : (C, N_c, d) padded client datasets, or a list/tuple of
        per-client :class:`DataSource` streams (each client then runs its
        local k-means out-of-core; ragged sizes need no padding or masks)
    client_weights : (C, N_c) 0/1 mask (or general weights) for padding;
        array clients only (source rows all have weight 1)
    Returns (k_global, d) global centers.
    """
    if is_source_list(client_data):
        if client_weights is not None:
            raise ValueError(
                "federated_kmeans over DataSources: client_weights is "
                "array-path-only (weights mask padded fixed-shape client "
                "arrays; source shards are ragged by nature and every "
                "source row has weight 1)")
        return _federated_kmeans_sources(key, client_data, k_global,
                                         k_local=k_local, max_iter=max_iter,
                                         chunk_size=chunk_size,
                                         assign_backend=assign_backend)
    c = client_data.shape[0]
    k_local = k_local or k_global
    keys = jax.random.split(key, c + 1)

    def local(key, x, w):
        res = kmeans(key, x, k_local, sample_weight=w, max_iter=max_iter,
                     chunk_size=chunk_size, assign_backend=assign_backend)
        return res.centers, res.cluster_sizes

    if client_weights is None:
        client_weights = jnp.ones(client_data.shape[:2], client_data.dtype)
    centers, sizes = jax.vmap(local)(keys[:c], client_data, client_weights)  # (C,k,d),(C,k)
    flat_centers = centers.reshape(-1, client_data.shape[-1])
    flat_sizes = sizes.reshape(-1)
    res = kmeans(keys[-1], flat_centers, k_global,
                 sample_weight=flat_sizes, max_iter=max_iter)
    return res.centers


# ----------------------------------------------------------------------
# Out-of-core k-means: host-driven loops over DataSource blocks (§7)
# ----------------------------------------------------------------------
# Per-block functions are module-level jitted with parameters (centers,
# keys) as traced arguments, so every pass over a source hits the trace
# cache after the first block of each shape.

@jax.jit
def _seed_block(centers: jax.Array, valid: jax.Array, round_key: jax.Array,
                start: jax.Array, xb: jax.Array, wb: jax.Array):
    """One k-means++ sampling round over one block via the Gumbel-max
    trick: sampling a row with probability ∝ min-distance² equals taking
    the argmax of ``log(min_d²) + Gumbel``. Per-row Gumbel noise is keyed
    by the global row index, so the draw is chunking-invariant, and block
    maxima compose into the global argmax on the host — a streamed
    categorical sample without an (N,) probability vector. With no valid
    centers yet (round 0) the score degenerates to pure Gumbel noise,
    i.e. a uniform first-center draw. ``wb`` is the prefetch pad mask:
    padded rows score -inf, so they can never be drawn as a center."""
    b = xb.shape[0]
    idx = jnp.arange(b, dtype=jnp.uint32) + start
    row_keys = jax.vmap(jax.random.fold_in, (None, 0))(round_key, idx)
    g = jax.vmap(lambda kk: jax.random.gumbel(kk, (), xb.dtype))(row_keys)
    d2 = jnp.where(valid[None, :], _sq_dists(xb, centers), jnp.inf)
    d2min = jnp.min(d2, axis=1)
    base = jnp.where(jnp.isfinite(d2min),
                     jnp.log(jnp.maximum(d2min, 1e-30)), 0.0)
    score = jnp.where(wb > 0, base + g, -jnp.inf)
    i = jnp.argmax(score)
    return score[i], xb[i]


def kmeans_plusplus_streaming(key: jax.Array, source: DataSource, k: int,
                              chunk_size: Optional[int] = None) -> jax.Array:
    """k-means++ seeding over a :class:`DataSource` -> (k, d).

    The ROADMAP's last resident-array scan: each of the k rounds streams
    the blocks once (through the prefetching loader), recomputing min
    distances against the centers chosen so far (O(k²·N·d) total instead
    of the cached-min-d O(k·N·d) of the resident pass — the price of
    holding no (N,) state)."""
    chunk_size = resolve_source_chunk(chunk_size)
    d = source.dim
    centers = jnp.zeros((k, d), source.dtype)
    valid = jnp.zeros((k,), bool)
    for r in range(k):
        round_key = jax.random.fold_in(key, r)
        best_score, best_row = -float("inf"), None
        start = 0
        for xb, wb in prefetch_blocks(source, chunk_size):
            score, row = _seed_block(centers, valid, round_key,
                                     jnp.uint32(start), xb, wb)
            score = float(score)
            if score > best_score:
                best_score, best_row = score, row
            start += xb.shape[0]
        centers = centers.at[r].set(best_row)
        valid = valid.at[r].set(True)
    return centers


@partial(jax.jit, static_argnames=("backend",))
def _lloyd_block(centers: jax.Array, xb: jax.Array, wb: jax.Array,
                 backend: str):
    """(counts, sums, inertia) of one block — the Lloyd-sweep sufficient
    statistics the host loop accumulates. ``wb`` is the prefetch pad mask
    (source rows all carry weight 1; padded rows weight 0)."""
    return _sweep_block(xb, wb, centers, backend)


@partial(jax.jit, static_argnames=("covariance_type", "backend"))
def kmeans_label_block(centers: jax.Array, xb: jax.Array, wb: jax.Array,
                       covariance_type: str, backend: str) -> SufficientStats:
    """Hard-assignment label statistics of one block against fixed centers
    — the out-of-core replacement for ``label_stats``: assignment and
    labelling fuse into one pass, so the (N,) label vector of the resident
    init never exists. ``wb`` masks prefetch pad rows out of every sum."""
    k = centers.shape[0]
    idx, _ = _assign_block(xb, centers, backend)
    oh = _labels_onehot(idx, k, wb, xb.dtype)
    s0 = jnp.sum(oh, axis=0)
    s1 = oh.T @ xb
    if covariance_type == "diag":
        s2 = oh.T @ (xb * xb)
    else:
        s2 = jnp.einsum("nk,ni,nj->kij", oh, xb, xb)
    return SufficientStats(s0, s1, s2, jnp.zeros((), xb.dtype),
                           jnp.sum(wb))


def lloyd_round_stats(centers: jax.Array, x, sample_weight=None,
                      assign_backend: str = "reference",
                      chunk_size: Optional[int] = None):
    """One weighted Lloyd sweep against *fixed* centers ->
    ``(counts (K,), sums (K, d), inertia ())`` — the per-center label
    statistics one federated k-means client ships each round (Garst et
    al.; DESIGN.md §9). Additive in N, so per-client results sum into the
    server-side center update exactly like EM sufficient statistics.

    ``x`` is a resident ``(N, d)`` array (``sample_weight`` masks padded
    rows) or a :class:`DataSource` (never padded, no weights); either way
    the reduction runs through the §6 engine, so ``chunk_size`` bounds
    the working set. ``assign_backend`` must arrive resolved (the caller
    sits inside jit where "auto" has already been pinned)."""
    if isinstance(x, DataSource):
        require_array_weights(sample_weight,
                              "lloyd_round_stats over a DataSource")
        return reduce_rows(
            lambda xb, wb: _lloyd_block(centers, xb, wb, assign_backend), x,
            chunk_size)
    w = (jnp.ones(x.shape[0], x.dtype) if sample_weight is None
         else sample_weight)
    return reduce_rows(
        lambda xb, wb: _sweep_block(xb, wb, centers, assign_backend),
        (x, w), chunk_size)


def kmeans_source(key: jax.Array, source: DataSource, k: int,
                  max_iter: int = 100, tol: float = 1e-4,
                  chunk_size: Optional[int] = None,
                  assign_backend: str = "auto",
                  init_centers: Optional[jax.Array] = None) -> KMeansResult:
    """Lloyd's algorithm over a :class:`DataSource`: streamed k-means++
    seeding, then host-driven sweeps accumulating (counts, sums, inertia)
    per block. Mirrors :func:`kmeans` (same update, same stopping rule,
    final re-score against the returned centers) except that assignments
    are not collected — they would be the only O(N) output.
    ``init_centers`` skips seeding, as in :func:`kmeans`."""
    chunk_size = resolve_source_chunk(chunk_size)
    backend = resolve_backend(assign_backend)
    if init_centers is None:
        centers = kmeans_plusplus_streaming(key, source, k, chunk_size)
    else:
        centers = init_centers

    def sweep(c):
        return streaming_reduce(
            lambda xb, wb: _lloyd_block(c, xb, wb, backend),
            source, chunk_size)

    it, shift, tol = 0, float("inf"), float(tol)
    while it < max_iter and shift > tol:
        counts, sums, _ = sweep(centers)
        new_centers = jnp.where(
            counts[:, None] > 0,
            sums / jnp.maximum(counts[:, None], 1e-12), centers)
        shift = float(jnp.sum((new_centers - centers) ** 2))
        centers, it = new_centers, it + 1
    counts, _, inertia = sweep(centers)
    return KMeansResult(centers, None, inertia, jnp.asarray(it), counts)


def kmeans_multi_source(key: jax.Array, source: DataSource, k: int,
                        max_iter: int = 100, tol: float = 1e-4,
                        n_init: int = 4,
                        chunk_size: Optional[int] = None,
                        assign_backend: str = "auto",
                        pilot_iters: int = PILOT_ITERS) -> KMeansResult:
    """Best of ``n_init`` out-of-core restarts — the source twin of
    :func:`kmeans_multi`, pilot-pruned the same way: each seed streams
    ``pilot_iters`` fixed Lloyd sweeps, the lowest pilot inertia wins, and
    only the winner iterates to convergence (restarts run sequentially on
    the host; N full-convergence streams became one)."""
    if n_init == 1:
        return kmeans_source(key, source, k, max_iter=max_iter, tol=tol,
                             chunk_size=chunk_size,
                             assign_backend=assign_backend)
    chunk_size = resolve_source_chunk(chunk_size)
    backend = resolve_backend(assign_backend)
    best_centers, best_inertia = None, float("inf")
    for sub in jax.random.split(key, n_init):
        centers = kmeans_plusplus_streaming(sub, source, k, chunk_size)
        inertia = float("inf")
        for _ in range(pilot_iters):
            counts, sums, inertia = streaming_reduce(
                lambda xb, wb: _lloyd_block(centers, xb, wb, backend),
                source, chunk_size)
            centers = jnp.where(
                counts[:, None] > 0,
                sums / jnp.maximum(counts[:, None], 1e-12), centers)
            inertia = float(inertia)
        if inertia < best_inertia:
            best_centers, best_inertia = centers, inertia
    res = kmeans_source(key, source, k, max_iter=max_iter, tol=tol,
                        chunk_size=chunk_size, assign_backend=backend,
                        init_centers=best_centers)
    return res._replace(n_iter=res.n_iter + pilot_iters)


def federated_kmeans_from_sources(key: jax.Array,
                                  sources: Sequence[DataSource],
                                  k_global: int,
                                  k_local: Optional[int] = None,
                                  max_iter: int = 100,
                                  chunk_size: Optional[int] = None,
                                  assign_backend: str = "auto") -> jax.Array:
    """Deprecated: :func:`federated_kmeans` now dispatches on its input
    type, so a list of sources goes straight in. This shim forwards
    (bit-identical result) and will be removed."""
    warnings.warn(
        "federated_kmeans_from_sources is deprecated; pass the list of "
        "DataSources directly to federated_kmeans — same engine, same bits",
        DeprecationWarning, stacklevel=2)
    return federated_kmeans(key, list(sources), k_global, k_local=k_local,
                            max_iter=max_iter, chunk_size=chunk_size,
                            assign_backend=assign_backend)


def _federated_kmeans_sources(key: jax.Array,
                              sources: Sequence[DataSource],
                              k_global: int,
                              k_local: Optional[int] = None,
                              max_iter: int = 100,
                              chunk_size: Optional[int] = None,
                              assign_backend: str = "auto") -> jax.Array:
    """One-shot federated k-means with per-client :class:`DataSource` data:
    each client streams its own local k-means; the server clusters the
    size-weighted local centers (C·K_local rows — always resident-tiny).
    Ragged client sizes need no padding or masks on this path."""
    c = len(sources)
    k_local = k_local or k_global
    keys = jax.random.split(key, c + 1)
    centers, sizes = [], []
    for kk, src in zip(keys[:c], sources):
        res = kmeans_source(kk, src, k_local, max_iter=max_iter,
                            chunk_size=chunk_size,
                            assign_backend=assign_backend)
        centers.append(res.centers)
        sizes.append(res.cluster_sizes)
    flat_centers = jnp.concatenate(centers, axis=0)
    flat_sizes = jnp.concatenate(sizes, axis=0)
    res = kmeans(keys[-1], flat_centers, k_global,
                 sample_weight=flat_sizes, max_iter=max_iter)
    return res.centers
