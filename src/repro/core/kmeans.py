"""K-means in JAX: k-means++ seeding, weighted Lloyd iterations, and the
one-shot federated k-means of Dennis et al. '21 (paper ref [7]) used both
standalone and as DEM init 3."""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class KMeansResult(NamedTuple):
    centers: jax.Array        # (K, d)
    assignments: jax.Array    # (N,)
    inertia: jax.Array        # ()
    n_iter: jax.Array         # ()
    cluster_sizes: jax.Array  # (K,) sum of sample weights per cluster


def _sq_dists(x: jax.Array, centers: jax.Array) -> jax.Array:
    """Squared euclidean distances (N, K) via the matmul identity."""
    x2 = jnp.sum(x * x, axis=1, keepdims=True)           # (N, 1)
    c2 = jnp.sum(centers * centers, axis=1)[None, :]     # (1, K)
    return jnp.maximum(x2 - 2.0 * (x @ centers.T) + c2, 0.0)


def kmeans_plusplus(key: jax.Array, x: jax.Array, k: int,
                    sample_weight: Optional[jax.Array] = None) -> jax.Array:
    """k-means++ seeding -> (k, d). Supports zero-weighted (padded) rows."""
    n = x.shape[0]
    w = jnp.ones(n, x.dtype) if sample_weight is None else sample_weight
    key, sub = jax.random.split(key)
    first = jax.random.categorical(sub, jnp.log(jnp.maximum(w, 1e-30)))
    centers0 = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(x[first])
    min_d0 = jnp.sum((x - x[first]) ** 2, axis=1)

    def body(i, carry):
        centers, min_d, key = carry
        key, sub = jax.random.split(key)
        probs = jnp.maximum(min_d * w, 1e-30)
        idx = jax.random.categorical(sub, jnp.log(probs))
        c = x[idx]
        centers = centers.at[i].set(c)
        min_d = jnp.minimum(min_d, jnp.sum((x - c) ** 2, axis=1))
        return centers, min_d, key

    centers, _, _ = jax.lax.fori_loop(1, k, body, (centers0, min_d0, key))
    return centers


@partial(jax.jit, static_argnames=("k", "max_iter"))
def kmeans(key: jax.Array, x: jax.Array, k: int,
           sample_weight: Optional[jax.Array] = None,
           max_iter: int = 100, tol: float = 1e-4) -> KMeansResult:
    """Weighted Lloyd's algorithm with k-means++ init."""
    n, d = x.shape
    w = jnp.ones(n, x.dtype) if sample_weight is None else sample_weight
    centers = kmeans_plusplus(key, x, k, w)

    def step(centers):
        dists = _sq_dists(x, centers)                    # (N, K)
        assign = jnp.argmin(dists, axis=1)
        onehot = jax.nn.one_hot(assign, k, dtype=x.dtype) * w[:, None]  # (N, K)
        counts = jnp.sum(onehot, axis=0)                 # (K,)
        sums = onehot.T @ x                              # (K, d)
        new_centers = jnp.where(
            counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1e-12), centers)
        inertia = jnp.sum(jnp.min(dists, axis=1) * w)
        return new_centers, assign, inertia, counts

    def cond(state):
        _, _, it, shift, *_ = state
        return jnp.logical_and(it < max_iter, shift > tol)

    def body(state):
        centers, _, it, _, _, _ = state
        new_centers, assign, inertia, counts = step(centers)
        shift = jnp.sum((new_centers - centers) ** 2)
        return new_centers, assign, it + 1, shift, inertia, counts

    assign0 = jnp.zeros(n, jnp.int32)
    state = (centers, assign0, jnp.array(0), jnp.array(jnp.inf, x.dtype),
             jnp.array(0.0, x.dtype), jnp.zeros(k, x.dtype))
    centers, assign, n_iter, _, inertia, counts = jax.lax.while_loop(cond, body, state)
    return KMeansResult(centers, assign, inertia, n_iter, counts)


@partial(jax.jit, static_argnames=("k", "max_iter", "n_init"))
def kmeans_multi(key: jax.Array, x: jax.Array, k: int,
                 sample_weight: Optional[jax.Array] = None,
                 max_iter: int = 100, tol: float = 1e-4,
                 n_init: int = 4) -> KMeansResult:
    """Best of ``n_init`` k-means restarts (lowest inertia) — sklearn-style
    robustness against bad seeding, which matters for small local client
    datasets."""
    keys = jax.random.split(key, n_init)
    runs = jax.vmap(lambda kk: kmeans(kk, x, k, sample_weight, max_iter, tol))(keys)
    best = jnp.argmin(runs.inertia)
    return jax.tree.map(lambda a: a[best], runs)


def federated_kmeans(key: jax.Array, client_data: jax.Array, k_global: int,
                     k_local: Optional[int] = None,
                     client_weights: Optional[jax.Array] = None,
                     max_iter: int = 100) -> jax.Array:
    """One-shot federated k-means (Dennis et al. '21).

    Each client runs local k-means; the server clusters the (weighted) local
    centers to produce global centers.

    client_data : (C, N_c, d) padded client datasets
    client_weights : (C, N_c) 0/1 mask (or general weights) for padding
    Returns (k_global, d) global centers.
    """
    c = client_data.shape[0]
    k_local = k_local or k_global
    keys = jax.random.split(key, c + 1)

    def local(key, x, w):
        res = kmeans(key, x, k_local, sample_weight=w, max_iter=max_iter)
        return res.centers, res.cluster_sizes

    if client_weights is None:
        client_weights = jnp.ones(client_data.shape[:2], client_data.dtype)
    centers, sizes = jax.vmap(local)(keys[:c], client_data, client_weights)  # (C,k,d),(C,k)
    flat_centers = centers.reshape(-1, client_data.shape[-1])
    flat_sizes = sizes.reshape(-1)
    res = kmeans(keys[-1], flat_centers, k_global,
                 sample_weight=flat_sizes, max_iter=max_iter)
    return res.centers
