"""FedGenGMM core: GMM primitives, EM, federated one-shot aggregation and
distributed-EM baselines.

The supported public surface is ``repro.api`` (FitConfig + estimator
facades); the entry points exported here are the internal/legacy keyword
spellings the facade runs on."""
from repro.core.config import FitConfig
from repro.core.gmm import GMM, merge_gmms, merge_gmms_stacked
from repro.core.em import (DEFAULT_SOURCE_CHUNK, EMResult, SufficientStats,
                           bic_streaming, e_step_stats, e_step_stats_chunked,
                           em_step, fit_gmm, fit_gmm_bic, fit_gmm_bic_cfg,
                           fit_gmm_cfg, fit_gmm_streaming,
                           init_from_kmeans, init_from_means, label_stats,
                           log_prob_chunked, m_step, reduce_rows,
                           resolve_backend, resolve_estep_backend,
                           resolve_source_chunk, score_streaming,
                           streaming_map_reduce, streaming_reduce)
from repro.core.kmeans import (KMeansResult, federated_kmeans,
                               federated_kmeans_from_sources, kmeans,
                               kmeans_fit_cfg, kmeans_multi,
                               kmeans_multi_source,
                               kmeans_plusplus_streaming, kmeans_source)
from repro.core.partition import (ClientSplit, partition, partition_dirichlet,
                                  partition_quantity)
from repro.core.fedgen import (CommStats, FedGenResult, aggregate,
                               aggregate_cfg, fedgengmm, fedgengmm_cfg,
                               fedgengmm_from_sources, payload_floats,
                               train_locals, train_locals_bic,
                               train_locals_from_sources,
                               train_locals_sources_cfg)
from repro.core.dem import DEMResult, dem, dem_cfg, dem_from_sources
from repro.core.privacy import DPConfig, privatize_clients, privatize_gmm
from repro.core.continual import ContinualState, continual_round, init_state
from repro.core.splitmerge import split_merge_fit
from repro.core import metrics

__all__ = [
    "FitConfig",
    "GMM", "merge_gmms", "merge_gmms_stacked",
    "DEFAULT_SOURCE_CHUNK",
    "EMResult", "SufficientStats", "e_step_stats", "e_step_stats_chunked",
    "em_step", "fit_gmm", "fit_gmm_bic", "fit_gmm_bic_cfg", "fit_gmm_cfg",
    "fit_gmm_streaming",
    "init_from_kmeans", "init_from_means", "label_stats", "m_step",
    "bic_streaming", "score_streaming", "log_prob_chunked",
    "reduce_rows", "streaming_reduce", "streaming_map_reduce",
    "resolve_backend", "resolve_estep_backend", "resolve_source_chunk",
    "KMeansResult", "federated_kmeans", "federated_kmeans_from_sources",
    "kmeans", "kmeans_fit_cfg", "kmeans_multi", "kmeans_multi_source",
    "kmeans_plusplus_streaming", "kmeans_source",
    "ClientSplit", "partition", "partition_dirichlet", "partition_quantity",
    "CommStats", "FedGenResult", "aggregate", "aggregate_cfg", "fedgengmm",
    "fedgengmm_cfg", "fedgengmm_from_sources", "payload_floats",
    "train_locals", "train_locals_bic", "train_locals_from_sources",
    "train_locals_sources_cfg",
    "DEMResult", "dem", "dem_cfg", "dem_from_sources", "metrics",
    "DPConfig", "privatize_clients", "privatize_gmm",
    "ContinualState", "continual_round", "init_state", "split_merge_fit",
]
