"""Continual one-shot federated GMM learning (beyond-paper: the paper's
conclusion names "the feasibility of applying the FedGenGMM concept to the
problem of continuous federated learning" as future work — this module
implements one concrete design and the benchmark exercises it).

Design: time proceeds in windows. In window t each client trains a local
GMM on its new data and uploads it (one round per window). The server keeps
the previous global model G_{t-1} and aggregates

    G_t = FedGenAggregate( clients_t  U  decay-weighted G_{t-1} )

by treating G_{t-1} as one extra "client" whose pseudo dataset size is
``memory * N_t`` — i.e. the server samples the synthetic refit set from a
mixture of fresh client components and the old global model. ``memory`` in
[0, 1) trades plasticity vs stability (0 = paper's stateless per-window
behaviour; ->1 = frozen). No client ever re-uploads old data, preserving
the one-round-per-window property.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.em import fit_gmm
from repro.core.fedgen import train_locals
from repro.core.gmm import GMM, merge_gmms


class ContinualState(NamedTuple):
    global_gmm: Optional[GMM]
    window: int
    rounds_total: int


def init_state() -> ContinualState:
    return ContinualState(None, 0, 0)


def continual_round(key: jax.Array, state: ContinualState,
                    data: jax.Array, mask: jax.Array, sizes,
                    k_clients: int, k_global: int,
                    h: int = 100, memory: float = 0.5,
                    max_iter: int = 200, tol: float = 1e-3) -> ContinualState:
    """One window: local training on fresh data + one-shot aggregation with
    the decayed previous global model. data (C, N, d), mask (C, N)."""
    c = data.shape[0]
    k_train, k_agg, k_fit = jax.random.split(key, 3)
    stacked, _, _ = train_locals(k_train, data, mask, k_clients,
                                 max_iter=max_iter, tol=tol)
    gmms = [GMM(stacked.weights[i], stacked.means[i], stacked.covs[i])
            for i in range(c)]
    weights = [float(s) for s in sizes]
    n_fresh = sum(weights)
    if state.global_gmm is not None and memory > 0.0:
        gmms.append(state.global_gmm)
        weights.append(memory / max(1.0 - memory, 1e-6) * n_fresh)
    merged = merge_gmms(gmms, jnp.asarray(weights, jnp.float32))
    n_synth = h * sum(g.n_components for g in gmms)
    synth = merged.sample(k_agg, n_synth)
    res = fit_gmm(k_fit, synth, k_global, max_iter=max_iter, tol=tol)
    return ContinualState(res.gmm, state.window + 1, state.rounds_total + 1)
