"""Split-merge EM refinement — an alternative local trainer (beyond-paper).

The paper (§4.1) claims FedGenGMM makes it "fairly straightforward to
replace the standard EM algorithm with another method to train local GMMs"
(citing split-merge EM [Li & Li '09] and robust EM [Kasa & Rajan '23]).
This module demonstrates that modularity: after a standard EM fit, the
weakest component (lowest weight) is MERGED into its nearest neighbour and
the strongest high-variance component is SPLIT along its dominant axis;
EM then refines. The candidate is accepted only if it improves the
average log-likelihood — so the refinement is monotone by construction.

Drop-in: pass ``trainer=split_merge_fit`` wherever ``fit_gmm`` is used
for local training (see tests/test_splitmerge.py for the federated use).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.em import EMResult, fit_gmm
from repro.core.gmm import GMM


def _merge_weakest(gmm: GMM) -> GMM:
    """Merge the lowest-weight component into its nearest neighbour
    (moment-preserving merge), duplicating the strongest component's slot
    so K stays constant (the duplicate is then perturbed by the split)."""
    k = gmm.n_components
    wk = jnp.argmin(gmm.weights)
    d2 = jnp.sum((gmm.means - gmm.means[wk]) ** 2, axis=1)
    d2 = d2.at[wk].set(jnp.inf)
    nb = jnp.argmin(d2)
    w_sum = gmm.weights[wk] + gmm.weights[nb]
    a = gmm.weights[wk] / jnp.maximum(w_sum, 1e-12)
    mu = a * gmm.means[wk] + (1 - a) * gmm.means[nb]
    var = (a * (gmm.covs[wk] + gmm.means[wk] ** 2)
           + (1 - a) * (gmm.covs[nb] + gmm.means[nb] ** 2)) - mu ** 2
    weights = gmm.weights.at[nb].set(w_sum)
    means = gmm.means.at[nb].set(mu)
    covs = gmm.covs.at[nb].set(jnp.maximum(var, 1e-6))
    return GMM(weights, means, covs), wk


def _split_strongest(gmm: GMM, slot) -> GMM:
    """Split the largest-total-variance component along its widest axis,
    writing one half into ``slot``."""
    score = gmm.weights * jnp.sum(gmm.covs, axis=1)
    sp = jnp.argmax(score.at[slot].set(-jnp.inf))
    axis = jnp.argmax(gmm.covs[sp])
    delta = jnp.sqrt(gmm.covs[sp][axis])
    offset = jnp.zeros_like(gmm.means[sp]).at[axis].set(delta)
    w_half = gmm.weights[sp] / 2.0
    weights = gmm.weights.at[sp].set(w_half).at[slot].set(w_half)
    means = gmm.means.at[sp].set(gmm.means[sp] - offset) \
        .at[slot].set(gmm.means[sp] + offset)
    covs = gmm.covs.at[slot].set(gmm.covs[sp])
    return GMM(weights, means, covs)


def split_merge_fit(key: jax.Array, x: jax.Array, k: int,
                    sample_weight: Optional[jax.Array] = None,
                    n_rounds: int = 2, max_iter: int = 200,
                    tol: float = 1e-3, reg_covar: float = 1e-6) -> EMResult:
    """fit_gmm + accept-if-better split-merge refinement rounds."""
    best = fit_gmm(key, x, k, sample_weight, max_iter=max_iter, tol=tol,
                   reg_covar=reg_covar)
    if k < 3:
        return best
    for r in range(n_rounds):
        merged, slot = _merge_weakest(best.gmm)
        proposal = _split_strongest(merged, slot)
        cand = fit_gmm(jax.random.fold_in(key, r + 1), x, k, sample_weight,
                       max_iter=max_iter, tol=tol, reg_covar=reg_covar,
                       init_gmm=proposal)
        if float(cand.log_likelihood) > float(best.log_likelihood) + 1e-6:
            best = cand
    return best
