"""`FitConfig` — the one training configuration every estimator consumes
(DESIGN.md §8).

PRs 1–3 grew three parallel entry-point families (`fit_gmm` /
`fit_gmm_streaming` / source paths, resident vs out-of-core k-means,
`*_from_sources` federated twins), each re-threading the same
backend / chunk_size / covariance / tolerance knobs by hand.  This module
collapses that plumbing into a single frozen dataclass, validated once at
construction, plus the backend/chunk resolvers the engine shares.  The
public facade (`repro.api`) builds a `FitConfig` and hands it to the
cfg-core functions (`fit_gmm_cfg`, `kmeans_fit_cfg`, `fedgengmm_cfg`,
`dem_cfg`); the legacy keyword entry points construct the same config
internally, so both surfaces run literally the same code.

This module sits below the whole core (it imports only `jax` and
`repro.data.sources`, which itself imports nothing from `repro`), so
`em.py`, `kmeans.py`, `fedgen.py`, `dem.py` and `distributed/fed.py` can
all import it without cycles.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax

from repro.data.sources import DataSource

ENGINE_BACKENDS = ("auto", "reference", "fused")
COVARIANCE_TYPES = ("diag", "full")
INIT_STRATEGIES = ("auto", "kmeans", "separated", "pilot", "fed-kmeans")

# Per-algorithm defaults behind tol="auto" / max_iter="auto". The raw
# k-means entry points always converged on 1e-4 / 100 while EM used
# 1e-3 / 200; resolving the difference HERE (instead of one shared
# concrete default) is what lets `KMeansEstimator` match legacy `kmeans`
# without callers pinning the knobs by hand (the PR-4 caveat).
TOL_DEFAULTS = {"em": 1e-3, "kmeans": 1e-4}
MAX_ITER_DEFAULTS = {"em": 200, "kmeans": 100}

# Default block size for DataSource paths when the config says
# chunk_size="auto" (a source has no full batch to fall back to, so it
# streams at this granularity instead).
DEFAULT_SOURCE_CHUNK = 65536


def resolve_backend(backend: str, fused_supported: bool = True) -> str:
    """Resolve the user-facing engine knob to a concrete implementation.

    ``auto`` picks the fused Pallas kernel when it can win (the op has a
    kernel and we are on a TPU backend); interpret mode on CPU is
    bit-compatible but much slower than XLA, so ``auto`` keeps the
    reference path there. Ops whose kernel does not support the requested
    configuration (``fused_supported=False``, e.g. full covariance) always
    fall back to reference semantics.
    """
    if backend not in ENGINE_BACKENDS:
        raise ValueError(
            f"engine backend must be one of {ENGINE_BACKENDS}, "
            f"got {backend!r}")
    if not fused_supported:
        return "reference"
    if backend == "auto":
        return "fused" if jax.default_backend() == "tpu" else "reference"
    return backend


def resolve_estep_backend(estep_backend: str, is_diagonal: bool) -> str:
    """E-step flavour of :func:`resolve_backend`: the fused kernel only
    implements diagonal covariance (DESIGN.md §6)."""
    try:
        return resolve_backend(estep_backend, fused_supported=is_diagonal)
    except ValueError:
        raise ValueError(
            f"estep_backend must be one of {ENGINE_BACKENDS}, "
            f"got {estep_backend!r}") from None


def resolve_source_chunk(chunk_size: Optional[int]) -> int:
    """The one ``chunk_size`` rule for source paths: ``None`` means
    :data:`DEFAULT_SOURCE_CHUNK`; explicit values are validated —
    ``chunk_size=0`` is a caller bug (e.g. integer division gone wrong),
    not a request for the default working set."""
    if chunk_size is None:
        return DEFAULT_SOURCE_CHUNK
    chunk_size = int(chunk_size)
    if chunk_size <= 0:
        raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    return chunk_size


def require_array_weights(sample_weight, what: str) -> None:
    """THE sample-weight rule, stated once: weights exist to mask padded
    fixed-shape client arrays (weight 0 = padding) and are therefore
    array-path-only by design; a :class:`DataSource` block stream is never
    padded, so every source row has weight 1."""
    if sample_weight is not None:
        raise ValueError(
            f"{what}: sample_weight is only supported on resident-array "
            f"inputs. Weights exist to mask padded fixed-shape client "
            f"arrays; DataSource block streams are never padded, so every "
            f"source row has weight 1 by design. Represent ragged client "
            f"shards directly with repro.data.sources.ConcatSource and "
            f"drop the weights.")


def is_source(data) -> bool:
    """True if ``data`` is a single out-of-core :class:`DataSource`."""
    return isinstance(data, DataSource)


def is_source_list(data) -> bool:
    """True if ``data`` is a non-empty list/tuple of per-client
    :class:`DataSource` objects (the federated out-of-core input shape)."""
    return (isinstance(data, (list, tuple)) and len(data) > 0
            and all(isinstance(s, DataSource) for s in data))


_CHUNK_NONE_ERROR = (
    "chunk_size=None is ambiguous and no longer accepted: the legacy entry "
    "points made it mean 'full batch' for resident arrays but "
    f"{DEFAULT_SOURCE_CHUNK}-row blocks for DataSources, silently diverging "
    "by input type. Pass chunk_size='auto' to keep exactly those defaults "
    "explicitly, or an integer block size to stream both paths in "
    "O(chunk_size*K) memory.")


@dataclasses.dataclass(frozen=True)
class FitConfig:
    """Frozen, validated-at-construction training configuration (§8).

    backend : engine implementation knob ("auto" | "reference" | "fused");
        the E-step, k-means assignment and scoring paths all resolve it via
        :func:`resolve_backend` ("auto" = fused Pallas kernel on TPU,
        reference elsewhere; unsupported configs fall back to reference).
    chunk_size : "auto" or a positive int. "auto" keeps the historical
        defaults — full batch for resident arrays, DEFAULT_SOURCE_CHUNK
        blocks for DataSources; an int streams both input types in
        O(chunk_size*K) memory. ``None`` is rejected with an explanation
        (it used to silently mean different things per input type).
    covariance_type : "diag" | "full", threaded through init, EM and BIC.
    reg_covar : covariance floor added at every M-step.
    tol : convergence threshold on the avg-loglik delta (EM/DEM/FedEM) or
        the squared center shift (k-means/FedKMeans). "auto" resolves per
        algorithm at config-resolution time (:data:`TOL_DEFAULTS`: 1e-3
        for the EM family, 1e-4 for k-means — the historical per-entry-
        point defaults); an explicit float applies everywhere.
    max_iter : EM iteration / federated round / Lloyd sweep budget.
        "auto" resolves per algorithm (:data:`MAX_ITER_DEFAULTS`: 200 EM,
        100 k-means); an explicit int applies everywhere.
    init : init strategy. "auto" resolves per estimator (k-means init for
        GMM fits; DEM picks fed-kmeans for resident splits and separated
        centers for source clients). DEM also accepts the explicit
        schemes "separated" | "pilot" | "fed-kmeans" (paper inits 1/2/3).
    seed : seed policy — estimators derive their jax PRNG key as
        ``jax.random.key(seed)`` unless an explicit key is passed to
        ``fit``/``run``.

    Instances are hashable (frozen dataclass), so a config can ride
    through ``functools.partial``/static jit arguments unchanged.
    """

    backend: str = "auto"
    chunk_size: Union[int, str] = "auto"
    covariance_type: str = "diag"
    reg_covar: float = 1e-6
    tol: Union[float, str] = "auto"
    max_iter: Union[int, str] = "auto"
    init: str = "auto"
    seed: int = 0

    def __post_init__(self):
        if self.backend not in ENGINE_BACKENDS:
            raise ValueError(
                f"engine backend must be one of {ENGINE_BACKENDS}, got "
                f"{self.backend!r} (legacy knob name: estep_backend)")
        cs = self.chunk_size
        if cs is None:
            raise ValueError(_CHUNK_NONE_ERROR)
        if isinstance(cs, str):
            if cs != "auto":
                raise ValueError(
                    f"chunk_size must be 'auto' or a positive int, "
                    f"got {cs!r}")
        else:
            # integral values only (int, np.int64, 8192.0 all fine) —
            # silently truncating 8192.5 would mask exactly the
            # division-gone-wrong caller bugs this validation exists for
            if isinstance(cs, bool) or int(cs) != cs:
                raise ValueError(
                    f"chunk_size must be 'auto' or a positive int, "
                    f"got {cs!r}")
            cs = int(cs)
            if cs <= 0:
                raise ValueError(
                    f"chunk_size must be positive, got {cs}")
            object.__setattr__(self, "chunk_size", cs)
        if self.covariance_type not in COVARIANCE_TYPES:
            raise ValueError(
                f"covariance_type must be one of {COVARIANCE_TYPES}, "
                f"got {self.covariance_type!r}")
        if not float(self.reg_covar) >= 0.0:
            raise ValueError(f"reg_covar must be >= 0, got {self.reg_covar}")
        object.__setattr__(self, "reg_covar", float(self.reg_covar))
        if isinstance(self.tol, str):
            if self.tol != "auto":
                raise ValueError(
                    f"tol must be 'auto' or a float >= 0, got {self.tol!r}")
        else:
            if not float(self.tol) >= 0.0:
                raise ValueError(f"tol must be >= 0, got {self.tol}")
            object.__setattr__(self, "tol", float(self.tol))
        # same integral strictness as chunk_size: truncating 2.5
        # iterations would mask division-gone-wrong caller bugs
        mi = self.max_iter
        if isinstance(mi, str):
            if mi != "auto":
                raise ValueError(
                    f"max_iter must be 'auto' or an integer >= 1, "
                    f"got {mi!r}")
        else:
            if isinstance(mi, bool) or int(mi) != mi:
                raise ValueError(f"max_iter must be an integer, got {mi!r}")
            if int(mi) < 1:
                raise ValueError(f"max_iter must be >= 1, got {mi}")
            object.__setattr__(self, "max_iter", int(mi))
        if self.init not in INIT_STRATEGIES:
            raise ValueError(
                f"init must be one of {INIT_STRATEGIES}, got {self.init!r}")
        sd = self.seed
        if isinstance(sd, bool) or int(sd) != sd:
            raise ValueError(f"seed must be an integer, got {sd!r}")
        object.__setattr__(self, "seed", int(sd))

    # -- the one resolve step (replaces five copies of knob threading) ----

    @classmethod
    def from_legacy(cls, *, backend: str = "auto",
                    chunk_size: Optional[int] = None,
                    covariance_type: str = "diag", reg_covar: float = 1e-6,
                    tol: float = 1e-3, max_iter: int = 200,
                    init: str = "auto", seed: int = 0) -> "FitConfig":
        """Build a config from the legacy keyword surface, where
        ``chunk_size=None`` meant what ``"auto"`` now spells out."""
        return cls(backend=backend,
                   chunk_size="auto" if chunk_size is None else chunk_size,
                   covariance_type=covariance_type, reg_covar=reg_covar,
                   tol=float(tol), max_iter=max_iter, init=init, seed=seed)

    def resolve_chunk(self, source: bool) -> Optional[int]:
        """Concrete engine chunk for one input type: ``None`` (full batch)
        on resident arrays under "auto", :data:`DEFAULT_SOURCE_CHUNK` on
        sources; explicit ints pass through unchanged."""
        if self.chunk_size == "auto":
            return DEFAULT_SOURCE_CHUNK if source else None
        return self.chunk_size

    def resolve_tol(self, algorithm: str = "em") -> float:
        """Concrete convergence threshold for one algorithm family:
        "auto" keeps the historical per-entry-point defaults
        (:data:`TOL_DEFAULTS`), explicit floats pass through."""
        if self.tol == "auto":
            if algorithm not in TOL_DEFAULTS:
                raise ValueError(
                    f"algorithm must be one of {tuple(TOL_DEFAULTS)}, "
                    f"got {algorithm!r}")
            return TOL_DEFAULTS[algorithm]
        return self.tol

    def resolve_max_iter(self, algorithm: str = "em") -> int:
        """Concrete iteration/round budget for one algorithm family:
        "auto" keeps the historical per-entry-point defaults
        (:data:`MAX_ITER_DEFAULTS`), explicit ints pass through."""
        if self.max_iter == "auto":
            if algorithm not in MAX_ITER_DEFAULTS:
                raise ValueError(
                    f"algorithm must be one of {tuple(MAX_ITER_DEFAULTS)}, "
                    f"got {algorithm!r}")
            return MAX_ITER_DEFAULTS[algorithm]
        return self.max_iter

    def resolved_for(self, algorithm: str) -> "FitConfig":
        """A config with tol/max_iter made concrete for one algorithm —
        the cache-key normalization used where a config rides through jit
        as a static argument (an "auto" config and its resolved twin must
        not compile twice)."""
        return self.replace(tol=self.resolve_tol(algorithm),
                            max_iter=self.resolve_max_iter(algorithm))

    def resolved_backend(self, fused_supported: bool = True) -> str:
        return resolve_backend(self.backend, fused_supported)

    def resolved_estep(self, is_diagonal: Optional[bool] = None) -> str:
        if is_diagonal is None:
            is_diagonal = self.is_diagonal
        return resolve_estep_backend(self.backend, is_diagonal)

    @property
    def is_diagonal(self) -> bool:
        return self.covariance_type == "diag"

    def key(self) -> jax.Array:
        """The seed policy: the PRNG key estimators use when the caller
        does not pass one explicitly."""
        return jax.random.key(self.seed)

    def replace(self, **changes) -> "FitConfig":
        """A new validated config with the given fields replaced."""
        return dataclasses.replace(self, **changes)
