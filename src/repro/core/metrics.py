"""Evaluation metrics: average log-likelihood (Eq. 2) and AUC-PR for the
anomaly-detection experiments (§5.8)."""
from __future__ import annotations

import numpy as np


def average_log_likelihood(gmm, x, chunk_size=None) -> float:
    """The paper's fitness score gamma_G (Eq. 2). ``chunk_size`` scores in
    O(chunk·K) memory via the streaming engine (DESIGN.md §6); the engine
    owns the None → full-batch dispatch."""
    from repro.core.em import score_streaming
    return float(score_streaming(gmm, x, chunk_size=chunk_size))


def precision_recall_curve(scores: np.ndarray, labels: np.ndarray):
    """PR curve for anomaly scores (higher score = more anomalous).

    labels: 1 = anomaly (positive class), 0 = inlier.
    Returns (precision, recall, thresholds) sklearn-compatible.
    """
    scores = np.asarray(scores, dtype=np.float64)
    labels = np.asarray(labels).astype(np.int64)
    order = np.argsort(-scores, kind="mergesort")
    scores, labels = scores[order], labels[order]
    distinct = np.r_[np.flatnonzero(np.diff(scores)), len(scores) - 1]
    tp = np.cumsum(labels)[distinct]
    fp = (distinct + 1) - tp
    total_pos = labels.sum()
    precision = tp / np.maximum(tp + fp, 1)
    recall = tp / max(total_pos, 1)
    # prepend the (recall=0, precision=1) point
    precision = np.r_[1.0, precision]
    recall = np.r_[0.0, recall]
    return precision, recall, scores[distinct]


def auc_pr(scores: np.ndarray, labels: np.ndarray) -> float:
    """Average precision (step-wise integral of the PR curve)."""
    precision, recall, _ = precision_recall_curve(scores, labels)
    return float(np.sum(np.diff(recall) * precision[1:]))


def anomaly_scores(gmm, x, chunk_size=None) -> np.ndarray:
    """Point-wise anomaly score = negative log-likelihood under the model.

    ``chunk_size`` computes the log density in fixed-size row chunks
    (O(chunk·K) peak memory) — the edge-client scoring mode; the engine
    owns the None → full-batch dispatch."""
    from repro.core.em import log_prob_chunked
    return -np.asarray(log_prob_chunked(gmm, x, chunk_size=chunk_size))


def auc_pr_for_model(gmm, x_inlier, x_ood, chunk_size=None) -> float:
    import numpy as np
    s_in = anomaly_scores(gmm, x_inlier, chunk_size=chunk_size)
    s_out = anomaly_scores(gmm, x_ood, chunk_size=chunk_size)
    scores = np.concatenate([s_in, s_out])
    labels = np.concatenate([np.zeros(len(s_in)), np.ones(len(s_out))])
    return auc_pr(scores, labels)
