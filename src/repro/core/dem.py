"""Distributed EM (DEM) baselines (§5.4 of the paper, after Wu et al. '23).

Every client runs the E-step locally and ships sufficient statistics; the
server aggregates (a psum in the sharded runtime), runs the M-step, and
broadcasts the new parameters. One EM iteration = one communication round
— which makes DEM a one-screen :class:`DEMStrategy` on the federation
runtime (``repro.fed.runtime``, DESIGN.md §9): ``local_step`` is the
engine E-step, ``server_combine`` is the M-step plus the avg-loglik
convergence scalar, and :func:`run_rounds` owns the client loop, the
round loop and the communication ledger for every input type
(ClientSplit, list of DataSources, sharded mesh).

Three initializations of the global component centers are reproduced,
named in :class:`repro.core.config.FitConfig` init-strategy terms:
  "separated"  (init 1) — maximally separated centers in the (normalized)
               feature range,
  "pilot"      (init 2) — pilot GMM on a small (100-point) subset uploaded
               to the server,
  "fed-kmeans" (init 3) — one-shot federated k-means (Dennis et al. '21).

:func:`dem_cfg` dispatches on the client input type with one validated
:class:`FitConfig` and is what ``repro.api.DEM`` runs; its results are
bit-identical to the pre-runtime round loops (pinned in
``tests/test_fed_runtime.py``). The iterative FedEM baseline
(``repro.fed.strategies``) generalizes :class:`DEMStrategy` with
partial-participation / local-epochs knobs.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.config import FitConfig, is_source_list
from repro.core.em import (e_step_stats, fit_gmm, init_from_means, m_step)
from repro.core.gmm import GMM
from repro.core.kmeans import federated_kmeans
from repro.core.partition import ClientSplit
from repro.data.sources import ConcatSource, DataSource
from repro.fed.ledger import (CommStats, dtype_itemsize, gmm_payload_floats,
                              RoundPayload, stats_payload_floats)
from repro.fed.runtime import run_rounds


class DEMResult(NamedTuple):
    global_gmm: GMM
    log_likelihood: jax.Array   # avg loglik over all client data
    n_rounds: jax.Array
    converged: jax.Array
    comm: CommStats


# DEM init schemes: paper numbering <-> FitConfig init-strategy names.
INIT_SCHEME_NAMES = {1: "separated", 2: "pilot", 3: "fed-kmeans"}
INIT_SCHEMES = {v: k for k, v in INIT_SCHEME_NAMES.items()}


def _legacy_init_name(init) -> str:
    """The one legacy-knob rule: paper scheme numbers (1/2/3) and
    FitConfig strategy names are both accepted, anything else is the
    historical error."""
    name = INIT_SCHEME_NAMES.get(init, init)
    if name not in INIT_SCHEMES:
        raise ValueError(f"unknown DEM init scheme {init}")
    return name


def _resolve_init(init: str, sources: bool) -> str:
    """``auto`` keeps the historical per-input defaults: fed-kmeans
    (init 3) for resident splits, separated centers (init 1) for source
    clients (the pilot subset would upload raw rows)."""
    if init == "auto":
        return "separated" if sources else "fed-kmeans"
    if init == "kmeans":
        raise ValueError(
            "init='kmeans' is the single-model GMM init; DEM init "
            "strategies are 'separated' | 'pilot' | 'fed-kmeans' (paper "
            "schemes 1/2/3) or 'auto'")
    return init


# ----------------------------------------------------------------------
# Initializations
# ----------------------------------------------------------------------

def max_separated_centers(key: jax.Array, k: int, d: int,
                          n_candidates: int = 2048) -> jax.Array:
    """Init 1: greedy farthest-point centers in the unit hypercube [0,1]^d
    (features are normalized to [0,1], §5.1)."""
    cand = jax.random.uniform(key, (n_candidates, d))
    center0 = jnp.full((d,), 0.5, cand.dtype)
    centers = jnp.zeros((k, d), cand.dtype).at[0].set(center0)
    min_d = jnp.sum((cand - center0) ** 2, axis=1)

    def body(i, carry):
        centers, min_d = carry
        idx = jnp.argmax(min_d)
        c = cand[idx]
        centers = centers.at[i].set(c)
        min_d = jnp.minimum(min_d, jnp.sum((cand - c) ** 2, axis=1))
        return centers, min_d

    centers, _ = jax.lax.fori_loop(1, k, body, (centers, min_d))
    return centers


# Init 2's pilot subset size (raw rows uploaded to the server) — also
# what the comm ledger charges a pilot init for.
PILOT_ROWS = 100


def pilot_subset_centers(key: jax.Array, split: ClientSplit, k: int,
                         n_pilot: int = PILOT_ROWS) -> jax.Array:
    """Init 2: clients upload a tiny uniform subset (n_pilot points total);
    the server fits a pilot GMM and uses its means. NOTE: uploads raw data."""
    data = jnp.asarray(split.data).reshape(-1, split.data.shape[-1])
    mask = jnp.asarray(split.mask).reshape(-1)
    # weighted sampling without replacement over real (unpadded) rows
    g = jax.random.gumbel(key, mask.shape)
    scores = jnp.where(mask > 0, g, -jnp.inf)
    idx = jax.lax.top_k(scores, n_pilot)[1]
    pilot = data[idx]
    res = fit_gmm(jax.random.fold_in(key, 1), pilot, k, max_iter=100)
    return res.gmm.means


def fed_kmeans_centers(key: jax.Array, split: ClientSplit, k: int,
                       chunk_size: int | None = None) -> jax.Array:
    """Init 3: one-shot federated k-means global centers. ``chunk_size``
    streams the client-side Lloyd sweeps (DESIGN.md §6)."""
    return federated_kmeans(key, jnp.asarray(split.data), k,
                            client_weights=jnp.asarray(split.mask),
                            chunk_size=chunk_size)


# ----------------------------------------------------------------------
# DEM as a federation strategy
# ----------------------------------------------------------------------

class DEMState(NamedTuple):
    """Round-loop state: the global model plus the convergence scalars.
    Leaves are jnp under the jitted driver and Python floats on the host
    (source-client) path, mirroring the engine's ``host_em_loop``
    semantics; tol/reg_covar ride here as *traced* values so sweeping
    them never recompiles the loop."""
    gmm: GMM
    prev_ll: jax.Array
    ll: jax.Array
    tol: jax.Array
    reg_covar: jax.Array


@dataclasses.dataclass(frozen=True)
class DEMStrategy:
    """Distributed EM on the federation runtime: clients ship
    :class:`~repro.core.em.SufficientStats`, the server M-steps, one EM
    iteration per communication round. Frozen/hashable so it rides the
    jitted round driver as a static argument; ``tol``/``reg_covar`` are
    ``compare=False`` because they enter the computation through the
    (traced) state, never the cache key."""

    k: int
    covariance_type: str = "diag"
    backend: str = "auto"            # engine knob (resolved per op)
    chunk: Optional[int] = None      # resolved for the input type
    init: str = "fed-kmeans"
    host: bool = False               # source clients -> host round loop
    tol: float = dataclasses.field(default=1e-3, compare=False)
    reg_covar: float = dataclasses.field(default=1e-6, compare=False)

    one_shot = False
    name = "dem"

    # -- init ----------------------------------------------------------

    def init_state(self, key: jax.Array, backend) -> DEMState:
        k_init, _ = jax.random.split(key)
        if backend.kind == "sources":
            d = backend.dim
            if self.init == "separated":
                centers = max_separated_centers(k_init, self.k, d)
            elif self.init == "fed-kmeans":
                centers = federated_kmeans(k_init, list(backend.sources),
                                           self.k, chunk_size=self.chunk)
            else:  # "pilot"
                raise ValueError(
                    "DEM init 'pilot' uploads raw rows and needs resident "
                    "client data; use a ClientSplit for it")
            union = ConcatSource(backend.sources)
            gmm0 = init_from_means(centers, union,
                                   covariance_type=self.covariance_type,
                                   reg_covar=self.reg_covar,
                                   chunk_size=self.chunk)
            return self.state_from_gmm(gmm0)
        data, mask = backend.data, backend.mask
        d = data.shape[-1]
        if self.init == "separated":
            centers = max_separated_centers(k_init, self.k, d)
        elif self.init == "pilot":
            split = getattr(backend, "split", None)
            if split is None:
                raise ValueError(
                    "DEM init 'pilot' needs a ClientSplit (it uploads a "
                    "raw pilot subset)")
            centers = pilot_subset_centers(k_init, split, self.k)
        else:  # "fed-kmeans" (validated upstream)
            centers = federated_kmeans(k_init, data, self.k,
                                       client_weights=mask,
                                       chunk_size=self.chunk)
        flat = data.reshape(-1, d)
        flat_w = mask.reshape(-1)
        gmm0 = init_from_means(centers, flat, flat_w,
                               covariance_type=self.covariance_type,
                               reg_covar=self.reg_covar)
        return self.state_from_gmm(gmm0, dtype=data.dtype)

    def state_from_gmm(self, gmm0: GMM, dtype=None) -> "DEMState":
        """Round-0 state around an externally built initial model — what
        ``init_state`` ends in, and what the sharded entry point uses to
        honor caller-chosen init centers. ``dtype`` (the data dtype) pins
        the convergence scalars on the jitted path; the host (source)
        path carries Python floats instead."""
        if self.host:
            neg_inf = float("-inf")
            return self._make_state(gmm0, neg_inf, neg_inf,
                                    float(self.tol), float(self.reg_covar))
        neg_inf = jnp.array(-jnp.inf, dtype)
        return self._make_state(gmm0, neg_inf, neg_inf,
                                jnp.asarray(self.tol, dtype), self.reg_covar)

    def _make_state(self, gmm, prev_ll, ll, tol, reg_covar):
        return DEMState(gmm, prev_ll, ll, tol, reg_covar)

    # -- one round ------------------------------------------------------

    def local_step(self, state: DEMState, x, w, idx):
        """One client's E-step over its own rows -> SufficientStats (the
        uplink payload; additive, so backends sum it)."""
        return e_step_stats(state.gmm, x, w, self.backend, self.chunk)

    def server_combine(self, state: DEMState, stats) -> DEMState:
        gmm = m_step(stats, state.reg_covar)
        ll = stats.loglik / jnp.maximum(stats.wsum, 1e-12)
        if self.host:
            ll = float(ll)
        return self._next_state(state, gmm, ll)

    def _next_state(self, state, gmm, ll):
        return DEMState(gmm, state.ll, ll, state.tol, state.reg_covar)

    def converged(self, state: DEMState):
        return abs(state.ll - state.prev_ll) <= state.tol

    def keep_going(self, state: DEMState):
        """The historical loop predicate, kept distinct from
        ``converged``: with a NaN loglik (degenerate run) both are false,
        so the loop stops after one more round AND reports not-converged
        — exactly the pre-§9 ``_dem_loop`` / ``host_em_loop`` behavior."""
        return abs(state.ll - state.prev_ll) > state.tol

    # -- accounting / result -------------------------------------------

    def round_payload(self, backend, state) -> RoundPayload:
        c, d = backend.num_clients, backend.dim
        diag = self.covariance_type == "diag"
        # Under a cohort sampler the driver's accounting view reports
        # num_clients == cohort size (per-round traffic) while
        # population_clients stays C — init-phase traffic touches the
        # whole population exactly once.
        pop = getattr(backend, "population_clients", c)
        if self.init == "fed-kmeans":
            # one-shot warm start: every client uploads its k local
            # centers + k cluster sizes (Dennis et al. '21)
            init_up = pop * (self.k * d + self.k)
        elif self.init == "pilot":
            init_up = PILOT_ROWS * d   # raw pilot rows to the server
        else:  # "separated": server-side construction, no uplink
            init_up = 0
        return RoundPayload(
            uplink_floats=c * stats_payload_floats(self.k, d, diag),
            downlink_floats=c * gmm_payload_floats(self.k, d, diag),
            itemsize=dtype_itemsize(state.gmm.means.dtype),
            extra_uplink_floats=init_up,
            # the round-0 global model broadcast (every init scheme ends
            # in one; warm starts used to ride the ledger for free)
            extra_downlink_floats=pop * gmm_payload_floats(self.k, d, diag))

    def finalize(self, state: DEMState, n_rounds, converged,
                 comm: CommStats) -> DEMResult:
        ll = state.ll
        if self.host:
            ll = jnp.asarray(ll, state.gmm.means.dtype)
        return DEMResult(state.gmm, ll, n_rounds, jnp.asarray(converged),
                         comm)


def dem_cfg(key: jax.Array, clients, config: FitConfig, k: int,
            transform=None, async_policy=None) -> DEMResult:
    """Run DEM — the cfg-core behind ``repro.api.DEM``, dispatching on the
    client input type (:class:`ClientSplit` vs list of
    :class:`DataSource`) through the federation runtime. The init strategy
    comes from ``config.init`` ("auto" resolves to fed-kmeans for splits,
    separated centers for sources; "pilot" requires resident data — it
    uploads raw rows). ``async_policy`` (a
    :class:`repro.fed.AsyncPolicy`) reroutes the rounds through the
    buffered asynchronous driver (``repro.fed.run_async``, DESIGN.md
    §12); None keeps the synchronous loop."""
    sources = is_source_list(clients)
    if not sources and not isinstance(clients, ClientSplit):
        raise TypeError(
            f"dem clients must be a ClientSplit or a list of DataSources, "
            f"got {type(clients).__name__}")
    strategy = DEMStrategy(
        k=k, covariance_type=config.covariance_type, backend=config.backend,
        chunk=config.resolve_chunk(source=sources),
        init=_resolve_init(config.init, sources), host=sources,
        tol=config.resolve_tol("em"), reg_covar=config.reg_covar)
    if async_policy is not None:
        from repro.fed.async_runtime import run_async  # sits beside runtime
        return run_async(strategy, clients, key=key,
                         max_rounds=config.resolve_max_iter("em"),
                         transform=transform, **async_policy.driver_kwargs())
    return run_rounds(strategy, clients, key=key,
                      max_rounds=config.resolve_max_iter("em"),
                      transform=transform)


def dem(key: jax.Array, split: ClientSplit, k: int, init: int = 3,
        max_rounds: int = 200, tol: float = 1e-3,
        reg_covar: float = 1e-6, estep_backend: str = "auto",
        chunk_size: int | None = None,
        covariance_type: str = "diag") -> DEMResult:
    """Legacy keyword surface of :func:`dem_cfg` (internal; prefer
    ``repro.api.DEM``). ``init`` takes the paper's scheme numbers 1/2/3
    (or their FitConfig names)."""
    cfg = FitConfig.from_legacy(
        backend=estep_backend, chunk_size=chunk_size,
        covariance_type=covariance_type, reg_covar=reg_covar, tol=tol,
        max_iter=max_rounds, init=_legacy_init_name(init))
    return dem_cfg(key, split, cfg, k)


def dem_from_sources(key: jax.Array, sources: Sequence[DataSource], k: int,
                     init: int = 1, max_rounds: int = 200, tol: float = 1e-3,
                     reg_covar: float = 1e-6, estep_backend: str = "auto",
                     chunk_size: int | None = None,
                     covariance_type: str = "diag") -> DEMResult:
    """Deprecated: ``repro.api.DEM(k).run(sources)`` dispatches on the
    input type, so the separate ``_from_sources`` spelling is obsolete.
    This shim forwards to the facade (bit-identical result) and will be
    removed."""
    warnings.warn(
        "dem_from_sources is deprecated; use repro.api.DEM(k).run(sources) "
        "— same engine, same bits",
        DeprecationWarning, stacklevel=2)
    from repro.api import DEM  # facade sits above core; lazy
    runner = DEM(k, config=FitConfig.from_legacy(
        backend=estep_backend, chunk_size=chunk_size,
        covariance_type=covariance_type, reg_covar=reg_covar, tol=tol,
        max_iter=max_rounds, init=_legacy_init_name(init)))
    return runner.run(list(sources), key=key)
