"""Distributed EM (DEM) baselines (§5.4 of the paper, after Wu et al. '23).

Every client runs the E-step locally and ships sufficient statistics; the
server aggregates (a psum in the sharded runtime), runs the M-step, and
broadcasts the new parameters. One EM iteration = one communication round.

Three initializations of the global component centers are reproduced,
named in :class:`repro.core.config.FitConfig` init-strategy terms:
  "separated"  (init 1) — maximally separated centers in the (normalized)
               feature range,
  "pilot"      (init 2) — pilot GMM on a small (100-point) subset uploaded
               to the server,
  "fed-kmeans" (init 3) — one-shot federated k-means (Dennis et al. '21).

Clients arrive either as a padded :class:`ClientSplit` or as a list of
per-client :class:`DataSource` streams; :func:`dem_cfg` dispatches on the
input type with one validated :class:`FitConfig` and is what
``repro.api.DEM`` runs.
"""
from __future__ import annotations

import warnings
from functools import partial
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core.config import FitConfig, is_source_list
from repro.core.em import (SufficientStats, e_step_stats, fit_gmm,
                           host_em_loop, init_from_means, m_step)
from repro.core.fedgen import CommStats, payload_floats
from repro.core.gmm import GMM
from repro.core.kmeans import federated_kmeans
from repro.core.partition import ClientSplit
from repro.data.sources import ConcatSource, DataSource


class DEMResult(NamedTuple):
    global_gmm: GMM
    log_likelihood: jax.Array   # avg loglik over all client data
    n_rounds: jax.Array
    converged: jax.Array
    comm: CommStats


# DEM init schemes: paper numbering <-> FitConfig init-strategy names.
INIT_SCHEME_NAMES = {1: "separated", 2: "pilot", 3: "fed-kmeans"}
INIT_SCHEMES = {v: k for k, v in INIT_SCHEME_NAMES.items()}


def _legacy_init_name(init) -> str:
    """The one legacy-knob rule: paper scheme numbers (1/2/3) and
    FitConfig strategy names are both accepted, anything else is the
    historical error."""
    name = INIT_SCHEME_NAMES.get(init, init)
    if name not in INIT_SCHEMES:
        raise ValueError(f"unknown DEM init scheme {init}")
    return name


def _resolve_init(init: str, sources: bool) -> str:
    """``auto`` keeps the historical per-input defaults: fed-kmeans
    (init 3) for resident splits, separated centers (init 1) for source
    clients (the pilot subset would upload raw rows)."""
    if init == "auto":
        return "separated" if sources else "fed-kmeans"
    if init == "kmeans":
        raise ValueError(
            "init='kmeans' is the single-model GMM init; DEM init "
            "strategies are 'separated' | 'pilot' | 'fed-kmeans' (paper "
            "schemes 1/2/3) or 'auto'")
    return init


def _stats_floats(k: int, d: int, diagonal: bool) -> int:
    """Per-round uplink floats of one client's SufficientStats:
    s0 (k) + s1 (k·d) + s2 (k·d diag / k·d² full) + loglik + wsum."""
    cov = k * d if diagonal else k * d * d
    return k + k * d + cov + 2


# ----------------------------------------------------------------------
# Initializations
# ----------------------------------------------------------------------

def max_separated_centers(key: jax.Array, k: int, d: int,
                          n_candidates: int = 2048) -> jax.Array:
    """Init 1: greedy farthest-point centers in the unit hypercube [0,1]^d
    (features are normalized to [0,1], §5.1)."""
    cand = jax.random.uniform(key, (n_candidates, d))
    center0 = jnp.full((d,), 0.5, cand.dtype)
    centers = jnp.zeros((k, d), cand.dtype).at[0].set(center0)
    min_d = jnp.sum((cand - center0) ** 2, axis=1)

    def body(i, carry):
        centers, min_d = carry
        idx = jnp.argmax(min_d)
        c = cand[idx]
        centers = centers.at[i].set(c)
        min_d = jnp.minimum(min_d, jnp.sum((cand - c) ** 2, axis=1))
        return centers, min_d

    centers, _ = jax.lax.fori_loop(1, k, body, (centers, min_d))
    return centers


def pilot_subset_centers(key: jax.Array, split: ClientSplit, k: int,
                         n_pilot: int = 100) -> jax.Array:
    """Init 2: clients upload a tiny uniform subset (n_pilot points total);
    the server fits a pilot GMM and uses its means. NOTE: uploads raw data."""
    data = jnp.asarray(split.data).reshape(-1, split.data.shape[-1])
    mask = jnp.asarray(split.mask).reshape(-1)
    # weighted sampling without replacement over real (unpadded) rows
    g = jax.random.gumbel(key, mask.shape)
    scores = jnp.where(mask > 0, g, -jnp.inf)
    idx = jax.lax.top_k(scores, n_pilot)[1]
    pilot = data[idx]
    res = fit_gmm(jax.random.fold_in(key, 1), pilot, k, max_iter=100)
    return res.gmm.means


def fed_kmeans_centers(key: jax.Array, split: ClientSplit, k: int,
                       chunk_size: int | None = None) -> jax.Array:
    """Init 3: one-shot federated k-means global centers. ``chunk_size``
    streams the client-side Lloyd sweeps (DESIGN.md §6)."""
    return federated_kmeans(key, jnp.asarray(split.data), k,
                            client_weights=jnp.asarray(split.mask),
                            chunk_size=chunk_size)


# ----------------------------------------------------------------------
# DEM main loop
# ----------------------------------------------------------------------

@partial(jax.jit, static_argnames=("max_rounds", "estep_backend",
                                   "chunk_size"))
def _dem_loop(gmm0: GMM, data: jax.Array, mask: jax.Array, tol: jax.Array,
              reg_covar: float, max_rounds: int,
              estep_backend: str = "auto", chunk_size: int | None = None):
    """data: (C, N, d), mask: (C, N). Aggregation over the client axis is a
    tree-sum here; in the sharded runtime it is a jax.lax.psum. The
    full-batch/chunked dispatch lives in the engine (``e_step_stats``)."""

    def global_stats(gmm: GMM) -> SufficientStats:
        per_client = jax.vmap(
            lambda x, w: e_step_stats(gmm, x, w, estep_backend, chunk_size))(
            data, mask)
        return jax.tree.map(lambda s: jnp.sum(s, axis=0), per_client)

    def cond(state):
        _, prev_ll, ll, it = state
        return jnp.logical_and(it < max_rounds, jnp.abs(ll - prev_ll) > tol)

    def body(state):
        gmm, _, ll, it = state
        stats = global_stats(gmm)
        new_gmm = m_step(stats, reg_covar)
        new_ll = stats.loglik / jnp.maximum(stats.wsum, 1e-12)
        return new_gmm, ll, new_ll, it + 1

    stats0 = global_stats(gmm0)
    gmm1 = m_step(stats0, reg_covar)
    ll0 = stats0.loglik / jnp.maximum(stats0.wsum, 1e-12)
    neg_inf = jnp.array(-jnp.inf, data.dtype)
    state = (gmm1, neg_inf, ll0, jnp.array(1))
    gmm, prev_ll, ll, rounds = jax.lax.while_loop(cond, body, state)
    converged = jnp.abs(ll - prev_ll) <= tol
    return gmm, ll, rounds, converged


def _dem_split_cfg(key: jax.Array, split: ClientSplit, config: FitConfig,
                   k: int, init: str) -> DEMResult:
    """Resident-array DEM round loop (jitted while_loop, tree-sum
    aggregation)."""
    data = jnp.asarray(split.data)
    mask = jnp.asarray(split.mask)
    d = data.shape[-1]
    cs = config.resolve_chunk(source=False)
    k_init, _ = jax.random.split(key)
    if init == "separated":
        centers = max_separated_centers(k_init, k, d)
    elif init == "pilot":
        centers = pilot_subset_centers(k_init, split, k)
    else:  # "fed-kmeans" (validated upstream)
        centers = fed_kmeans_centers(k_init, split, k, chunk_size=cs)

    flat = data.reshape(-1, d)
    flat_w = mask.reshape(-1)
    gmm0 = init_from_means(centers, flat, flat_w,
                           covariance_type=config.covariance_type,
                           reg_covar=config.reg_covar)
    gmm, ll, rounds, converged = _dem_loop(
        gmm0, data, mask, jnp.asarray(config.tol, data.dtype),
        config.reg_covar, config.max_iter, config.backend, cs)

    c = data.shape[0]
    n_rounds = int(rounds)
    comm = CommStats(
        rounds=n_rounds,
        uplink_floats=n_rounds * c * _stats_floats(k, d, config.is_diagonal),
        downlink_floats=n_rounds * c * payload_floats(gmm))
    return DEMResult(gmm, ll, rounds, converged, comm)


def _dem_sources_cfg(key: jax.Array, sources: Sequence[DataSource],
                     config: FitConfig, k: int, init: str) -> DEMResult:
    """DEM with per-client :class:`DataSource` data (DESIGN.md §7).

    Each round, every client streams its own E-step through the engine and
    ships only ``SufficientStats`` — exactly the resident payload — so the
    communication pattern is unchanged while no client (nor the server)
    ever holds O(N) rows. Ragged client sizes need no padding.
    """
    d = sources[0].dim
    cs = config.resolve_chunk(source=True)
    k_init, _ = jax.random.split(key)
    if init == "separated":
        centers = max_separated_centers(k_init, k, d)
    elif init == "fed-kmeans":
        centers = federated_kmeans(k_init, list(sources), k, chunk_size=cs)
    else:  # "pilot" (validated upstream)
        raise ValueError(
            "DEM init 'pilot' uploads raw rows and needs resident client "
            "data; use a ClientSplit for it")

    union = ConcatSource(sources)
    gmm0 = init_from_means(centers, union,
                           covariance_type=config.covariance_type,
                           reg_covar=config.reg_covar, chunk_size=cs)

    def step(gmm: GMM):
        """One DEM round: per-client streamed stats -> sum -> M-step."""
        per = [e_step_stats(gmm, src, None, config.backend, cs)
               for src in sources]
        stats: SufficientStats = jax.tree.map(lambda *s: sum(s), *per)
        avg_ll = float(stats.loglik / jnp.maximum(stats.wsum, 1e-12))
        return m_step(stats, config.reg_covar), avg_ll

    gmm, ll, rounds, converged = host_em_loop(step, gmm0, config.tol,
                                              config.max_iter)

    c = len(sources)
    n_rounds = int(rounds)
    comm = CommStats(
        rounds=n_rounds,
        uplink_floats=n_rounds * c * _stats_floats(k, d, config.is_diagonal),
        downlink_floats=n_rounds * c * payload_floats(gmm))
    return DEMResult(gmm, ll, rounds, converged, comm)


def dem_cfg(key: jax.Array, clients, config: FitConfig, k: int) -> DEMResult:
    """Run DEM — the cfg-core behind ``repro.api.DEM``, dispatching on the
    client input type (:class:`ClientSplit` vs list of
    :class:`DataSource`). The init strategy comes from ``config.init``
    ("auto" resolves to fed-kmeans for splits, separated centers for
    sources; "pilot" requires resident data — it uploads raw rows)."""
    sources = is_source_list(clients)
    init = _resolve_init(config.init, sources)
    if sources:
        return _dem_sources_cfg(key, clients, config, k, init)
    if isinstance(clients, ClientSplit):
        return _dem_split_cfg(key, clients, config, k, init)
    raise TypeError(
        f"dem clients must be a ClientSplit or a list of DataSources, "
        f"got {type(clients).__name__}")


def dem(key: jax.Array, split: ClientSplit, k: int, init: int = 3,
        max_rounds: int = 200, tol: float = 1e-3,
        reg_covar: float = 1e-6, estep_backend: str = "auto",
        chunk_size: int | None = None,
        covariance_type: str = "diag") -> DEMResult:
    """Legacy keyword surface of :func:`dem_cfg` (internal; prefer
    ``repro.api.DEM``). ``init`` takes the paper's scheme numbers 1/2/3
    (or their FitConfig names)."""
    cfg = FitConfig.from_legacy(
        backend=estep_backend, chunk_size=chunk_size,
        covariance_type=covariance_type, reg_covar=reg_covar, tol=tol,
        max_iter=max_rounds, init=_legacy_init_name(init))
    return dem_cfg(key, split, cfg, k)


def dem_from_sources(key: jax.Array, sources: Sequence[DataSource], k: int,
                     init: int = 1, max_rounds: int = 200, tol: float = 1e-3,
                     reg_covar: float = 1e-6, estep_backend: str = "auto",
                     chunk_size: int | None = None,
                     covariance_type: str = "diag") -> DEMResult:
    """Deprecated: ``repro.api.DEM(k).run(sources)`` dispatches on the
    input type, so the separate ``_from_sources`` spelling is obsolete.
    This shim forwards to the facade (bit-identical result) and will be
    removed."""
    warnings.warn(
        "dem_from_sources is deprecated; use repro.api.DEM(k).run(sources) "
        "— same engine, same bits",
        DeprecationWarning, stacklevel=2)
    from repro.api import DEM  # facade sits above core; lazy
    runner = DEM(k, config=FitConfig.from_legacy(
        backend=estep_backend, chunk_size=chunk_size,
        covariance_type=covariance_type, reg_covar=reg_covar, tol=tol,
        max_iter=max_rounds, init=_legacy_init_name(init)))
    return runner.run(list(sources), key=key)
