"""Distributed EM (DEM) baselines (§5.4 of the paper, after Wu et al. '23).

Every client runs the E-step locally and ships sufficient statistics; the
server aggregates (a psum in the sharded runtime), runs the M-step, and
broadcasts the new parameters. One EM iteration = one communication round.

Three initializations of the global component centers are reproduced:
  init 1 — maximally separated centers in the (normalized) feature range,
  init 2 — pilot GMM on a small (100-point) subset uploaded to the server,
  init 3 — one-shot federated k-means (Dennis et al. '21).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core.em import (SufficientStats, e_step_stats, fit_gmm,
                           host_em_loop, init_from_means, m_step)
from repro.core.fedgen import CommStats, payload_floats
from repro.core.gmm import GMM
from repro.core.kmeans import federated_kmeans, federated_kmeans_from_sources
from repro.core.partition import ClientSplit
from repro.data.sources import ConcatSource, DataSource


class DEMResult(NamedTuple):
    global_gmm: GMM
    log_likelihood: jax.Array   # avg loglik over all client data
    n_rounds: jax.Array
    converged: jax.Array
    comm: CommStats


# ----------------------------------------------------------------------
# Initializations
# ----------------------------------------------------------------------

def max_separated_centers(key: jax.Array, k: int, d: int,
                          n_candidates: int = 2048) -> jax.Array:
    """Init 1: greedy farthest-point centers in the unit hypercube [0,1]^d
    (features are normalized to [0,1], §5.1)."""
    cand = jax.random.uniform(key, (n_candidates, d))
    center0 = jnp.full((d,), 0.5, cand.dtype)
    centers = jnp.zeros((k, d), cand.dtype).at[0].set(center0)
    min_d = jnp.sum((cand - center0) ** 2, axis=1)

    def body(i, carry):
        centers, min_d = carry
        idx = jnp.argmax(min_d)
        c = cand[idx]
        centers = centers.at[i].set(c)
        min_d = jnp.minimum(min_d, jnp.sum((cand - c) ** 2, axis=1))
        return centers, min_d

    centers, _ = jax.lax.fori_loop(1, k, body, (centers, min_d))
    return centers


def pilot_subset_centers(key: jax.Array, split: ClientSplit, k: int,
                         n_pilot: int = 100) -> jax.Array:
    """Init 2: clients upload a tiny uniform subset (n_pilot points total);
    the server fits a pilot GMM and uses its means. NOTE: uploads raw data."""
    data = jnp.asarray(split.data).reshape(-1, split.data.shape[-1])
    mask = jnp.asarray(split.mask).reshape(-1)
    # weighted sampling without replacement over real (unpadded) rows
    g = jax.random.gumbel(key, mask.shape)
    scores = jnp.where(mask > 0, g, -jnp.inf)
    idx = jax.lax.top_k(scores, n_pilot)[1]
    pilot = data[idx]
    res = fit_gmm(jax.random.fold_in(key, 1), pilot, k, max_iter=100)
    return res.gmm.means


def fed_kmeans_centers(key: jax.Array, split: ClientSplit, k: int,
                       chunk_size: int | None = None) -> jax.Array:
    """Init 3: one-shot federated k-means global centers. ``chunk_size``
    streams the client-side Lloyd sweeps (DESIGN.md §6)."""
    return federated_kmeans(key, jnp.asarray(split.data), k,
                            client_weights=jnp.asarray(split.mask),
                            chunk_size=chunk_size)


# ----------------------------------------------------------------------
# DEM main loop
# ----------------------------------------------------------------------

@partial(jax.jit, static_argnames=("max_rounds", "estep_backend",
                                   "chunk_size"))
def _dem_loop(gmm0: GMM, data: jax.Array, mask: jax.Array, tol: jax.Array,
              reg_covar: float, max_rounds: int,
              estep_backend: str = "auto", chunk_size: int | None = None):
    """data: (C, N, d), mask: (C, N). Aggregation over the client axis is a
    tree-sum here; in the sharded runtime it is a jax.lax.psum. The
    full-batch/chunked dispatch lives in the engine (``e_step_stats``)."""

    def global_stats(gmm: GMM) -> SufficientStats:
        per_client = jax.vmap(
            lambda x, w: e_step_stats(gmm, x, w, estep_backend, chunk_size))(
            data, mask)
        return jax.tree.map(lambda s: jnp.sum(s, axis=0), per_client)

    def cond(state):
        _, prev_ll, ll, it = state
        return jnp.logical_and(it < max_rounds, jnp.abs(ll - prev_ll) > tol)

    def body(state):
        gmm, _, ll, it = state
        stats = global_stats(gmm)
        new_gmm = m_step(stats, reg_covar)
        new_ll = stats.loglik / jnp.maximum(stats.wsum, 1e-12)
        return new_gmm, ll, new_ll, it + 1

    stats0 = global_stats(gmm0)
    gmm1 = m_step(stats0, reg_covar)
    ll0 = stats0.loglik / jnp.maximum(stats0.wsum, 1e-12)
    neg_inf = jnp.array(-jnp.inf, data.dtype)
    state = (gmm1, neg_inf, ll0, jnp.array(1))
    gmm, prev_ll, ll, rounds = jax.lax.while_loop(cond, body, state)
    converged = jnp.abs(ll - prev_ll) <= tol
    return gmm, ll, rounds, converged


def dem(key: jax.Array, split: ClientSplit, k: int, init: int = 3,
        max_rounds: int = 200, tol: float = 1e-3,
        reg_covar: float = 1e-6, estep_backend: str = "auto",
        chunk_size: int | None = None) -> DEMResult:
    """Run DEM with the requested initialization scheme (1, 2 or 3).

    ``estep_backend``/``chunk_size`` select the per-client E-step engine
    (DESIGN.md §6), matching ``dem_sharded`` so baseline comparisons run
    the same engine as FedGenGMM.
    """
    data = jnp.asarray(split.data)
    mask = jnp.asarray(split.mask)
    d = data.shape[-1]
    k_init, _ = jax.random.split(key)
    if init == 1:
        centers = max_separated_centers(k_init, k, d)
    elif init == 2:
        centers = pilot_subset_centers(k_init, split, k)
    elif init == 3:
        centers = fed_kmeans_centers(k_init, split, k, chunk_size=chunk_size)
    else:
        raise ValueError(f"unknown DEM init scheme {init}")

    flat = data.reshape(-1, d)
    flat_w = mask.reshape(-1)
    gmm0 = init_from_means(centers, flat, flat_w, reg_covar=reg_covar)
    gmm, ll, rounds, converged = _dem_loop(
        gmm0, data, mask, jnp.asarray(tol, data.dtype), reg_covar, max_rounds,
        estep_backend, chunk_size)

    c = data.shape[0]
    stats_floats = k + 2 * k * d + 2  # s0, s1, s2 (diag), loglik, wsum
    n_rounds = int(rounds)
    comm = CommStats(
        rounds=n_rounds,
        uplink_floats=n_rounds * c * stats_floats,
        downlink_floats=n_rounds * c * payload_floats(gmm))
    return DEMResult(gmm, ll, rounds, converged, comm)


def dem_from_sources(key: jax.Array, sources: Sequence[DataSource], k: int,
                     init: int = 1, max_rounds: int = 200, tol: float = 1e-3,
                     reg_covar: float = 1e-6, estep_backend: str = "auto",
                     chunk_size: int | None = None) -> DEMResult:
    """DEM with per-client :class:`DataSource` data (DESIGN.md §7).

    Each round, every client streams its own E-step through the engine and
    ships only ``SufficientStats`` — exactly the payload of :func:`dem` —
    so the communication pattern is unchanged while no client (nor the
    server) ever holds O(N) rows. Ragged client sizes need no padding.

    Supports init 1 (maximally separated centers; needs only ``d``) and
    init 3 (one-shot federated k-means, itself streamed per client).
    Init 2 uploads a raw pilot subset and therefore requires resident
    client arrays — use :func:`dem` for it.
    """
    d = sources[0].dim
    k_init, _ = jax.random.split(key)
    if init == 1:
        centers = max_separated_centers(k_init, k, d)
    elif init == 3:
        centers = federated_kmeans_from_sources(k_init, sources, k,
                                                chunk_size=chunk_size)
    elif init == 2:
        raise ValueError(
            "DEM init 2 (pilot subset) uploads raw rows and needs resident "
            "client data; use dem() with a ClientSplit")
    else:
        raise ValueError(f"unknown DEM init scheme {init}")

    union = ConcatSource(sources)
    gmm0 = init_from_means(centers, union, reg_covar=reg_covar,
                           chunk_size=chunk_size)

    def step(gmm: GMM):
        """One DEM round: per-client streamed stats -> sum -> M-step."""
        per = [e_step_stats(gmm, src, None, estep_backend, chunk_size)
               for src in sources]
        stats: SufficientStats = jax.tree.map(lambda *s: sum(s), *per)
        avg_ll = float(stats.loglik / jnp.maximum(stats.wsum, 1e-12))
        return m_step(stats, reg_covar), avg_ll

    gmm, ll, rounds, converged = host_em_loop(step, gmm0, tol, max_rounds)

    c = len(sources)
    n_rounds = int(rounds)
    stats_floats = k + 2 * k * d + 2  # s0, s1, s2 (diag), loglik, wsum
    comm = CommStats(
        rounds=n_rounds,
        uplink_floats=n_rounds * c * stats_floats,
        downlink_floats=n_rounds * c * payload_floats(gmm))
    return DEMResult(gmm, ll, rounds, converged, comm)
