"""Expectation-Maximization for GMMs (weighted, jit-compiled, while_loop
convergence) plus BIC-based model selection — the TrainGMM procedure of
Algorithm 4.1.

Sample weights make padded/ragged federated client datasets representable as
fixed-shape arrays (weight 0 = padding), which is what lets local training
run under vmap/shard_map.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.gmm import GMM
from repro.core.kmeans import kmeans_multi


class EMResult(NamedTuple):
    gmm: GMM
    log_likelihood: jax.Array  # final average log-likelihood
    n_iter: jax.Array
    converged: jax.Array


class SufficientStats(NamedTuple):
    """Weighted sufficient statistics of one E-step.

    s0 : (K,)     sum_n w_n r_nk
    s1 : (K, d)   sum_n w_n r_nk x_n
    s2 : (K, d) or (K, d, d)   sum_n w_n r_nk x_n x_n(^T)
    loglik : ()   weighted total log-likelihood
    wsum : ()     total sample weight
    """
    s0: jax.Array
    s1: jax.Array
    s2: jax.Array
    loglik: jax.Array
    wsum: jax.Array


# ----------------------------------------------------------------------
# E / M steps
# ----------------------------------------------------------------------

ESTEP_BACKENDS = ("auto", "reference", "fused")


def resolve_estep_backend(estep_backend: str, is_diagonal: bool) -> str:
    """Resolve the user-facing backend knob to a concrete implementation.

    ``auto`` picks the fused Pallas kernel when it can win (diagonal
    covariance on a TPU backend); interpret mode on CPU is bit-compatible
    but much slower than XLA, so ``auto`` keeps the reference path there.
    The fused kernel only implements diagonal covariance, so full
    covariance always falls back to reference semantics (DESIGN.md §6).
    """
    if estep_backend not in ESTEP_BACKENDS:
        raise ValueError(
            f"estep_backend must be one of {ESTEP_BACKENDS}, "
            f"got {estep_backend!r}")
    if not is_diagonal:
        return "reference"
    if estep_backend == "auto":
        return "fused" if jax.default_backend() == "tpu" else "reference"
    return estep_backend


def _e_step_stats_reference(gmm: GMM, x: jax.Array,
                            w: jax.Array) -> SufficientStats:
    """Pure-jnp E-step: materializes the (N, K) responsibility matrix."""
    lp = gmm.component_log_prob(x) + jnp.log(gmm.weights)[None, :]   # (N, K)
    log_norm = jax.scipy.special.logsumexp(lp, axis=1)               # (N,)
    resp = jnp.exp(lp - log_norm[:, None]) * w[:, None]              # (N, K)
    s0 = jnp.sum(resp, axis=0)                                       # (K,)
    s1 = resp.T @ x                                                  # (K, d)
    if gmm.is_diagonal:
        s2 = resp.T @ (x * x)                                        # (K, d)
    else:
        s2 = jnp.einsum("nk,ni,nj->kij", resp, x, x)                 # (K, d, d)
    loglik = jnp.sum(log_norm * w)
    return SufficientStats(s0, s1, s2, loglik, jnp.sum(w))


def e_step_stats(gmm: GMM, x: jax.Array,
                 sample_weight: Optional[jax.Array] = None,
                 estep_backend: str = "auto") -> SufficientStats:
    """One E-step: responsibilities -> sufficient statistics.

    This is the communication payload of DEM (each client computes local
    stats; the server psums them) and the compute hot spot. The
    ``estep_backend`` knob dispatches between the pure-jnp reference path
    and the fused Pallas kernel (``repro.kernels.ops.estep_stats``), which
    never materializes the (N, K) responsibility matrix.
    """
    n = x.shape[0]
    w = jnp.ones(n, x.dtype) if sample_weight is None else sample_weight
    backend = resolve_estep_backend(estep_backend, gmm.is_diagonal)
    if backend == "fused":
        return e_step_stats_fused(gmm, x, w)
    return _e_step_stats_reference(gmm, x, w)


def e_step_stats_fused(gmm: GMM, x: jax.Array,
                       sample_weight: Optional[jax.Array] = None,
                       interpret: Optional[bool] = None) -> SufficientStats:
    """Kernel-backed E-step (diagonal covariance only): the Pallas
    ``estep_stats`` kernel fuses log-pdf -> softmax -> reductions in VMEM.
    Semantically identical to :func:`e_step_stats`; used on TPU where the
    (N, K) responsibility matrix would otherwise round-trip HBM."""
    from repro.kernels import ops  # local import: kernels are optional
    assert gmm.is_diagonal, "fused E-step kernel supports diagonal covariance"
    n = x.shape[0]
    w = jnp.ones(n, x.dtype) if sample_weight is None else sample_weight
    s0, s1, s2, ll = ops.estep_stats(x, gmm.means, gmm.covs,
                                     jnp.log(gmm.weights), w,
                                     interpret=interpret)
    return SufficientStats(s0, s1, s2, ll, jnp.sum(w))


def e_step_stats_chunked(gmm: GMM, x: jax.Array,
                         sample_weight: Optional[jax.Array] = None,
                         chunk_size: int = 4096,
                         estep_backend: str = "auto") -> SufficientStats:
    """Constant-memory E-step: ``lax.scan`` over fixed-size row chunks.

    ``SufficientStats`` is additive in N, so the full-batch statistics are
    the chunk-wise sum — the working set is one (chunk_size, K) block
    instead of the whole (N, K) responsibility matrix. Rows are padded to a
    multiple of ``chunk_size`` with zero sample weight, which contributes
    exactly zero to every field. Accumulation runs at least in float32
    (``promote_types(x.dtype, float32)``, so f64 stays f64 under x64); the
    result is cast back to ``x.dtype`` so downstream loops see the same
    dtypes as the full-batch path. Caveat: the *fused* backend computes
    each chunk in f32 regardless (the kernel packs params as f32), so f64
    precision is only preserved end-to-end on the reference backend.
    """
    n, d = x.shape
    k = gmm.n_components
    chunk_size = int(chunk_size)
    if chunk_size <= 0:
        raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    w = jnp.ones(n, x.dtype) if sample_weight is None else sample_weight
    n_chunks = -(-n // chunk_size)
    pad = n_chunks * chunk_size - n
    xc = jnp.pad(x, ((0, pad), (0, 0))).reshape(n_chunks, chunk_size, d)
    wc = jnp.pad(w, (0, pad)).reshape(n_chunks, chunk_size)
    acc_dtype = jnp.promote_types(x.dtype, jnp.float32)
    s2_shape = (k, d) if gmm.is_diagonal else (k, d, d)
    init = SufficientStats(
        jnp.zeros((k,), acc_dtype), jnp.zeros((k, d), acc_dtype),
        jnp.zeros(s2_shape, acc_dtype), jnp.zeros((), acc_dtype),
        jnp.zeros((), acc_dtype))

    def body(carry, chunk):
        xb, wb = chunk
        s = e_step_stats(gmm, xb, wb, estep_backend=estep_backend)
        carry = jax.tree.map(lambda acc, v: acc + v.astype(acc.dtype),
                             carry, s)
        return carry, None

    stats, _ = jax.lax.scan(body, init, (xc, wc))
    return jax.tree.map(lambda s: s.astype(x.dtype), stats)


def m_step(stats: SufficientStats, reg_covar: float = 1e-6) -> GMM:
    """M-step from (possibly aggregated) sufficient statistics."""
    s0 = jnp.maximum(stats.s0, 1e-10)
    weights = stats.s0 / jnp.maximum(stats.wsum, 1e-12)
    weights = weights / jnp.sum(weights)
    means = stats.s1 / s0[:, None]
    if stats.s2.ndim == 2:  # diagonal
        covs = stats.s2 / s0[:, None] - means * means
        covs = jnp.maximum(covs, 0.0) + reg_covar
    else:
        outer = jnp.einsum("ki,kj->kij", means, means)
        covs = stats.s2 / s0[:, None, None] - outer
        # robustness against component collapse (few near-colinear points):
        # symmetrize, sanitize non-finite, floor the diagonal — the EM
        # iteration then reassigns mass instead of diverging to NaN
        covs = 0.5 * (covs + jnp.swapaxes(covs, -1, -2))
        covs = jnp.where(jnp.isfinite(covs), covs, 0.0)
        d = means.shape[1]
        eye = jnp.eye(d, dtype=means.dtype)[None]
        covs = covs + reg_covar * eye
        diag = jnp.maximum(jnp.diagonal(covs, axis1=-2, axis2=-1), reg_covar)
        covs = covs * (1.0 - eye) + diag[..., None] * eye
    means = jnp.where(jnp.isfinite(means), means, 0.0)
    return GMM(weights, means, covs)


def em_step(gmm: GMM, x: jax.Array, sample_weight: Optional[jax.Array] = None,
            reg_covar: float = 1e-6, estep_backend: str = "auto",
            chunk_size: Optional[int] = None) -> tuple[GMM, jax.Array]:
    """One full EM iteration. Returns (new_gmm, avg_loglik_of_old_gmm).

    ``chunk_size=None`` runs the whole batch in one E-step; an integer
    streams it through :func:`e_step_stats_chunked` in bounded memory.
    """
    if chunk_size is None:
        stats = e_step_stats(gmm, x, sample_weight, estep_backend)
    else:
        stats = e_step_stats_chunked(gmm, x, sample_weight, chunk_size,
                                     estep_backend)
    avg_ll = stats.loglik / jnp.maximum(stats.wsum, 1e-12)
    return m_step(stats, reg_covar), avg_ll


# ----------------------------------------------------------------------
# Initialization
# ----------------------------------------------------------------------

def init_from_kmeans(key: jax.Array, x: jax.Array, k: int,
                     sample_weight: Optional[jax.Array] = None,
                     covariance_type: str = "diag",
                     reg_covar: float = 1e-6) -> GMM:
    """sklearn-style init: k-means labels -> one-hot responsibilities -> M-step."""
    n = x.shape[0]
    w = jnp.ones(n, x.dtype) if sample_weight is None else sample_weight
    res = kmeans_multi(key, x, k, sample_weight=w, max_iter=50)
    resp = jax.nn.one_hot(res.assignments, k, dtype=x.dtype) * w[:, None]
    s0 = jnp.sum(resp, axis=0)
    s1 = resp.T @ x
    s2 = resp.T @ (x * x) if covariance_type == "diag" else jnp.einsum(
        "nk,ni,nj->kij", resp, x, x)
    stats = SufficientStats(s0, s1, s2, jnp.array(0.0, x.dtype), jnp.sum(w))
    return m_step(stats, reg_covar)


def init_from_means(means: jax.Array, x: jax.Array,
                    sample_weight: Optional[jax.Array] = None,
                    covariance_type: str = "diag",
                    reg_covar: float = 1e-6) -> GMM:
    """Init with given centers, uniform weights, data-variance covariances.

    Used by the DEM baselines, where the server proposes centers without
    seeing client data.
    """
    k, d = means.shape
    n = x.shape[0]
    w = jnp.ones(n, x.dtype) if sample_weight is None else sample_weight
    wsum = jnp.maximum(jnp.sum(w), 1e-12)
    mean = jnp.sum(x * w[:, None], axis=0) / wsum
    var = jnp.sum((x - mean) ** 2 * w[:, None], axis=0) / wsum + reg_covar
    weights = jnp.full((k,), 1.0 / k, x.dtype)
    if covariance_type == "diag":
        covs = jnp.broadcast_to(var, (k, d))
    else:
        covs = jnp.broadcast_to(jnp.diag(var), (k, d, d))
    return GMM(weights, means, covs)


# ----------------------------------------------------------------------
# Full EM fit
# ----------------------------------------------------------------------

@partial(jax.jit, static_argnames=("max_iter", "estep_backend", "chunk_size"))
def _em_loop(gmm0: GMM, x: jax.Array, w: jax.Array, tol: float,
             reg_covar: float, max_iter: int, estep_backend: str = "auto",
             chunk_size: Optional[int] = None):
    def cond(state):
        _, prev_ll, ll, it = state
        return jnp.logical_and(it < max_iter, jnp.abs(ll - prev_ll) > tol)

    def body(state):
        gmm, _, ll, it = state
        new_gmm, avg_ll = em_step(gmm, x, w, reg_covar, estep_backend,
                                  chunk_size)
        return new_gmm, ll, avg_ll, it + 1

    neg_inf = jnp.array(-jnp.inf, x.dtype)
    # Bootstrap: one step to get an initial loglik.
    gmm1, ll0 = em_step(gmm0, x, w, reg_covar, estep_backend, chunk_size)
    state = (gmm1, neg_inf, ll0, jnp.array(1))
    gmm, prev_ll, ll, it = jax.lax.while_loop(cond, body, state)
    converged = jnp.abs(ll - prev_ll) <= tol
    return gmm, ll, it, converged


def fit_gmm(key: jax.Array, x: jax.Array, k: int,
            sample_weight: Optional[jax.Array] = None,
            covariance_type: str = "diag",
            max_iter: int = 200, tol: float = 1e-3,
            reg_covar: float = 1e-6,
            init_gmm: Optional[GMM] = None,
            estep_backend: str = "auto",
            chunk_size: Optional[int] = None) -> EMResult:
    """Train a GMM with EM until the avg-loglik delta drops below ``tol``
    (the paper's convergence criterion, 1e-3).

    ``estep_backend`` selects the E-step implementation (DESIGN.md §6);
    ``chunk_size`` streams the E-step in bounded memory.
    """
    n = x.shape[0]
    w = jnp.ones(n, x.dtype) if sample_weight is None else sample_weight
    # Validate eagerly: _em_loop sees the knob as a static jit arg and a
    # typo'd value would otherwise surface as an opaque trace-time error.
    resolve_estep_backend(estep_backend, covariance_type == "diag"
                          if init_gmm is None else init_gmm.is_diagonal)
    if init_gmm is None:
        init_gmm = init_from_kmeans(key, x, k, w, covariance_type, reg_covar)
    gmm, ll, it, converged = _em_loop(init_gmm, x, w, jnp.asarray(tol, x.dtype),
                                      reg_covar, max_iter, estep_backend,
                                      chunk_size)
    return EMResult(gmm, ll, it, converged)


def fit_gmm_streaming(key: jax.Array, x: jax.Array, k: int,
                      sample_weight: Optional[jax.Array] = None,
                      covariance_type: str = "diag",
                      max_iter: int = 200, tol: float = 1e-3,
                      reg_covar: float = 1e-6,
                      init_gmm: Optional[GMM] = None,
                      estep_backend: str = "auto",
                      chunk_size: int = 4096) -> EMResult:
    """Streaming EM: every E-step scans (chunk_size, d) slices, so the
    peak working set is O(chunk_size * K) instead of O(N * K) and N is no
    longer bounded by one resident responsibility matrix. Mathematically
    identical to :func:`fit_gmm` (chunk sums reorder float additions only).
    """
    return fit_gmm(key, x, k, sample_weight=sample_weight,
                   covariance_type=covariance_type, max_iter=max_iter,
                   tol=tol, reg_covar=reg_covar, init_gmm=init_gmm,
                   estep_backend=estep_backend, chunk_size=int(chunk_size))


def fit_gmm_bic(key: jax.Array, x: jax.Array, k_candidates: Sequence[int],
                sample_weight: Optional[jax.Array] = None,
                covariance_type: str = "diag",
                max_iter: int = 200, tol: float = 1e-3,
                reg_covar: float = 1e-6,
                estep_backend: str = "auto",
                chunk_size: Optional[int] = None) -> tuple[EMResult,
                                                           dict[int, float]]:
    """TrainGMM of Algorithm 4.1: fit every K in the candidate range, return
    the fit minimizing BIC (plus all BIC scores)."""
    best, best_bic, bics = None, jnp.inf, {}
    for i, k in enumerate(k_candidates):
        res = fit_gmm(jax.random.fold_in(key, i), x, k, sample_weight,
                      covariance_type, max_iter, tol, reg_covar,
                      estep_backend=estep_backend, chunk_size=chunk_size)
        b = float(res.gmm.bic(x, sample_weight))
        bics[k] = b
        if b < best_bic:
            best, best_bic = res, b
    return best, bics
