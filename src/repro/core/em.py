"""Expectation-Maximization for GMMs (weighted, jit-compiled, while_loop
convergence) plus BIC-based model selection — the TrainGMM procedure of
Algorithm 4.1.

This module also owns the **streaming-statistics engine** (DESIGN.md §6):
one generic ``lax.scan``-over-row-chunks reduction (:func:`streaming_reduce`
/ :func:`streaming_map_reduce`) plus the single ``chunk_size is None`` →
full-batch / chunked dispatch (:func:`reduce_rows`). The E-step, the k-means
Lloyd sweeps (``repro.core.kmeans``), the k-means-init label statistics and
the log-likelihood/BIC scoring reductions below all run through it, so the
whole TrainGMM pipeline — init, EM, model selection — has an O(chunk·K)
constant-memory mode.

Every engine entry point also accepts a :class:`repro.data.sources.DataSource`
in the rows position (DESIGN.md §7): sources drive a **host-side block
loop** over ``iter_blocks(chunk_size)`` with jitted per-block statistics
instead of a ``lax.scan`` over a resident reshaped array, so N never has to
be resident at all — the out-of-core mode. The same additivity argument
applies; block sums accumulate in the same order with the same per-block
math, so source-backed fits are bit-reproducible across source types
holding the same rows.

Sample weights make padded/ragged federated client datasets representable as
fixed-shape arrays (weight 0 = padding), which is what lets local training
run under vmap/shard_map — and what lets the engine pad row counts to chunk
boundaries for free (zero-weight rows contribute exactly zero to every
statistic).
"""
from __future__ import annotations

import warnings
from functools import partial
from typing import Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.config import (DEFAULT_SOURCE_CHUNK, ENGINE_BACKENDS,
                               FitConfig, require_array_weights,
                               resolve_backend, resolve_estep_backend,
                               resolve_source_chunk)
from repro.core.gmm import GMM
from repro.data.sources import DataSource, prefetch_blocks


class EMResult(NamedTuple):
    gmm: GMM
    log_likelihood: jax.Array  # final average log-likelihood
    n_iter: jax.Array
    converged: jax.Array


class SufficientStats(NamedTuple):
    """Weighted sufficient statistics of one E-step.

    s0 : (K,)     sum_n w_n r_nk
    s1 : (K, d)   sum_n w_n r_nk x_n
    s2 : (K, d) or (K, d, d)   sum_n w_n r_nk x_n x_n(^T)
    loglik : ()   weighted total log-likelihood
    wsum : ()     total sample weight
    """
    s0: jax.Array
    s1: jax.Array
    s2: jax.Array
    loglik: jax.Array
    wsum: jax.Array


# ----------------------------------------------------------------------
# Streaming-statistics engine (DESIGN.md §6)
# ----------------------------------------------------------------------
# The backend/chunk resolvers and the FitConfig they fold into live in
# ``repro.core.config`` (below this module); re-exported here because this
# module has been their historical public home since PR 1.

ESTEP_BACKENDS = ENGINE_BACKENDS  # historical alias (PR 1 public name)

_require_no_weight = require_array_weights  # historical internal name


def _pad_to_chunks(arrays: Sequence[jax.Array], chunk_size: int):
    """Zero-pad leading axis N to a chunk multiple, reshape to
    (n_chunks, chunk_size, ...). Zero padding is safe because every engine
    statistic weights rows by a sample weight that pads to zero."""
    chunk_size = int(chunk_size)
    if chunk_size <= 0:
        raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    n = arrays[0].shape[0]
    n_chunks = -(-n // chunk_size)
    pad = n_chunks * chunk_size - n
    return tuple(
        jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1)).reshape(
            (n_chunks, chunk_size) + a.shape[1:]) for a in arrays)


# Module-level jitted promote/accumulate for the host block loop: ONE
# dispatch per block (not one per stats leaf), and the trace cache is keyed
# only on the stats pytree structure — never on the block index.

@jax.jit
def _promote_stats(stats):
    return jax.tree.map(
        lambda s: s.astype(jnp.promote_types(s.dtype, jnp.float32)), stats)


@jax.jit
def _acc_stats(acc, stats):
    return jax.tree.map(lambda a, s: a + s.astype(a.dtype), acc, stats)


def _source_map_reduce(block_fn: Callable, source: DataSource,
                       chunk_size: int):
    """Host-side twin of the ``lax.scan`` path for :class:`DataSource` rows.

    ``block_fn(x_block, w_block) -> (stats, per_row)`` with the same
    additive-stats / per-row contract and the same
    accumulate-in-f32-then-cast-back dtype semantics as
    :func:`streaming_map_reduce`. Blocks arrive through
    :func:`repro.data.sources.prefetch_blocks`: every block is padded to
    one static shape with a 0/1 row-weight mask (``w_block``) marking real
    rows, and the next block's host-side work (paging, generation,
    padding, ``jax.device_put``) overlaps device compute on the current
    one. ``block_fn`` must be a module-level jitted function that weights
    every per-row contribution by ``w_block`` — then it compiles exactly
    once per chunk shape, ragged tail included, and padded rows contribute
    exact zeros to every statistic. Accumulation stays strictly in block
    order, so source-backed fits remain bit-identical across source types
    holding the same rows.
    """
    acc = rows_dtypes = None
    rows_parts: list = []
    n_blocks = 0
    for xb, wb in prefetch_blocks(source, chunk_size):
        stats, rows = block_fn(xb, wb)
        if n_blocks == 0:
            rows_dtypes = jax.tree.map(lambda s: s.dtype, stats)
            acc = _promote_stats(stats)
        else:
            acc = _acc_stats(acc, stats)
        rows_parts.append(rows)
        n_blocks += 1
    if n_blocks == 0:
        raise ValueError(f"source yielded no blocks: {source!r}")
    stats = jax.tree.map(lambda a, dt: a.astype(dt), acc, rows_dtypes)
    # Per-row outputs carry the pad rows; concatenate, then trim back to N
    # (padding only ever trails the final block).
    rows = jax.tree.map(
        lambda *parts: jnp.concatenate(parts, axis=0)[:source.num_rows],
        *rows_parts)
    return stats, rows


def streaming_map_reduce(block_fn: Callable, arrays, chunk_size: int,
                         scan_width: int = 1):
    """Scan ``block_fn`` over fixed-size row chunks of ``arrays``.

    ``block_fn(*chunk_arrays) -> (stats, per_row)`` where ``stats`` is an
    additive pytree (summed across chunks; pass ``()`` for map-only) and
    ``per_row`` is a pytree of per-row outputs (stacked across chunks and
    truncated back to N rows; pass ``()`` for reduce-only).

    The working set is one chunk, not N: this is the constant-memory core
    every streaming path shares. Stats accumulate at least in float32
    (f64 stays f64 under x64) and are cast back to ``block_fn``'s output
    dtypes, so callers see the same dtypes as a full-batch call.

    ``scan_width > 1`` runs a **2-level scan**: the scan steps over
    super-chunks of ``scan_width`` chunks, evaluating ``block_fn`` on the
    width axis under ``vmap`` — same O(width·chunk) working set per step,
    but the chunk-level work is exposed to XLA as one batched computation
    instead of a serial carry chain. Per-super-chunk stats are summed over
    the width axis, so reduction *order* differs from ``scan_width=1``
    (f32-rounding-level differences, not bit-identity) — the default
    width of 1 is therefore part of the reproducibility contract.

    ``arrays`` may instead be a single :class:`DataSource`, in which case
    ``block_fn`` receives ``(block, row_mask)`` per call and the reduction
    runs as a host-side prefetching block loop (:func:`_source_map_reduce`)
    instead of a ``lax.scan`` — same contract, no resident N
    (``scan_width`` does not apply: blocks arrive one at a time).
    """
    if isinstance(arrays, DataSource):
        return _source_map_reduce(block_fn, arrays, int(chunk_size))
    n = arrays[0].shape[0]
    chunks = _pad_to_chunks(arrays, chunk_size)
    if scan_width > 1:
        return _two_level_map_reduce(block_fn, chunks, int(scan_width), n)
    stats_shape, _ = jax.eval_shape(block_fn, *(c[0] for c in chunks))
    init = jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.promote_types(s.dtype, jnp.float32)),
        stats_shape)

    def body(carry, chunk):
        stats, rows = block_fn(*chunk)
        carry = jax.tree.map(lambda acc, v: acc + v.astype(acc.dtype),
                             carry, stats)
        return carry, rows

    stats, rows = jax.lax.scan(body, init, chunks)
    stats = jax.tree.map(lambda acc, s: acc.astype(s.dtype),
                         stats, stats_shape)
    rows = jax.tree.map(lambda r: r.reshape((-1,) + r.shape[2:])[:n], rows)
    return stats, rows


def _two_level_map_reduce(block_fn: Callable, chunks, width: int, n: int):
    """scan-of-vmapped-chunks: group the (m, chunk, ...) chunk stack into
    (outer, width, chunk, ...) super-chunks (zero-chunk padding at the end
    — safe for the same weight-0 reason as row padding) and reduce
    ``block_fn`` over the width axis inside each scan step."""
    m = chunks[0].shape[0]
    outer = -(-m // width)
    pad = outer * width - m
    supers = tuple(
        jnp.pad(c, ((0, pad),) + ((0, 0),) * (c.ndim - 1)).reshape(
            (outer, width) + c.shape[1:]) for c in chunks)
    stats_shape, _ = jax.eval_shape(block_fn, *(c[0][0] for c in supers))
    init = jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.promote_types(s.dtype, jnp.float32)),
        stats_shape)

    def body(carry, super_chunk):
        stats, rows = jax.vmap(block_fn)(*super_chunk)
        carry = jax.tree.map(
            lambda acc, v: acc + jnp.sum(v.astype(acc.dtype), axis=0),
            carry, stats)
        return carry, rows

    stats, rows = jax.lax.scan(body, init, supers)
    stats = jax.tree.map(lambda acc, s: acc.astype(s.dtype),
                         stats, stats_shape)
    rows = jax.tree.map(lambda r: r.reshape((-1,) + r.shape[3:])[:n], rows)
    return stats, rows


def streaming_reduce(block_fn: Callable, arrays, chunk_size: int,
                     scan_width: int = 1):
    """Reduce-only :func:`streaming_map_reduce`: sum ``block_fn``'s additive
    pytree over all row chunks (arrays or a :class:`DataSource`)."""
    stats, _ = streaming_map_reduce(lambda *a: (block_fn(*a), ()),
                                    arrays, chunk_size, scan_width)
    return stats


def reduce_rows(block_fn: Callable, arrays,
                chunk_size: Optional[int] = None):
    """THE chunk dispatch (previously copy-pasted across em/dem/fed):
    ``chunk_size is None`` runs one full-batch call, an integer streams
    fixed-size chunks through :func:`streaming_reduce`. A
    :class:`DataSource` in the ``arrays`` position always streams
    (``chunk_size=None`` falls back to :data:`DEFAULT_SOURCE_CHUNK` — a
    source has no full batch to run)."""
    if isinstance(arrays, DataSource):
        return streaming_reduce(block_fn, arrays,
                                resolve_source_chunk(chunk_size))
    if chunk_size is None:
        return block_fn(*arrays)
    return streaming_reduce(block_fn, arrays, chunk_size)


# ----------------------------------------------------------------------
# E / M steps
# ----------------------------------------------------------------------

def _e_step_stats_reference(gmm: GMM, x: jax.Array,
                            w: jax.Array) -> SufficientStats:
    """Pure-jnp E-step: materializes the (N, K) responsibility matrix."""
    lp = gmm.component_log_prob(x) + jnp.log(gmm.weights)[None, :]   # (N, K)
    log_norm = jax.scipy.special.logsumexp(lp, axis=1)               # (N,)
    resp = jnp.exp(lp - log_norm[:, None]) * w[:, None]              # (N, K)
    s0 = jnp.sum(resp, axis=0)                                       # (K,)
    s1 = resp.T @ x                                                  # (K, d)
    if gmm.is_diagonal:
        s2 = resp.T @ (x * x)                                        # (K, d)
    else:
        s2 = jnp.einsum("nk,ni,nj->kij", resp, x, x)                 # (K, d, d)
    loglik = jnp.sum(log_norm * w)
    return SufficientStats(s0, s1, s2, loglik, jnp.sum(w))


def e_step_stats(gmm: GMM, x: jax.Array,
                 sample_weight: Optional[jax.Array] = None,
                 estep_backend: str = "auto",
                 chunk_size: Optional[int] = None,
                 scan_width: int = 1) -> SufficientStats:
    """One E-step: responsibilities -> sufficient statistics.

    This is the communication payload of DEM (each client computes local
    stats; the server psums them) and the compute hot spot. The
    ``estep_backend`` knob dispatches between the pure-jnp reference path
    and the fused Pallas kernel (``repro.kernels.ops.estep_stats``), which
    never materializes the (N, K) responsibility matrix; ``chunk_size``
    streams either backend through the engine in O(chunk·K) memory, so
    this one function is the whole dispatch table for federated callers.
    ``x`` may be a :class:`DataSource` (host-side block loop, §7); sources
    carry no sample weights. ``scan_width > 1`` batches that many chunks
    per scan step on the resident chunked path (2-level scan, see
    :func:`streaming_map_reduce`) — reduction order changes, so the
    default of 1 is part of the reproducibility contract.
    """
    backend = resolve_estep_backend(estep_backend, gmm.is_diagonal)
    if isinstance(x, DataSource):
        _require_no_weight(sample_weight, "e_step_stats over a DataSource")
        block_fn = (_estep_block_fused if backend == "fused"
                    else _estep_block_reference)
        return reduce_rows(lambda xb, wb: block_fn(gmm, xb, wb), x,
                           chunk_size)
    n = x.shape[0]
    w = jnp.ones(n, x.dtype) if sample_weight is None else sample_weight
    if backend == "fused":
        block = lambda xb, wb: e_step_stats_fused(gmm, xb, wb)
    else:
        block = lambda xb, wb: _e_step_stats_reference(gmm, xb, wb)
    if scan_width > 1 and chunk_size is not None:
        return streaming_reduce(block, (x, w), int(chunk_size), scan_width)
    return reduce_rows(block, (x, w), chunk_size)


def e_step_stats_fused(gmm: GMM, x: jax.Array,
                       sample_weight: Optional[jax.Array] = None,
                       interpret: Optional[bool] = None) -> SufficientStats:
    """Kernel-backed E-step (diagonal covariance only): the Pallas
    ``estep_stats`` kernel fuses log-pdf -> softmax -> reductions in VMEM.
    Semantically identical to :func:`e_step_stats`; used on TPU where the
    (N, K) responsibility matrix would otherwise round-trip HBM."""
    from repro.kernels import ops  # local import: kernels are optional
    assert gmm.is_diagonal, "fused E-step kernel supports diagonal covariance"
    n = x.shape[0]
    w = jnp.ones(n, x.dtype) if sample_weight is None else sample_weight
    s0, s1, s2, ll = ops.estep_stats(x, gmm.means, gmm.covs,
                                     jnp.log(gmm.weights), w,
                                     interpret=interpret)
    return SufficientStats(s0, s1, s2, ll, jnp.sum(w))


# Per-block statistics for the DataSource host loop. Module-level jitted so
# every pass over a source hits the trace cache — exactly ONE block shape
# exists per stream (prefetch_blocks pads the ragged tail to the full chunk
# and hands each block a 0/1 row mask ``wb``); parameters (gmm) are traced
# arguments, never closure constants.

@jax.jit
def _estep_block_reference(gmm: GMM, xb: jax.Array,
                           wb: jax.Array) -> SufficientStats:
    return _e_step_stats_reference(gmm, xb, wb)


@jax.jit
def _estep_block_fused(gmm: GMM, xb: jax.Array,
                       wb: jax.Array) -> SufficientStats:
    return e_step_stats_fused(gmm, xb, wb)


def e_step_stats_chunked(gmm: GMM, x: jax.Array,
                         sample_weight: Optional[jax.Array] = None,
                         chunk_size: int = 4096,
                         estep_backend: str = "auto") -> SufficientStats:
    """Constant-memory E-step: ``lax.scan`` over fixed-size row chunks.

    ``SufficientStats`` is additive in N, so the full-batch statistics are
    the chunk-wise sum — the working set is one (chunk_size, K) block
    instead of the whole (N, K) responsibility matrix (see
    :func:`streaming_reduce` for padding/accumulation semantics). Caveat:
    the *fused* backend computes each chunk in f32 regardless (the kernel
    packs params as f32), so f64 precision is only preserved end-to-end on
    the reference backend.
    """
    return e_step_stats(gmm, x, sample_weight, estep_backend,
                        chunk_size=int(chunk_size))


def m_step(stats: SufficientStats, reg_covar: float = 1e-6) -> GMM:
    """M-step from (possibly aggregated) sufficient statistics."""
    s0 = jnp.maximum(stats.s0, 1e-10)
    weights = stats.s0 / jnp.maximum(stats.wsum, 1e-12)
    weights = weights / jnp.sum(weights)
    means = stats.s1 / s0[:, None]
    if stats.s2.ndim == 2:  # diagonal
        covs = stats.s2 / s0[:, None] - means * means
        covs = jnp.maximum(covs, 0.0) + reg_covar
    else:
        outer = jnp.einsum("ki,kj->kij", means, means)
        covs = stats.s2 / s0[:, None, None] - outer
        # robustness against component collapse (few near-colinear points):
        # symmetrize, sanitize non-finite, floor the diagonal — the EM
        # iteration then reassigns mass instead of diverging to NaN
        covs = 0.5 * (covs + jnp.swapaxes(covs, -1, -2))
        covs = jnp.where(jnp.isfinite(covs), covs, 0.0)
        d = means.shape[1]
        eye = jnp.eye(d, dtype=means.dtype)[None]
        covs = covs + reg_covar * eye
        diag = jnp.maximum(jnp.diagonal(covs, axis1=-2, axis2=-1), reg_covar)
        covs = covs * (1.0 - eye) + diag[..., None] * eye
    means = jnp.where(jnp.isfinite(means), means, 0.0)
    return GMM(weights, means, covs)


def em_step(gmm: GMM, x: jax.Array, sample_weight: Optional[jax.Array] = None,
            reg_covar: float = 1e-6, estep_backend: str = "auto",
            chunk_size: Optional[int] = None) -> tuple[GMM, jax.Array]:
    """One full EM iteration. Returns (new_gmm, avg_loglik_of_old_gmm).

    ``chunk_size=None`` runs the whole batch in one E-step; an integer
    streams it through the engine in bounded memory.
    """
    stats = e_step_stats(gmm, x, sample_weight, estep_backend, chunk_size)
    avg_ll = stats.loglik / jnp.maximum(stats.wsum, 1e-12)
    return m_step(stats, reg_covar), avg_ll


# ----------------------------------------------------------------------
# Streaming scoring: log-likelihood and BIC without the (N, K) matrix
# ----------------------------------------------------------------------

def _log_prob_block(gmm: GMM, xb: jax.Array, backend: str) -> jax.Array:
    """Mixture log density of one row block, (B, d) -> (B,). The fused
    backend routes the (B, K) per-component density through the Pallas
    ``gmm_logpdf`` kernel (diagonal only); reference uses ``GMM.log_prob``."""
    if backend == "fused":
        from repro.kernels import ops  # local import: kernels are optional
        lp = ops.gmm_logpdf(xb, gmm.means, gmm.covs, jnp.log(gmm.weights))
        return jax.scipy.special.logsumexp(lp, axis=1).astype(xb.dtype)
    return gmm.log_prob(xb)


@partial(jax.jit, static_argnames=("backend",))
def _log_prob_block_jit(gmm: GMM, xb: jax.Array, backend: str) -> jax.Array:
    return _log_prob_block(gmm, xb, backend)


@partial(jax.jit, static_argnames=("backend",))
def _score_block(gmm: GMM, xb: jax.Array, wb: jax.Array, backend: str):
    lp = _log_prob_block(gmm, xb, backend)
    return jnp.sum(lp * wb), jnp.sum(wb)


def log_prob_chunked(gmm: GMM, x: jax.Array,
                     chunk_size: Optional[int] = 4096,
                     backend: str = "auto") -> jax.Array:
    """``GMM.log_prob`` in fixed-size row chunks -> (N,).

    Peak working set is one (chunk_size, K) density block instead of the
    full (N, K) matrix — what the anomaly-detection scorer needs to run
    over datasets that don't fit the full-batch path. ``chunk_size=None``
    runs one full-batch block (same backend resolution), so callers can
    delegate unconditionally like every other engine entry point. Accepts a
    :class:`DataSource` (the per-row *output* is still O(N), but only 4
    bytes a row — the (N, K) block never exists).

    Every path runs the ONE jitted block (``_log_prob_block_jit``), which
    is row-wise bit-stable across batch shapes — so chunked, full-batch
    and the serving engine's padded-slab scores are bit-identical.
    """
    backend = resolve_backend(backend, fused_supported=gmm.is_diagonal)
    if isinstance(x, DataSource):
        _, lp = streaming_map_reduce(
            lambda xb, wb: ((), _log_prob_block_jit(gmm, xb, backend)), x,
            resolve_source_chunk(chunk_size))
        return lp
    if chunk_size is None:
        return _log_prob_block_jit(gmm, x, backend)
    _, lp = streaming_map_reduce(
        lambda xb: ((), _log_prob_block_jit(gmm, xb, backend)), (x,),
        chunk_size)
    return lp


def _score_sums(gmm: GMM, x: jax.Array, sample_weight: Optional[jax.Array],
                chunk_size: Optional[int], backend: str):
    """(sum_n w_n log p(x_n), sum_n w_n) through the engine."""
    backend = resolve_backend(backend, fused_supported=gmm.is_diagonal)
    if isinstance(x, DataSource):
        _require_no_weight(sample_weight, "scoring over a DataSource")
        return reduce_rows(lambda xb, wb: _score_block(gmm, xb, wb, backend),
                           x, chunk_size)
    n = x.shape[0]
    w = jnp.ones(n, x.dtype) if sample_weight is None else sample_weight

    def block(xb, wb):
        lp = _log_prob_block(gmm, xb, backend)
        return jnp.sum(lp * wb), jnp.sum(wb)

    return reduce_rows(block, (x, w), chunk_size)


def score_streaming(gmm: GMM, x: jax.Array,
                    sample_weight: Optional[jax.Array] = None,
                    chunk_size: Optional[int] = 4096,
                    backend: str = "auto") -> jax.Array:
    """Average log-likelihood (the paper's fitness score, Eq. 2) in
    O(chunk·K) memory. Equals ``GMM.score`` up to float-summation order."""
    total, wsum = _score_sums(gmm, x, sample_weight, chunk_size, backend)
    return total / jnp.maximum(wsum, 1e-12)


def bic_streaming(gmm: GMM, x: jax.Array,
                  sample_weight: Optional[jax.Array] = None,
                  chunk_size: Optional[int] = 4096,
                  backend: str = "auto") -> jax.Array:
    """Bayesian Information Criterion in O(chunk·K) memory (lower is
    better). Equals ``GMM.bic`` up to float-summation order; this is what
    makes BIC model selection over candidate K constant-memory."""
    total, wsum = _score_sums(gmm, x, sample_weight, chunk_size, backend)
    return gmm.n_free_params() * jnp.log(wsum) - 2.0 * total


# ----------------------------------------------------------------------
# Initialization
# ----------------------------------------------------------------------

@partial(jax.jit, static_argnames=("k", "covariance_type", "chunk_size"))
def label_stats(x: jax.Array, assignments: jax.Array, k: int,
                sample_weight: Optional[jax.Array] = None,
                covariance_type: str = "diag",
                chunk_size: Optional[int] = None) -> SufficientStats:
    """Hard-assignment sufficient statistics via weighted one-hot matmuls
    — per-cluster sums as ``oh.T @ xb`` instead of ``segment_sum`` scatter
    adds (an order of magnitude faster on the CPU backend), with
    ``chunk_size`` bounding the row working set to one (chunk, K) block.

    Resident arrays only (``assignments`` is row-aligned with ``x``); the
    out-of-core init fuses labelling into the final assignment sweep
    instead (``repro.core.kmeans.kmeans_label_block``), so no (N,) label
    vector is ever needed on the source path. Jitted at module level so
    repeated init calls at one (n, k) shape trace once.
    """
    n = x.shape[0]
    w = jnp.ones(n, x.dtype) if sample_weight is None else sample_weight

    def block(xb, wb, ab):
        cols = jnp.arange(k, dtype=ab.dtype)[None, :]
        oh = (ab[:, None] == cols).astype(xb.dtype) * wb[:, None]
        s0 = jnp.sum(oh, axis=0)
        s1 = oh.T @ xb
        if covariance_type == "diag":
            s2 = oh.T @ (xb * xb)
        else:
            s2 = jnp.einsum("nk,ni,nj->kij", oh, xb, xb)
        return SufficientStats(s0, s1, s2, jnp.zeros((), xb.dtype),
                               jnp.sum(wb))

    return reduce_rows(block, (x, w, assignments), chunk_size)


def init_from_kmeans(key: jax.Array, x: jax.Array, k: int,
                     sample_weight: Optional[jax.Array] = None,
                     covariance_type: str = "diag",
                     reg_covar: float = 1e-6,
                     chunk_size: Optional[int] = None,
                     assign_backend: str = "auto") -> GMM:
    """sklearn-style init: k-means labels -> label stats -> M-step.

    With ``chunk_size`` set, both the Lloyd iterations (chunked k-means,
    see ``repro.core.kmeans``) and the label statistics stream in
    O(chunk·K) memory, closing the init leg of the constant-memory
    pipeline. A :class:`DataSource` runs fully out-of-core: streamed
    k-means++ seeding, host-loop Lloyd sweeps, and label statistics fused
    into a final assignment pass (no (N,) assignment vector ever exists).
    """
    # Local import: this module hosts the engine that kmeans.py builds on.
    from repro.core.kmeans import (kmeans_label_block, kmeans_multi,
                                   kmeans_multi_source)
    if isinstance(x, DataSource):
        _require_no_weight(sample_weight, "init_from_kmeans over a DataSource")
        cs = resolve_source_chunk(chunk_size)
        res = kmeans_multi_source(key, x, k, max_iter=50, chunk_size=cs,
                                  assign_backend=assign_backend)
        backend = resolve_backend(assign_backend)
        stats = streaming_reduce(
            lambda xb, wb: kmeans_label_block(res.centers, xb, wb,
                                              covariance_type, backend),
            x, cs)
        return m_step(stats, reg_covar)
    n = x.shape[0]
    w = jnp.ones(n, x.dtype) if sample_weight is None else sample_weight
    res = kmeans_multi(key, x, k, sample_weight=w, max_iter=50,
                       chunk_size=chunk_size, assign_backend=assign_backend)
    stats = label_stats(x, res.assignments, k, w, covariance_type, chunk_size)
    return m_step(stats, reg_covar)


def init_from_means(means: jax.Array, x: jax.Array,
                    sample_weight: Optional[jax.Array] = None,
                    covariance_type: str = "diag",
                    reg_covar: float = 1e-6,
                    chunk_size: Optional[int] = None) -> GMM:
    """Init with given centers, uniform weights, data-variance covariances.

    Used by the DEM baselines, where the server proposes centers without
    seeing client data. Accepts a :class:`DataSource` (streamed one-pass
    moments at ``chunk_size`` granularity; the variance uses E[x²]−E[x]²,
    clamped at zero, instead of the resident two-pass form). On resident
    arrays ``chunk_size`` is ignored — the moments are already O(d).
    """
    k, d = means.shape
    if isinstance(x, DataSource):
        _require_no_weight(sample_weight, "init_from_means over a DataSource")
        s, ss, cnt = reduce_rows(_moments_block, x, chunk_size)
        wsum = jnp.maximum(cnt, 1e-12)
        mean = s / wsum
        var = jnp.maximum(ss / wsum - mean * mean, 0.0) + reg_covar
        weights = jnp.full((k,), 1.0 / k, means.dtype)
        if covariance_type == "diag":
            covs = jnp.broadcast_to(var, (k, d))
        else:
            covs = jnp.broadcast_to(jnp.diag(var), (k, d, d))
        return GMM(weights, means, covs)
    n = x.shape[0]
    w = jnp.ones(n, x.dtype) if sample_weight is None else sample_weight
    wsum = jnp.maximum(jnp.sum(w), 1e-12)
    mean = jnp.sum(x * w[:, None], axis=0) / wsum
    var = jnp.sum((x - mean) ** 2 * w[:, None], axis=0) / wsum + reg_covar
    weights = jnp.full((k,), 1.0 / k, x.dtype)
    if covariance_type == "diag":
        covs = jnp.broadcast_to(var, (k, d))
    else:
        covs = jnp.broadcast_to(jnp.diag(var), (k, d, d))
    return GMM(weights, means, covs)


@jax.jit
def _moments_block(xb: jax.Array, wb: jax.Array):
    """(Σ w x, Σ w x², Σ w) of one block — streamed data moments (``wb`` is
    the 0/1 pad mask, so padded rows count for nothing)."""
    return (jnp.sum(xb * wb[:, None], axis=0),
            jnp.sum(xb * xb * wb[:, None], axis=0), jnp.sum(wb))


# ----------------------------------------------------------------------
# Full EM fit
# ----------------------------------------------------------------------

@partial(jax.jit, static_argnames=("max_iter", "estep_backend", "chunk_size"))
def _em_loop(gmm0: GMM, x: jax.Array, w: jax.Array, tol: float,
             reg_covar: float, max_iter: int, estep_backend: str = "auto",
             chunk_size: Optional[int] = None):
    def cond(state):
        _, prev_ll, ll, it = state
        return jnp.logical_and(it < max_iter, jnp.abs(ll - prev_ll) > tol)

    def body(state):
        gmm, _, ll, it = state
        new_gmm, avg_ll = em_step(gmm, x, w, reg_covar, estep_backend,
                                  chunk_size)
        return new_gmm, ll, avg_ll, it + 1

    neg_inf = jnp.array(-jnp.inf, x.dtype)
    # Bootstrap: one step to get an initial loglik.
    gmm1, ll0 = em_step(gmm0, x, w, reg_covar, estep_backend, chunk_size)
    state = (gmm1, neg_inf, ll0, jnp.array(1))
    gmm, prev_ll, ll, it = jax.lax.while_loop(cond, body, state)
    converged = jnp.abs(ll - prev_ll) <= tol
    return gmm, ll, it, converged


_m_step_jit = jax.jit(m_step)


def host_em_loop(step: Callable, gmm0: GMM, tol: float, max_iter: int):
    """The host-side EM convergence loop shared by every out-of-core
    trainer (:func:`fit_gmm` over a source, ``dem_from_sources``): run a
    bootstrap ``step(gmm) -> (new_gmm, avg_ll)``, then iterate while the
    avg-loglik delta exceeds ``tol``. State transitions, the bootstrap
    round and the tolerance test mirror the jitted resident loops
    (:func:`_em_loop`, ``_dem_loop``) exactly, so resident and source
    paths converge on the same iteration sequence — keep all three in
    lock-step. Returns ``(gmm, avg_ll, n_iter, converged)``."""
    tol = float(tol)
    gmm, ll = step(gmm0)
    prev_ll, it = float("-inf"), 1
    while it < max_iter and abs(ll - prev_ll) > tol:
        new_gmm, avg_ll = step(gmm)
        gmm, prev_ll, ll, it = new_gmm, ll, avg_ll, it + 1
    converged = abs(ll - prev_ll) <= tol
    dt = gmm.means.dtype
    return gmm, jnp.asarray(ll, dt), jnp.asarray(it), jnp.asarray(converged)


def _em_loop_source(gmm0: GMM, source: DataSource, tol: float,
                    reg_covar: float, max_iter: int, estep_backend: str,
                    chunk_size: int):
    """Out-of-core twin of :func:`_em_loop`: the convergence loop runs on
    the host (a source cannot live inside jit) while every per-block E-step
    and the M-step stay jitted."""
    backend = resolve_estep_backend(estep_backend, gmm0.is_diagonal)
    block_fn = (_estep_block_fused if backend == "fused"
                else _estep_block_reference)

    def step(gmm):
        stats = streaming_reduce(lambda xb, wb: block_fn(gmm, xb, wb), source,
                                 chunk_size)
        avg_ll = float(stats.loglik / jnp.maximum(stats.wsum, 1e-12))
        return _m_step_jit(stats, reg_covar), avg_ll

    return host_em_loop(step, gmm0, tol, max_iter)


def fit_gmm_cfg(key: jax.Array, x, k: int, config: FitConfig,
                sample_weight: Optional[jax.Array] = None,
                init_gmm: Optional[GMM] = None) -> EMResult:
    """Train a GMM with EM until the avg-loglik delta drops below the
    config's ``tol`` (the paper's convergence criterion, 1e-3).

    The cfg-core trainer behind both :func:`fit_gmm` and
    ``repro.api.GMMEstimator``: every knob arrives pre-validated in one
    :class:`FitConfig`, resolved exactly once here. ``config.backend``
    selects the E-step implementation (DESIGN.md §6); an integer
    ``config.chunk_size`` streams the init (k-means + label stats) *and*
    every E-step in bounded memory. The k-means assignment backend stays
    "auto" (kernel on TPU, reference elsewhere) rather than following the
    E-step backend: an explicitly requested fused E-step off-TPU is a
    parity-testing configuration, and interpret-mode Lloyd sweeps would
    make it unusably slow.

    ``x`` may be a :class:`DataSource` (DESIGN.md §7): init, every E-step
    and convergence then run as host-driven block loops with an
    O(chunk·K) working set independent of N — true out-of-core training
    (``chunk_size="auto"`` streams at :data:`DEFAULT_SOURCE_CHUNK`).
    """
    # Validate eagerly: _em_loop sees the knob as a static jit arg and a
    # typo'd value would otherwise surface as an opaque trace-time error.
    config.resolved_estep(config.is_diagonal if init_gmm is None
                          else init_gmm.is_diagonal)
    tol = config.resolve_tol("em")
    max_iter = config.resolve_max_iter("em")
    if isinstance(x, DataSource):
        require_array_weights(sample_weight, "fit_gmm over a DataSource")
        cs = config.resolve_chunk(source=True)
        if init_gmm is None:
            init_gmm = init_from_kmeans(
                key, x, k, covariance_type=config.covariance_type,
                reg_covar=config.reg_covar, chunk_size=cs)
        gmm, ll, it, converged = _em_loop_source(
            init_gmm, x, tol, config.reg_covar, max_iter,
            config.backend, cs)
        return EMResult(gmm, ll, it, converged)
    cs = config.resolve_chunk(source=False)
    n = x.shape[0]
    w = jnp.ones(n, x.dtype) if sample_weight is None else sample_weight
    if init_gmm is None:
        init_gmm = init_from_kmeans(key, x, k, w, config.covariance_type,
                                    config.reg_covar, chunk_size=cs)
    gmm, ll, it, converged = _em_loop(
        init_gmm, x, w, jnp.asarray(tol, x.dtype), config.reg_covar,
        max_iter, config.backend, cs)
    return EMResult(gmm, ll, it, converged)


def fit_gmm(key: jax.Array, x: jax.Array, k: int,
            sample_weight: Optional[jax.Array] = None,
            covariance_type: str = "diag",
            max_iter: int = 200, tol: float = 1e-3,
            reg_covar: float = 1e-6,
            init_gmm: Optional[GMM] = None,
            estep_backend: str = "auto",
            chunk_size: Optional[int] = None) -> EMResult:
    """Legacy keyword surface of :func:`fit_gmm_cfg` (internal; prefer
    ``repro.api.GMMEstimator``): folds the loose knobs into one validated
    :class:`FitConfig` — ``chunk_size=None`` keeps its historical meaning
    (full batch resident / :data:`DEFAULT_SOURCE_CHUNK` out-of-core) by
    mapping to ``chunk_size="auto"``."""
    cfg = FitConfig.from_legacy(
        backend=estep_backend, chunk_size=chunk_size,
        covariance_type=covariance_type, reg_covar=reg_covar, tol=tol,
        max_iter=max_iter)
    return fit_gmm_cfg(key, x, k, cfg, sample_weight, init_gmm)


def fit_gmm_streaming(key: jax.Array, x: jax.Array, k: int,
                      sample_weight: Optional[jax.Array] = None,
                      covariance_type: str = "diag",
                      max_iter: int = 200, tol: float = 1e-3,
                      reg_covar: float = 1e-6,
                      init_gmm: Optional[GMM] = None,
                      estep_backend: str = "auto",
                      chunk_size: int = 4096) -> EMResult:
    """Deprecated: ``repro.api.GMMEstimator`` with an integer
    ``FitConfig.chunk_size`` is the same all-streaming fit. This shim
    forwards to the facade (bit-identical result) and will be removed."""
    warnings.warn(
        "fit_gmm_streaming is deprecated; use repro.api.GMMEstimator(k, "
        "chunk_size=<int>).fit(x) — same engine, same bits",
        DeprecationWarning, stacklevel=2)
    from repro.api import GMMEstimator  # facade sits above core; lazy
    est = GMMEstimator(k, config=FitConfig.from_legacy(
        backend=estep_backend, chunk_size=int(chunk_size),
        covariance_type=covariance_type, reg_covar=reg_covar, tol=tol,
        max_iter=max_iter))
    est.fit(x, sample_weight=sample_weight, init_gmm=init_gmm, key=key)
    return est.result_


def fit_gmm_bic_cfg(key: jax.Array, x, k_candidates: Sequence[int],
                    config: FitConfig,
                    sample_weight: Optional[jax.Array] = None
                    ) -> tuple[EMResult, dict[int, float]]:
    """TrainGMM of Algorithm 4.1: fit every K in the candidate range, return
    the fit minimizing BIC (plus all BIC scores).

    With an integer ``config.chunk_size`` the per-candidate scoring runs
    through :func:`bic_streaming`, so model selection never materializes
    the (N, K) log-prob matrix the full-batch ``GMM.bic`` builds. With a
    :class:`DataSource` the whole selection — every candidate's init, EM
    and BIC score — runs out-of-core.
    """
    score_chunk = config.resolve_chunk(isinstance(x, DataSource))
    best, best_bic, bics = None, jnp.inf, {}
    for i, k in enumerate(k_candidates):
        res = fit_gmm_cfg(jax.random.fold_in(key, i), x, k, config,
                          sample_weight)
        # scoring backend stays "auto" (kernel on TPU, reference elsewhere)
        # rather than following config.backend, for the same reason the
        # fit pins the k-means assign backend: an explicit fused E-step
        # off-TPU is a parity-testing configuration, and interpret-mode
        # scoring of every candidate K would crawl.
        b = float(bic_streaming(res.gmm, x, sample_weight,
                                chunk_size=score_chunk))
        bics[k] = b
        if b < best_bic:
            best, best_bic = res, b
    return best, bics


def fit_gmm_bic(key: jax.Array, x: jax.Array, k_candidates: Sequence[int],
                sample_weight: Optional[jax.Array] = None,
                covariance_type: str = "diag",
                max_iter: int = 200, tol: float = 1e-3,
                reg_covar: float = 1e-6,
                estep_backend: str = "auto",
                chunk_size: Optional[int] = None) -> tuple[EMResult,
                                                           dict[int, float]]:
    """Legacy keyword surface of :func:`fit_gmm_bic_cfg` (internal; prefer
    ``repro.api.GMMEstimator`` with ``k_candidates``)."""
    cfg = FitConfig.from_legacy(
        backend=estep_backend, chunk_size=chunk_size,
        covariance_type=covariance_type, reg_covar=reg_covar, tol=tol,
        max_iter=max_iter)
    return fit_gmm_bic_cfg(key, x, k_candidates, cfg, sample_weight)
