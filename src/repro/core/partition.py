"""Client data partitioning schemes (§5.2 of the paper).

Heterogeneity is feature-distribution skew driven by class identity:
each "class" is one underlying distribution p^(m) in Eq. 1.

- ``Dir(alpha)``: for each class, its samples are distributed over the C
  clients with proportions drawn from a symmetric Dirichlet(alpha).
  Smaller alpha => more heterogeneous (Fig. 1).
- ``Quantity(alpha)``: each client receives data from exactly ``alpha``
  randomly chosen classes ("quantity-based label imbalance").

Partitioning is host-side data-pipeline work, so it runs in numpy; the
result is padded fixed-shape arrays + 0/1 masks so that local training can
run under vmap / shard_map with ragged client sizes.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class ClientSplit(NamedTuple):
    """Padded per-client datasets.

    data : (C, N_max, d) float32, zero-padded
    mask : (C, N_max) float32 in {0, 1}
    sizes: (C,) int64 true local dataset sizes |D_c|
    class_counts: (C, M) number of points of each class per client
    """
    data: np.ndarray
    mask: np.ndarray
    sizes: np.ndarray
    class_counts: np.ndarray


def _pack(per_client: list[np.ndarray], n_classes: int,
          per_client_labels: list[np.ndarray], pad_to: int | None = None) -> ClientSplit:
    c = len(per_client)
    d = per_client[0].shape[1]
    sizes = np.array([len(p) for p in per_client], dtype=np.int64)
    n_max = int(pad_to or max(int(sizes.max()), 1))
    data = np.zeros((c, n_max, d), dtype=np.float32)
    mask = np.zeros((c, n_max), dtype=np.float32)
    counts = np.zeros((c, n_classes), dtype=np.int64)
    for i, (p, lab) in enumerate(zip(per_client, per_client_labels)):
        n = len(p)
        data[i, :n] = p
        mask[i, :n] = 1.0
        if n:
            counts[i] = np.bincount(lab, minlength=n_classes)
    return ClientSplit(data, mask, sizes, counts)


def partition_dirichlet(rng: np.random.Generator, x: np.ndarray, y: np.ndarray,
                        n_clients: int, alpha: float,
                        min_size: int = 2) -> ClientSplit:
    """Dir(alpha) partitioning: per-class Dirichlet proportions over clients."""
    n_classes = int(y.max()) + 1
    while True:  # re-draw until every client has at least min_size points
        idx_lists: list[list[int]] = [[] for _ in range(n_clients)]
        for m in range(n_classes):
            idx = np.flatnonzero(y == m)
            rng.shuffle(idx)
            props = rng.dirichlet(alpha * np.ones(n_clients))
            cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
            for c, part in enumerate(np.split(idx, cuts)):
                idx_lists[c].extend(part.tolist())
        if min(len(l) for l in idx_lists) >= min_size:
            break
    per, labels = [], []
    for l in idx_lists:
        sel = np.array(sorted(l))
        per.append(x[sel])
        labels.append(y[sel])
    return _pack(per, n_classes, labels)


def partition_quantity(rng: np.random.Generator, x: np.ndarray, y: np.ndarray,
                       n_clients: int, alpha: int,
                       min_size: int = 2) -> ClientSplit:
    """Quantity(alpha): each client gets data from ``alpha`` random classes.

    Each class's points are split evenly among the clients assigned to it.
    Every class is guaranteed at least one client (round-robin backstop) so
    no part of the global distribution disappears.
    """
    n_classes = int(y.max()) + 1
    alpha = int(alpha)
    # choose alpha classes per client (as sets)
    choices = [set(rng.choice(n_classes, size=min(alpha, n_classes),
                              replace=False).tolist())
               for _ in range(n_clients)]
    # backstop: every class must keep >= 1 client so no data is dropped —
    # each uncovered class is ADDED to the currently least-loaded client
    # (max classes per client stays <= alpha + ceil(M / n_clients);
    # documented data-conservation choice)
    covered = set().union(*choices)
    for m in range(n_classes):
        if m not in covered:
            least = min(range(n_clients), key=lambda c: len(choices[c]))
            choices[least].add(m)

    idx_lists: list[list[int]] = [[] for _ in range(n_clients)]
    for m in range(n_classes):
        takers = [c for c in range(n_clients) if m in choices[c]]
        idx = np.flatnonzero(y == m)
        rng.shuffle(idx)
        for c, part in zip(takers, np.array_split(idx, len(takers))):
            idx_lists[c].extend(part.tolist())
    per, labels = [], []
    for l in idx_lists:
        sel = np.array(sorted(l), dtype=np.int64) if l else np.zeros(0, np.int64)
        per.append(x[sel])
        labels.append(y[sel])
    return _pack(per, n_classes, labels)


def partition(rng: np.random.Generator, x: np.ndarray, y: np.ndarray,
              n_clients: int, scheme: str, alpha: float) -> ClientSplit:
    if scheme == "dirichlet":
        return partition_dirichlet(rng, x, y, n_clients, alpha)
    if scheme == "quantity":
        return partition_quantity(rng, x, y, n_clients, int(alpha))
    raise ValueError(f"unknown partition scheme: {scheme!r}")
