"""The continuous-batching GMM scoring engine (DESIGN.md §10).

One queue, one fixed :class:`~repro.serve.slots.SlotPool`, one jitted
scoring step::

    submit -> [queue] -> admit into free slots -> jitted score step
                 ^            (mid-flight)        (ONE compiled shape,
                 |                                 donated slab buffers)
                 +---- retire finished requests <--+

Each :meth:`ScoringEngine.step` call is one micro-batch: poll the
attached model store, finish a pending hot swap if the pool has drained,
admit queued requests into free slots, score the ``(slots,
rows_per_slot, d)`` slab in one jitted call (slab and mask buffers are
donated — XLA reuses their memory for the outputs), and harvest/retire.
Requests longer than ``rows_per_slot`` stream through their slot across
micro-batches; short ones are padded to the static shape, so the hot
path compiles exactly once per ``(slots, rows_per_slot, d, K, mode,
backend)`` — admitting, retiring and re-seeding requests never retraces.

**Hot model swap** (the drain-and-install protocol): :meth:`install` (or
a newer version appearing in the attached store) marks the new model
*pending* — admission stops, in-flight requests keep scoring under the
old model, and the instant the pool drains the new model is installed
and admission resumes. The guarantee: every request is scored by exactly
ONE model version — the one echoed in its result — so per-request scores
are bit-identical to a single-model engine that only ever held that
version, no request is ever dropped, and the version tag observed across
the retirement stream flips at exactly one admission boundary. The cost
is a bounded admission pause (the tail of the longest in-flight
request), measured per swap in :attr:`ScoringEngine.swap_pauses` and
tracked as the ``swap`` section of ``BENCH_serve.json``.
"""
from __future__ import annotations

import time
import warnings
from collections import deque
from functools import partial
from typing import List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import resolve_backend
from repro.core.em import _log_prob_block
from repro.core.gmm import GMM
from repro.serve.slots import InFlight, SlotPool
from repro.serve.types import ScoreConfig, ScoreRequest, ScoreResult


@partial(jax.jit, static_argnames=("mode", "backend"),
         donate_argnums=(1, 2))
def _score_slab(gmm: GMM, slab: jax.Array, mask: jax.Array, *,
                mode: str, backend: str):
    """THE jitted scoring step: ``(S, R, d)`` slab + ``(S, R)`` row mask
    -> ``(S, R)`` scores (log_prob/anomaly) or ``(S, R, K)``
    responsibilities.

    Per-row math is exactly the training engine's
    (``repro.core.em._log_prob_block`` — kernel-dispatched, so "fused"
    rides the Pallas ``gmm_logpdf`` on TPU), which is what pins engine
    scores bit-identical to ``repro.api.log_prob``: a row's mixture
    density never depends on its batch peers, and masked padding rows
    are multiplied to zero AFTER the per-row computation (``x * 1.0`` is
    exact in IEEE f32, so valid rows are untouched). ``slab`` and
    ``mask`` are donated — both are dead after the call (the engine
    rebuilds them host-side every micro-batch), and XLA aliases whatever
    shapes line up (the ``(S, R)`` mask buffer becomes the ``(S, R)``
    score buffer in log_prob/anomaly mode; the rest is simply freed
    early). The engine suppresses XLA's "donated buffer not usable"
    note for the shapes that can't alias."""
    s, r, d = slab.shape
    x = slab.reshape(s * r, d)
    if mode == "responsibilities":
        if backend == "fused":
            from repro.kernels import ops  # kernels are optional
            lp = ops.gmm_logpdf(x, gmm.means, gmm.covs,
                                jnp.log(gmm.weights))
            resp = jax.nn.softmax(lp, axis=1)
        else:
            resp = gmm.responsibilities(x)
        k = resp.shape[-1]
        return resp.reshape(s, r, k) * mask[:, :, None]
    lp = _log_prob_block(gmm, x, backend).reshape(s, r) * mask
    return lp if mode == "log_prob" else -lp


class ScoringEngine:
    """Serve one global GMM to a stream of scoring requests.

    - ``gmm``: the model to serve (diag or full covariance; shapes
      ``weights (K,)``, ``means (K, d)``, ``covs (K, d)|(K, d, d)``).
    - ``config``: a :class:`~repro.serve.types.ScoreConfig` (mode, slot
      pool geometry, backend, store poll cadence).
    - ``version``: tag echoed in every result scored by this model.
    - ``store``: optional subscription — any object with a ``poll()``
      returning an object with ``.version``/``.gmm`` attributes for a
      newly published model, or None (``repro.serve.ModelStore`` is the
      canonical one). Polled every ``config.poll_every`` micro-batches;
      a new version triggers the drain-and-install swap.

    Streaming use is ``submit`` + repeated ``step``; offline convenience
    is ``run(requests)`` (submit all, drain, return every result).
    Results surface in retirement order; ``rid`` maps them back.
    """

    def __init__(self, gmm: GMM, config: Optional[ScoreConfig] = None, *,
                 version: Union[int, str] = "v0", store=None):
        self.config = config if config is not None else ScoreConfig()
        if not isinstance(self.config, ScoreConfig):
            raise TypeError(f"config must be a ScoreConfig, "
                            f"got {type(self.config).__name__}")
        self._store = store
        self._queue: deque = deque()
        self._pending: Optional[tuple] = None     # (gmm, version)
        self._pending_since: Optional[float] = None
        self.steps = 0
        self.swaps = 0
        self.completed = 0
        #: seconds each completed swap stalled admission (drain time)
        self.swap_pauses: List[float] = []
        self._pool = SlotPool(self.config.slots, self.config.rows_per_slot,
                              int(gmm.n_features))
        self._set_model(gmm, version)

    # -- model ----------------------------------------------------------

    @property
    def version(self) -> Union[int, str]:
        """Version tag of the currently installed model (new admissions
        are scored — and tagged — with this)."""
        return self._version

    @property
    def gmm(self) -> GMM:
        """The currently installed model (a device-resident GMM)."""
        return self._gmm

    @property
    def dim(self) -> int:
        """Feature dimension every request's rows must match."""
        return self._pool.dim

    @property
    def swap_pending(self) -> bool:
        """True while a newer model waits for in-flight requests to
        drain (admission is stalled)."""
        return self._pending is not None

    def _set_model(self, gmm: GMM, version: Union[int, str]) -> None:
        if not isinstance(gmm, GMM):
            raise TypeError(f"engine serves a repro.core.gmm.GMM, "
                            f"got {type(gmm).__name__}")
        if int(gmm.n_features) != self._pool.dim:
            raise ValueError(
                f"model dim {int(gmm.n_features)} != engine dim "
                f"{self._pool.dim}; a swap cannot change the feature "
                f"dimension")
        self._gmm = jax.device_put(gmm)
        self._version = version
        # "auto" resolves per model: the fused kernel serves diag
        # covariances only (same rule as training).
        self._backend = resolve_backend(self.config.backend,
                                        fused_supported=gmm.is_diagonal)

    def install(self, gmm: GMM, version: Union[int, str]) -> None:
        """Hot-swap to a new model. Installs immediately when no request
        is in flight; otherwise the swap goes *pending*: admission stops,
        in-flight requests finish under the old model, and the install
        lands the moment the pool drains (within the step that retires
        the last of them). A second install while pending replaces the
        pending model (latest wins) but keeps the original stall clock."""
        if self._pool.idle:
            self._set_model(gmm, version)
            self.swaps += 1
            return
        if self._pending_since is None:
            self._pending_since = time.time()
        self._pending = (gmm, version)

    def _finish_swap_if_drained(self) -> None:
        if self._pending is not None and self._pool.idle:
            gmm, version = self._pending
            self._pending = None
            if self._pending_since is not None:
                self.swap_pauses.append(time.time() - self._pending_since)
                self._pending_since = None
            self._set_model(gmm, version)
            self.swaps += 1

    def _poll_store(self) -> None:
        if self._store is None or self.steps % self.config.poll_every:
            return
        published = self._store.poll()
        if published is not None:
            self.install(published.gmm, published.version)

    @classmethod
    def from_store(cls, store, config: Optional[ScoreConfig] = None,
                   *, follow: bool = True) -> "ScoringEngine":
        """Build an engine serving the latest model published in
        ``store`` (a :class:`repro.serve.ModelStore`). ``follow=True``
        keeps the subscription attached, so later publishes hot-swap in;
        ``follow=False`` pins the latest version forever. Raises
        :class:`FileNotFoundError` when nothing has been published."""
        published = store.latest()
        if published is None:
            raise FileNotFoundError(
                f"model store {store.root!r} has no published model yet")
        return cls(published.gmm, config, version=published.version,
                   store=store if follow else None)

    # -- the request stream --------------------------------------------

    @property
    def queued(self) -> int:
        """Requests submitted but not yet admitted to a slot."""
        return len(self._queue)

    @property
    def in_flight(self) -> int:
        """Requests currently occupying slots (admitted, not retired)."""
        return self._pool.in_flight

    @property
    def pending_requests(self) -> int:
        """Requests the engine still owes results for (queued plus in
        flight) — ``drain`` loops until this reaches zero."""
        return self.queued + self.in_flight

    def submit(self, request: ScoreRequest) -> None:
        """Enqueue one request (FIFO). Validates the feature dimension
        against the served model now, so a malformed request fails at the
        submit site, not mid-micro-batch."""
        if not isinstance(request, ScoreRequest):
            raise TypeError(f"submit takes a ScoreRequest, "
                            f"got {type(request).__name__}")
        if request.rows.shape[1] != self.dim:
            raise ValueError(
                f"request {request.rid}: rows have dim "
                f"{request.rows.shape[1]}, the served model expects "
                f"{self.dim}")
        self._queue.append(request)

    def _admit(self, results: List[ScoreResult]) -> None:
        """Fill free slots from the queue (FIFO). Blocked entirely while
        a swap is pending — that is the drain half of the protocol.
        Zero-row requests retire immediately (they still consume an
        admission, so their version tag honors the swap boundary)."""
        if self._pending is not None:
            return
        while self._queue:
            head = self._queue[0]
            if head.num_rows == 0:
                self._queue.popleft()
                entry = InFlight(head, time.time(), self._version)
                trailing = ((int(self._gmm.n_components),)
                            if self.config.mode == "responsibilities"
                            else ())
                results.append(self._pool.retire_empty(entry, trailing))
                self.completed += 1
                continue
            if self._pool.free == 0:
                return
            self._pool.admit(InFlight(head, time.time(), self._version))
            self._queue.popleft()
    # -- micro-batches --------------------------------------------------

    def step(self) -> List[ScoreResult]:
        """Run ONE micro-batch -> the requests that finished in it.

        Order of operations: poll the store -> finish a drained swap ->
        admit into free slots -> one jitted scoring call over the slab ->
        harvest/retire -> finish the swap again if those retirements
        drained the pool (so the stall never lasts longer than the drain
        itself). A fully idle step returns ``[]``."""
        self.steps += 1
        self._poll_store()
        self._finish_swap_if_drained()
        results: List[ScoreResult] = []
        self._admit(results)
        active = self._pool.stage()
        if active:
            with warnings.catch_warnings():
                # Donation is deliberate (both buffers are rebuilt every
                # micro-batch); XLA notes the shapes it cannot alias.
                warnings.filterwarnings(
                    "ignore", message="Some donated buffers were not usable")
                out = _score_slab(self._gmm, jnp.asarray(self._pool.slab),
                                  jnp.asarray(self._pool.mask),
                                  mode=self.config.mode,
                                  backend=self._backend)
            finished = self._pool.harvest(np.asarray(out), active)
            self.completed += len(finished)
            results.extend(finished)
        self._finish_swap_if_drained()
        return results

    def drain(self) -> List[ScoreResult]:
        """Step until every submitted request has retired -> all results
        (retirement order). A pending swap cannot stall this: once the
        pool drains it installs and admission resumes."""
        results: List[ScoreResult] = []
        while self.pending_requests:
            results.extend(self.step())
        return results

    def run(self, requests) -> List[ScoreResult]:
        """Offline convenience: submit every request, drain, return all
        results (retirement order; match them back by ``rid``)."""
        for request in requests:
            self.submit(request)
        return self.drain()
