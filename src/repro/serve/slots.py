"""The fixed slot pool behind continuous batching (DESIGN.md §10).

The pool owns the ONE static device-facing shape of the hot path: a
``(slots, rows_per_slot, d)`` f32 slab plus its ``(slots, rows_per_slot)``
0/1 row mask. Requests are admitted into free slots *mid-flight* — there
are no lockstep waves — and a request longer than ``rows_per_slot``
streams through its slot across micro-batches, its cursor advancing
``rows_per_slot`` rows per step. Short requests are zero-padded to the
static shape, so the jitted scoring step compiles exactly once per
``(slots, rows_per_slot, d, K, mode, backend)`` and admission, progress
and retirement are pure host bookkeeping.

Nothing here touches jax: the pool stages NumPy buffers (which the engine
transfers and donates to the scoring step) and accumulates per-request
output chunks. The engine owns the model, the jitted step, and the swap
protocol.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Union

import numpy as np

from repro.serve.types import ScoreRequest, ScoreResult


@dataclasses.dataclass
class InFlight:
    """Host bookkeeping of one admitted request: the cursor into its rows
    and the output chunks harvested so far. ``version`` is pinned at
    admission — the swap protocol guarantees it is the version of every
    model that touches this request."""

    request: ScoreRequest
    submitted_s: float
    version: Union[int, str]
    cursor: int = 0
    chunks: List[np.ndarray] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        """True once every row of the request has been scored."""
        return self.cursor >= self.request.num_rows


class SlotPool:
    """Fixed pool of ``slots`` request slots over one static slab shape.

    The engine's per-micro-batch protocol is three calls:

    1. :meth:`admit` queued requests into free slots (any time, including
       while other slots are mid-request — that is the "continuous" in
       continuous batching);
    2. :meth:`stage` — write each active slot's next
       ``<= rows_per_slot``-row window into the slab/mask buffers;
    3. :meth:`harvest` the step's ``(slots, rows_per_slot[, K])`` output
       back into per-request chunks, retiring finished requests.
    """

    def __init__(self, slots: int, rows_per_slot: int, dim: int):
        if slots < 1 or rows_per_slot < 1 or dim < 1:
            raise ValueError(
                f"slots, rows_per_slot and dim must be positive, got "
                f"({slots}, {rows_per_slot}, {dim})")
        self.slots = slots
        self.rows_per_slot = rows_per_slot
        self.dim = dim
        self.slab = np.zeros((slots, rows_per_slot, dim), np.float32)
        self.mask = np.zeros((slots, rows_per_slot), np.float32)
        self._entries: List[Optional[InFlight]] = [None] * slots

    # -- occupancy ------------------------------------------------------

    @property
    def in_flight(self) -> int:
        """Number of occupied slots (requests admitted, not yet retired)."""
        return sum(e is not None for e in self._entries)

    @property
    def free(self) -> int:
        """Number of slots currently available for admission."""
        return self.slots - self.in_flight

    @property
    def idle(self) -> bool:
        """True when no request is in flight."""
        return self.in_flight == 0

    # -- the three-call protocol ---------------------------------------

    def admit(self, entry: InFlight) -> int:
        """Bind an in-flight entry to the first free slot -> slot index.
        Raises :class:`RuntimeError` when the pool is full (the engine
        checks ``free`` first; the queue absorbs overflow)."""
        for s, occupant in enumerate(self._entries):
            if occupant is None:
                self._entries[s] = entry
                return s
        raise RuntimeError("slot pool is full; check .free before admit")

    def stage(self) -> List[int]:
        """Write each active slot's next row window into the slab and
        mask buffers (zero-padding the tail) -> the list of active slot
        indices this micro-batch. Inactive slots get mask 0; their stale
        slab rows are dead weight the mask cancels."""
        active = []
        for s, entry in enumerate(self._entries):
            if entry is None:
                self.mask[s] = 0.0
                continue
            rows = entry.request.rows[
                entry.cursor: entry.cursor + self.rows_per_slot]
            take = rows.shape[0]
            self.slab[s, :take] = rows
            self.slab[s, take:] = 0.0
            self.mask[s, :take] = 1.0
            self.mask[s, take:] = 0.0
            active.append(s)
        return active

    def harvest(self, out: np.ndarray,
                active: List[int]) -> List[ScoreResult]:
        """Slice the step output ``out`` (``(slots, rows_per_slot[, K])``)
        back into the active requests' chunk lists, advance their
        cursors, and retire every request whose rows are exhausted ->
        the finished :class:`ScoreResult` list (slots are freed)."""
        results: List[ScoreResult] = []
        now = time.time()
        for s in active:
            entry = self._entries[s]
            take = min(entry.request.num_rows - entry.cursor,
                       self.rows_per_slot)
            entry.chunks.append(np.asarray(out[s, :take]))
            entry.cursor += take
            if entry.done:
                scores = (np.concatenate(entry.chunks, axis=0)
                          if entry.chunks else
                          np.zeros((0,) + out.shape[2:], np.float32))
                results.append(ScoreResult(
                    rid=entry.request.rid, scores=scores,
                    model_version=entry.version,
                    latency_s=now - entry.submitted_s))
                self._entries[s] = None
        return results

    def retire_empty(self, entry: InFlight,
                     trailing: tuple = ()) -> ScoreResult:
        """Zero-row requests never occupy a slot: retire one directly
        with an empty, correctly-shaped score array (``trailing`` is
        ``(K,)`` in responsibilities mode, ``()`` otherwise)."""
        return ScoreResult(
            rid=entry.request.rid,
            scores=np.zeros((0,) + tuple(trailing), np.float32),
            model_version=entry.version,
            latency_s=time.time() - entry.submitted_s)
