"""Request/response types and the one scoring configuration of the
serving engine (DESIGN.md §10).

A scoring request is a batch of feature rows; a response is the per-row
scores plus the version tag of the model that produced them. Everything
here is host-side plumbing — the device-facing contract (one static
``(slots, rows_per_slot, d)`` slab shape) lives in
:class:`~repro.serve.slots.SlotPool` and the engine.
"""
from __future__ import annotations

import dataclasses
from typing import Union

import numpy as np

#: Scoring modes: per-row mixture log density, per-row anomaly score
#: (its negation — higher = more anomalous, the paper's §5.4 detector),
#: or per-row posterior responsibilities (an (n, K) block per request).
SCORE_MODES = ("log_prob", "anomaly", "responsibilities")

#: Engine backends mirror the training engine's dispatch
#: (``repro.core.config.resolve_backend``): "auto" picks the fused Pallas
#: ``gmm_logpdf`` kernel on TPU and the pure-jnp reference elsewhere.
SERVE_BACKENDS = ("auto", "reference", "fused")


@dataclasses.dataclass(frozen=True)
class ScoreConfig:
    """The one validated serving configuration (frozen/hashable).

    - ``mode``: ``"log_prob"`` (per-row mixture log density, f32, shape
      ``(n,)`` per request), ``"anomaly"`` (its negation, same shape) or
      ``"responsibilities"`` (posterior ``(n, K)`` block per request).
    - ``slots``: size of the fixed slot pool — how many requests can be
      in flight at once. The hot path compiles ONCE per
      ``(slots, rows_per_slot, d, K, mode, backend)``.
    - ``rows_per_slot``: rows a slot feeds the scoring step per
      micro-batch. Requests longer than this stream through their slot
      over multiple micro-batches (the continuous-batching contract);
      shorter ones are zero-padded to the static shape.
    - ``backend``: kernel dispatch, as in training ("auto" = fused Pallas
      ``gmm_logpdf`` on TPU, pure-jnp reference on CPU).
    - ``poll_every``: poll the attached model store every this many
      micro-batches (1 = every step); purely a host-side cadence knob.

    Validation happens here, once, at construction — the engine trusts
    its config.
    """

    mode: str = "log_prob"
    slots: int = 8
    rows_per_slot: int = 512
    backend: str = "auto"
    poll_every: int = 1

    def __post_init__(self):
        if self.mode not in SCORE_MODES:
            raise ValueError(
                f"mode must be one of {SCORE_MODES}, got {self.mode!r}")
        if self.backend not in SERVE_BACKENDS:
            raise ValueError(
                f"backend must be one of {SERVE_BACKENDS}, "
                f"got {self.backend!r}")
        for name in ("slots", "rows_per_slot", "poll_every"):
            v = getattr(self, name)
            if isinstance(v, bool) or not isinstance(v, int) or v < 1:
                raise ValueError(
                    f"{name} must be a positive int, got {v!r}")


@dataclasses.dataclass(frozen=True)
class ScoreRequest:
    """One scoring request: ``rid`` (caller-chosen id, echoed in the
    result) and ``rows`` — an ``(n, d)`` float array of feature rows
    (``n >= 0``; ``d`` must match the served model's feature dim, checked
    at submit). Rows are captured as a NumPy f32 array at construction so
    a request is immutable host data."""

    rid: int
    rows: np.ndarray

    def __post_init__(self):
        rows = np.asarray(self.rows, dtype=np.float32)
        if rows.ndim != 2:
            raise ValueError(
                f"request rows must be (n, d), got shape {rows.shape}")
        object.__setattr__(self, "rows", rows)

    @property
    def num_rows(self) -> int:
        """Number of feature rows in this request."""
        return self.rows.shape[0]


@dataclasses.dataclass(frozen=True)
class ScoreResult:
    """One completed request: per-row ``scores`` (``(n,)`` f32 for
    log_prob/anomaly, ``(n, K)`` f32 for responsibilities, row-aligned
    with the request), the ``model_version`` tag of the model that scored
    EVERY row (the hot-swap protocol guarantees a request never spans two
    models), and wall-clock ``latency_s`` from submit to retirement."""

    rid: int
    scores: np.ndarray
    model_version: Union[int, str]
    latency_s: float

    @property
    def num_rows(self) -> int:
        """Number of scored rows (equals the request's row count)."""
        return self.scores.shape[0]
