"""repro.serve — the production-shaped GMM scoring engine (DESIGN.md §10).

The seam the paper's deployment story needs between "a fitted global
model" and "a stream of scoring requests":

- :class:`ScoringEngine` — continuous batching over a fixed slot pool
  (one compiled slab shape, donated buffers) with drain-and-install hot
  model swap;
- :class:`ModelStore` — the versioned publish/subscribe watcher over
  ``repro.checkpoint.store``, so the federation runtime publishes a new
  global model each round and a live engine picks it up without dropping
  a request;
- :class:`ScoreConfig` / :class:`ScoreRequest` / :class:`ScoreResult` —
  the one configuration and the request/response pair (every result
  echoes the version of the model that scored it).

The public-facing entry is ``repro.api.Scorer`` (this package sits below
the facade, next to ``repro.core``); ``examples/serve_anomaly.py`` is
the end-to-end train -> publish -> serve walk, and
``benchmarks/serve_bench.py`` tracks latency/QPS/swap-pause in
``BENCH_serve.json``.
"""
from repro.serve.engine import ScoringEngine
from repro.serve.model_store import ModelStore, PublishedModel
from repro.serve.slots import SlotPool
from repro.serve.types import (SCORE_MODES, ScoreConfig, ScoreRequest,
                               ScoreResult)

__all__ = [
    "ScoringEngine",
    "ModelStore",
    "PublishedModel",
    "SlotPool",
    "ScoreConfig",
    "ScoreRequest",
    "ScoreResult",
    "SCORE_MODES",
]
