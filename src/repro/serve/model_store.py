"""The hot-swap model watcher: a GMM-typed publish/subscribe view over
the versioned checkpoint stream in ``repro.checkpoint.store``
(DESIGN.md §10).

The federation runtime (or anything that produces a new global model)
calls :meth:`ModelStore.publish` — one atomic versioned checkpoint per
round. The serving engine holds the subscriber half: it calls
:meth:`ModelStore.poll` between micro-batches, which returns a newly
published model exactly once (and always jumps to the *latest* version —
a server that fell behind skips intermediates rather than replaying
them). Shapes and dtypes ride in the published metadata
(``checkpoint.store.leaf_spec``), so a subscriber needs no out-of-band
template: a store directory is self-describing.

Publisher and subscriber can be different processes on one filesystem —
the atomicity lives in ``publish_checkpoint``'s write-then-rename
protocol, not in this class.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Optional, Union

import jax.numpy as jnp

from repro.checkpoint.store import (latest_version, load_published,
                                    publish_checkpoint)
from repro.core.gmm import GMM

# GMM.tree_flatten order -> the flat checkpoint keys (weights, means,
# covs). Pinned here so a template can be rebuilt from metadata alone.
_GMM_LEAF_KEYS = ("0", "1", "2")


def _gmm_template(leaves: dict) -> GMM:
    """Zero-filled GMM with the shapes/dtypes a published checkpoint's
    ``leaves`` metadata describes — the ``like`` pytree the loader
    restores into (this is what preserves bf16 leaves through the f32
    npz storage)."""
    missing = [k for k in _GMM_LEAF_KEYS if k not in leaves]
    if missing:
        raise ValueError(
            f"published checkpoint is not a GMM: metadata is missing "
            f"leaf keys {missing} (has {sorted(leaves)})")
    w, mu, cov = (jnp.zeros(tuple(leaves[k]["shape"]),
                            jnp.dtype(leaves[k]["dtype"]))
                  for k in _GMM_LEAF_KEYS)
    return GMM(w, mu, cov)


@dataclasses.dataclass(frozen=True)
class PublishedModel:
    """One published global model: its monotonic ``version``, the
    restored :class:`GMM`, and the publisher's metadata dict (which
    includes ``version`` and the ``leaves`` shape table)."""

    version: int
    gmm: GMM
    metadata: dict


class ModelStore:
    """One directory = one versioned stream of global GMMs.

    - ``publish(gmm, metadata)`` -> new version number (atomic; the
      single publisher is whoever owns the training loop).
    - ``poll()`` -> a :class:`PublishedModel` the first time a version
      newer than anything this store object has returned appears, else
      None — the engine's between-micro-batches check.
    - ``latest()`` / ``load(version)`` -> explicit reads (``latest``
      returns None on an empty stream; ``load`` raises on a version that
      was never published).

    The seen-version cursor is per ``ModelStore`` instance (each
    subscriber tracks its own progress); the directory itself is the
    shared truth.
    """

    def __init__(self, root: Union[str, Path]):
        self.root = str(root)
        self._seen = 0

    def publish(self, gmm: GMM, metadata: Optional[dict] = None) -> int:
        """Publish a new global model -> its version (1-based,
        monotonic). ``metadata`` (e.g. the federation round, the
        training loglik) is stored in the version's json alongside the
        auto-generated ``version``/``leaves`` entries."""
        if not isinstance(gmm, GMM):
            raise TypeError(
                f"ModelStore publishes repro.core.gmm.GMM models, got "
                f"{type(gmm).__name__}")
        return publish_checkpoint(self.root, gmm, metadata)

    def latest_version(self) -> Optional[int]:
        """Highest published version, or None on an empty stream (one
        small-file read; safe to call every micro-batch)."""
        return latest_version(self.root)

    def load(self, version: Optional[int] = None) -> PublishedModel:
        """Load one version (None = latest) -> :class:`PublishedModel`.
        Advances this subscriber's seen-cursor, so a later ``poll`` only
        fires on something newer still."""
        meta_path = self._meta_path(version)
        meta = json.loads(meta_path.read_text())
        like = _gmm_template(meta["leaves"])
        gmm, meta, v = load_published(self.root, like,
                                      meta["version"])
        self._seen = max(self._seen, v)
        return PublishedModel(v, gmm, meta)

    def latest(self) -> Optional[PublishedModel]:
        """The newest published model, or None on an empty stream."""
        if self.latest_version() is None:
            return None
        return self.load(None)

    def poll(self) -> Optional[PublishedModel]:
        """Return the newest published model IF it is newer than
        anything this subscriber has seen, else None. Always jumps to
        the latest version (intermediate versions published since the
        last poll are skipped, not replayed)."""
        v = self.latest_version()
        if v is None or v <= self._seen:
            return None
        return self.load(v)

    def _meta_path(self, version: Optional[int]) -> Path:
        from repro.checkpoint.store import _STEM_FMT
        if version is None:
            version = self.latest_version()
            if version is None:
                raise FileNotFoundError(
                    f"no published model under {self.root!r}")
        path = Path(self.root) / (_STEM_FMT.format(version) + ".json")
        if not path.exists():
            raise ValueError(
                f"version {version} was never published under "
                f"{self.root!r} (latest is {self.latest_version()})")
        return path
