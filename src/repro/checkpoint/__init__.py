from repro.checkpoint.store import (latest_version, leaf_spec,
                                    load_checkpoint, load_published,
                                    publish_checkpoint, save_checkpoint)

__all__ = ["load_checkpoint", "save_checkpoint", "publish_checkpoint",
           "latest_version", "load_published", "leaf_spec"]
