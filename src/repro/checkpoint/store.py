"""Minimal pytree checkpointing: flat-key npz + json metadata (no external
deps; sufficient for CPU-scale training and the examples)."""
from __future__ import annotations

import json
from pathlib import Path

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        # npz has no portable bfloat16: store extended floats as f32 (the
        # restore path casts back to the target leaf dtype)
        if arr.dtype not in (np.float64, np.float32, np.float16,
                             np.int64, np.int32, np.int16, np.int8,
                             np.uint8, np.bool_):
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_checkpoint(path: str, params, metadata: dict | None = None):
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    np.savez_compressed(p.with_suffix(".npz"), **_flatten(params))
    if metadata is not None:
        p.with_suffix(".json").write_text(json.dumps(metadata, indent=2))


def load_checkpoint(path: str, like):
    """Restore into the structure of ``like`` (a params pytree)."""
    p = Path(path)
    data = np.load(p.with_suffix(".npz"))
    flat_like, tdef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_k, leaf in flat_like:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in path_k)
        arr = data[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(jax.numpy.asarray(arr).astype(leaf.dtype))
    meta = {}
    if p.with_suffix(".json").exists():
        meta = json.loads(p.with_suffix(".json").read_text())
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves), meta
