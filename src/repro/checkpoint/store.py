"""Minimal pytree checkpointing: flat-key npz + json metadata (no external
deps; sufficient for CPU-scale training and the examples) — plus the
**versioned publish/subscribe seam** the serving engine hot-swaps on
(DESIGN.md §10).

Two layers:

- :func:`save_checkpoint` / :func:`load_checkpoint` — one named
  checkpoint, caller-chosen path. ``load_checkpoint`` restores into the
  structure of a ``like`` pytree and raises :class:`ValueError` naming
  the offending flat key on a missing leaf or a shape mismatch (a real
  exception, not an ``assert`` — the check survives ``python -O``).
- :func:`publish_checkpoint` / :func:`latest_version` /
  :func:`load_published` — a monotonically versioned stream of models in
  one directory. Publishing is **atomic for a single publisher** (the
  federation server): payload files are written to hidden temp names and
  ``os.replace``-d into place, and the ``LATEST`` pointer file is
  replaced last, so a subscriber that reads ``LATEST`` never observes a
  version whose payload is missing or half-written. Subscribers poll
  ``latest_version`` cheaply (one small file read) —
  ``repro.serve.ModelStore`` is the consumer.

Extended float dtypes (bf16 et al.) are stored as f32 in the npz (npz has
no portable bfloat16) and cast back to the target leaf dtype on restore;
bf16 -> f32 -> bf16 is exact, so the round trip is lossless.
"""
from __future__ import annotations

import json
import os
from pathlib import Path

import jax
import numpy as np

# Version v of a published stream lives at <root>/model-<v:06d>.{npz,json};
# <root>/LATEST holds {"version": v, "stem": "model-<v:06d>"}.
LATEST_NAME = "LATEST"
_STEM_FMT = "model-{:06d}"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        # npz has no portable bfloat16: store extended floats as f32 (the
        # restore path casts back to the target leaf dtype)
        if arr.dtype not in (np.float64, np.float32, np.float16,
                             np.int64, np.int32, np.int16, np.int8,
                             np.uint8, np.bool_):
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def leaf_spec(tree) -> dict[str, dict]:
    """Flat-key -> {"shape", "dtype"} table of a pytree's leaves, with the
    ORIGINAL dtypes (bf16 stays "bfloat16" even though the npz stores
    f32). Published alongside every versioned checkpoint so a subscriber
    can rebuild a ``like`` template without out-of-band shape knowledge."""
    spec = {}
    for key, leaf in _flatten_specs(tree):
        arr = np.asarray(leaf)
        spec[key] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
    return spec


def _flatten_specs(tree):
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        yield key, leaf


def save_checkpoint(path: str, params, metadata: dict | None = None):
    """Write ``params`` (any pytree) to ``<path>.npz`` (+ ``<path>.json``
    when ``metadata`` is given). Leaves are flattened to ``/``-joined key
    paths; extended float dtypes are stored as f32 (see module note)."""
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    np.savez_compressed(p.with_suffix(".npz"), **_flatten(params))
    if metadata is not None:
        p.with_suffix(".json").write_text(json.dumps(metadata, indent=2))


def load_checkpoint(path: str, like):
    """Restore ``<path>.npz`` into the structure of ``like`` (a params
    pytree) -> ``(params, metadata)``.

    Every leaf is cast to the dtype of the corresponding ``like`` leaf
    (the bf16 round-trip contract). Raises :class:`ValueError` naming the
    flat pytree key when the checkpoint is missing a leaf ``like``
    expects, or when a stored leaf's shape does not match — both are real
    exceptions (the historical bare ``assert`` vanished under
    ``python -O`` and the KeyError on a missing leaf was opaque)."""
    p = Path(path)
    data = np.load(p.with_suffix(".npz"))
    flat_like, tdef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_k, leaf in flat_like:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in path_k)
        if key not in data.files:
            raise ValueError(
                f"checkpoint {p.with_suffix('.npz')} is missing pytree "
                f"leaf {key!r}; stored leaves: {sorted(data.files)}")
        arr = data[key]
        if arr.shape != leaf.shape:
            raise ValueError(
                f"checkpoint leaf {key!r} has shape {arr.shape} but the "
                f"template expects {leaf.shape} "
                f"(checkpoint: {p.with_suffix('.npz')})")
        leaves.append(jax.numpy.asarray(arr).astype(leaf.dtype))
    meta = {}
    if p.with_suffix(".json").exists():
        meta = json.loads(p.with_suffix(".json").read_text())
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves), meta


# ----------------------------------------------------------------------
# Versioned publish/subscribe (the serving hot-swap seam)
# ----------------------------------------------------------------------

def latest_version(root: str) -> int | None:
    """Highest published version in ``root``, or None when nothing has
    been published yet. One small-file read in the normal case — cheap
    enough to poll between serving micro-batches. A missing ``LATEST``
    pointer (e.g. a publisher crash between the payload and pointer
    renames) falls back to scanning the published payloads, so a torn
    pointer can never wedge the stream or recycle a version number."""
    pointer = Path(root) / LATEST_NAME
    try:
        return int(json.loads(pointer.read_text())["version"])
    except FileNotFoundError:
        versions = [int(p.stem.split("-")[-1])
                    for p in Path(root).glob("model-*.npz")]
        return max(versions) if versions else None


def publish_checkpoint(root: str, params, metadata: dict | None = None) -> int:
    """Publish ``params`` as the next version of the stream in ``root``
    and return the new version number (1-based, monotonic).

    Write order is the atomicity protocol: the npz and json payloads land
    under hidden temp names, each is ``os.replace``-d to its final name,
    and the ``LATEST`` pointer is replaced last — so a subscriber that
    learns about version v through ``LATEST`` can always read v's files.
    Single-publisher by design (the federation server owns the stream);
    the json metadata automatically gains ``version`` and a ``leaves``
    shape/dtype table (:func:`leaf_spec`) so subscribers can rebuild a
    ``like`` template with no out-of-band knowledge."""
    rootp = Path(root)
    rootp.mkdir(parents=True, exist_ok=True)
    version = (latest_version(root) or 0) + 1
    stem = _STEM_FMT.format(version)
    meta = dict(metadata or {})
    meta["version"] = version
    meta["leaves"] = leaf_spec(params)

    tmp = rootp / f".tmp-{stem}"
    np.savez_compressed(tmp.with_suffix(".npz"), **_flatten(params))
    tmp.with_suffix(".json").write_text(json.dumps(meta, indent=2))
    os.replace(tmp.with_suffix(".npz"), (rootp / stem).with_suffix(".npz"))
    os.replace(tmp.with_suffix(".json"), (rootp / stem).with_suffix(".json"))

    ptr_tmp = rootp / (".tmp-" + LATEST_NAME)
    ptr_tmp.write_text(json.dumps({"version": version, "stem": stem}))
    os.replace(ptr_tmp, rootp / LATEST_NAME)
    return version


def load_published(root: str, like, version: int | None = None):
    """Load one version of a published stream -> ``(params, metadata,
    version)``, restoring into the structure/dtypes of ``like`` exactly
    like :func:`load_checkpoint`. ``version=None`` loads the latest;
    raises :class:`FileNotFoundError` when the stream is empty and
    :class:`ValueError` when the named version was never published."""
    if version is None:
        version = latest_version(root)
        if version is None:
            raise FileNotFoundError(
                f"no published checkpoint under {root!r} (no "
                f"{LATEST_NAME} pointer)")
    stem = Path(root) / _STEM_FMT.format(version)
    if not stem.with_suffix(".npz").exists():
        raise ValueError(
            f"version {version} was never published under {root!r} "
            f"(latest is {latest_version(root)})")
    params, meta = load_checkpoint(str(stem), like)
    return params, meta, int(version)
