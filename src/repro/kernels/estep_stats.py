"""Pallas TPU kernel: fused EM E-step sufficient statistics.

Fuses  log-pdf -> per-row softmax (responsibilities) -> the three weighted
reductions  (s0, s1, s2)  plus the total log-likelihood into one pass over
the data. The (N, K) responsibility matrix never exists in HBM — the
flash-attention trick applied to EM. K (number of mixture components) is
small (<= a few hundred), so the K axis and the (K, d) accumulators stay
VMEM-resident while (bn, d) data tiles stream through.

The TPU grid is sequential over the N tiles, so accumulation into the
output refs (initialized at program_id 0) is race-free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_N = 512


def _estep_kernel(x_ref, w_ref, a_ref, b_ref, c_ref,
                  s0_ref, s1_ref, s2_ref, ll_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        s0_ref[...] = jnp.zeros_like(s0_ref)
        s1_ref[...] = jnp.zeros_like(s1_ref)
        s2_ref[...] = jnp.zeros_like(s2_ref)
        ll_ref[...] = jnp.zeros_like(ll_ref)

    x = x_ref[...].astype(jnp.float32)            # (bn, d)
    w = w_ref[...].astype(jnp.float32)            # (bn, 1)
    xx = x * x
    lp = jnp.dot(xx, a_ref[...].astype(jnp.float32),
                 preferred_element_type=jnp.float32)
    lp += jnp.dot(x, b_ref[...].astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    lp += c_ref[...].astype(jnp.float32)          # (bn, K)
    m = jnp.max(lp, axis=1, keepdims=True)        # (bn, 1)
    p = jnp.exp(lp - m)
    denom = jnp.sum(p, axis=1, keepdims=True)     # (bn, 1)
    log_norm = m + jnp.log(denom)                 # (bn, 1)
    resp = (p / denom) * w                        # (bn, K)
    s0_ref[...] += jnp.sum(resp, axis=0, keepdims=True)            # (1, K)
    s1_ref[...] += jnp.dot(resp.T, x, preferred_element_type=jnp.float32)
    s2_ref[...] += jnp.dot(resp.T, xx, preferred_element_type=jnp.float32)
    ll_ref[...] += jnp.sum(log_norm * w, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def estep_stats_pallas(x: jax.Array, w: jax.Array, a: jax.Array,
                       b: jax.Array, c: jax.Array, *,
                       block_n: int = DEFAULT_BLOCK_N,
                       interpret: bool = False):
    """Raw fused kernel (padded shapes).

    x (N, d), w (N, 1) sample weights (0 on padded rows), a (d, K),
    b (d, K), c (1, K) with c = -1e30 on padded K columns.
    Returns (s0 (1,K), s1 (K,d), s2 (K,d), loglik (1,1)), all float32.
    """
    n, d = x.shape
    k = a.shape[1]
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    return pl.pallas_call(
        _estep_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((d, k), lambda i: (0, 0)),
            pl.BlockSpec((d, k), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, k), jnp.float32),
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, a, b, c)
