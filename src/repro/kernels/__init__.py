"""Pallas TPU kernels for the EM hot path (validated in interpret mode on
CPU; see EXAMPLE.md / DESIGN.md for the TPU tiling rationale)."""
from repro.kernels.ops import estep_stats, gmm_logpdf, kmeans_assign
from repro.kernels import ref

__all__ = ["estep_stats", "gmm_logpdf", "kmeans_assign", "ref"]
