"""Pallas TPU kernel: k-means assignment (nearest center + squared distance).

Same matmul identity as the GMM kernels: ||x - c||^2 = ||x||^2 - 2 x.c +
||c||^2; the centers panel (d, K) stays VMEM-resident, data tiles stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_N = 512


def _assign_kernel(x_ref, ct_ref, c2_ref, idx_ref, dist_ref):
    x = x_ref[...].astype(jnp.float32)            # (bn, d)
    ct = ct_ref[...].astype(jnp.float32)          # (d, K)
    c2 = c2_ref[...].astype(jnp.float32)          # (1, K) (+inf on padding)
    x2 = jnp.sum(x * x, axis=1, keepdims=True)    # (bn, 1)
    d2 = x2 - 2.0 * jnp.dot(x, ct, preferred_element_type=jnp.float32) + c2
    d2 = jnp.maximum(d2, 0.0)
    idx_ref[...] = jnp.argmin(d2, axis=1, keepdims=True).astype(jnp.int32)
    dist_ref[...] = jnp.min(d2, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign_pallas(x: jax.Array, ct: jax.Array, c2: jax.Array, *,
                         block_n: int = DEFAULT_BLOCK_N,
                         interpret: bool = False):
    """x (N, d), ct (d, K) transposed centers, c2 (1, K) squared norms
    (+1e30 on padded columns). Returns (assign (N,1) int32, d2min (N,1))."""
    n, d = x.shape
    k = ct.shape[1]
    assert n % block_n == 0
    return pl.pallas_call(
        _assign_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d, k), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, ct, c2)
