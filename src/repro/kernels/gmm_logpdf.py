"""Pallas TPU kernel: batched diagonal-Gaussian mixture log densities.

The E-step hot spot. Uses the matmul identity (DESIGN.md §3): with
``A = -0.5 / var`` (d, K), ``B = mu / var`` (d, K) and a per-component
constant row ``c`` (1, K),

    logpdf[n, k] = (x[n]*x[n]) @ A[:, k] + x[n] @ B[:, k] + c[k]

Both contractions hit the MXU. The kernel streams (bn, d) tiles of x
through VMEM, keeps the (d, bk) parameter panels resident, and squares x
in-register so x**2 never round-trips through HBM (that is the win over the
naive XLA lowering, which materializes x*x at HBM).

Grid: (N/bn, K/bk); the feature dim d is small for GMM workloads (<= a few
hundred after the paper's PCA) and lives whole in VMEM, padded to the
128-lane boundary.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_N = 256
DEFAULT_BLOCK_K = 128


def _logpdf_kernel(x_ref, a_ref, b_ref, c_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)           # (bn, d)
    a = a_ref[...].astype(jnp.float32)           # (d, bk)
    b = b_ref[...].astype(jnp.float32)           # (d, bk)
    acc = jnp.dot(x * x, a, preferred_element_type=jnp.float32)
    acc += jnp.dot(x, b, preferred_element_type=jnp.float32)
    out_ref[...] = (acc + c_ref[...].astype(jnp.float32)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "block_k", "interpret"))
def gmm_logpdf_pallas(x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
                      *, block_n: int = DEFAULT_BLOCK_N,
                      block_k: int = DEFAULT_BLOCK_K,
                      interpret: bool = False) -> jax.Array:
    """Raw tiled kernel. Shapes must already be padded:
    x (N, d), a (d, K), b (d, K), c (1, K) with N % block_n == 0,
    K % block_k == 0, d % 128 == 0. Returns (N, K) float32.
    """
    n, d = x.shape
    k = a.shape[1]
    assert n % block_n == 0 and k % block_k == 0, (n, k, block_n, block_k)
    grid = (n // block_n, k // block_k)
    return pl.pallas_call(
        _logpdf_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, block_k), lambda i, j: (0, j)),
            pl.BlockSpec((d, block_k), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_k), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_k), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=interpret,
    )(x, a, b, c)
