"""Public jit'd wrappers around the Pallas kernels.

Handle padding to TPU tile boundaries (lanes = 128, tunable N/K blocks),
parameter re-packing into the matmul-identity form, and automatic fallback
to ``interpret=True`` when not running on TPU (this container is CPU-only;
interpret mode executes the kernel body in Python and is bit-compatible
with the TPU lowering at f32).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels.estep_stats import estep_stats_pallas
from repro.kernels.gmm_logpdf import gmm_logpdf_pallas
from repro.kernels.kmeans_assign import kmeans_assign_pallas

LOG_2PI = 1.8378770664093453
_NEG_BIG = -1e30


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _pack_params(means, variances, log_weights, d_pad, k_pad, pad_c=0.0):
    """Repack (means, variances) into (a, b, c) for the matmul identity,
    padded to (d_pad, k_pad)."""
    k, d = means.shape
    inv_var = 1.0 / variances
    a = jnp.zeros((d_pad, k_pad), jnp.float32).at[:d, :k].set(
        (-0.5 * inv_var).T)
    b = jnp.zeros((d_pad, k_pad), jnp.float32).at[:d, :k].set(
        (means * inv_var).T)
    cvec = -0.5 * (jnp.sum(means * means * inv_var, axis=-1)
                   + jnp.sum(jnp.log(variances), axis=-1) + d * LOG_2PI)
    if log_weights is not None:
        cvec = cvec + log_weights
    c = jnp.full((1, k_pad), pad_c, jnp.float32).at[0, :k].set(cvec)
    return a, b, c


def gmm_logpdf(x: jax.Array, means: jax.Array, variances: jax.Array,
               log_weights: jax.Array | None = None, *,
               block_n: int = 256, block_k: int = 128,
               interpret: bool | None = None) -> jax.Array:
    """Diagonal-GMM per-component log density, (N, d) -> (N, K) float32."""
    interpret = _auto_interpret(interpret)
    n, d = x.shape
    k = means.shape[0]
    n_pad, k_pad, d_pad = _round_up(n, block_n), _round_up(k, block_k), _round_up(d, 128)
    a, b, c = _pack_params(means, variances, log_weights, d_pad, k_pad)
    xp = jnp.zeros((n_pad, d_pad), jnp.float32).at[:n, :d].set(x)
    out = gmm_logpdf_pallas(xp, a, b, c, block_n=block_n, block_k=block_k,
                            interpret=interpret)
    return out[:n, :k]


def estep_stats(x: jax.Array, means: jax.Array, variances: jax.Array,
                log_weights: jax.Array,
                sample_weight: jax.Array | None = None, *,
                block_n: int = 512, interpret: bool | None = None):
    """Fused E-step statistics. Returns (s0 (K,), s1 (K,d), s2 (K,d), ll)."""
    interpret = _auto_interpret(interpret)
    n, d = x.shape
    k = means.shape[0]
    n_pad = _round_up(n, block_n)
    d_pad = _round_up(d, 128)
    k_pad = _round_up(k, 128)
    a, b, c = _pack_params(means, variances, log_weights, d_pad, k_pad,
                           pad_c=_NEG_BIG)
    xp = jnp.zeros((n_pad, d_pad), jnp.float32).at[:n, :d].set(x)
    w = jnp.ones(n, jnp.float32) if sample_weight is None else sample_weight
    wp = jnp.zeros((n_pad, 1), jnp.float32).at[:n, 0].set(w)
    s0, s1, s2, ll = estep_stats_pallas(xp, wp, a, b, c, block_n=block_n,
                                        interpret=interpret)
    return s0[0, :k], s1[:k, :d], s2[:k, :d], ll[0, 0]


def kmeans_assign(x: jax.Array, centers: jax.Array, *,
                  block_n: int = 512, interpret: bool | None = None):
    """Nearest-center assignment. Returns ((N,) int32, (N,) squared dist)."""
    interpret = _auto_interpret(interpret)
    n, d = x.shape
    k = centers.shape[0]
    n_pad = _round_up(n, block_n)
    d_pad = _round_up(d, 128)
    k_pad = _round_up(k, 128)
    xp = jnp.zeros((n_pad, d_pad), jnp.float32).at[:n, :d].set(x)
    ct = jnp.zeros((d_pad, k_pad), jnp.float32).at[:d, :k].set(centers.T)
    c2 = jnp.full((1, k_pad), 1e30, jnp.float32).at[0, :k].set(
        jnp.sum(centers * centers, axis=1))
    idx, d2 = kmeans_assign_pallas(xp, ct, c2, block_n=block_n,
                                   interpret=interpret)
    return idx[:n, 0], d2[:n, 0]
