"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp

LOG_2PI = 1.8378770664093453


def gmm_logpdf_ref(x: jax.Array, means: jax.Array, variances: jax.Array,
                   log_weights: jax.Array | None = None) -> jax.Array:
    """Per-component diagonal-Gaussian log density. (N,d),(K,d),(K,d)->(N,K).

    If log_weights is given, returns log(w_k N(x|...)) (the E-step numerator).
    """
    d = x.shape[-1]
    inv_var = 1.0 / variances
    a = (x * x) @ inv_var.T
    b = x @ (means * inv_var).T
    c = jnp.sum(means * means * inv_var + jnp.log(variances), axis=-1)
    out = -0.5 * (a - 2.0 * b + c[None, :] + d * LOG_2PI)
    if log_weights is not None:
        out = out + log_weights[None, :]
    return out


def estep_stats_ref(x: jax.Array, means: jax.Array, variances: jax.Array,
                    log_weights: jax.Array,
                    sample_weight: jax.Array | None = None):
    """Fused E-step sufficient statistics (diagonal covariance).

    Returns (s0 (K,), s1 (K,d), s2 (K,d), loglik ()).
    """
    n = x.shape[0]
    w = jnp.ones(n, x.dtype) if sample_weight is None else sample_weight
    lp = gmm_logpdf_ref(x, means, variances, log_weights)       # (N, K)
    log_norm = jax.scipy.special.logsumexp(lp, axis=1)           # (N,)
    resp = jnp.exp(lp - log_norm[:, None]) * w[:, None]          # (N, K)
    s0 = jnp.sum(resp, axis=0)
    s1 = resp.T @ x
    s2 = resp.T @ (x * x)
    loglik = jnp.sum(log_norm * w)
    return s0, s1, s2, loglik


def kmeans_assign_ref(x: jax.Array, centers: jax.Array):
    """Squared distances + argmin assignment. (N,d),(K,d) -> ((N,), (N,))."""
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    c2 = jnp.sum(centers * centers, axis=1)[None, :]
    d2 = jnp.maximum(x2 - 2.0 * (x @ centers.T) + c2, 0.0)
    return jnp.argmin(d2, axis=1), jnp.min(d2, axis=1)
