"""The serving facade: :class:`Scorer` — score rows against a published
global model without touching the engine plumbing (DESIGN.md §10).

``repro.serve`` exposes the full streaming machinery (slot pools,
micro-batches, hot swap); :class:`Scorer` is the two-line version for
callers that just have rows to score::

    from repro.api import Scorer

    scorer = Scorer.from_checkpoint("runs/models")   # latest version
    anomaly = scorer.score(x)                        # (n,) float32

A ``Scorer`` built with ``follow=True`` (the default for
``from_checkpoint``) keeps watching the model store: when the federation
runtime publishes a new round's global model, the next ``score`` call is
served by it — the drain-and-install swap guarantees every batch is
scored by exactly one model version, reported in
:attr:`Scorer.model_version`.
"""
from __future__ import annotations

from typing import Optional, Union

import numpy as np

from repro.core.gmm import GMM
from repro.serve.engine import ScoringEngine
from repro.serve.model_store import ModelStore
from repro.serve.types import ScoreConfig, ScoreRequest


class Scorer:
    """Batch-in / scores-out facade over the continuous-batching engine.

    - ``gmm``: the model to serve (any :class:`repro.core.gmm.GMM` — a
      fitted estimator's ``gmm_``, a federated result's ``global_gmm_``,
      or a loaded checkpoint).
    - ``mode``: ``"log_prob"`` (per-row mixture log density),
      ``"anomaly"`` (its negation — higher = more anomalous, the paper's
      §5.4 detector) or ``"responsibilities"`` (per-row posterior over
      the K components).
    - ``slots`` / ``rows_per_slot`` / ``backend`` / ``poll_every``:
      engine knobs, validated by :class:`repro.serve.ScoreConfig`.
    - ``version``: tag reported for this model (a store-backed scorer
      tracks the published version instead).

    Prefer :meth:`from_checkpoint` when the model lives in a versioned
    store directory published by the training loop.
    """

    def __init__(self, gmm: GMM, mode: str = "log_prob", *,
                 slots: int = 8, rows_per_slot: int = 512,
                 backend: str = "auto", poll_every: int = 1,
                 version: Union[int, str] = "v0", _store=None):
        config = ScoreConfig(mode=mode, slots=slots,
                             rows_per_slot=rows_per_slot, backend=backend,
                             poll_every=poll_every)
        self._engine = ScoringEngine(gmm, config, version=version,
                                     store=_store)
        self._next_rid = 0

    @classmethod
    def from_checkpoint(cls, root, mode: str = "log_prob", *,
                        version: Optional[int] = None, follow: bool = True,
                        **knobs) -> "Scorer":
        """Build a scorer from a versioned model-store directory (the one
        the training side publishes into with
        ``repro.serve.ModelStore.publish`` or
        ``repro.checkpoint.publish_checkpoint``).

        - ``version=None`` serves the latest published model; an int pins
          a specific version.
        - ``follow=True`` (only valid with ``version=None``) keeps the
          subscription attached: newly published models hot-swap in
          between batches.
        - ``**knobs`` are the :class:`Scorer` engine knobs
          (``slots=...``, ``backend=...``, ...).

        Raises :class:`FileNotFoundError` when nothing has been published
        under ``root`` yet.
        """
        store = ModelStore(root)
        if version is not None:
            published = store.load(version)
            follow = False
        else:
            published = store.latest()
            if published is None:
                raise FileNotFoundError(
                    f"no published model under {str(root)!r}")
        return cls(published.gmm, mode,
                   version=published.version,
                   _store=store if follow else None, **knobs)

    @property
    def model_version(self) -> Union[int, str]:
        """Version tag of the model currently being served."""
        return self._engine.version

    @property
    def gmm(self) -> GMM:
        """The currently served model."""
        return self._engine.gmm

    @property
    def engine(self) -> ScoringEngine:
        """The underlying :class:`repro.serve.ScoringEngine`, for callers
        that want the streaming interface (``submit`` / ``step``)."""
        return self._engine

    def score(self, rows) -> np.ndarray:
        """Score one batch of rows -> per-row scores, row-aligned with the
        input: ``(n,)`` f32 for log_prob/anomaly, ``(n, K)`` f32 for
        responsibilities. Polls the attached store first, so a
        store-following scorer always serves the newest published model
        (check :attr:`model_version` for which one that was)."""
        rid = self._next_rid
        self._next_rid += 1
        self._engine.submit(ScoreRequest(rid, np.asarray(rows)))
        results = self._engine.drain()
        (result,) = [r for r in results if r.rid == rid]
        return result.scores
