"""repro.api — THE public estimator surface (DESIGN.md §8).

One frozen, validated :class:`FitConfig` carries every training knob
(backend, chunk_size, covariance_type, reg_covar, tol, max_iter, init
strategy, seed policy); four facades dispatch on the input type (resident
array · DataSource · ClientSplit · list of sources), so the parallel
``*_streaming`` / ``*_source`` / ``*_from_sources`` entry-point families
are internal details:

    from repro.api import FitConfig, GMMEstimator, FedGenGMM

    est = GMMEstimator(k=8, chunk_size=65536).fit(NpyFileSource("x.npy"))
    fed = FedGenGMM(k_clients=4, k_global=4).run(split)

``score`` / ``log_prob`` / ``bic`` are the matching model-level scorers.
Everything below this package (``repro.core.*`` entry points included) is
internal; ``tests/test_api_surface.py`` snapshots this surface so drift
fails CI.
"""
from repro.core.config import DEFAULT_SOURCE_CHUNK, FitConfig
from repro.api.estimators import (DEM, FedGenGMM, GMMEstimator,
                                  KMeansEstimator, bic, log_prob, score)

__all__ = [
    "FitConfig",
    "GMMEstimator",
    "KMeansEstimator",
    "FedGenGMM",
    "DEM",
    "score",
    "log_prob",
    "bic",
    "DEFAULT_SOURCE_CHUNK",
]
