"""repro.api — THE public estimator surface (DESIGN.md §8/§9).

One frozen, validated :class:`FitConfig` carries every training knob
(backend, chunk_size, covariance_type, reg_covar, tol/max_iter with
per-algorithm "auto" resolution, init strategy, seed policy); the facades
dispatch on the input type (resident array · DataSource · ClientSplit ·
list of sources), so the parallel ``*_streaming`` / ``*_source`` /
``*_from_sources`` entry-point families are internal details:

    from repro.api import FitConfig, GMMEstimator, FedGenGMM

    est = GMMEstimator(k=8, chunk_size=65536).fit(NpyFileSource("x.npy"))
    fed = FedGenGMM(k_clients=4, k_global=4).run(split)

The federated runners — one-shot :class:`FedGenGMM` and the iterative
baselines :class:`DEM`, :class:`FedEM`, :class:`FedKMeans` — all run on
the §9 federation runtime and return results carrying a dtype-aware
communication ledger; :func:`fit_federated` is the ``strategy=`` seam
(named strategies or a custom ``repro.fed.FederationStrategy``), and its
``transform=`` keyword installs an uplink transform
(``repro.fed.transforms`` §11: DP, quantization, secure-agg masking) —
:class:`DPConfig` is the FitConfig-style budget sugar FedGenGMM takes
directly (``FedGenGMM(..., dp=DPConfig(epsilon=1.0))``).
``score`` / ``log_prob`` / ``bic`` are the matching model-level scorers,
and :class:`Scorer` is the serving facade — score rows against the
latest *published* global model (hot-swapping as new rounds land) via
the §10 continuous-batching engine. Everything below this package
(``repro.core.*`` entry points included) is internal;
``tests/test_api_surface.py`` snapshots this surface so drift fails CI.
"""
from repro.core.config import DEFAULT_SOURCE_CHUNK, FitConfig
from repro.core.privacy import DPConfig
from repro.api.estimators import (DEM, FedEM, FedGenGMM, FedKMeans,
                                  GMMEstimator, KMeansEstimator, bic,
                                  fit_federated, log_prob, score)
from repro.api.serving import Scorer

__all__ = [
    "FitConfig",
    "DPConfig",
    "GMMEstimator",
    "KMeansEstimator",
    "FedGenGMM",
    "DEM",
    "FedEM",
    "FedKMeans",
    "fit_federated",
    "score",
    "log_prob",
    "bic",
    "Scorer",
    "DEFAULT_SOURCE_CHUNK",
]
