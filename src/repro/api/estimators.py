"""Estimator facades: one stable public surface over the training stack
(DESIGN.md §8).

Each facade holds exactly one validated :class:`FitConfig` and dispatches
on the *type* of the data it is handed — a resident ``(N, d)`` array, a
single out-of-core :class:`DataSource`, a padded federated
:class:`ClientSplit`, or a list of per-client sources — so the parallel
``*_streaming`` / ``*_source`` / ``*_from_sources`` entry-point families
of PRs 1–3 collapse into four classes:

=====================  ==================================================
facade                 accepted inputs
=====================  ==================================================
``GMMEstimator.fit``   ``(N, d)`` array · ``DataSource``
``KMeansEstimator.fit``  ``(N, d)`` array · ``DataSource``
``FedGenGMM.run``      ``ClientSplit`` · list of ``DataSource``
``DEM.run``            ``ClientSplit`` · list of ``DataSource``
=====================  ==================================================

The facades are thin by construction: they validate, resolve the PRNG key
from the config's seed policy, and call the cfg-core functions
(``fit_gmm_cfg`` & co.) — the same code the legacy keyword entry points
run — so facade fits are bit-identical to the pre-refactor entry points
for the same configuration.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.config import (FitConfig, is_source, is_source_list,
                               require_array_weights)
from repro.core.dem import DEMResult, _resolve_init, dem_cfg
from repro.core.em import (EMResult, bic_streaming, fit_gmm_bic_cfg,
                           fit_gmm_cfg, log_prob_chunked, score_streaming)
from repro.core.fedgen import FedGenResult, fedgengmm_cfg
from repro.core.gmm import GMM
from repro.core.kmeans import KMeansResult, kmeans_fit_cfg
from repro.core.partition import ClientSplit
from repro.core.privacy import DPConfig
from repro.fed.runtime import FederationStrategy, run_rounds
from repro.fed.transforms import GaussianDP
from repro.fed.strategies import (FedEMResult, FedKMeansResult,
                                  _resolve_fedkmeans_init, fed_kmeans_cfg,
                                  fedem_cfg)


def _make_config(config: Optional[FitConfig], overrides: dict) -> FitConfig:
    """One config per facade: an explicit ``FitConfig``, field overrides
    on top of it (or of the defaults), or both. Validation happens in
    ``FitConfig`` itself — exactly once, at construction."""
    cfg = config if config is not None else FitConfig()
    if not isinstance(cfg, FitConfig):
        raise TypeError(f"config must be a FitConfig, "
                        f"got {type(cfg).__name__}")
    if overrides:
        valid = {f.name for f in dataclasses.fields(FitConfig)}
        unknown = set(overrides) - valid
        if unknown:
            raise TypeError(
                f"unknown FitConfig field(s) {sorted(unknown)}; "
                f"valid fields: {sorted(valid)}")
        cfg = cfg.replace(**overrides)
    return cfg


_INPUT_NAMES = {"array": "an (N, d) array", "source": "a DataSource",
                "sources": "a list of per-client DataSources",
                "split": "a ClientSplit"}


def _accept_names(accept: tuple) -> str:
    return " or ".join(_INPUT_NAMES[a] for a in accept)


def _classify(data, who: str, accept: tuple) -> str:
    """THE input-type dispatch map (§8): array | source | sources | split,
    with a pointed error naming what ``who`` accepts."""
    if is_source(data):
        kind = "source"
    elif isinstance(data, ClientSplit):
        kind = "split"
    elif is_source_list(data):
        kind = "sources"
    elif isinstance(data, (list, tuple)):
        if not data:
            raise TypeError(
                f"{who}: got an empty {type(data).__name__} — "
                + ("need at least one client DataSource"
                   if "sources" in accept else
                   f"{who} accepts {_accept_names(accept)}"))
        if "sources" not in accept:
            raise TypeError(
                f"{who}: got a {type(data).__name__} — {who} accepts "
                f"{_accept_names(accept)}")
        raise TypeError(
            f"{who}: got a {type(data).__name__} that is not a list of "
            f"DataSources; federated clients must all be DataSource "
            f"instances (wrap resident shards in ArraySource)")
    elif hasattr(data, "shape") and hasattr(data, "ndim"):
        kind = "array"
    else:
        raise TypeError(
            f"{who}: cannot dispatch input of type {type(data).__name__}")
    if kind not in accept:
        raise TypeError(
            f"{who} accepts {_accept_names(accept)}, "
            f"got {_INPUT_NAMES[kind]}")
    return kind


def _check_weights(kind: str, sample_weight, who: str) -> None:
    """Satellite rule, enforced once at the facade boundary: sample
    weights are array-path-only by design."""
    if kind == "source":
        require_array_weights(sample_weight, who)


def _resolve_key(key: Optional[jax.Array], config: FitConfig) -> jax.Array:
    """Seed policy: an explicit key wins; otherwise the config's seed."""
    return config.key() if key is None else key


def _as_int(value, name: str, minimum: int = 1) -> int:
    """Same integral strictness as FitConfig's knobs: truncating k=3.7
    would mask division-gone-wrong caller bugs."""
    if isinstance(value, bool) or int(value) != value:
        raise ValueError(f"{name} must be an integer, got {value!r}")
    value = int(value)
    if value < minimum:
        raise ValueError(f"{name} must be >= {minimum}, got {value}")
    return value


# ----------------------------------------------------------------------
# Model-level scoring helpers (facade twins of the streaming scorers)
# ----------------------------------------------------------------------

def score(gmm: GMM, data, sample_weight=None,
          config: Optional[FitConfig] = None) -> jax.Array:
    """Average log-likelihood of ``data`` under ``gmm`` (the paper's
    fitness score, Eq. 2) — array or :class:`DataSource`, chunked per the
    config (O(chunk·K) memory with an integer ``chunk_size``)."""
    cfg = config if config is not None else FitConfig()
    kind = _classify(data, "repro.api.score", ("array", "source"))
    _check_weights(kind, sample_weight, "repro.api.score over a DataSource")
    return score_streaming(gmm, data, sample_weight,
                           chunk_size=cfg.resolve_chunk(kind == "source"),
                           backend=cfg.backend)


def log_prob(gmm: GMM, data, config: Optional[FitConfig] = None) -> jax.Array:
    """Per-row mixture log density -> (N,), chunked per the config (the
    anomaly-detection scorer; the (N, K) density block never exists)."""
    cfg = config if config is not None else FitConfig()
    kind = _classify(data, "repro.api.log_prob", ("array", "source"))
    return log_prob_chunked(gmm, data,
                            chunk_size=cfg.resolve_chunk(kind == "source"),
                            backend=cfg.backend)


def bic(gmm: GMM, data, sample_weight=None,
        config: Optional[FitConfig] = None) -> jax.Array:
    """Bayesian Information Criterion (lower is better), chunked per the
    config — what makes model selection over candidate K constant-memory."""
    cfg = config if config is not None else FitConfig()
    kind = _classify(data, "repro.api.bic", ("array", "source"))
    _check_weights(kind, sample_weight, "repro.api.bic over a DataSource")
    return bic_streaming(gmm, data, sample_weight,
                         chunk_size=cfg.resolve_chunk(kind == "source"),
                         backend=cfg.backend)


# ----------------------------------------------------------------------
# Single-model estimators
# ----------------------------------------------------------------------

class GMMEstimator:
    """EM-trained Gaussian mixture (the paper's TrainGMM, Algorithm 4.1).

    Fix ``k`` for a single fit, or pass ``k_candidates`` for BIC model
    selection (``bics_`` then holds every candidate's score). ``fit``
    accepts a resident ``(N, d)`` array or a :class:`DataSource` (init, EM
    and scoring then run out-of-core); after fitting, ``gmm_`` /
    ``result_`` hold the model and the full :class:`EMResult`.

        est = GMMEstimator(k=8, chunk_size=65536).fit(NpyFileSource(p))
        est.score(x_test)
    """

    def __init__(self, k: Optional[int] = None, *,
                 k_candidates: Optional[Sequence[int]] = None,
                 config: Optional[FitConfig] = None, **overrides):
        if (k is None) == (k_candidates is None):
            raise ValueError(
                "pass exactly one of k (single fit) or k_candidates "
                "(BIC model selection)")
        self.k = None if k is None else _as_int(k, "k")
        self.k_candidates = (None if k_candidates is None else tuple(
            _as_int(kc, "k_candidates entry") for kc in k_candidates))
        self.config = _make_config(config, overrides)
        if self.config.init not in ("auto", "kmeans"):
            raise ValueError(
                f"GMMEstimator init strategy must be 'auto' or 'kmeans' "
                f"(the DEM schemes do not apply), got {self.config.init!r}")
        self.gmm_: Optional[GMM] = None
        self.result_: Optional[EMResult] = None
        self.bics_: Optional[dict[int, float]] = None

    def fit(self, data, *, sample_weight=None,
            init_gmm: Optional[GMM] = None,
            key: Optional[jax.Array] = None) -> "GMMEstimator":
        """Fit on a resident ``(N, d)`` array or a :class:`DataSource`
        (out-of-core). ``sample_weight`` is per-row (resident data only);
        ``init_gmm`` warm-starts EM (exclusive with ``k_candidates``);
        ``key`` overrides the config's seed policy. Returns ``self``."""
        kind = _classify(data, "GMMEstimator.fit", ("array", "source"))
        _check_weights(kind, sample_weight,
                       "GMMEstimator.fit over a DataSource")
        if kind == "array":
            data = jnp.asarray(data)
        key = _resolve_key(key, self.config)
        if self.k_candidates is None:
            self.result_ = fit_gmm_cfg(key, data, self.k, self.config,
                                       sample_weight, init_gmm)
            self.bics_ = None
        else:
            if init_gmm is not None:
                raise ValueError("init_gmm and k_candidates are exclusive "
                                 "(each candidate K needs its own init)")
            self.result_, self.bics_ = fit_gmm_bic_cfg(
                key, data, self.k_candidates, self.config, sample_weight)
        self.gmm_ = self.result_.gmm
        return self

    # scoring rides the same config (backend + chunking) as the fit
    def _fitted(self) -> GMM:
        if self.gmm_ is None:
            raise RuntimeError("estimator is not fitted; call fit() first")
        return self.gmm_

    def score(self, data, sample_weight=None) -> jax.Array:
        """Average per-row log-likelihood of ``data`` (array or
        :class:`DataSource`) under the fitted model — a scalar."""
        return score(self._fitted(), data, sample_weight, self.config)

    def log_prob(self, data) -> jax.Array:
        """Per-row mixture log density under the fitted model -> (N,)."""
        return log_prob(self._fitted(), data, self.config)

    def bic(self, data, sample_weight=None) -> jax.Array:
        """Bayesian information criterion of the fitted model on ``data``
        (lower is better) — the model-selection score behind
        ``k_candidates``."""
        return bic(self._fitted(), data, sample_weight, self.config)


class KMeansEstimator:
    """Weighted Lloyd's algorithm with k-means++ seeding (also DEM init 3
    and the GMM init leg). ``n_init`` restarts keep the lowest-inertia
    centers. ``fit`` accepts a resident ``(N, d)`` array or a
    :class:`DataSource` (streamed seeding + host-loop sweeps;
    ``assignments_`` is then None — it would be the only O(N) output)."""

    def __init__(self, k: int, *, n_init: int = 1,
                 config: Optional[FitConfig] = None, **overrides):
        self.k = _as_int(k, "k")
        self.n_init = _as_int(n_init, "n_init")
        self.config = _make_config(config, overrides)
        if self.config.init not in ("auto", "kmeans"):
            raise ValueError(
                f"KMeansEstimator seeding is k-means++; init must stay "
                f"'auto' or 'kmeans', got {self.config.init!r}")
        self.result_: Optional[KMeansResult] = None

    def fit(self, data, *, sample_weight=None,
            key: Optional[jax.Array] = None) -> "KMeansEstimator":
        """Fit on a resident ``(N, d)`` array or a :class:`DataSource`.
        ``sample_weight`` is per-row (resident data only); ``key``
        overrides the config's seed policy. Returns ``self``."""
        kind = _classify(data, "KMeansEstimator.fit", ("array", "source"))
        _check_weights(kind, sample_weight,
                       "KMeansEstimator.fit over a DataSource")
        if kind == "array":
            data = jnp.asarray(data)
        key = _resolve_key(key, self.config)
        self.result_ = kmeans_fit_cfg(key, data, self.k, self.config,
                                      sample_weight, self.n_init)
        return self

    @property
    def centers_(self):
        """Fitted ``(k, d)`` cluster centers (best restart)."""
        if self.result_ is None:
            raise RuntimeError("estimator is not fitted; call fit() first")
        return self.result_.centers

    @property
    def assignments_(self):
        """Per-row cluster index ``(N,)`` — None after a DataSource fit
        (the only O(N) output is skipped out-of-core)."""
        if self.result_ is None:
            raise RuntimeError("estimator is not fitted; call fit() first")
        return self.result_.assignments

    @property
    def inertia_(self):
        """Weighted sum of squared distances to the assigned centers
        (the quantity ``n_init`` restarts minimize)."""
        if self.result_ is None:
            raise RuntimeError("estimator is not fitted; call fit() first")
        return self.result_.inertia


# ----------------------------------------------------------------------
# Federated runners
# ----------------------------------------------------------------------

class FedGenGMM:
    """The paper's one-shot federated pipeline (Algorithm 4.1): local EM
    per client, ONE communication round of (K, 2d+1) parameter blocks,
    server-side merge -> synthetic replay -> global refit.

    ``run(clients)`` dispatches on the client container: a padded
    :class:`ClientSplit` trains residents under vmap; a list of
    :class:`DataSource` streams every local fit and (by default,
    ``synthetic="auto"``) replays the merged mixture as a seeded block
    stream, so no stage holds O(N) rows. Returns a
    :class:`repro.core.fedgen.FedGenResult`.
    """

    def __init__(self, *, k_clients: Optional[int] = None,
                 k_global: Optional[int] = None,
                 k_candidates: Optional[Sequence[int]] = None,
                 h: int = 100, synthetic: str = "auto",
                 dp: Optional[DPConfig] = None, transform=None,
                 config: Optional[FitConfig] = None, **overrides):
        if k_clients is None and k_candidates is None:
            raise ValueError("pass k_clients (fixed local K) or "
                             "k_candidates (per-client BIC selection)")
        if k_global is None and k_candidates is None:
            raise ValueError("pass k_global (fixed global K) or "
                             "k_candidates (server-side BIC selection)")
        if synthetic not in ("auto", "resident", "source"):
            raise ValueError(f"synthetic must be 'auto', 'resident' or "
                             f"'source', got {synthetic!r}")
        self.k_clients = (None if k_clients is None
                          else _as_int(k_clients, "k_clients"))
        self.k_global = (None if k_global is None
                         else _as_int(k_global, "k_global"))
        self.k_candidates = (None if k_candidates is None else tuple(
            _as_int(kc, "k_candidates entry") for kc in k_candidates))
        self.h = _as_int(h, "h")
        self.synthetic = synthetic
        if dp is not None and transform is not None:
            raise ValueError(
                "pass dp (a DPConfig, sugar for a one-shot GaussianDP "
                "uplink transform) OR transform (any PayloadTransform), "
                "not both")
        if dp is not None:
            if not isinstance(dp, DPConfig):
                raise TypeError(
                    f"dp must be a DPConfig, got {type(dp).__name__}")
            transform = GaussianDP(epsilon=float(dp.epsilon),
                                   delta=float(dp.delta), rounds=1,
                                   min_count=float(dp.min_count))
        self.transform = transform
        self.config = _make_config(config, overrides)
        if self.config.init not in ("auto", "kmeans"):
            raise ValueError(
                f"FedGenGMM local fits use the k-means init; init must "
                f"stay 'auto' or 'kmeans' (the DEM schemes do not apply), "
                f"got {self.config.init!r}")
        self.result_: Optional[FedGenResult] = None

    def run(self, clients, *, key: Optional[jax.Array] = None) -> FedGenResult:
        """Run the one-shot pipeline over a :class:`ClientSplit` (vmapped
        residents) or a list of per-client :class:`DataSource`\\ s
        (streamed) -> :class:`repro.core.fedgen.FedGenResult`."""
        _classify(clients, "FedGenGMM.run", ("split", "sources"))
        key = _resolve_key(key, self.config)
        self.result_ = fedgengmm_cfg(
            key, clients, self.config, k_clients=self.k_clients,
            k_global=self.k_global, k_candidates=self.k_candidates,
            h=self.h, synthetic=self.synthetic, transform=self.transform)
        return self.result_

    @property
    def global_gmm_(self) -> GMM:
        """The merged-and-refit global mixture from the last ``run``."""
        if self.result_ is None:
            raise RuntimeError("runner has no result; call run() first")
        return self.result_.global_gmm


class DEM:
    """The iterative distributed-EM baseline (§5.4): one round of
    sufficient-statistics aggregation per EM iteration.

    ``run(clients)`` dispatches like :class:`FedGenGMM`; the init strategy
    comes from ``FitConfig.init`` ("auto" = fed-kmeans for splits,
    separated centers for source clients; "pilot" uploads raw rows and
    needs resident data). ``FitConfig.max_iter`` bounds the communication
    rounds. Returns a :class:`repro.core.dem.DEMResult`.
    """

    def __init__(self, k: int, *, transform=None, async_policy=None,
                 config: Optional[FitConfig] = None, **overrides):
        self.k = _as_int(k, "k")
        self.transform = transform
        self.async_policy = async_policy
        self.config = _make_config(config, overrides)
        # one copy of the strategy rule: construction-time validation
        # delegates to the core resolver (input-type resolution of "auto"
        # happens per run(); "pilot" additionally needs resident data)
        _resolve_init(self.config.init, sources=False)
        self.result_: Optional[DEMResult] = None

    def run(self, clients, *, key: Optional[jax.Array] = None) -> DEMResult:
        """Run distributed EM to convergence (or ``max_iter`` rounds)
        over a :class:`ClientSplit` or list of per-client
        :class:`DataSource`\\ s -> :class:`repro.core.dem.DEMResult`.
        With an ``async_policy`` (:class:`repro.fed.AsyncPolicy`) the
        rounds run buffered-asynchronously (``repro.fed.run_async``)."""
        _classify(clients, "DEM.run", ("split", "sources"))
        key = _resolve_key(key, self.config)
        self.result_ = dem_cfg(key, clients, self.config, self.k,
                               transform=self.transform,
                               async_policy=self.async_policy)
        return self.result_

    @property
    def global_gmm_(self) -> GMM:
        """The converged global mixture from the last ``run``."""
        if self.result_ is None:
            raise RuntimeError("runner has no result; call run() first")
        return self.result_.global_gmm


class FedEM:
    """Iterative federated EM (Tian et al.): per round, each participating
    client runs ``local_epochs`` local EM steps from the broadcast
    parameters and ships sufficient statistics; the server M-steps. With
    the default knobs this IS the DEM baseline bit for bit; the knobs are
    what stage the paper's accuracy-vs-communication comparison under
    realistic client availability.

    ``run(clients)`` dispatches like :class:`DEM` (ClientSplit or list of
    per-client DataSources; the sharded-mesh variant is
    ``repro.distributed.fedem_sharded``). ``participation`` in (0, 1] is
    the per-round cohort fraction; ``cohort`` picks how the driver
    samples it — ``"cyclic"`` (deterministic window, never empty, covers
    every client) or ``"uniform"`` (seeded sampling without replacement,
    ``cohort_seed``) — and ONLY the sampled clients compute, so a round
    costs O(cohort). ``stragglers`` (an
    :class:`repro.fed.ArrivalStragglers` or any ``drop_mask`` policy)
    drops each round's slowest arrivals to exact-zero contribution.
    ``local_epochs >= 1`` is the client-side EM steps per round. Init
    comes from ``FitConfig.init`` exactly as in DEM. Returns a
    :class:`repro.fed.strategies.FedEMResult` with the populated
    cohort-sized communication ledger (init-phase warm-start traffic
    included).
    """

    def __init__(self, k: int, *, participation: float = 1.0,
                 local_epochs: int = 1, cohort: str = "cyclic",
                 cohort_seed: int = 0, stragglers=None, transform=None,
                 async_policy=None, config: Optional[FitConfig] = None,
                 **overrides):
        self.k = _as_int(k, "k")
        if not 0.0 < float(participation) <= 1.0:
            raise ValueError(
                f"participation must be in (0, 1], got {participation}")
        if cohort not in ("cyclic", "uniform"):
            raise ValueError(
                f"cohort must be 'cyclic' or 'uniform', got {cohort!r}")
        self.participation = float(participation)
        self.local_epochs = _as_int(local_epochs, "local_epochs")
        self.cohort = cohort
        self.cohort_seed = _as_int(cohort_seed, "cohort_seed", minimum=0)
        self.stragglers = stragglers
        self.transform = transform
        self.async_policy = async_policy
        self.config = _make_config(config, overrides)
        # same strategy rule as DEM: validate the init scheme name now,
        # resolve "auto" per input type at run()
        _resolve_init(self.config.init, sources=False)
        self.result_: Optional[FedEMResult] = None

    def run(self, clients, *, key: Optional[jax.Array] = None) -> FedEMResult:
        """Run federated EM under the configured participation/cohort/
        straggler policy -> :class:`repro.fed.strategies.FedEMResult`
        (with the cohort-sized communication ledger)."""
        _classify(clients, "FedEM.run", ("split", "sources"))
        key = _resolve_key(key, self.config)
        self.result_ = fedem_cfg(key, clients, self.config, self.k,
                                 participation=self.participation,
                                 local_epochs=self.local_epochs,
                                 cohort=self.cohort,
                                 cohort_seed=self.cohort_seed,
                                 stragglers=self.stragglers,
                                 transform=self.transform,
                                 async_policy=self.async_policy)
        return self.result_

    @property
    def global_gmm_(self) -> GMM:
        """The final broadcast mixture from the last ``run``."""
        if self.result_ is None:
            raise RuntimeError("runner has no result; call run() first")
        return self.result_.global_gmm


class FedKMeans:
    """Iterative federated k-means (Garst et al.): per round, clients ship
    per-center label statistics (counts, sums, inertia) against the
    broadcast centers; the server recombines into new centers and stops on
    the squared center shift (``FitConfig.tol``, resolving through the
    k-means defaults — 1e-4 / 100 rounds).

    ``run(clients)`` dispatches like the other federated runners
    (sharded-mesh variant: ``repro.distributed.fed_kmeans_sharded``).
    ``FitConfig.init`` is "auto"/"fed-kmeans" (one-shot warm start,
    Dennis et al. '21) or "separated". Returns a
    :class:`repro.fed.strategies.FedKMeansResult`.
    """

    def __init__(self, k: int, *, transform=None,
                 config: Optional[FitConfig] = None, **overrides):
        self.k = _as_int(k, "k")
        self.transform = transform
        self.config = _make_config(config, overrides)
        _resolve_fedkmeans_init(self.config.init)
        self.result_: Optional[FedKMeansResult] = None

    def run(self, clients, *,
            key: Optional[jax.Array] = None) -> FedKMeansResult:
        """Run federated k-means to center convergence (or the round
        budget) -> :class:`repro.fed.strategies.FedKMeansResult`."""
        _classify(clients, "FedKMeans.run", ("split", "sources"))
        key = _resolve_key(key, self.config)
        self.result_ = fed_kmeans_cfg(key, clients, self.config, self.k,
                                      transform=self.transform)
        return self.result_

    @property
    def centers_(self):
        """The final ``(k, d)`` global centers from the last ``run``."""
        if self.result_ is None:
            raise RuntimeError("runner has no result; call run() first")
        return self.result_.centers


# The four named strategies of the §9 runtime, as facade constructors.
_STRATEGY_RUNNERS = {"fedgen": FedGenGMM, "dem": DEM, "fedem": FedEM,
                     "fedkmeans": FedKMeans}


def fit_federated(clients, *, strategy, key: Optional[jax.Array] = None,
                  config: Optional[FitConfig] = None, max_rounds=None,
                  sampler=None, stragglers=None, transform=None,
                  async_policy=None, **kwargs):
    """THE strategy seam for FitConfig-driven federated runs (§9).

    ``strategy`` is either a name — ``"fedgen"`` | ``"dem"`` | ``"fedem"``
    | ``"fedkmeans"`` — whose facade is constructed from ``config`` plus
    the remaining keyword arguments (``k=...``, ``participation=...``,
    ...), or a custom :class:`repro.fed.FederationStrategy` instance,
    which runs directly on the round driver (``max_rounds`` then bounds
    it; default: the config's EM round budget). Custom strategies also
    take the driver's cohort-execution seams directly: ``sampler`` (a
    ``repro.fed.cohort`` sampler — each round computes only its sampled
    cohort) and ``stragglers`` (a ``drop_mask`` policy). Named
    strategies express the same knobs through their own keywords
    (``participation=...``, ``cohort=...``, ``stragglers=...`` for
    FedEM). Scenario PRs plug in HERE: a new baseline is one strategy
    class, not a new entry-point family.

    ``transform`` installs an uplink :class:`repro.fed.PayloadTransform`
    (§11) — :class:`~repro.fed.GaussianDP`,
    :class:`~repro.fed.StochasticQuantize`,
    :class:`~repro.fed.PairwiseMask`, a :class:`~repro.fed.Compose` of
    them, or anything implementing the protocol — applied to every
    client's payload before the server aggregate, on every backend and
    for named and custom strategies alike.

    ``async_policy`` (a :class:`repro.fed.AsyncPolicy`) reroutes the
    round loop through the buffered asynchronous driver
    (``repro.fed.run_async``, §12): the server combines every
    ``buffer_size`` updates under the staleness-weighting rule instead
    of waiting for the full cohort. It applies to the iterative
    strategies — ``"dem"`` / ``"fedem"`` by name, or any custom
    iterative :class:`~repro.fed.FederationStrategy`.
    """
    if isinstance(strategy, str):
        if strategy not in _STRATEGY_RUNNERS:
            raise ValueError(
                f"unknown strategy {strategy!r}; named strategies are "
                f"{sorted(_STRATEGY_RUNNERS)} (or pass a "
                f"FederationStrategy instance)")
        if max_rounds is not None:
            raise TypeError(
                "max_rounds is for custom FederationStrategy instances; "
                "named strategies take FitConfig.max_iter")
        if sampler is not None:
            raise TypeError(
                "sampler is for custom FederationStrategy instances; "
                "named strategies build their own (FedEM: participation="
                "... with cohort='cyclic'|'uniform')")
        if stragglers is not None:
            kwargs["stragglers"] = stragglers
        if transform is not None:
            kwargs["transform"] = transform
        if async_policy is not None:
            if strategy not in ("dem", "fedem"):
                raise TypeError(
                    f"async_policy applies to the iterative strategies "
                    f"('dem', 'fedem'), not {strategy!r}")
            kwargs["async_policy"] = async_policy
        runner = _STRATEGY_RUNNERS[strategy](config=config, **kwargs)
        return runner.run(clients, key=key)
    if not isinstance(strategy, FederationStrategy):
        raise TypeError(
            f"strategy must be a name or a FederationStrategy "
            f"(local_step/server_combine/converged/...), got "
            f"{type(strategy).__name__}")
    if kwargs:
        raise TypeError(
            f"unknown argument(s) for a custom strategy run: "
            f"{sorted(kwargs)}")
    cfg = config if config is not None else FitConfig()
    if max_rounds is None:
        max_rounds = 1 if getattr(strategy, "one_shot", False) \
            else cfg.resolve_max_iter("em")
    key = _resolve_key(key, cfg)
    if async_policy is not None:
        from repro.fed.async_runtime import run_async
        return run_async(strategy, clients, key=key, max_rounds=max_rounds,
                         sampler=sampler, stragglers=stragglers,
                         transform=transform,
                         **async_policy.driver_kwargs())
    return run_rounds(strategy, clients, key=key, max_rounds=max_rounds,
                      sampler=sampler, stragglers=stragglers,
                      transform=transform)
