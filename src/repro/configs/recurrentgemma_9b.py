"""RecurrentGemma 9B [arXiv:2402.19427 Griffin / 2404.07839]: 38L hybrid,
d_model 4096, pattern = 2 RG-LRU recurrent blocks : 1 local attention block
(window 2048), 16 heads head_dim 256 MQA (kv=1), GeGLU d_ff 12288,
lru_width 5632, vocab 256000. 38 = 12 full (rec,rec,attn) groups + 2
trailing recurrent layers."""
from repro.configs.base import register
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="recurrentgemma-9b",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab_size=256000,
    activation="gelu", gated_mlp=True,
    pattern=("rglru", "rglru", "local_attn"), local_window=2048,
    d_rnn=5632, embed_scale=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-9b-smoke",
    n_layers=3, d_model=256, n_heads=4, n_kv_heads=1, head_dim=64,
    d_ff=512, vocab_size=512,
    activation="gelu", gated_mlp=True,
    pattern=("rglru", "rglru", "local_attn"), local_window=32,
    d_rnn=320, embed_scale=True, chunk_q=32, remat=False,
)

register("recurrentgemma-9b", FULL, SMOKE, "arXiv:2402.19427")
