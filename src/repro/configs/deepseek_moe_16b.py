"""DeepSeekMoE 16B [arXiv:2401.06066]: 28L, d_model 2048, 16 heads (MHA
kv=16), fine-grained experts d_ff 1408, vocab 102400, 64 routed experts
top-6 + 2 shared experts; first layer uses a dense FFN (width 10944)."""
from repro.configs.base import register
from repro.models.moe import MoEDims
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="deepseek-moe-16b",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=102400,
    pattern=("attn",),
    moe=MoEDims(n_experts=64, top_k=6, d_ff=1408, n_shared=2,
                group_size=512),
    first_k_dense=1, first_dense_d_ff=10944,
)

SMOKE = ModelConfig(
    name="deepseek-moe-16b-smoke",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
    d_ff=128, vocab_size=512,
    pattern=("attn",),
    moe=MoEDims(n_experts=4, top_k=2, d_ff=128, n_shared=1, group_size=64),
    first_k_dense=1, first_dense_d_ff=512,
    chunk_q=32, remat=False,
)

register("deepseek-moe-16b", FULL, SMOKE, "arXiv:2401.06066")
