"""Yi-6B [arXiv:2403.04652]: llama-arch GQA. 32L, d_model 4096, 32 heads
(GQA kv=4), d_ff 11008, vocab 64000."""
from repro.configs.base import register
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="yi-6b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=11008, vocab_size=64000,
    pattern=("attn",), rope_theta=5e6,
)

SMOKE = ModelConfig(
    name="yi-6b-smoke",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
    d_ff=512, vocab_size=512,
    pattern=("attn",), chunk_q=32, remat=False,
)

register("yi-6b", FULL, SMOKE, "arXiv:2403.04652")
