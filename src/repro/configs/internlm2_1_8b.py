"""InternLM2-1.8B [arXiv:2403.17297]: 24L, d_model 2048, 16 heads (GQA
kv=8), d_ff 8192, vocab 92544."""
from repro.configs.base import register
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="internlm2-1.8b",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=92544,
    pattern=("attn",),
)

SMOKE = ModelConfig(
    name="internlm2-1.8b-smoke",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
    d_ff=512, vocab_size=512,
    pattern=("attn",), chunk_q=32, remat=False,
)

register("internlm2-1.8b", FULL, SMOKE, "arXiv:2403.17297")
