"""xLSTM-350M [arXiv:2405.04517]: 24 blocks alternating mLSTM (matrix
memory, parallel form) and sLSTM (scalar memory, sequential), d_model 1024,
4 heads, no separate FFN (d_ff=0 — blocks carry their own projections),
vocab 50304 (GPT-NeoX tokenizer rounding)."""
from repro.configs.base import register
from repro.models.transformer import ModelConfig
from repro.models.xlstm import XLSTMDims

FULL = ModelConfig(
    name="xlstm-350m",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, head_dim=256,
    d_ff=0, vocab_size=50304,
    pattern=("mlstm", "slstm"),
    xlstm=XLSTMDims(n_heads=4, head_dim=512, up_factor=2),  # d_inner = 2048
)

SMOKE = ModelConfig(
    name="xlstm-350m-smoke",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
    d_ff=0, vocab_size=512,
    pattern=("mlstm", "slstm"),
    xlstm=XLSTMDims(n_heads=4, head_dim=128, up_factor=2),
    chunk_q=32, remat=False,
)

register("xlstm-350m", FULL, SMOKE, "arXiv:2405.04517")
