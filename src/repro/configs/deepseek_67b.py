"""DeepSeek 67B [arXiv:2401.02954]: llama-arch. 95L, d_model 8192, 64 heads
(GQA kv=8), d_ff 22016, vocab 102400."""
from repro.configs.base import register
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="deepseek-67b",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22016, vocab_size=102400,
    pattern=("attn",),
)

SMOKE = ModelConfig(
    name="deepseek-67b-smoke",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
    d_ff=512, vocab_size=512,
    pattern=("attn",), chunk_q=32, remat=False,
)

register("deepseek-67b", FULL, SMOKE, "arXiv:2401.02954")
