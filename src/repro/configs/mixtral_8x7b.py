"""Mixtral 8x7B [arXiv:2401.04088]: 32L, d_model 4096, 32 heads (GQA kv=8),
d_ff 14336 per expert, vocab 32000, 8 experts top-2, sliding-window
attention (W=4096)."""
from repro.configs.base import register
from repro.models.moe import MoEDims
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="mixtral-8x7b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    pattern=("swa",), window=4096,
    moe=MoEDims(n_experts=8, top_k=2, d_ff=14336, group_size=1024),
    rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="mixtral-8x7b-smoke",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
    d_ff=512, vocab_size=512,
    pattern=("swa",), window=64,
    moe=MoEDims(n_experts=4, top_k=2, d_ff=512, group_size=64),
    chunk_q=32, remat=False,
)

register("mixtral-8x7b", FULL, SMOKE, "arXiv:2401.04088")
