"""InternVL2-26B [arXiv:2404.16821]: InternViT-6B vision encoder +
InternLM2-20B language model. The assignment specifies the language
backbone: 48L, d_model 6144, 48 heads (GQA kv=8), d_ff 16384, vocab 92553
(padded to 92672 = 16*5792 for tensor sharding).

The vision frontend (InternViT + MLP projector) is a STUB per the
assignment: input_specs provides 256 precomputed patch embeddings."""
from repro.configs.base import register
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="internvl2-26b",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=92672,  # 92553 padded for model-axis sharding
    pattern=("attn",),
    frontend="vision", n_prefix=256,
)

SMOKE = ModelConfig(
    name="internvl2-26b-smoke",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
    d_ff=512, vocab_size=512,
    pattern=("attn",),
    frontend="vision", n_prefix=16, chunk_q=32, remat=False,
)

register("internvl2-26b", FULL, SMOKE, "arXiv:2404.16821")
