"""Gemma 7B [arXiv:2403.08295]: 28L, d_model 3072, 16 heads (MHA kv=16),
head_dim 256, GeGLU d_ff 24576, vocab 256000, sqrt(d) embedding scaling."""
from repro.configs.base import register
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="gemma-7b",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab_size=256000,
    activation="gelu", gated_mlp=True,   # GeGLU
    pattern=("attn",), embed_scale=True,
)

SMOKE = ModelConfig(
    name="gemma-7b-smoke",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
    d_ff=512, vocab_size=512,
    activation="gelu", gated_mlp=True,
    pattern=("attn",), embed_scale=True, chunk_q=32, remat=False,
)

register("gemma-7b", FULL, SMOKE, "arXiv:2403.08295")
