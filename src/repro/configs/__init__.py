"""Assigned architecture configs (one module per arch; each cites its
source paper) + the input-shape registry used by the dry-run."""
from repro.configs.base import (INPUT_SHAPES, decode_capacity, get_citation,
                                get_config, input_specs, list_archs,
                                uses_ring)

__all__ = ["INPUT_SHAPES", "decode_capacity", "get_citation", "get_config",
           "input_specs", "list_archs", "uses_ring"]
