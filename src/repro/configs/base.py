"""Architecture registry + assigned input shapes + dry-run input specs.

Every assigned architecture registers a full-size ``ModelConfig`` (exact
paper dimensions) and a ``smoke`` reduced variant (<=2 layers, d_model<=512,
<=4 experts) used by the CPU smoke tests.

Input shapes (assigned):
    train_4k      seq_len=4096    global_batch=256   (train_step)
    prefill_32k   seq_len=32768   global_batch=32    (prefill_step)
    decode_32k    seq_len=32768   global_batch=128   (serve_step, full cache)
    long_500k     seq_len=524288  global_batch=1     (serve_step, ring cache)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.transformer import ModelConfig

INPUT_SHAPES = {
    "train_4k": {"seq_len": 4096, "global_batch": 256, "kind": "train"},
    "prefill_32k": {"seq_len": 32768, "global_batch": 32, "kind": "prefill"},
    "decode_32k": {"seq_len": 32768, "global_batch": 128, "kind": "decode"},
    "long_500k": {"seq_len": 524288, "global_batch": 1, "kind": "decode_ring"},
}

_REGISTRY: dict[str, dict] = {}


def register(arch_id: str, full: ModelConfig, smoke: ModelConfig,
             citation: str):
    _REGISTRY[arch_id] = {"full": full, "smoke": smoke, "citation": citation}


def get_config(arch_id: str, variant: str = "full") -> ModelConfig:
    _ensure_loaded()
    return _REGISTRY[arch_id][variant]


def get_citation(arch_id: str) -> str:
    _ensure_loaded()
    return _REGISTRY[arch_id]["citation"]


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded():
    if not _REGISTRY:
        from repro.configs import (deepseek_67b, deepseek_moe_16b, gemma_7b,
                                   internlm2_1_8b, internvl2_26b,
                                   mixtral_8x7b, recurrentgemma_9b,
                                   seamless_m4t_medium, xlstm_350m, yi_6b)


# ----------------------------------------------------------------------
# Dry-run input specs (ShapeDtypeStruct stand-ins, no allocation)
# ----------------------------------------------------------------------

def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """ShapeDtypeStruct pytree for one (arch, input-shape) combination.

    Modality frontends are STUBS per the assignment: vision/audio entries
    receive precomputed patch/frame embeddings of the right shape.
    """
    sh = INPUT_SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]
    if kind == "train":
        batch = {
            "tokens": sds((b, s), jnp.int32),
            "targets": sds((b, s), jnp.int32),
            "mask": sds((b, s), jnp.float32),
        }
        if cfg.frontend == "vision":
            batch["prefix"] = sds((b, cfg.n_prefix, cfg.d_model), cfg.dtype)
        if cfg.n_enc_layers:
            batch["src_embeds"] = sds((b, s // cfg.src_ratio, cfg.d_model),
                                      cfg.dtype)
        return {"batch": batch}
    if kind == "prefill":
        batch = {"tokens": sds((b, s), jnp.int32)}
        if cfg.frontend == "vision":
            batch["prefix"] = sds((b, cfg.n_prefix, cfg.d_model), cfg.dtype)
        if cfg.n_enc_layers:
            batch["src_embeds"] = sds((b, s // cfg.src_ratio, cfg.d_model),
                                      cfg.dtype)
        return {"batch": batch}
    # decode kinds: ONE new token + cache of the context length
    ring = kind == "decode_ring"
    capacity = cfg.long_window if ring else s
    from repro.models.transformer import init_cache
    cache = jax.eval_shape(
        lambda: init_cache(cfg, b, capacity,
                           enc_len=(s // cfg.src_ratio
                                    if cfg.n_enc_layers else 0)))
    return {
        "cache": cache,
        "token": sds((b,), jnp.int32),
        "pos": sds((), jnp.int32),
    }


def decode_capacity(cfg: ModelConfig, shape_name: str) -> int:
    sh = INPUT_SHAPES[shape_name]
    return cfg.long_window if sh["kind"] == "decode_ring" else sh["seq_len"]


def uses_ring(shape_name: str) -> bool:
    return INPUT_SHAPES[shape_name]["kind"] == "decode_ring"
