"""SeamlessM4T-medium [arXiv:2308.11596]: encoder-decoder, 12 encoder +
12 decoder layers, d_model 1024, 16 heads (MHA kv=16), d_ff 4096, vocab
256206 (padded to 256256 = 16*16016 for tensor sharding).

The speech frontend (mel-spectrogram + conformer feature extractor) is a
STUB per the assignment: input_specs provides precomputed frame embeddings
at seq_len // 4 (conv subsampling factor)."""
from repro.configs.base import register
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="seamless-m4t-medium",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=256256,  # 256206 padded
    pattern=("attn",),
    n_enc_layers=12, src_ratio=4,
    frontend="audio",
)

SMOKE = ModelConfig(
    name="seamless-m4t-medium-smoke",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
    d_ff=512, vocab_size=512,
    pattern=("attn",),
    n_enc_layers=2, src_ratio=4,
    frontend="audio", chunk_q=32, remat=False,
)

register("seamless-m4t-medium", FULL, SMOKE, "arXiv:2308.11596")
