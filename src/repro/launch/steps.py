"""Jit-able step functions (train / prefill / decode) with their sharding
specs — shared by the real trainer, the serving loop, and the dry-run.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, decode_capacity, uses_ring
from repro.launch.mesh import batch_axes, fsdp_axes
from repro.models.transformer import (ModelConfig, cache_specs, decode_step,
                                      init_cache, init_params, param_specs,
                                      prefill_forward, train_forward)
from repro.optim.adamw import (AdamWConfig, apply_updates, init_opt_state,
                               opt_state_specs)


def _serve_dtype(params_shape, cfg):
    """Serve weights in the compute dtype (bf16): halves weight traffic."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, cfg.dtype if jnp.issubdtype(s.dtype, jnp.floating)
            else s.dtype), params_shape)


def to_shardings(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def batch_specs(cfg: ModelConfig, shape_name: str, multi_pod: bool,
                kind: str):
    sh = INPUT_SHAPES[shape_name]
    bax = batch_axes(multi_pod, sh["global_batch"])
    specs = {"tokens": P(bax, None)}
    if kind == "train":
        specs["targets"] = P(bax, None)
        specs["mask"] = P(bax, None)
    if cfg.frontend == "vision":
        specs["prefix"] = P(bax, None, None)
    if cfg.n_enc_layers:
        specs["src_embeds"] = P(bax, None, None)
    return specs


# ----------------------------------------------------------------------
# Step functions
# ----------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig):
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            # cast params to the compute dtype ONCE, outside the layer
            # scan: FSDP all-gathers then move bf16, not f32 (halves the
            # dominant weight-gather traffic; §Perf iteration 4). The
            # astype boundary routes gradients back to f32 masters.
            p_compute = jax.tree.map(
                lambda a: a.astype(cfg.dtype)
                if jnp.issubdtype(a.dtype, jnp.floating) else a, p)
            loss, metrics = train_forward(p_compute, cfg, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params, opt_state, opt_metrics = apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, capacity: int, ring: bool = False):
    def prefill_step(params, batch):
        return prefill_forward(params, cfg, batch, capacity, ring)

    return prefill_step


def make_decode_step(cfg: ModelConfig, ring: bool = False):
    def serve_step(params, cache, token, pos):
        return decode_step(params, cfg, cache, token, pos, ring=ring)

    return serve_step


# ----------------------------------------------------------------------
# Jit assembly for one (arch, shape, mesh) combination
# ----------------------------------------------------------------------

def build_jitted(cfg: ModelConfig, shape_name: str, mesh, *,
                 multi_pod: bool,
                 opt_cfg: Optional[AdamWConfig] = None,
                 decode_cache_mode: str = "seq"):
    """Returns (jitted_fn, example_args as ShapeDtypeStructs)."""
    from repro.configs.base import input_specs, sds

    from repro.models import sharding_ctx

    sh = INPUT_SHAPES[shape_name]
    kind = sh["kind"]
    fsdp = fsdp_axes(multi_pod)
    bax = batch_axes(multi_pod, sh["global_batch"])
    expert_ax = None
    if cfg.moe is not None and cfg.moe.n_experts % 16 == 0:
        expert_ax = "model"
    sharding_ctx.set_axes(batch=bax, model="model", expert=expert_ax)
    p_specs = param_specs(cfg, fsdp=fsdp, model_axis_size=16)
    params_shape = jax.eval_shape(
        lambda: init_params(jax.random.key(0), cfg))

    if kind == "train":
        opt_cfg = opt_cfg or AdamWConfig()
        step = make_train_step(cfg, opt_cfg)
        o_specs = opt_state_specs(p_specs)
        opt_shape = jax.eval_shape(lambda: init_opt_state(
            jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         params_shape)))
        b_specs = batch_specs(cfg, shape_name, multi_pod, "train")
        in_sh = (to_shardings(mesh, p_specs), to_shardings(mesh, o_specs),
                 to_shardings(mesh, b_specs))
        out_sh = (to_shardings(mesh, p_specs), to_shardings(mesh, o_specs),
                  None)
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=(0, 1))
        batch = input_specs(cfg, shape_name)["batch"]
        return jitted, (params_shape, opt_shape, batch)

    if kind == "prefill":
        # Serving param layout (§Perf iteration 2): weights are stationary
        # in inference, so FSDP only adds per-layer all-gathers — replicate
        # over the data axes, shard over model, and serve in bf16.
        p_specs = param_specs(cfg, fsdp=None, model_axis_size=16)
        params_shape = _serve_dtype(params_shape, cfg)
        capacity = sh["seq_len"]
        step = make_prefill_step(cfg, capacity)
        b_specs = batch_specs(cfg, shape_name, multi_pod, "prefill")
        c_specs = cache_specs(cfg, bax, None)
        in_sh = (to_shardings(mesh, p_specs), to_shardings(mesh, b_specs))
        out_sh = (to_shardings(mesh, P(bax, "model")),
                  to_shardings(mesh, c_specs))
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
        batch = input_specs(cfg, shape_name)["batch"]
        return jitted, (params_shape, batch)

    # decode kinds — same serving param layout as prefill
    p_specs = param_specs(cfg, fsdp=None, model_axis_size=16)
    params_shape = _serve_dtype(params_shape, cfg)
    ring = uses_ring(shape_name)
    capacity = decode_capacity(cfg, shape_name)
    b = sh["global_batch"]
    seq_axis = None
    if bax is None:
        # B too small to shard: shard the cache length over the data axis
        seq_axis = "data"
    step = make_decode_step(cfg, ring)
    c_specs = cache_specs(cfg, bax, seq_axis, decode_cache_mode)
    enc_len = (sh["seq_len"] // cfg.src_ratio) if cfg.n_enc_layers else 0
    cache_shape = jax.eval_shape(
        lambda: init_cache(cfg, b, capacity, enc_len=enc_len))
    in_sh = (to_shardings(mesh, p_specs), to_shardings(mesh, c_specs),
             to_shardings(mesh, P(bax)), to_shardings(mesh, P()))
    out_sh = (to_shardings(mesh, P(bax, "model")),
              to_shardings(mesh, c_specs))
    jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(1,))
    from repro.configs.base import sds
    token = sds((b,), jnp.int32)
    pos = sds((), jnp.int32)
    return jitted, (params_shape, cache_shape, token, pos)
