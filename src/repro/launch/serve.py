"""Batched serving loop: queue -> batch -> prefill -> greedy decode ->
retire, with per-request latency stats and optional FedGenGMM activation
monitoring of the served traffic.

Batching model: slot-synchronous static batching — up to ``max_batch``
requests are padded to a common prompt length, prefilled together, then
decoded in lockstep until every request hits its token budget
(per-request early EOS masks it out of the loss-of-interest but the slot
runs on). This is deliberately the simple scheduler for the transformer
demo; the repo's real continuous-batching engine — free slots reused
mid-flight, one compiled slab shape, hot model swap — is
``repro.serve.ScoringEngine`` (DESIGN.md §10), which serves the paper's
GMM scoring/anomaly path.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --variant smoke --requests 12 --max-new 8
"""
from __future__ import annotations

import argparse
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import decode_step, init_params, prefill_forward


class Request(NamedTuple):
    rid: int
    prompt: np.ndarray          # (L,) int32
    max_new: int


class Result(NamedTuple):
    rid: int
    tokens: list[int]
    ttft_s: float               # time to first token (batch-level)
    latency_s: float


class ServeEngine:
    def __init__(self, cfg, params, max_batch: int = 8,
                 max_context: int = 256, monitor=None):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_context = max_context
        self.monitor = monitor
        self._prefill = jax.jit(
            lambda p, b: prefill_forward(p, cfg, b, capacity=max_context))
        self._step = jax.jit(
            lambda p, c, t, pos: decode_step(p, cfg, c, t, pos))

    def _pad_batch(self, reqs: list[Request]):
        b = len(reqs)
        lmax = max(len(r.prompt) for r in reqs)
        toks = np.zeros((b, lmax), np.int32)
        for i, r in enumerate(reqs):
            toks[i, lmax - len(r.prompt):] = r.prompt  # left-pad
        return jnp.asarray(toks), lmax

    def serve(self, queue: list[Request]) -> list[Result]:
        results: list[Result] = []
        qi = 0
        while qi < len(queue):
            reqs = queue[qi: qi + self.max_batch]
            qi += len(reqs)
            t0 = time.time()
            tokens, lmax = self._pad_batch(reqs)
            batch = {"tokens": tokens}
            if self.monitor is not None:
                self.monitor.observe(0, self.params, batch)
            logits, cache = self._prefill(self.params, batch)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            ttft = time.time() - t0
            outs = [[int(t)] for t in tok]
            max_new = max(r.max_new for r in reqs)
            for i in range(max_new - 1):
                logits, cache = self._step(self.params, cache, tok,
                                           jnp.asarray(lmax + i, jnp.int32))
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
                for j in range(len(reqs)):
                    if len(outs[j]) < reqs[j].max_new:
                        outs[j].append(int(tok[j]))
            dt = time.time() - t0
            for j, r in enumerate(reqs):
                results.append(Result(r.rid, outs[j], ttft, dt))
        return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--variant", default="smoke")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch, args.variant)
    params = init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    queue = [Request(i, rng.integers(0, min(cfg.vocab_size, 100),
                                     rng.integers(8, 33)).astype(np.int32),
                     args.max_new)
             for i in range(args.requests)]
    engine = ServeEngine(cfg, params, max_batch=args.max_batch)
    t0 = time.time()
    results = engine.serve(queue)
    dt = time.time() - t0
    total_toks = sum(len(r.tokens) for r in results)
    print(f"served {len(results)} requests / {total_toks} tokens in "
          f"{dt:.1f}s ({total_toks / dt:.1f} tok/s incl. compile)")
    for r in results[:3]:
        print(f"  rid={r.rid} ttft={r.ttft_s:.2f}s "
              f"latency={r.latency_s:.2f}s tokens={r.tokens}")


if __name__ == "__main__":
    main()
