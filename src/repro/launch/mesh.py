"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — only launch/dryrun.py (which sets the
512-device host-platform flag before any jax import) actually builds the
production meshes.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Small mesh over whatever devices actually exist (tests/examples)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def fsdp_axes(multi_pod: bool):
    """The spec entry used for FSDP sharding of parameters: batch-parallel
    axes also shard the parameter d_model/d_ff dimensions (ZeRO-3 style)."""
    return ("pod", "data") if multi_pod else "data"


def batch_axes(multi_pod: bool, global_batch: int):
    """Axes over which the batch dimension shards (None when the batch is
    too small to shard, e.g. long-context B=1 decode)."""
    total = 32 if multi_pod else 16
    if global_batch % total == 0:
        return ("pod", "data") if multi_pod else "data"
    if global_batch % 16 == 0:
        return "data"
    return None
