"""Training driver: runs real steps of any registered architecture on the
available devices (CPU smoke / host mesh) or lowers against the production
mesh. The FedGenGMM activation monitor (repro.monitor) can be attached to
collect pooled hidden-state features during training.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --variant smoke --steps 20 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.tokens import batches
from repro.launch.steps import make_train_step
from repro.models.transformer import init_params
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.checkpoint.store import save_checkpoint


def train(arch: str, variant: str = "smoke", steps: int = 50,
          batch_size: int = 8, seq_len: int = 128, lr: float = 3e-4,
          seed: int = 0, log_every: int = 10,
          checkpoint_path: str | None = None):
    cfg = get_config(arch, variant)
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=max(steps // 10, 1),
                          total_steps=steps)
    params = init_params(jax.random.key(seed), cfg)
    opt_state = init_opt_state(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))

    rng = np.random.default_rng(seed)
    losses = []
    t0 = time.time()
    for i, b in enumerate(batches(seed, cfg.vocab_size, batch_size, seq_len,
                                  steps)):
        batch = {"tokens": jnp.asarray(b.tokens),
                 "targets": jnp.asarray(b.targets),
                 "mask": jnp.asarray(b.mask)}
        if cfg.frontend == "vision":
            batch["prefix"] = jnp.asarray(
                rng.normal(0, 0.02, (batch_size, cfg.n_prefix, cfg.d_model)),
                cfg.dtype)
        if cfg.n_enc_layers:
            batch["src_embeds"] = jnp.asarray(
                rng.normal(0, 0.02,
                           (batch_size, seq_len // cfg.src_ratio,
                            cfg.d_model)), cfg.dtype)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if (i + 1) % log_every == 0 or i == 0:
            print(f"step {i + 1:4d} loss {losses[-1]:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.time() - t0) / (i + 1):.2f}s/step)", flush=True)
    if checkpoint_path:
        save_checkpoint(checkpoint_path, params,
                        {"step": steps, "arch": arch, "variant": variant})
        print(f"checkpoint -> {checkpoint_path}")
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--variant", default="smoke")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()
    _, losses = train(args.arch, args.variant, args.steps, args.batch,
                      args.seq, args.lr, checkpoint_path=args.checkpoint)
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f})")


if __name__ == "__main__":
    main()
