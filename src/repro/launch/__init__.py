"""Launcher: meshes, step functions, trainer, dry-run driver."""
