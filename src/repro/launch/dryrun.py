import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) combination against the production mesh, record memory analysis, cost
analysis and the collective schedule (bytes per collective op parsed from
the optimized HLO).

MUST be run as its own process (the two lines above force a 512-device host
platform before jax initializes — do not import this module from tests).

Usage:
    python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all [--mesh both] [--out experiments/dryrun]
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax

from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_jitted

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:calls=|to_apply=)%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str) -> tuple[dict, str]:
    """-> ({name: [lines]}, entry_name)."""
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_HDR.match(line.strip())
        if m and (line.startswith("ENTRY") or line.startswith("%")
                  or line.strip().startswith("%")
                  or line.strip().startswith("ENTRY")):
            cur = m.group(1)
            comps[cur] = []
            if line.strip().startswith("ENTRY"):
                entry = cur
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line.strip())
    return comps, entry


def _trip_count(cond_lines: list[str]) -> int:
    """Heuristic: a scan/while condition compares the induction variable
    against a constant — take the largest integer constant in the cond."""
    best = 1
    for ln in cond_lines:
        for c in _CONST_RE.findall(ln):
            best = max(best, int(c))
    return best


def collective_bytes(hlo_text: str) -> dict:
    """Per-device collective traffic, with while-loop bodies multiplied by
    their trip counts (XLA's cost_analysis counts loop bodies ONCE, which
    silently drops the per-layer-scan collectives — we walk the computation
    graph ourselves)."""
    comps, entry = _split_computations(hlo_text)
    memo: dict[str, dict] = {}

    def analyze(name: str) -> dict:
        if name in memo:
            return memo[name]
        out = {c: {"count": 0, "bytes": 0} for c in _COLLECTIVES}
        memo[name] = out  # break cycles defensively
        for ls in comps.get(name, ()):
            m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(",
                         ls)
            if not m:
                continue
            type_str, opname = m.groups()
            matched = False
            for c in _COLLECTIVES:
                if opname in (c, c + "-start"):
                    out[c]["count"] += 1
                    out[c]["bytes"] += _shape_bytes(type_str)
                    matched = True
                    break
            if matched:
                continue
            if opname == "while":
                wm = _WHILE_RE.search(ls)
                if wm:
                    cond, body = wm.groups()
                    trips = _trip_count(comps.get(cond, []))
                    sub = analyze(body)
                    for c in _COLLECTIVES:
                        out[c]["count"] += sub[c]["count"] * trips
                        out[c]["bytes"] += sub[c]["bytes"] * trips
            elif opname in ("fusion", "call", "conditional", "custom-call"):
                for callee in _CALL_RE.findall(ls):
                    sub = analyze(callee)
                    for c in _COLLECTIVES:
                        out[c]["count"] += sub[c]["count"]
                        out[c]["bytes"] += sub[c]["bytes"]
        return out

    if entry is None:
        return {c: {"count": 0, "bytes": 0} for c in _COLLECTIVES}
    return analyze(entry)


def run_one(arch: str, shape: str, multi_pod: bool, out_dir: Path,
            variant: str = "full", save_hlo: bool = False,
            decode_cache_mode: str = "hd", tag: str = "") -> dict:
    mesh_name = ("multipod" if multi_pod else "singlepod") + tag
    cfg = get_config(arch, variant)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    jitted, args = build_jitted(cfg, shape, mesh, multi_pod=multi_pod,
                                decode_cache_mode=decode_cache_mode)
    with mesh:
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    result = {
        "arch": arch, "shape": shape, "mesh": mesh_name,
        "kind": INPUT_SHAPES[shape]["kind"],
        "devices": int(mesh.devices.size),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "peak_bytes": int(mem.argument_size_in_bytes
                              + max(mem.output_size_in_bytes,
                                    mem.temp_size_in_bytes)),
        },
        "cost": {
            "flops": float(cost.get("flops", -1.0)),
            "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
            "transcendentals": float(cost.get("transcendentals", -1.0)),
        },
        "collectives": coll,
        "collective_bytes_total": sum(v["bytes"] for v in coll.values()),
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{arch}__{shape}__{mesh_name}"
    (out_dir / f"{name}.json").write_text(json.dumps(result, indent=2))
    if save_hlo:
        (out_dir / f"{name}.hlo.txt").write_text(hlo)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="full")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--decode-cache-mode", default="seq",
                    choices=["hd", "seq"])
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    out_dir = Path(args.out)
    archs = list_archs() if args.all or not args.arch else [args.arch]
    shapes = list(INPUT_SHAPES) if args.all or not args.shape \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "multipod" if mp else "singlepod"
                name = f"{arch}__{shape}__{mesh_name}"
                if args.skip_existing and (out_dir / f"{name}.json").exists():
                    print(f"[skip] {name}")
                    continue
                try:
                    r = run_one(arch, shape, mp, out_dir, args.variant,
                                args.save_hlo, args.decode_cache_mode,
                                args.tag)
                    print(f"[ok] {name}: flops={r['cost']['flops']:.3e} "
                          f"coll={r['collective_bytes_total']:.3e}B "
                          f"compile={r['compile_s']}s", flush=True)
                except Exception as e:
                    failures.append((name, repr(e)))
                    print(f"[FAIL] {name}: {e}", flush=True)
                    traceback.print_exc()
    if failures:
        print(f"{len(failures)} failures:")
        for n, e in failures:
            print(" ", n, e)
        sys.exit(1)


if __name__ == "__main__":
    main()
