"""PCA feature reduction (§5.1: MNIST 784->24, RWHAR 63->16)."""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class PCAModel(NamedTuple):
    mean: np.ndarray        # (d,)
    components: np.ndarray  # (k, d) principal axes (rows)
    explained_variance: np.ndarray  # (k,)


def fit_pca(x: np.ndarray, n_components: int) -> PCAModel:
    x = np.asarray(x, dtype=np.float64)
    mean = x.mean(axis=0)
    xc = x - mean
    # economy SVD; rows of vt are principal axes
    _, s, vt = np.linalg.svd(xc, full_matrices=False)
    ev = (s ** 2) / max(len(x) - 1, 1)
    return PCAModel(mean.astype(np.float32),
                    vt[:n_components].astype(np.float32),
                    ev[:n_components].astype(np.float32))


def transform_pca(model: PCAModel, x: np.ndarray) -> np.ndarray:
    return ((np.asarray(x, np.float32) - model.mean) @ model.components.T)
