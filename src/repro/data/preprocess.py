"""Feature preprocessing: min-max normalization to [0,1] (§5.1)."""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class MinMaxScaler(NamedTuple):
    lo: np.ndarray
    hi: np.ndarray

    def transform(self, x: np.ndarray) -> np.ndarray:
        span = np.maximum(self.hi - self.lo, 1e-9)
        return np.clip((np.asarray(x, np.float32) - self.lo) / span, 0.0, 1.0)


def fit_minmax(x: np.ndarray) -> MinMaxScaler:
    x = np.asarray(x, np.float32)
    return MinMaxScaler(x.min(axis=0), x.max(axis=0))
