"""Synthetic token pipeline for the transformer substrate.

Produces reproducible Zipf-distributed token streams with short-range
structure (Markov bigram mixing) so language-model smoke training has a
learnable signal. Used by the per-arch smoke tests and the training example.
"""
from __future__ import annotations

from typing import Iterator, NamedTuple

import numpy as np


class Batch(NamedTuple):
    tokens: np.ndarray   # (B, S) int32 inputs
    targets: np.ndarray  # (B, S) int32 next-token targets
    mask: np.ndarray     # (B, S) float32 loss mask


def synthetic_stream(seed: int, vocab_size: int, length: int,
                     zipf_a: float = 1.3) -> np.ndarray:
    rng = np.random.default_rng(seed)
    # zipf base distribution truncated to vocab
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    probs = ranks ** (-zipf_a)
    probs /= probs.sum()
    base = rng.choice(vocab_size, size=length, p=probs)
    # inject bigram structure: with prob .5, next token = f(prev)
    shift = rng.integers(1, 17)
    follow = rng.uniform(size=length) < 0.5
    base[1:] = np.where(follow[1:], (base[:-1] * 31 + shift) % vocab_size,
                        base[1:])
    return base.astype(np.int32)


def batches(seed: int, vocab_size: int, batch_size: int, seq_len: int,
            n_batches: int) -> Iterator[Batch]:
    stream = synthetic_stream(seed, vocab_size,
                              n_batches * batch_size * (seq_len + 1) + 1)
    pos = 0
    for _ in range(n_batches):
        chunk = stream[pos:pos + batch_size * (seq_len + 1)]
        pos += batch_size * (seq_len + 1)
        chunk = chunk.reshape(batch_size, seq_len + 1)
        yield Batch(chunk[:, :-1].copy(), chunk[:, 1:].copy(),
                    np.ones((batch_size, seq_len), np.float32))
