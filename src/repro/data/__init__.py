"""Data substrate: synthetic dataset analogues, PCA, normalization, token
pipeline, and out-of-core data sources (DESIGN.md §7)."""
from repro.data.datasets import REGISTRY, Dataset, load
from repro.data.pca import PCAModel, fit_pca, transform_pca
from repro.data.preprocess import MinMaxScaler, fit_minmax
from repro.data.sources import (ArraySource, ConcatSource, DataSource,
                                NpyFileSource, SyntheticGMMSource, as_source)
from repro.data.tokens import Batch, batches, synthetic_stream

__all__ = ["REGISTRY", "Dataset", "load", "PCAModel", "fit_pca",
           "transform_pca", "MinMaxScaler", "fit_minmax", "Batch",
           "batches", "synthetic_stream",
           "ArraySource", "ConcatSource", "DataSource", "NpyFileSource",
           "SyntheticGMMSource", "as_source"]
