"""Out-of-core data sources: row streams the training engine can consume
without ever materializing the dataset (DESIGN.md §7).

A :class:`DataSource` is the host-side seam between storage (a file, a
generator, another process) and the device-side streaming-statistics engine
(``repro.core.em``): it knows its row count and feature dimension and can
iterate fixed-size `(chunk_size, dim)` blocks. Every statistic the training
pipeline reduces (``SufficientStats``, Lloyd-sweep stats, score sums) is
additive in N, so a host loop over blocks with a jitted per-block function
computes exactly the same numbers as the resident-array paths — with an
O(chunk · K) peak working set that is independent of N.

Block iteration is **restartable**: ``iter_blocks`` may be called any number
of times (EM takes one pass per iteration) and must yield the same rows in
the same order each time. Blocks are full ``chunk_size`` rows except the
final ragged remainder, and for a fixed dataset the row content must not
depend on ``chunk_size`` (only the block boundaries may) — that is what
makes fits reproducible across chunk sizes and bit-identical across source
types backed by the same rows.

Sources carry no sample weights: weights exist to make padded fixed-shape
federated arrays representable, and block streams are never padded. Ragged
client shards are expressed directly (:class:`ConcatSource`), so every row
a source yields has weight 1.

This module deliberately imports nothing from ``repro`` (it is below the
whole stack); :class:`SyntheticGMMSource` duck-types the ``GMM`` pytree
(``weights`` / ``means`` / ``covs`` attributes) instead of importing it.
"""
from __future__ import annotations

import abc
import os
import queue
import threading
from functools import partial
from typing import Iterator, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def default_prefetch_depth() -> int:
    """Host-aware default lookahead for :func:`prefetch_blocks`.

    The producer thread only pays off when it has a core to run on: on a
    1–2-core host it competes with device compute and loses (the
    ``estep_source_prefetch{0,1,2}_us`` rows of BENCH_streaming.json
    document depth 0 winning there), so ``os.cpu_count() <= 2`` defaults
    to 0 (synchronous loop, no thread) and anything wider keeps the
    historical depth 2. The ``REPRO_PREFETCH_DEPTH`` environment
    variable overrides the heuristic outright (and call sites can always
    pass ``depth=`` explicitly).
    """
    env = os.environ.get("REPRO_PREFETCH_DEPTH")
    if env is not None:
        depth = int(env)
        if depth < 0:
            raise ValueError(
                f"REPRO_PREFETCH_DEPTH must be >= 0, got {env!r}")
        return depth
    cpus = os.cpu_count() or 1
    return 0 if cpus <= 2 else 2


# Default lookahead of :func:`prefetch_blocks` (how many prepared blocks a
# loader keeps in flight ahead of the consumer), auto-sized from the host
# core count. Module-level so tests and benchmarks can pin it (0 =
# synchronous loop, no thread).
PREFETCH_DEPTH = default_prefetch_depth()


def _check_chunk(chunk_size: int) -> int:
    chunk_size = int(chunk_size)
    if chunk_size <= 0:
        raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    return chunk_size


class DataSource(abc.ABC):
    """Protocol for out-of-core row streams: ``num_rows``, ``dim``,
    ``iter_blocks(chunk_size)`` (restartable, see module docstring)."""

    @property
    @abc.abstractmethod
    def num_rows(self) -> int:
        """Total number of rows the source yields per pass."""

    @property
    @abc.abstractmethod
    def dim(self) -> int:
        """Feature dimension of every yielded block."""

    @property
    def dtype(self):
        """Dtype of yielded blocks (canonicalized, i.e. what ``jnp`` will
        actually hand the engine)."""
        return jax.dtypes.canonicalize_dtype(jnp.float32)

    @abc.abstractmethod
    def iter_blocks(self, chunk_size: int) -> Iterator[jax.Array]:
        """Yield ``(b, dim)`` blocks with ``b == chunk_size`` everywhere but
        the final ragged block. Must be restartable and deterministic."""

    # ------------------------------------------------------------------
    def num_blocks(self, chunk_size: int) -> int:
        return -(-self.num_rows // _check_chunk(chunk_size))

    def materialize(self, chunk_size: int = 65536) -> jax.Array:
        """Concatenate all blocks into one resident ``(num_rows, dim)``
        array — O(N) memory by definition; for tests and small sources."""
        return jnp.concatenate(list(self.iter_blocks(chunk_size)), axis=0)

    def __len__(self) -> int:
        return self.num_rows

    def __repr__(self) -> str:
        return (f"{type(self).__name__}(num_rows={self.num_rows}, "
                f"dim={self.dim}, dtype={jnp.dtype(self.dtype).name})")


# ----------------------------------------------------------------------
# Prefetching block loader (DESIGN.md §7): pad-and-mask + double buffering
# ----------------------------------------------------------------------

def pad_target(num_rows: int, chunk_size: int) -> int:
    """The ONE static row count every block of a ``(num_rows, chunk_size)``
    stream is padded to. Multi-block streams pad the ragged tail up to the
    full ``chunk_size`` (each per-block stage then compiles exactly once
    per chunk shape); single-block streams round up to a multiple of 64 so
    federated clients of slightly different sizes share traces instead of
    each forcing one."""
    chunk_size = _check_chunk(chunk_size)
    if num_rows > chunk_size:
        return chunk_size
    return min(chunk_size, -(-num_rows // 64) * 64)


@partial(jax.jit, static_argnames=("pad",))
def _pad_rows(xb: jax.Array, pad: int) -> jax.Array:
    return jnp.pad(xb, ((0, pad),) + ((0, 0),) * (xb.ndim - 1))


_MASK_CACHE: dict = {}


def _block_mask(target: int, valid: int, dtype) -> jax.Array:
    """(target,) 0/1 row mask with ``valid`` leading ones — cached, so
    every full block of a pass shares one buffer."""
    key = (target, valid, jnp.dtype(dtype).name)
    mask = _MASK_CACHE.get(key)
    if mask is None:
        mask = jnp.asarray(
            np.r_[np.ones(valid), np.zeros(target - valid)].astype(dtype))
        _MASK_CACHE[key] = mask
    return mask


_DONE = object()


def prefetch_blocks(source: DataSource, chunk_size: int,
                    depth: Optional[int] = None
                    ) -> Iterator[tuple[jax.Array, jax.Array]]:
    """Iterate ``(block, mask)`` pairs of a source with the next blocks
    prepared ahead of the consumer — the host-side loader every engine
    block loop drives (DESIGN.md §7).

    Two jobs, one seam:

    - **pad-and-mask**: every yielded block has the SAME static shape
      (:func:`pad_target` rows), with a cached 0/1 row mask marking real
      rows. Zero-padded rows carry weight 0 through every engine
      statistic, so per-block jitted stages compile once per chunk shape
      instead of once per distinct ragged tail.
    - **prefetch**: with ``depth > 0`` a background thread stays up to
      ``depth`` prepared blocks ahead, overlapping the host-side work of
      block i+1 (mmap page-in, synthetic generation dispatch, slicing,
      padding, ``jax.device_put``) with device compute on block i.
      ``depth`` defaults to the module-level :data:`PREFETCH_DEPTH`;
      ``depth=0`` runs the same prepare inline (no thread).

    Block order is never changed — the consumer sees exactly the
    partition ``iter_blocks`` emits, so accumulation order (and therefore
    the bit-identity of source-backed fits) is untouched.
    """
    chunk_size = _check_chunk(chunk_size)
    if depth is None:
        depth = PREFETCH_DEPTH
    target = pad_target(source.num_rows, chunk_size)
    dtype = source.dtype

    def prepare(xb):
        b = xb.shape[0]
        if b == target:
            return jax.device_put(xb), _block_mask(target, b, dtype)
        return (_pad_rows(jax.device_put(xb), target - b),
                _block_mask(target, b, dtype))

    if depth <= 0:
        for xb in source.iter_blocks(chunk_size):
            yield prepare(xb)
        return

    q: queue.Queue = queue.Queue(maxsize=int(depth))
    stop = threading.Event()

    def put(item) -> bool:
        while not stop.is_set():
            try:
                q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def producer():
        try:
            for xb in source.iter_blocks(chunk_size):
                if not put((None, prepare(xb))):
                    return
            put((_DONE, None))
        except BaseException as exc:  # noqa: BLE001 — re-raised downstream
            put((exc, None))

    thread = threading.Thread(target=producer, daemon=True,
                              name="prefetch_blocks")
    thread.start()
    try:
        while True:
            tag, item = q.get()
            if tag is _DONE:
                return
            if tag is not None:
                raise tag
            yield item
    finally:
        stop.set()


class ArraySource(DataSource):
    """A resident array viewed as a source — the bridge that lets one code
    path serve both worlds, and the parity oracle for every other source."""

    def __init__(self, x):
        if x.ndim != 2:
            raise ValueError(f"ArraySource expects (N, d) rows, got {x.shape}")
        if x.shape[0] == 0:
            raise ValueError("ArraySource needs at least one row")
        self._x = x

    @property
    def num_rows(self) -> int:
        return int(self._x.shape[0])

    @property
    def dim(self) -> int:
        return int(self._x.shape[1])

    @property
    def dtype(self):
        return jax.dtypes.canonicalize_dtype(self._x.dtype)

    def iter_blocks(self, chunk_size: int) -> Iterator[jax.Array]:
        chunk_size = _check_chunk(chunk_size)
        for start in range(0, self.num_rows, chunk_size):
            yield jnp.asarray(self._x[start:start + chunk_size])


class NpyFileSource(DataSource):
    """Memory-mapped ``.npy`` rows: only the active block is ever copied
    into (device) memory; the OS page cache owns the rest."""

    def __init__(self, path):
        self._path = str(path)
        self._mm = np.load(self._path, mmap_mode="r")
        if self._mm.ndim != 2:
            raise ValueError(
                f"NpyFileSource expects a 2-D (N, d) array file, "
                f"got shape {self._mm.shape} in {self._path}")
        if self._mm.shape[0] == 0:
            raise ValueError(f"empty .npy source: {self._path}")

    @property
    def num_rows(self) -> int:
        return int(self._mm.shape[0])

    @property
    def dim(self) -> int:
        return int(self._mm.shape[1])

    @property
    def dtype(self):
        return jax.dtypes.canonicalize_dtype(self._mm.dtype)

    def iter_blocks(self, chunk_size: int) -> Iterator[jax.Array]:
        chunk_size = _check_chunk(chunk_size)
        for start in range(0, self.num_rows, chunk_size):
            # np.asarray slices exactly one block out of the mmap; the
            # device transfer is the only copy.
            yield jnp.asarray(np.asarray(self._mm[start:start + chunk_size]))


class ConcatSource(DataSource):
    """Row-wise concatenation of sources (ragged federated shards).

    Blocks are re-chunked across child boundaries, so the emitted block
    partition — and therefore every engine reduction, bit for bit — is
    identical to an :class:`ArraySource` over the concatenated rows, no
    matter how unevenly the children split them.
    """

    def __init__(self, sources: Sequence[DataSource]):
        sources = list(sources)
        if not sources:
            raise ValueError("ConcatSource needs at least one child source")
        dims = {s.dim for s in sources}
        if len(dims) != 1:
            raise ValueError(f"child sources disagree on dim: {sorted(dims)}")
        dtypes = {jnp.dtype(s.dtype) for s in sources}
        if len(dtypes) != 1:
            # Mixed dtypes would make a block's dtype depend on which
            # children it straddles — i.e. on the chunk partition — and
            # silently break the bit-parity contract above.
            raise ValueError("child sources disagree on dtype: "
                             f"{sorted(d.name for d in dtypes)}")
        self._sources = sources
        self._num_rows = sum(s.num_rows for s in sources)
        self._dim = sources[0].dim

    @property
    def num_rows(self) -> int:
        return self._num_rows

    @property
    def dim(self) -> int:
        return self._dim

    @property
    def dtype(self):
        return self._sources[0].dtype

    def iter_blocks(self, chunk_size: int) -> Iterator[jax.Array]:
        chunk_size = _check_chunk(chunk_size)
        pending: list[jax.Array] = []
        have = 0
        for src in self._sources:
            for block in src.iter_blocks(chunk_size):
                pending.append(block)
                have += block.shape[0]
                while have >= chunk_size:
                    buf = (pending[0] if len(pending) == 1
                           else jnp.concatenate(pending, axis=0))
                    yield buf[:chunk_size]
                    rest = buf[chunk_size:]
                    pending = [rest] if rest.shape[0] else []
                    have = rest.shape[0]
        if have:
            yield (pending[0] if len(pending) == 1
                   else jnp.concatenate(pending, axis=0))


# Generation granule of the mixture stream: draws are batched per TILE
# rows, with tiles aligned to GLOBAL row index (tile t owns rows
# [t*TILE, (t+1)*TILE)) — never to block position, so the stream stays
# invariant to ``chunk_size`` and restartable even though a block
# boundary can land mid-tile. Per tile there is ONE fold_in and two
# batched draws over all TILE rows: one uniform per row inverted through
# the mixture CDF (searchsorted) for the component, one (TILE, d) normal
# for the offset. The per-row spelling (fold_in + split + K-way gumbel
# categorical + normal per row) made generation ~3x the whole E-step on
# CPU (the estep_synthetic_source outlier in BENCH_streaming.json, now
# guarded by ``synthetic_vs_array``).
_TILE = 1024


@partial(jax.jit, static_argnames=("size",))
def _synth_block(cum_weights, means, scale, key, start, size):
    """Rows [start, start+size) of the mixture stream: generate the
    covering index-aligned tiles in one batched draw each, slice the
    block out. Worst-case waste is one tile of rows per block (a block
    never spans more than ``size // TILE + 2`` tiles)."""
    d = means.shape[1]
    ntiles = (size - 1) // _TILE + 2        # covers any tile alignment
    tile0 = start // _TILE
    tile_ids = tile0 + jnp.arange(ntiles, dtype=jnp.uint32)
    tile_keys = jax.vmap(jax.random.fold_in, (None, 0))(key, tile_ids)
    pair = jax.vmap(jax.random.split)(tile_keys)           # (ntiles, 2)
    u = jax.vmap(lambda kk: jax.random.uniform(kk, (_TILE,)))(pair[:, 0])
    # u < 1 <= cum_weights[-1], so the right-bisection index is in [0, K)
    # and P(comp = j) is exactly the j-th mixture weight
    comp = jnp.searchsorted(cum_weights, u.reshape(-1), side="right")
    eps = jax.vmap(lambda kk: jax.random.normal(
        kk, (_TILE, d), means.dtype))(pair[:, 1]).reshape(-1, d)
    mu = means[comp]
    if scale.ndim == 2:                                     # diagonal: std
        rows = mu + scale[comp] * eps
    else:
        rows = mu + jnp.einsum("nij,nj->ni", scale[comp], eps)  # Cholesky
    return jax.lax.dynamic_slice_in_dim(rows, start - tile0 * _TILE, size)


class SyntheticGMMSource(DataSource):
    """Samples from a GMM generated block-by-block from a seeded key — the
    server-side synthetic-replay set of FedGenGMM (|S| = H · Σ K_c) without
    materializing it up front. Re-iteration yields identical rows (from
    the bounded block cache when the source fits the ``cache_rows``
    budget, regenerated from the same keys otherwise), so a multi-pass
    EM fit sees one fixed virtual dataset either way.

    ``gmm`` is any object with ``weights (K,)``, ``means (K, d)`` and
    ``covs`` (``(K, d)`` diagonal variances or ``(K, d, d)`` full)
    attributes — i.e. a ``repro.core.gmm.GMM``, duck-typed to keep this
    module import-free below the stack.
    """

    def __init__(self, gmm, num_rows: int, key, cache_rows: int = 1 << 17):
        num_rows = int(num_rows)
        if num_rows <= 0:
            raise ValueError(f"num_rows must be positive, got {num_rows}")
        means = jnp.asarray(gmm.means)
        covs = jnp.asarray(gmm.covs)
        weights = jnp.asarray(gmm.weights)
        self._cum_weights = jnp.cumsum(weights / jnp.sum(weights))
        self._means = means
        self._scale = (jnp.sqrt(covs) if covs.ndim == 2
                       else jnp.linalg.cholesky(covs))
        self._key = key
        self._num_rows = num_rows
        # Generation costs real device time on EVERY pass of a multi-pass
        # fit (EM takes one pass per iteration) while the rows never
        # change. Sources within the `cache_rows` budget keep their
        # generated blocks after the first pass — a bounded memoization
        # (default 2^17 rows ≈ a few MB; the FedGen synthetic-replay sets
        # are a few thousand rows). Anything larger streams every pass,
        # so the O(chunk) working-set guarantee for big-N sources is
        # untouched (pinned by the million-row test in
        # tests/test_source_parity.py). ``cache_rows=0`` disables caching.
        self._cache_rows = int(cache_rows)
        self._cache: dict[int, list] = {}

    @property
    def num_rows(self) -> int:
        return self._num_rows

    @property
    def dim(self) -> int:
        return int(self._means.shape[1])

    @property
    def dtype(self):
        return self._means.dtype

    def iter_blocks(self, chunk_size: int) -> Iterator[jax.Array]:
        chunk_size = _check_chunk(chunk_size)
        if self._num_rows <= self._cache_rows:
            blocks = self._cache.get(chunk_size)
            if blocks is None:
                blocks = [self._gen_block(start, chunk_size)
                          for start in range(0, self._num_rows, chunk_size)]
                self._cache[chunk_size] = blocks
            yield from blocks
            return
        for start in range(0, self._num_rows, chunk_size):
            yield self._gen_block(start, chunk_size)

    def _gen_block(self, start: int, chunk_size: int) -> jax.Array:
        size = min(chunk_size, self._num_rows - start)
        return _synth_block(self._cum_weights, self._means, self._scale,
                            self._key, jnp.uint32(start), size)


class ShuffledSource(DataSource):
    """Windowed multi-epoch reshuffle of another source.

    ``epoch=0`` is an exact passthrough — same blocks, same order, bit for
    bit — so wrapping a source costs nothing until the caller actually asks
    for a new ordering. For ``epoch >= 1``, rows are permuted inside
    windows of ``window_blocks`` consecutive blocks (an O(window · chunk)
    buffer, never O(N)), with the permutation keyed by
    ``fold_in(fold_in(key, epoch), window_index)``: deterministic,
    restartable, and different every epoch. ``with_epoch(e)`` derives the
    next epoch's view without touching the wrapped source.

    Streamed fits are pass-order-pinned by the bit-identity contract;
    this wrapper is the sanctioned way to vary that order across epochs
    (e.g. minibatch-flavoured EM) without giving up determinism.
    """

    def __init__(self, inner: DataSource, key, epoch: int = 0,
                 window_blocks: int = 8):
        self._inner = inner
        self._key = key
        self._epoch = int(epoch)
        if self._epoch < 0:
            raise ValueError(f"epoch must be >= 0, got {epoch}")
        self._window_blocks = int(window_blocks)
        if self._window_blocks <= 0:
            raise ValueError(
                f"window_blocks must be positive, got {window_blocks}")

    @property
    def num_rows(self) -> int:
        return self._inner.num_rows

    @property
    def dim(self) -> int:
        return self._inner.dim

    @property
    def dtype(self):
        return self._inner.dtype

    @property
    def epoch(self) -> int:
        return self._epoch

    def with_epoch(self, epoch: int) -> "ShuffledSource":
        return ShuffledSource(self._inner, self._key, epoch,
                              self._window_blocks)

    def iter_blocks(self, chunk_size: int) -> Iterator[jax.Array]:
        chunk_size = _check_chunk(chunk_size)
        if self._epoch == 0:
            yield from self._inner.iter_blocks(chunk_size)
            return
        ekey = jax.random.fold_in(self._key, jnp.uint32(self._epoch))
        window: list[jax.Array] = []
        widx = 0

        def flush(window, widx):
            buf = (window[0] if len(window) == 1
                   else jnp.concatenate(window, axis=0))
            perm = jax.random.permutation(
                jax.random.fold_in(ekey, jnp.uint32(widx)), buf.shape[0])
            buf = buf[perm]
            for s in range(0, buf.shape[0], chunk_size):
                yield buf[s:s + chunk_size]

        for block in self._inner.iter_blocks(chunk_size):
            window.append(block)
            if len(window) == self._window_blocks:
                yield from flush(window, widx)
                window, widx = [], widx + 1
        if window:
            yield from flush(window, widx)


def as_source(x) -> DataSource:
    """Coerce an `(N, d)` array to :class:`ArraySource`; pass sources
    through unchanged."""
    if isinstance(x, DataSource):
        return x
    return ArraySource(x)
