"""Out-of-core data sources: row streams the training engine can consume
without ever materializing the dataset (DESIGN.md §7).

A :class:`DataSource` is the host-side seam between storage (a file, a
generator, another process) and the device-side streaming-statistics engine
(``repro.core.em``): it knows its row count and feature dimension and can
iterate fixed-size `(chunk_size, dim)` blocks. Every statistic the training
pipeline reduces (``SufficientStats``, Lloyd-sweep stats, score sums) is
additive in N, so a host loop over blocks with a jitted per-block function
computes exactly the same numbers as the resident-array paths — with an
O(chunk · K) peak working set that is independent of N.

Block iteration is **restartable**: ``iter_blocks`` may be called any number
of times (EM takes one pass per iteration) and must yield the same rows in
the same order each time. Blocks are full ``chunk_size`` rows except the
final ragged remainder, and for a fixed dataset the row content must not
depend on ``chunk_size`` (only the block boundaries may) — that is what
makes fits reproducible across chunk sizes and bit-identical across source
types backed by the same rows.

Sources carry no sample weights: weights exist to make padded fixed-shape
federated arrays representable, and block streams are never padded. Ragged
client shards are expressed directly (:class:`ConcatSource`), so every row
a source yields has weight 1.

This module deliberately imports nothing from ``repro`` (it is below the
whole stack); :class:`SyntheticGMMSource` duck-types the ``GMM`` pytree
(``weights`` / ``means`` / ``covs`` attributes) instead of importing it.
"""
from __future__ import annotations

import abc
from functools import partial
from typing import Iterator, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def _check_chunk(chunk_size: int) -> int:
    chunk_size = int(chunk_size)
    if chunk_size <= 0:
        raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    return chunk_size


class DataSource(abc.ABC):
    """Protocol for out-of-core row streams: ``num_rows``, ``dim``,
    ``iter_blocks(chunk_size)`` (restartable, see module docstring)."""

    @property
    @abc.abstractmethod
    def num_rows(self) -> int:
        """Total number of rows the source yields per pass."""

    @property
    @abc.abstractmethod
    def dim(self) -> int:
        """Feature dimension of every yielded block."""

    @property
    def dtype(self):
        """Dtype of yielded blocks (canonicalized, i.e. what ``jnp`` will
        actually hand the engine)."""
        return jax.dtypes.canonicalize_dtype(jnp.float32)

    @abc.abstractmethod
    def iter_blocks(self, chunk_size: int) -> Iterator[jax.Array]:
        """Yield ``(b, dim)`` blocks with ``b == chunk_size`` everywhere but
        the final ragged block. Must be restartable and deterministic."""

    # ------------------------------------------------------------------
    def num_blocks(self, chunk_size: int) -> int:
        return -(-self.num_rows // _check_chunk(chunk_size))

    def materialize(self, chunk_size: int = 65536) -> jax.Array:
        """Concatenate all blocks into one resident ``(num_rows, dim)``
        array — O(N) memory by definition; for tests and small sources."""
        return jnp.concatenate(list(self.iter_blocks(chunk_size)), axis=0)

    def __len__(self) -> int:
        return self.num_rows

    def __repr__(self) -> str:
        return (f"{type(self).__name__}(num_rows={self.num_rows}, "
                f"dim={self.dim}, dtype={jnp.dtype(self.dtype).name})")


class ArraySource(DataSource):
    """A resident array viewed as a source — the bridge that lets one code
    path serve both worlds, and the parity oracle for every other source."""

    def __init__(self, x):
        if x.ndim != 2:
            raise ValueError(f"ArraySource expects (N, d) rows, got {x.shape}")
        if x.shape[0] == 0:
            raise ValueError("ArraySource needs at least one row")
        self._x = x

    @property
    def num_rows(self) -> int:
        return int(self._x.shape[0])

    @property
    def dim(self) -> int:
        return int(self._x.shape[1])

    @property
    def dtype(self):
        return jax.dtypes.canonicalize_dtype(self._x.dtype)

    def iter_blocks(self, chunk_size: int) -> Iterator[jax.Array]:
        chunk_size = _check_chunk(chunk_size)
        for start in range(0, self.num_rows, chunk_size):
            yield jnp.asarray(self._x[start:start + chunk_size])


class NpyFileSource(DataSource):
    """Memory-mapped ``.npy`` rows: only the active block is ever copied
    into (device) memory; the OS page cache owns the rest."""

    def __init__(self, path):
        self._path = str(path)
        self._mm = np.load(self._path, mmap_mode="r")
        if self._mm.ndim != 2:
            raise ValueError(
                f"NpyFileSource expects a 2-D (N, d) array file, "
                f"got shape {self._mm.shape} in {self._path}")
        if self._mm.shape[0] == 0:
            raise ValueError(f"empty .npy source: {self._path}")

    @property
    def num_rows(self) -> int:
        return int(self._mm.shape[0])

    @property
    def dim(self) -> int:
        return int(self._mm.shape[1])

    @property
    def dtype(self):
        return jax.dtypes.canonicalize_dtype(self._mm.dtype)

    def iter_blocks(self, chunk_size: int) -> Iterator[jax.Array]:
        chunk_size = _check_chunk(chunk_size)
        for start in range(0, self.num_rows, chunk_size):
            # np.asarray slices exactly one block out of the mmap; the
            # device transfer is the only copy.
            yield jnp.asarray(np.asarray(self._mm[start:start + chunk_size]))


class ConcatSource(DataSource):
    """Row-wise concatenation of sources (ragged federated shards).

    Blocks are re-chunked across child boundaries, so the emitted block
    partition — and therefore every engine reduction, bit for bit — is
    identical to an :class:`ArraySource` over the concatenated rows, no
    matter how unevenly the children split them.
    """

    def __init__(self, sources: Sequence[DataSource]):
        sources = list(sources)
        if not sources:
            raise ValueError("ConcatSource needs at least one child source")
        dims = {s.dim for s in sources}
        if len(dims) != 1:
            raise ValueError(f"child sources disagree on dim: {sorted(dims)}")
        dtypes = {jnp.dtype(s.dtype) for s in sources}
        if len(dtypes) != 1:
            # Mixed dtypes would make a block's dtype depend on which
            # children it straddles — i.e. on the chunk partition — and
            # silently break the bit-parity contract above.
            raise ValueError("child sources disagree on dtype: "
                             f"{sorted(d.name for d in dtypes)}")
        self._sources = sources
        self._num_rows = sum(s.num_rows for s in sources)
        self._dim = sources[0].dim

    @property
    def num_rows(self) -> int:
        return self._num_rows

    @property
    def dim(self) -> int:
        return self._dim

    @property
    def dtype(self):
        return self._sources[0].dtype

    def iter_blocks(self, chunk_size: int) -> Iterator[jax.Array]:
        chunk_size = _check_chunk(chunk_size)
        pending: list[jax.Array] = []
        have = 0
        for src in self._sources:
            for block in src.iter_blocks(chunk_size):
                pending.append(block)
                have += block.shape[0]
                while have >= chunk_size:
                    buf = (pending[0] if len(pending) == 1
                           else jnp.concatenate(pending, axis=0))
                    yield buf[:chunk_size]
                    rest = buf[chunk_size:]
                    pending = [rest] if rest.shape[0] else []
                    have = rest.shape[0]
        if have:
            yield (pending[0] if len(pending) == 1
                   else jnp.concatenate(pending, axis=0))


@partial(jax.jit, static_argnames=("size",))
def _synth_block(log_weights, means, scale, key, start, size):
    """Rows [start, start+size) of the mixture stream. Each row's draw is
    keyed by its global row index (``fold_in``), never by block position, so
    the stream is invariant to ``chunk_size`` and restartable by design."""
    d = means.shape[1]
    idx = jnp.arange(size, dtype=jnp.uint32) + start
    row_keys = jax.vmap(jax.random.fold_in, (None, 0))(key, idx)
    pair = jax.vmap(jax.random.split)(row_keys)            # (size, 2) keys
    comp = jax.vmap(
        lambda kk: jax.random.categorical(kk, log_weights))(pair[:, 0])
    eps = jax.vmap(
        lambda kk: jax.random.normal(kk, (d,), means.dtype))(pair[:, 1])
    mu = means[comp]
    if scale.ndim == 2:                                     # diagonal: std
        return mu + scale[comp] * eps
    return mu + jnp.einsum("nij,nj->ni", scale[comp], eps)  # full: Cholesky


class SyntheticGMMSource(DataSource):
    """Samples from a GMM generated block-by-block from a seeded key — the
    server-side synthetic-replay set of FedGenGMM (|S| = H · Σ K_c) without
    ever materializing it. Re-iteration regenerates identical rows, so a
    multi-pass EM fit sees one fixed virtual dataset.

    ``gmm`` is any object with ``weights (K,)``, ``means (K, d)`` and
    ``covs`` (``(K, d)`` diagonal variances or ``(K, d, d)`` full)
    attributes — i.e. a ``repro.core.gmm.GMM``, duck-typed to keep this
    module import-free below the stack.
    """

    def __init__(self, gmm, num_rows: int, key):
        num_rows = int(num_rows)
        if num_rows <= 0:
            raise ValueError(f"num_rows must be positive, got {num_rows}")
        means = jnp.asarray(gmm.means)
        covs = jnp.asarray(gmm.covs)
        self._log_weights = jnp.log(jnp.asarray(gmm.weights))
        self._means = means
        self._scale = (jnp.sqrt(covs) if covs.ndim == 2
                       else jnp.linalg.cholesky(covs))
        self._key = key
        self._num_rows = num_rows

    @property
    def num_rows(self) -> int:
        return self._num_rows

    @property
    def dim(self) -> int:
        return int(self._means.shape[1])

    @property
    def dtype(self):
        return self._means.dtype

    def iter_blocks(self, chunk_size: int) -> Iterator[jax.Array]:
        chunk_size = _check_chunk(chunk_size)
        for start in range(0, self._num_rows, chunk_size):
            size = min(chunk_size, self._num_rows - start)
            yield _synth_block(self._log_weights, self._means, self._scale,
                               self._key, jnp.uint32(start), size)


def as_source(x) -> DataSource:
    """Coerce an `(N, d)` array to :class:`ArraySource`; pass sources
    through unchanged."""
    if isinstance(x, DataSource):
        return x
    return ArraySource(x)
