"""Synthetic analogues of the paper's six evaluation datasets (Tables 1-3).

The real datasets (MNIST, Covertype, RWHAR, WADI, SMD, proprietary VEHICLE)
are unavailable offline (repro band 2), so each generator produces data with
the same post-preprocessing dimensionality, number of underlying classes,
partitioning scheme, and OOD construction as the paper:

  mnist_like     24 feats (PCA from procedural 16x16 digit images), 10 classes
  covertype_like 10 feats, 7 terrain classes; OOD = +N(0, 0.005) noise
  rwhar_like     16 feats (PCA from 63 synthetic IMU channels), 13 persons;
                 inlier = walking dynamics, OOD = running dynamics
  wadi_like      84 feats, 10 artificial classes built exactly as the paper
                 does (shift by 1*(m-1)*beta + N(0, 0.01)); OOD = attack mode
  vehicle_like   11 feats, 3 operating environments; OOD = induced air leak
  smd_like       38 feats, 28 server machines; OOD = observed malfunctions

All features are min-max normalized to [0,1] on the training split; OOD data
is transformed with the *training* scaler/PCA, as in the paper.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import numpy as np

from repro.data.pca import fit_pca, transform_pca
from repro.data.preprocess import fit_minmax


class Dataset(NamedTuple):
    name: str
    x_train: np.ndarray
    y_train: np.ndarray
    x_test_in: np.ndarray
    x_test_ood: np.ndarray
    n_classes: int
    scheme: str          # default partitioning scheme (Table 1)
    k_global: int        # GMM components for the global model (Table 3)
    n_clients: int       # Table 3
    anomaly_ratio: float # Table 2


def _finalize(name, x_tr, y_tr, x_in, x_ood, n_classes, scheme, k, clients,
              ratio) -> Dataset:
    scaler = fit_minmax(x_tr)
    return Dataset(name, scaler.transform(x_tr), y_tr.astype(np.int64),
                   scaler.transform(x_in), scaler.transform(x_ood),
                   n_classes, scheme, k, clients, ratio)


# ----------------------------------------------------------------------
# MNIST-like: procedural digit images -> PCA(24)
# ----------------------------------------------------------------------

def _digit_images(rng: np.random.Generator, n: int, n_classes: int = 10,
                  size: int = 16) -> tuple[np.ndarray, np.ndarray]:
    """Random smooth per-class stroke templates + jitter + pixel noise."""
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float64) / (size - 1)
    templates = []
    for m in range(n_classes):
        trng = np.random.default_rng(1000 + m)  # fixed class identity
        img = np.zeros((size, size))
        for _ in range(4):  # 4 gaussian strokes per class
            cx, cy = trng.uniform(0.15, 0.85, 2)
            sx, sy = trng.uniform(0.05, 0.25, 2)
            rot = trng.uniform(0, np.pi)
            dx, dy = xx - cx, yy - cy
            u = np.cos(rot) * dx + np.sin(rot) * dy
            v = -np.sin(rot) * dx + np.cos(rot) * dy
            img += np.exp(-(u ** 2 / (2 * sx ** 2) + v ** 2 / (2 * sy ** 2)))
        templates.append(img / img.max())
    y = rng.integers(0, n_classes, n)
    imgs = np.stack([templates[c] for c in y])
    # random shift by up to 2px via roll, amplitude jitter, pixel noise
    shifts = rng.integers(-2, 3, size=(n, 2))
    for i in range(n):
        imgs[i] = np.roll(imgs[i], shifts[i], axis=(0, 1))
    imgs = imgs * rng.uniform(0.7, 1.3, (n, 1, 1))
    imgs = imgs + rng.normal(0, 0.08, imgs.shape)
    return imgs.astype(np.float32), y


def _ood_images(imgs: np.ndarray) -> np.ndarray:
    """The paper's MNIST OOD: rotate 90 ccw, flip horizontally, scale 1.2."""
    out = np.rot90(imgs, k=1, axes=(1, 2))
    out = out[:, :, ::-1]
    return 1.2 * out


def mnist_like(rng: np.random.Generator, n_train: int = 6000,
               n_test: int = 1200) -> Dataset:
    n_ood = int(n_test * 0.10)
    imgs, y = _digit_images(rng, n_train + n_test + n_ood)
    flat = imgs.reshape(len(imgs), -1)
    pca = fit_pca(flat[:n_train], 24)
    tr = transform_pca(pca, flat[:n_train])
    te = transform_pca(pca, flat[n_train:n_train + n_test])
    ood = transform_pca(pca, _ood_images(imgs[n_train + n_test:]).reshape(n_ood, -1))
    return _finalize("mnist", tr, y[:n_train], te, ood, 10, "dirichlet",
                     30, 20, 0.10)


# ----------------------------------------------------------------------
# Covertype-like: 10 tabular features, 7 terrain classes
# ----------------------------------------------------------------------

def covertype_like(rng: np.random.Generator, n_train: int = 20000,
                   n_test: int = 4000) -> Dataset:
    n_classes, d = 7, 10
    n_ood = int(n_test * 0.10)
    n = n_train + n_test + n_ood
    y = rng.integers(0, n_classes, n)
    crng = np.random.default_rng(42)
    mus = crng.uniform(0, 1, (n_classes, d))
    # correlated, skewed class clouds (terrain variables are correlated)
    mix = crng.normal(0, 1, (n_classes, d, d)) * 0.035
    z = rng.normal(0, 1, (n, d))
    x = mus[y] + np.einsum("nij,nj->ni", mix[y], z)
    x += 0.3 * np.sin(3 * x[:, [0]]) * crng.uniform(0, 1, (1, d))  # mild nonlinearity
    x_tr, x_te = x[:n_train], x[n_train:n_train + n_test]
    # paper OOD: additive Gaussian noise, zero mean, variance 0.005
    x_ood = x[n_train + n_test:] + rng.normal(0, np.sqrt(0.005),
                                              (n_ood, d))
    return _finalize("covertype", x_tr, y[:n_train], x_te, x_ood, n_classes,
                     "dirichlet", 15, 20, 0.10)


# ----------------------------------------------------------------------
# RWHAR-like: 16 feats (PCA from 63 IMU channels), 13 persons
# ----------------------------------------------------------------------

def _imu_features(rng, y, activity: str):
    """Windowed IMU summary features for person y doing an activity."""
    n = len(y)
    prng = np.random.default_rng(7)
    person_gain = prng.uniform(0.6, 1.4, (13, 63))
    person_off = prng.normal(0, 0.3, (13, 63))
    if activity == "walking":
        freq, amp = 1.8, 1.0
    else:  # running
        freq, amp = 3.2, 2.4
    base_phase = rng.uniform(0, 2 * np.pi, (n, 1))
    ch = np.arange(63)[None, :] / 63.0
    feats = amp * np.sin(freq * 2 * np.pi * ch * 4 + base_phase)
    feats = feats * person_gain[y] + person_off[y]
    feats += rng.normal(0, 0.25, feats.shape)
    return feats.astype(np.float32)


def rwhar_like(rng: np.random.Generator, n_train: int = 12000,
               n_test: int = 2500) -> Dataset:
    n_ood = int(n_test * 0.10)
    y = rng.integers(0, 13, n_train + n_test)
    y_ood = rng.integers(0, 13, n_ood)
    walk = _imu_features(rng, y, "walking")
    run = _imu_features(rng, y_ood, "running")
    pca = fit_pca(walk[:n_train], 16)
    tr = transform_pca(pca, walk[:n_train])
    te = transform_pca(pca, walk[n_train:])
    ood = transform_pca(pca, run)
    return _finalize("rwhar", tr, y[:n_train], te, ood, 13, "dirichlet",
                     15, 20, 0.10)


# ----------------------------------------------------------------------
# WADI-like: 84 sensor features; classes built exactly as in the paper
# ----------------------------------------------------------------------

def wadi_like(rng: np.random.Generator, n_train: int = 15000,
              n_test: int = 3000, beta: float = 0.3,
              n_classes: int = 10) -> Dataset:
    d = 84
    n_ood = int(n_test * 0.06 / (1 - 0.06)) + 1
    n = n_train + n_test
    # base process: slow AR(1) drift per sensor + correlated station noise
    wrng = np.random.default_rng(11)
    loading = wrng.normal(0, 1, (8, d)) * 0.2
    t = rng.normal(0, 1, (n + n_ood, 8))
    base = 0.5 + t @ loading + rng.normal(0, 0.05, (n + n_ood, d))
    # paper: class m adds center 1*(m-1)*beta with diagonal covariance 0.01
    y = rng.integers(0, n_classes, n + n_ood)
    x = base + (y[:, None] - 1) * beta * 0.1 + rng.normal(
        0, 0.1, (n + n_ood, d))
    # attack mode: a coordinated push on a sensor subset (valve/pump group)
    attacked = wrng.choice(d, 12, replace=False)
    x_ood = x[n:].copy()
    x_ood[:, attacked] += rng.uniform(0.8, 1.6, (n_ood, 1)) * np.sign(
        wrng.normal(0, 1, (1, 12)))
    return _finalize("wadi", x[:n_train], y[:n_train], x[n_train:n], x_ood,
                     n_classes, "quantity", 10, 20, 0.06)


# ----------------------------------------------------------------------
# VEHICLE-like: 11 air-pressure-system signals, 3 environments
# ----------------------------------------------------------------------

def vehicle_like(rng: np.random.Generator, n_train: int = 9000,
                 n_test: int = 3000) -> Dataset:
    d, n_classes = 11, 3
    n_ood = n_test // 2  # 50% anomaly ratio (Table 2)
    n = n_train + n_test // 2
    y = rng.integers(0, n_classes, n)
    # environments: city (stop-go), highway (steady), test track (aggressive)
    env_mu = np.array([[0.55] * d, [0.75] * d, [0.45] * d])
    env_var = np.array([0.15, 0.05, 0.25])
    vrng = np.random.default_rng(5)
    chan = vrng.uniform(0.5, 1.5, d)
    x = env_mu[y] * chan + rng.normal(0, 1, (n, d)) * env_var[y][:, None] * chan
    # compressor duty cycle couples channels 0-3
    duty = rng.uniform(0, 1, (n, 1))
    x[:, :4] += 0.3 * duty
    y_ood = rng.integers(0, n_classes, n_ood)
    x_ood = env_mu[y_ood] * chan + rng.normal(0, 1, (n_ood, d)) * \
        env_var[y_ood][:, None] * chan
    x_ood[:, :4] += 0.3 * rng.uniform(0, 1, (n_ood, 1))
    # induced air leakage: pressure channels sag, compressor overworks
    leak = rng.uniform(0.25, 0.6, (n_ood, 1))
    x_ood[:, :4] -= leak
    x_ood[:, 4:7] += 0.5 * leak
    return _finalize("vehicle", x[:n_train], y[:n_train], x[n_train:],
                     x_ood, n_classes, "quantity", 15, 12, 0.50)


# ----------------------------------------------------------------------
# SMD-like: 38 server metrics, 28 machines
# ----------------------------------------------------------------------

def smd_like(rng: np.random.Generator, n_train: int = 20000,
             n_test: int = 5000) -> Dataset:
    d, n_classes = 38, 28
    n_ood = int(n_test * 0.04 / (1 - 0.04)) + 1
    n = n_train + n_test
    srng = np.random.default_rng(13)
    machine_mu = srng.uniform(0.2, 0.8, (n_classes, d))
    machine_scale = srng.uniform(0.02, 0.12, (n_classes, d))
    y = rng.integers(0, n_classes, n + n_ood)
    # load factor drives cpu/mem/net metrics jointly
    load = rng.beta(2, 5, (n + n_ood, 1))
    coupling = srng.uniform(0, 0.6, (1, d))
    x = machine_mu[y] + load * coupling + rng.normal(0, 1, (n + n_ood, d)) * \
        machine_scale[y]
    x_ood = x[n:].copy()
    # malfunctions: per-event random subset of metrics spikes or flatlines
    for i in range(n_ood):
        k = rng.integers(3, 9)
        chans = rng.choice(d, k, replace=False)
        if rng.uniform() < 0.5:
            x_ood[i, chans] += rng.uniform(0.5, 1.2)   # spike
        else:
            x_ood[i, chans] = machine_mu[y[n + i], chans] * 0.1  # flatline
    return _finalize("smd", x[:n_train], y[:n_train], x[n_train:n], x_ood,
                     n_classes, "dirichlet", 10, 20, 0.04)


REGISTRY: dict[str, Callable[..., Dataset]] = {
    "mnist": mnist_like,
    "covertype": covertype_like,
    "rwhar": rwhar_like,
    "wadi": wadi_like,
    "vehicle": vehicle_like,
    "smd": smd_like,
}


def load(name: str, rng: np.random.Generator | None = None, **kw) -> Dataset:
    if rng is None:
        rng = np.random.default_rng(0)
    return REGISTRY[name](rng, **kw)
