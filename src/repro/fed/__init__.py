"""repro.fed — the federation runtime (DESIGN.md §9).

One :class:`~repro.fed.runtime.FederationStrategy` protocol and one
:func:`~repro.fed.runtime.run_rounds` driver under every federated
algorithm: FedGenGMM and DEM (defined next to their numerics in
``repro.core.fedgen`` / ``repro.core.dem``) plus the iterative baselines
FedEM and FedKMeans (``repro.fed.strategies``). The ledger
(``repro.fed.ledger``) is the one copy of the communication accounting,
and the uplink-transform seam (``repro.fed.transforms``, §11) is the one
place DP noise, quantization, and secure-aggregation masking enter the
client->server payload. The asynchronous regime (``repro.fed.
async_runtime``, §12) adds :func:`~repro.fed.async_runtime.run_async`
(buffered staleness-weighted rounds) and
:class:`~repro.fed.async_runtime.ClientExecutor` (the concurrent
source-client worker pool) on the same strategy/backend substrate.

``strategies`` is loaded lazily (PEP 562): it imports ``repro.core.dem``
for the shared init machinery, and ``repro.core`` imports this package's
runtime — eager loading here would close that cycle.
"""
from repro.fed.async_runtime import (AsyncPolicy, ClientExecutor,
                                     run_async)
from repro.fed.cohort import (ArrivalStragglers, CyclicSampler,
                              PolynomialStaleness, UniformSampler,
                              make_sampler)
from repro.fed.ledger import (CommStats, RoundPayload, dtype_itemsize,
                              gmm_payload_floats, label_payload_floats,
                              payload_floats, stats_payload_floats)
from repro.fed.runtime import (FederationStrategy, SplitClients,
                               SourceClients, ShardedClients, make_backend,
                               run_rounds)
from repro.fed.transforms import (Compose, GaussianDP, Identity,
                                  PairwiseMask, PayloadTransform,
                                  StochasticQuantize)

_LAZY = {
    "FedEMStrategy": "repro.fed.strategies",
    "FedKMeansStrategy": "repro.fed.strategies",
    "FedEMResult": "repro.fed.strategies",
    "FedKMeansResult": "repro.fed.strategies",
    "fedem_cfg": "repro.fed.strategies",
    "fed_kmeans_cfg": "repro.fed.strategies",
}

__all__ = [
    "AsyncPolicy", "ClientExecutor", "run_async",
    "ArrivalStragglers", "CyclicSampler", "PolynomialStaleness",
    "UniformSampler", "make_sampler",
    "CommStats", "RoundPayload", "dtype_itemsize", "gmm_payload_floats",
    "label_payload_floats", "payload_floats", "stats_payload_floats",
    "FederationStrategy", "SplitClients", "SourceClients", "ShardedClients",
    "make_backend", "run_rounds",
    "PayloadTransform", "Identity", "GaussianDP", "StochasticQuantize",
    "PairwiseMask", "Compose",
    *sorted(_LAZY),
]


def __getattr__(name: str):
    if name in _LAZY:
        import importlib
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module 'repro.fed' has no attribute {name!r}")
