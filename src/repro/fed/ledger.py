"""The unified communication ledger of the federation runtime (DESIGN.md §9).

Every federated algorithm in this repo decomposes into client-update →
uplink → server-combine → broadcast rounds, and the paper's headline
argument is about what those rounds *cost*. Before the §9 refactor each
algorithm hand-rolled its own `CommStats` arithmetic (and none of it was
dtype-aware); this module states the accounting once:

- :class:`CommStats` — the per-run ledger every federated result carries.
  Float counts stay the primary unit (they are what Table 4 compares), and
  ``itemsize`` makes them convertible to wire bytes: ``payload_bytes`` /
  ``total_mb`` answer "how many megabytes actually moved" for the payload
  dtype in play (f32 vs f64 runs differ 2x in bytes at identical float
  counts).
- :class:`RoundPayload` — what one round moves; strategies declare it and
  the round driver (``repro.fed.runtime``) multiplies by the realized
  round count.
- the payload-size helpers (``gmm_payload_floats`` & co.) — the closed
  forms for the three payload families (model parameters, EM sufficient
  statistics, k-means label statistics).

This module is deliberately repro-free (jax + stdlib only): it sits below
``repro.core``, so `fedgen.py`/`dem.py` can import it without cycles.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp


def dtype_itemsize(dtype) -> int:
    """Bytes per element of a payload dtype (f32 -> 4, f64 -> 8, ...)."""
    return int(jnp.dtype(dtype).itemsize)


class CommStats(NamedTuple):
    """Communication accounting for one federated training run.

    ``itemsize`` (bytes per payload element, default f32) is what makes
    the float counts convertible to wire volume; it defaults to 4 so
    pre-§9 call sites constructing ``CommStats(rounds, up, down)`` keep
    their meaning.

    Uplink and downlink directions need not share a dtype: an uplink
    transform (``repro.fed.transforms``, §11) can quantize the
    client->server payload to int8 while the server broadcast stays
    float32.  ``uplink_itemsize`` / ``downlink_itemsize`` override
    ``itemsize`` per direction when set (None = inherit), so the byte
    accounting stays honest under asymmetric wires.  ``epsilon_spent``
    is the cumulative privacy budget the run consumed (transform's
    per-round spend x realized rounds; 0.0 for non-DP runs).

    ``staleness`` is the per-update staleness histogram of an
    asynchronous run (``repro.fed.run_async``, DESIGN.md §12):
    ``((s, count), ...)`` sorted by ``s``, where an update's staleness is
    the number of server combines that happened between its dispatch and
    its consumption. Synchronous runs leave it empty (every update is
    consumed at the model version it trained against).
    """
    rounds: int
    uplink_floats: int       # client -> server payload (total floats)
    downlink_floats: int     # server -> client payload (total floats)
    itemsize: int = 4        # bytes per payload element (dtype-aware)
    uplink_itemsize: Optional[int] = None    # override for the uplink
    downlink_itemsize: Optional[int] = None  # override for the downlink
    epsilon_spent: float = 0.0  # cumulative DP budget consumed
    staleness: tuple = ()    # ((staleness, count), ...) update histogram

    @property
    def uplink_bytes(self) -> int:
        size = self.itemsize if self.uplink_itemsize is None \
            else self.uplink_itemsize
        return self.uplink_floats * size

    @property
    def downlink_bytes(self) -> int:
        size = self.itemsize if self.downlink_itemsize is None \
            else self.downlink_itemsize
        return self.downlink_floats * size

    @property
    def payload_bytes(self) -> int:
        """Total wire volume (uplink + downlink) in bytes."""
        return self.uplink_bytes + self.downlink_bytes

    @property
    def total_mb(self) -> float:
        """Total wire volume in MiB — the unit the comm benchmark plots."""
        return self.payload_bytes / 2**20

    @property
    def mean_staleness(self) -> float:
        """Average per-update staleness of an async run (0.0 when the
        histogram is empty, i.e. every consumed update was fresh)."""
        n = sum(count for _, count in self.staleness)
        if n == 0:
            return 0.0
        return sum(s * count for s, count in self.staleness) / n


class RoundPayload(NamedTuple):
    """What one communication round moves, summed over the cohort.

    Strategies declare this once (``round_payload``); the round driver
    multiplies by the realized round count to build the run's
    :class:`CommStats`, so no strategy ever re-implements the ledger
    arithmetic.
    """
    uplink_floats: int
    downlink_floats: int
    itemsize: int = 4
    extra_uplink_floats: int = 0   # once-per-run uplink outside the round
    #                                loop (final-center rescore scalars,
    #                                warm-start statistics), added once
    extra_downlink_floats: int = 0  # once-per-run downlink outside the
    #                                 round loop — the init-phase model /
    #                                 center broadcast that warm starts
    #                                 used to ride for free, added once
    uplink_itemsize: Optional[int] = None    # transform-aware uplink
    #                                          dtype (None = itemsize)
    downlink_itemsize: Optional[int] = None  # broadcast dtype override
    epsilon_per_round: float = 0.0  # DP budget one round spends
    staleness: tuple = ()  # async runs: ((staleness, count), ...) over
    #                        every consumed update — the driver fills it
    #                        post hoc (it is realized, not declared)

    def totals(self, rounds: int) -> CommStats:
        return CommStats(
            rounds=rounds,
            uplink_floats=rounds * self.uplink_floats
            + self.extra_uplink_floats,
            downlink_floats=rounds * self.downlink_floats
            + self.extra_downlink_floats,
            itemsize=self.itemsize,
            uplink_itemsize=self.uplink_itemsize,
            downlink_itemsize=self.downlink_itemsize,
            epsilon_spent=rounds * self.epsilon_per_round,
            staleness=self.staleness)


# ----------------------------------------------------------------------
# Payload closed forms (floats per client, per round)
# ----------------------------------------------------------------------

def gmm_payload_floats(k: int, d: int, diagonal: bool) -> int:
    """One GMM's parameter block: weights (k) + means (k·d) + covariances
    (k·d diag / k·d² full) — the FedGenGMM uplink and every broadcast."""
    cov = k * d if diagonal else k * d * d
    return k + k * d + cov


def payload_floats(gmm) -> int:
    """:func:`gmm_payload_floats` of a concrete model (duck-typed: any
    object with ``means.shape`` and ``is_diagonal``)."""
    k, d = gmm.means.shape
    return gmm_payload_floats(k, d, gmm.is_diagonal)


def stats_payload_floats(k: int, d: int, diagonal: bool) -> int:
    """One client's EM ``SufficientStats``: s0 (k) + s1 (k·d) + s2 (k·d
    diag / k·d² full) + loglik + wsum — the DEM/FedEM per-round uplink."""
    cov = k * d if diagonal else k * d * d
    return k + k * d + cov + 2


def label_payload_floats(k: int, d: int) -> int:
    """One client's hard-assignment label statistics: counts (k) + sums
    (k·d) + inertia — the federated k-means per-round uplink."""
    return k + k * d + 1
