"""The iterative federated baselines the ROADMAP names, as strategies on
the federation runtime (DESIGN.md §9).

- :class:`FedEMStrategy` — iterative federated EM after Tian et al.
  (non-asymptotic analysis of federated EM): per round, each
  participating client runs ``local_epochs`` local EM steps from the
  current global parameters and ships its final-epoch
  ``SufficientStats``; the server sums and M-steps. With
  ``participation=1.0`` and ``local_epochs=1`` this IS the DEM baseline
  (``repro.core.dem``) — literally, it subclasses :class:`DEMStrategy`
  and the reduction is pinned bit-for-bit in
  ``tests/test_fed_runtime.py``. Partial participation is COHORT
  EXECUTION (``repro.fed.cohort``): the driver samples
  ``max(1, round(participation·C))`` clients per round — the default
  cyclic sampler is deterministic, non-empty, covers every client, and
  is pinned bit-identical to the historical train-all + zero-mask path;
  a seeded uniform sampler is one knob away — and ONLY the cohort
  computes, so a round costs O(cohort), not O(population).

- :class:`FedKMeansStrategy` — iterative federated k-means after Garst &
  Reinders: per round, each client assigns its rows to the current global
  centers and ships per-center label statistics (counts, sums, inertia —
  the existing ``lloyd_round_stats`` machinery); the server recombines
  into new centers and stops on the squared center shift. Init is a
  one-shot federated k-means warm start (Dennis et al. '21) or the
  "separated" scheme.

Both run under every client backend — padded :class:`ClientSplit`, list
of per-client :class:`DataSource` streams, or a sharded mesh
(``repro.distributed.fedem_sharded`` / ``fed_kmeans_sharded``) — with
populated communication ledgers, because :func:`~repro.fed.runtime.
run_rounds` owns all of that.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.config import FitConfig, is_source_list, resolve_backend
from repro.core.dem import DEMStrategy, _resolve_init, max_separated_centers
from repro.core.em import e_step_stats, m_step
from repro.core.gmm import GMM
from repro.core.kmeans import federated_kmeans, lloyd_round_stats
from repro.core.partition import ClientSplit
from repro.fed.cohort import make_sampler
from repro.fed.ledger import (CommStats, RoundPayload, dtype_itemsize,
                              label_payload_floats)
from repro.fed.runtime import run_rounds


class FedEMResult(NamedTuple):
    global_gmm: GMM
    log_likelihood: jax.Array   # avg loglik over the last round's cohort
    n_rounds: jax.Array
    converged: jax.Array
    comm: CommStats


class FedEMState(NamedTuple):
    """DEM's round state plus the round counter that drives the cyclic
    participation window and the per-cohort loglik history that makes
    partial-participation convergence judgeable (see
    :meth:`FedEMStrategy._next_state`)."""
    gmm: GMM
    prev_ll: jax.Array
    ll: jax.Array
    tol: jax.Array
    reg_covar: jax.Array
    rnd: jax.Array
    ll_hist: jax.Array   # (T,) ring buffer, T = cohort cycle length


@dataclasses.dataclass(frozen=True)
class FedEMStrategy(DEMStrategy):
    """DEM generalized per Tian et al.: ``local_epochs`` local EM steps
    per round (clients M-step on their own stats between E-steps and ship
    the final epoch's statistics) and partial participation
    (``participation`` fraction of clients per round). Defaults reduce it
    to :class:`DEMStrategy` exactly.

    Since the cohort-execution refactor WHICH clients run is not this
    strategy's business: the driver's sampler (``run_rounds(sampler=...)``,
    built by :func:`fedem_cfg`) hands each backend the round's cohort and
    only those clients compute. The knobs here still size the
    convergence machinery: ``participation``/``n_clients`` fix the
    cohort-cycle length of the loglik ring buffer."""

    participation: float = 1.0
    local_epochs: int = 1
    n_clients: int = 0   # required when participation < 1 (cycle length)

    name = "fedem"

    def __post_init__(self):
        if not 0.0 < float(self.participation) <= 1.0:
            raise ValueError(
                f"participation must be in (0, 1], got {self.participation}")
        if int(self.local_epochs) < 1:
            raise ValueError(
                f"local_epochs must be >= 1, got {self.local_epochs}")
        if self.participation < 1.0 and self.n_clients < 1:
            raise ValueError(
                "participation < 1 needs n_clients (the cyclic cohort "
                "window is sized from it); the cfg-cores fill it from the "
                "client container")

    def cohort_size(self) -> int:
        """Clients per round under cyclic participation (always >= 1)."""
        if self.participation >= 1.0:
            return self.n_clients
        return max(1, int(round(self.participation * self.n_clients)))

    def _period(self) -> int:
        """Rounds until the cyclic window revisits the same cohort: the
        additive order of the window stride ``m`` in Z_C, i.e.
        C / gcd(C, m). 1 under full participation."""
        if self.participation >= 1.0:
            return 1
        c, m = self.n_clients, self.cohort_size()
        return c // math.gcd(c, m)

    def _make_state(self, gmm, prev_ll, ll, tol, reg_covar):
        rnd = 0 if self.host else jnp.array(0)
        hist = jnp.full((self._period(),), -jnp.inf, gmm.means.dtype)
        return FedEMState(gmm, prev_ll, ll, tol, reg_covar, rnd, hist)

    def _next_state(self, state, gmm, ll):
        t = self._period()
        if t == 1:
            # full participation: exactly DEM's consecutive-round delta
            return FedEMState(gmm, state.ll, ll, state.tol, state.reg_covar,
                              state.rnd + 1, state.ll_hist)
        # Partial participation: consecutive rounds score DIFFERENT
        # cohorts, so their loglik delta never settles below tol and the
        # loop used to run to max_iter every time (the PR-5 caveat). The
        # ring buffer makes prev_ll "this same cohort's loglik one cycle
        # ago" — a like-for-like delta the inherited DEM predicates
        # (|ll - prev_ll| vs tol) can judge. Slots still at -inf (first
        # cycle) keep the loop going unconditionally.
        pos = state.rnd % t
        prev = state.ll_hist[pos]
        hist = state.ll_hist.at[pos].set(ll)
        if self.host:
            prev = float(prev)
        return FedEMState(gmm, prev, ll, state.tol, state.reg_covar,
                          state.rnd + 1, hist)

    def local_step(self, state: FedEMState, x, w, idx):
        """One cohort member's update. Participation is NOT handled here
        any more — the driver's sampler decides who runs and the backend
        computes only those clients (the historical per-client window
        test and the host-path skip both became driver/backend concerns;
        the uplink of a non-member is exactly absent, which the pinned
        zero-uplink ledger and e-step-count tests still assert)."""
        gmm = state.gmm
        stats = e_step_stats(gmm, x, w, self.backend, self.chunk)
        for _ in range(self.local_epochs - 1):
            gmm = m_step(stats, state.reg_covar)
            stats = e_step_stats(gmm, x, w, self.backend, self.chunk)
        return stats

    # round_payload is inherited from DEMStrategy: under a sampler the
    # driver's accounting view already reports num_clients == cohort
    # size, so the per-round arithmetic stays cohort-sized for free.

    def finalize(self, state: FedEMState, n_rounds, converged,
                 comm: CommStats) -> FedEMResult:
        ll = state.ll
        if self.host:
            ll = jnp.asarray(ll, state.gmm.means.dtype)
        return FedEMResult(state.gmm, ll, n_rounds, jnp.asarray(converged),
                           comm)


def fedem_cfg(key: jax.Array, clients, config: FitConfig, k: int,
              participation: float = 1.0, local_epochs: int = 1,
              cohort: str = "cyclic", cohort_seed: int = 0,
              stragglers=None, transform=None,
              async_policy=None) -> FedEMResult:
    """Run FedEM — the cfg-core behind ``repro.api.FedEM``, dispatching on
    the client input type through the federation runtime. Init strategies
    and their resolution are DEM's (``config.init``).

    ``participation < 1`` builds the driver-side cohort sampler
    (``cohort``: "cyclic" — the historical deterministic window — or
    "uniform" — seeded sampling without replacement from
    ``cohort_seed``); at full participation no sampler is installed, so
    the run reduces to DEM's full-population path bit for bit.
    ``stragglers`` (e.g. :class:`repro.fed.cohort.ArrivalStragglers`)
    drops each round's slowest arrivals. ``async_policy`` (a
    :class:`repro.fed.AsyncPolicy`) reroutes the rounds through the
    buffered asynchronous driver (``repro.fed.run_async``, DESIGN.md
    §12) — the server combines every ``buffer_size`` updates under the
    staleness-weighting rule instead of waiting for the whole cohort;
    None keeps the synchronous loop."""
    sources = is_source_list(clients)
    if not sources and not isinstance(clients, ClientSplit):
        raise TypeError(
            f"fedem clients must be a ClientSplit or a list of "
            f"DataSources, got {type(clients).__name__}")
    if not 0.0 < float(participation) <= 1.0:
        raise ValueError(
            f"participation must be in (0, 1], got {participation}")
    n_clients = len(clients) if sources else clients.data.shape[0]
    strategy = FedEMStrategy(
        k=k, covariance_type=config.covariance_type, backend=config.backend,
        chunk=config.resolve_chunk(source=sources),
        init=_resolve_init(config.init, sources), host=sources,
        tol=config.resolve_tol("em"), reg_covar=config.reg_covar,
        participation=float(participation), local_epochs=int(local_epochs),
        n_clients=n_clients)
    sampler = None
    if strategy.participation < 1.0:
        sampler = make_sampler(cohort, n_clients, strategy.cohort_size(),
                               seed=cohort_seed)
    elif cohort not in ("cyclic", "uniform"):
        raise ValueError(
            f"cohort sampler must be 'cyclic' or 'uniform', got {cohort!r}")
    if async_policy is not None:
        from repro.fed.async_runtime import run_async
        return run_async(strategy, clients, key=key,
                         max_rounds=config.resolve_max_iter("em"),
                         sampler=sampler, stragglers=stragglers,
                         transform=transform,
                         **async_policy.driver_kwargs())
    return run_rounds(strategy, clients, key=key,
                      max_rounds=config.resolve_max_iter("em"),
                      sampler=sampler, stragglers=stragglers,
                      transform=transform)


# ----------------------------------------------------------------------
# Federated k-means (Garst et al.)
# ----------------------------------------------------------------------

class FedKMeansResult(NamedTuple):
    centers: jax.Array        # (K, d) global centers
    inertia: jax.Array        # weighted inertia of the RETURNED centers:
    #                           one extra streamed assignment pass after
    #                           the last round (clients ship one scalar
    #                           each — accounted in comm as
    #                           extra_uplink_floats)
    n_rounds: jax.Array
    converged: jax.Array
    comm: CommStats


class FedKMeansState(NamedTuple):
    centers: jax.Array
    shift: jax.Array          # squared center shift of the last update
    inertia: jax.Array
    tol: jax.Array


FEDKMEANS_INITS = ("fed-kmeans", "separated")


@dataclasses.dataclass(frozen=True)
class FedKMeansStrategy:
    """Iterative federated Lloyd: clients ship per-center label statistics
    (counts, sums, inertia) against the broadcast centers; the server
    recombines ``sums/counts`` into new centers — a k-means M-step from
    summed hard-assignment statistics, exactly the EM pattern with
    responsibilities replaced by labels. Stops when the squared center
    shift drops to ``tol`` (the k-means convergence rule, so ``tol``
    resolves through the "kmeans" defaults)."""

    k: int
    assign_backend: str = "reference"   # resolved (never "auto") — this
    #                                     rides into jitted client steps
    chunk: Optional[int] = None
    init: str = "fed-kmeans"
    host: bool = False
    tol: float = dataclasses.field(default=1e-4, compare=False)

    one_shot = False
    name = "fedkmeans"

    def init_state(self, key: jax.Array, backend) -> FedKMeansState:
        k_init, _ = jax.random.split(key)
        if self.init == "separated":
            centers = max_separated_centers(k_init, self.k, backend.dim)
        elif backend.kind == "sources":
            centers = federated_kmeans(k_init, list(backend.sources), self.k,
                                       chunk_size=self.chunk)
        else:
            centers = federated_kmeans(k_init, backend.data, self.k,
                                       client_weights=backend.mask,
                                       chunk_size=self.chunk)
        if self.host:
            return FedKMeansState(centers, float("inf"), float("inf"),
                                  float(self.tol))
        dt = centers.dtype
        inf = jnp.array(jnp.inf, dt)
        return FedKMeansState(centers, inf, inf, jnp.asarray(self.tol, dt))

    def local_step(self, state: FedKMeansState, x, w, idx):
        return lloyd_round_stats(state.centers, x, w, self.assign_backend,
                                 self.chunk)

    def server_combine(self, state: FedKMeansState,
                       total) -> FedKMeansState:
        counts, sums, inertia = total
        new_centers = jnp.where(
            counts[:, None] > 0,
            sums / jnp.maximum(counts[:, None], 1e-12), state.centers)
        shift = jnp.sum((new_centers - state.centers) ** 2)
        if self.host:
            shift, inertia = float(shift), float(inertia)
        return FedKMeansState(new_centers, shift, inertia, state.tol)

    def converged(self, state: FedKMeansState):
        return state.shift <= state.tol

    def keep_going(self, state: FedKMeansState):
        """Distinct from ``not converged`` so a NaN center shift
        (degenerate geometry) halts the loop AND reports not-converged,
        like the EM loops."""
        return state.shift > state.tol

    def post_rounds(self, state: FedKMeansState, backend) -> FedKMeansState:
        """One extra assignment sweep against the FINAL centers, so the
        reported inertia describes the centers the caller gets. The round
        loop's own inertia scores the pre-update centers (the same bug
        class PR 2 fixed in ``kmeans``); each client ships one scalar
        back, accounted as ``extra_uplink_floats``."""

        def rescore(st, x, w, idx):
            _, _, inertia = lloyd_round_stats(st.centers, x, w,
                                              self.assign_backend, self.chunk)
            return inertia

        inertia = backend.reduce_clients(rescore, state)
        if self.host:
            inertia = float(inertia)
        return state._replace(inertia=inertia)

    def round_payload(self, backend, state) -> RoundPayload:
        c, d = backend.num_clients, backend.dim
        pop = getattr(backend, "population_clients", c)
        # Init-phase traffic rides the ledger too (warm starts are not
        # free): every scheme broadcasts the k·d round-0 centers to the
        # population; the fed-kmeans warm start first collects each
        # client's k local centers + k cluster sizes (Dennis et al.).
        warm_up = pop * (self.k * d + self.k) \
            if self.init == "fed-kmeans" else 0
        return RoundPayload(
            uplink_floats=c * label_payload_floats(self.k, d),
            downlink_floats=c * self.k * d,
            itemsize=dtype_itemsize(state.centers.dtype),
            # post-rounds inertia rescore (one scalar per population
            # client) + the warm-start statistics
            extra_uplink_floats=pop + warm_up,
            extra_downlink_floats=pop * self.k * d)

    def finalize(self, state: FedKMeansState, n_rounds, converged,
                 comm: CommStats) -> FedKMeansResult:
        inertia = state.inertia
        if self.host:
            inertia = jnp.asarray(inertia, state.centers.dtype)
        return FedKMeansResult(state.centers, inertia, n_rounds,
                               jnp.asarray(converged), comm)


def _resolve_fedkmeans_init(init: str) -> str:
    if init == "auto":
        return "fed-kmeans"
    if init not in FEDKMEANS_INITS:
        raise ValueError(
            f"FedKMeans init must be 'auto' or one of {FEDKMEANS_INITS} "
            f"(a one-shot warm start or separated centers), got {init!r}")
    return init


def fed_kmeans_cfg(key: jax.Array, clients, config: FitConfig,
                   k: int, transform=None) -> FedKMeansResult:
    """Run iterative federated k-means — the cfg-core behind
    ``repro.api.FedKMeans``, dispatching on the client input type through
    the federation runtime."""
    sources = is_source_list(clients)
    if not sources and not isinstance(clients, ClientSplit):
        raise TypeError(
            f"federated k-means clients must be a ClientSplit or a list "
            f"of DataSources, got {type(clients).__name__}")
    strategy = FedKMeansStrategy(
        k=k, assign_backend=resolve_backend(config.backend),
        chunk=config.resolve_chunk(source=sources),
        init=_resolve_fedkmeans_init(config.init), host=sources,
        tol=config.resolve_tol("kmeans"))
    return run_rounds(strategy, clients, key=key,
                      max_rounds=config.resolve_max_iter("kmeans"),
                      transform=transform)
