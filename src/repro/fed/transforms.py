"""Uplink payload transforms: DP noise, quantization and secure-agg
masking as ONE seam on the federation runtime (DESIGN.md §11).

Every federated algorithm in this repo ships a per-client *payload*
pytree from ``local_step`` into a backend reduce (vmap tree-sum, source
host loop, or shard_map psum).  A :class:`PayloadTransform` intercepts
exactly that edge: the driver applies it to every client's uplink
*between* ``local_step`` and the reduce, and applies the transform's
``finish`` to the summed total *before* ``server_combine``.  DP noise,
stochastic quantization and pairwise secure-aggregation masks are all
instances of the same hook, so they compose (:class:`Compose`) and every
strategy — DEM, FedEM, FedKMeans, one-shot FedGenGMM — gets them without
writing a line of privacy code.

Contract (the PR-7 sampler contract, restated for transforms):

- transforms are **frozen hashable dataclasses** and ride the jitted
  round loop as *static* arguments;
- the PRNG ``seed`` and every numeric knob that sweeps (epsilon, delta,
  min_count, rounds) are ``compare=False`` fields: two instances that
  differ only in those fields are equal/hash-equal, so swapping them
  adds **no jit cache entry**.  The seed enters the computation as a
  traced PRNG key and the numeric knobs enter via ``traced()`` — a small
  pytree of scalars the driver passes through jit as traced leaves;
- ``apply`` must be traceable (it runs under vmap / shard_map for
  resident clients) and is called once per client per round with the
  round's SHARED key ``fold_in(key(seed), round)`` — the same on every
  backend and for every client.  Each transform derives its own streams
  from it: value-level transforms (DP noise, quantization) fold in the
  client index, so split and source runs draw the same per-client
  noise; pairwise masking folds in the *sorted pair* ``(lo, hi)``, so
  both endpoints of a pair derive the SAME stream and their masks
  cancel — the reason the driver hands over the shared key rather than
  a pre-folded per-client one.

This module is deliberately repro-free (jax + stdlib only, like
``cohort.py``/``ledger.py``): it sits below the runtime, which sits
below ``repro.core``, so payload families (GMM parameter blocks, EM
``SufficientStats``) are recognized structurally (duck-typed) rather
than by importing their classes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

# Post-noise projection constants of the analytic Gaussian release
# (migrated from repro.core.privacy): weights floor before simplex
# re-normalization; variance window for features normalized to [0,1]^d
# (coordinate-wise variance of [0,1] data is at most 1/4).
WEIGHT_FLOOR = 1e-4
VAR_MIN = 1e-5
VAR_MAX = 0.25


@runtime_checkable
class PayloadTransform(Protocol):
    """The uplink-transform contract (duck-typed; frozen hashable
    dataclasses are the idiom — a transform rides jit as a static arg).

    - ``traced() -> pytree`` — the sweepable numeric knobs as a small
      pytree of scalars.  The driver passes it through jit as traced
      leaves, so changing epsilon/delta/... never retraces (the fields
      themselves are ``compare=False`` and MUST NOT be read inside
      ``apply`` — only ``params`` may be).
    - ``apply(key, params, payload, idx, members) -> wire`` — transform
      ONE client's uplink payload; traceable.  ``key`` is the round's
      SHARED key (derive per-client streams via ``fold_in(key, idx)``,
      pair streams via the sorted pair).  ``idx`` is the client's
      global index, ``members`` the (m,) array of this round's
      participating client indices (the full population when no sampler
      is installed) — what pairwise masking needs to pair against.
    - ``finish(total) -> payload`` — server-side inverse applied to the
      reduced total before ``server_combine`` (drop mask channels,
      identity for value-level transforms).
    - ``wire_itemsize(itemsize) -> int`` — bytes per uplink element
      after the transform (int8 quantization -> 1); feeds the ledger's
      asymmetric ``uplink_itemsize``.
    - ``epsilon_per_round() -> float`` — privacy budget spent per round
      (0 for non-DP transforms); the driver multiplies by the realized
      round count into ``CommStats.epsilon_spent``.
    """

    def traced(self) -> Any:
        """Sweepable numeric knobs as a pytree of scalars (traced by jit)."""
        ...

    def apply(self, key, params, payload, idx, members):
        """Transform ONE client's uplink payload (traceable)."""
        ...

    def finish(self, total):
        """Server-side inverse on the reduced total (before combine)."""
        ...

    def wire_itemsize(self, itemsize: int) -> int:
        """Bytes per uplink element after the transform (ledger feed)."""
        ...

    def epsilon_per_round(self) -> float:
        """Privacy budget one round spends (0 for non-DP transforms)."""
        ...


# ----------------------------------------------------------------------
# Payload-family detection (structural: this module imports no repro.core)
# ----------------------------------------------------------------------

def _is_gmm(p) -> bool:
    return hasattr(p, "weights") and hasattr(p, "means") and hasattr(p,
                                                                     "covs")


def _is_gmm_release(p) -> bool:
    """FedGenGMM's one-shot uplink: a ``(gmm, n_samples)`` pair."""
    return isinstance(p, tuple) and len(p) == 2 and _is_gmm(p[0])


def _is_stats(p) -> bool:
    """EM ``SufficientStats``-shaped payload (DEM / FedEM uplink)."""
    return all(hasattr(p, f) for f in ("s0", "s1", "s2"))


def _require_diagonal(covs, what: str):
    if covs.ndim != 2:
        raise ValueError(
            f"GaussianDP supports diagonal covariance; got a 'full' "
            f"covariance {what} (covs.ndim={covs.ndim})")


# ----------------------------------------------------------------------
# Projection helpers (shared with core/privacy.py, property-tested)
# ----------------------------------------------------------------------

def project_simplex(w, floor: float = WEIGHT_FLOOR):
    """Re-project noised mixture weights to the simplex: floor at
    ``floor`` (every component keeps positive mass) and renormalize."""
    w = jnp.maximum(w, floor)
    return w / jnp.sum(w)


def clip_variances(var, lo: float = VAR_MIN, hi: float = VAR_MAX):
    """Clip noised diagonal variances into the feasible window for
    features normalized to [0,1]^d (variance of [0,1] data <= 1/4)."""
    return jnp.clip(var, lo, hi)


def gaussian_sigma(sensitivity, epsilon, delta):
    """Analytic Gaussian mechanism calibration (traced arithmetic):
    ``sigma = sqrt(2 ln(1.25/delta)) * sensitivity / epsilon``."""
    return jnp.sqrt(2.0 * jnp.log(1.25 / delta)) * sensitivity / epsilon


@dataclasses.dataclass(frozen=True)
class Identity:
    """The no-op transform: the wire payload IS the local payload.

    Exists so pipelines can be configured uniformly (``transform=
    Identity()`` vs ``transform=None``) and as the bit-identity anchor:
    a run under ``Identity`` is ``assert_array_equal`` to a run with no
    transform installed (pinned in tests/test_fed_transforms.py)."""

    seed: int = dataclasses.field(default=0, compare=False)

    def traced(self):
        """No sweepable knobs: an empty pytree."""
        return ()

    def apply(self, key, params, payload, idx, members):
        """Return the payload unchanged."""
        return payload

    def finish(self, total):
        """Return the reduced total unchanged."""
        return total

    def wire_itemsize(self, itemsize: int) -> int:
        """The payload dtype is untouched."""
        return itemsize

    def epsilon_per_round(self) -> float:
        """No privacy budget is spent."""
        return 0.0


@dataclasses.dataclass(frozen=True)
class GaussianDP:
    """Per-client analytic Gaussian mechanism on the uplink, with a
    per-round epsilon accountant.

    The mechanism is the one ``repro.core.privacy`` introduced for the
    one-shot FedGenGMM release (paper §4.4's future work), absorbed into
    the transform seam so it now composes with EVERY strategy:

    - a ``(gmm, n_samples)`` payload (FedGenGMM's one-shot uplink) gets
      the three-way split parameter release: noised weights re-projected
      to the simplex, noised means clipped to [0,1], noised variances
      clipped to [``VAR_MIN``, ``VAR_MAX``] — features are assumed
      normalized to [0,1]^d (paper §5.1) so sensitivities are closed
      forms;
    - a ``SufficientStats`` payload (DEM / FedEM uplink) gets the same
      three-way split across the s0 / s1 / s2 releases with replace-one
      sensitivities sqrt(2), sqrt(2d), sqrt(2d) (responsibilities on the
      simplex, coordinates and their squares in [0,1]).  ``loglik`` and
      ``wsum`` are convergence telemetry, not model payload, and ride
      un-noised — a deployment would drop them from the wire entirely;
    - anything else (e.g. FedKMeans label statistics) raises TypeError —
      add a branch before relying on it.

    **Accountant**: the instance carries the TOTAL budget ``(epsilon,
    delta)`` and the round budget ``rounds`` it is split over (simple
    composition: each round spends ``epsilon/rounds, delta/rounds``).
    One-shot FedGen uses ``rounds=1`` — the whole budget in one release —
    while iterative strategies deplete it across their round budget; the
    driver multiplies :meth:`epsilon_per_round` by the realized round
    count into ``CommStats.epsilon_spent``, so an over-budget run is
    visible in the ledger rather than silent.

    Every numeric field is ``compare=False``: epsilon/delta/... enter
    the jitted loop via :meth:`traced`, so sweeping the budget never
    retraces (pinned in tests/test_compile_counts.py)."""

    epsilon: float = dataclasses.field(default=1.0, compare=False)
    delta: float = dataclasses.field(default=1e-5, compare=False)
    rounds: int = dataclasses.field(default=1, compare=False)
    min_count: float = dataclasses.field(default=8.0, compare=False)
    seed: int = dataclasses.field(default=0, compare=False)

    def __post_init__(self):
        if not float(self.epsilon) > 0.0:
            raise ValueError(f"epsilon must be > 0, got {self.epsilon}")
        if not 0.0 < float(self.delta) < 1.0:
            raise ValueError(f"delta must be in (0, 1), got {self.delta}")
        if int(self.rounds) < 1:
            raise ValueError(f"rounds must be >= 1, got {self.rounds}")
        if not float(self.min_count) > 0.0:
            raise ValueError(
                f"min_count must be > 0, got {self.min_count}")

    def traced(self):
        """Per-round budget as traced scalars: ``(eps_round, delta_round,
        min_count)`` — the compare=False fields never reach the graph
        directly."""
        r = float(self.rounds)
        return (float(self.epsilon) / r, float(self.delta) / r,
                float(self.min_count))

    def epsilon_per_round(self) -> float:
        """Budget spent per realized round: ``epsilon / rounds``."""
        return float(self.epsilon) / float(self.rounds)

    def wire_itemsize(self, itemsize: int) -> int:
        """Noise does not change the payload dtype."""
        return itemsize

    def finish(self, total):
        """Value-level transform: the summed total needs no decoding."""
        return total

    def apply(self, key, params, payload, idx, members):
        """Release an (eps_round, delta_round)-DP view of one client's
        payload (dispatch on the payload family; see class docstring).
        ``key`` is the shared round key; this client's draws come from
        ``fold_in(key, idx)``, identically on every backend."""
        key = jax.random.fold_in(key, idx)
        eps_r, delta_r, min_count = params
        if _is_gmm_release(payload):
            gmm, n = payload
            return self._release_gmm(key, gmm, n, eps_r, delta_r,
                                     min_count), payload[1]
        if _is_stats(payload):
            return self._release_stats(key, payload, eps_r, delta_r)
        raise TypeError(
            f"GaussianDP knows GMM parameter payloads ((gmm, n_samples) "
            f"pairs) and EM SufficientStats; got "
            f"{type(payload).__name__}")

    def _release_gmm(self, key, gmm, n, eps_r, delta_r, min_count):
        _require_diagonal(gmm.covs, "parameter release")
        k, d = gmm.means.shape
        dtype = gmm.means.dtype
        eps_each = eps_r / 3.0
        kw, km, kv = jax.random.split(key, 3)
        n = jnp.asarray(n, dtype)
        counts = jnp.maximum(gmm.weights * n, min_count)

        sig_w = gaussian_sigma(jnp.sqrt(2.0) / jnp.maximum(n, 1.0),
                               eps_each, delta_r)
        w = gmm.weights + jnp.asarray(sig_w, dtype) * \
            jax.random.normal(kw, (k,), dtype)
        w = project_simplex(w)

        sig_m = gaussian_sigma(jnp.sqrt(float(d)), eps_each, delta_r)
        mu = gmm.means + jnp.asarray(sig_m / counts[:, None], dtype) * \
            jax.random.normal(km, (k, d), dtype)
        mu = jnp.clip(mu, 0.0, 1.0)

        sig_v = gaussian_sigma(jnp.sqrt(float(d)) / 4.0, eps_each, delta_r)
        var = gmm.covs + jnp.asarray(sig_v / counts[:, None], dtype) * \
            jax.random.normal(kv, (k, d), dtype)
        var = clip_variances(var)
        return type(gmm)(w, mu, var)

    def _release_stats(self, key, stats, eps_r, delta_r):
        _require_diagonal(stats.s2, "statistics release")
        d = stats.s1.shape[-1]
        dtype = stats.s1.dtype
        eps_each = eps_r / 3.0
        k0, k1, k2 = jax.random.split(key, 3)

        sig0 = gaussian_sigma(jnp.sqrt(2.0), eps_each, delta_r)
        s0 = stats.s0 + jnp.asarray(sig0, dtype) * \
            jax.random.normal(k0, stats.s0.shape, dtype)
        s0 = jnp.maximum(s0, 0.0)

        sig1 = gaussian_sigma(jnp.sqrt(2.0 * d), eps_each, delta_r)
        s1 = stats.s1 + jnp.asarray(sig1, dtype) * \
            jax.random.normal(k1, stats.s1.shape, dtype)

        sig2 = gaussian_sigma(jnp.sqrt(2.0 * d), eps_each, delta_r)
        s2 = stats.s2 + jnp.asarray(sig2, dtype) * \
            jax.random.normal(k2, stats.s2.shape, dtype)
        s2 = jnp.maximum(s2, 0.0)
        return stats._replace(s0=s0, s1=s1, s2=s2)


@dataclasses.dataclass(frozen=True)
class StochasticQuantize:
    """Seeded stochastic rounding of every float leaf to an int8/int16
    grid (simulated compression: the wire carries ``bits``-bit integers
    plus one scale scalar per leaf; the simulator ships the dequantized
    values so the reduce stays a plain float sum).

    Per leaf the grid is symmetric around zero with dynamic range
    ``max|leaf|``: ``q = floor(x/scale + u)`` with ``u ~ U[0,1)`` —
    unbiased (``E[q*scale] = x``) and seeded, so a re-run with the same
    transform seed reproduces the same grid draws bit for bit.
    ``wire_itemsize`` reports the honest uplink bytes (1 for int8, 2 for
    int16); the per-leaf scale scalars ride the payload header and are
    not counted.  ``bits`` is a *structural* field (it changes the grid
    constants), so unlike the seed it participates in equality/hash."""

    bits: int = 8
    seed: int = dataclasses.field(default=0, compare=False)

    def __post_init__(self):
        if self.bits not in (8, 16):
            raise ValueError(
                f"bits must be 8 or 16 (int8/int16 wire), got {self.bits}")

    def traced(self):
        """No sweepable knobs: an empty pytree."""
        return ()

    def epsilon_per_round(self) -> float:
        """Quantization spends no privacy budget."""
        return 0.0

    def wire_itemsize(self, itemsize: int) -> int:
        """The wire carries ``bits``-bit integers: 1 or 2 bytes/elem."""
        return self.bits // 8

    def finish(self, total):
        """Dequantization already happened per client; the float sum is
        the decoded aggregate."""
        return total

    def apply(self, key, params, payload, idx, members):
        """Snap every float leaf of one client's payload to its seeded
        stochastic-rounding grid (non-float leaves pass through).
        ``key`` is the shared round key; this client's grid draws come
        from ``fold_in(key, idx)``."""
        key = jax.random.fold_in(key, idx)
        qmax = float(2 ** (self.bits - 1) - 1)
        leaves, treedef = jax.tree.flatten(payload)
        out = []
        for t, leaf in enumerate(leaves):
            if not jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating):
                out.append(leaf)
                continue
            leaf = jnp.asarray(leaf)
            lk = jax.random.fold_in(key, t)
            scale = jnp.max(jnp.abs(leaf)) / qmax
            safe = jnp.where(scale > 0.0, scale, 1.0)
            u = jax.random.uniform(lk, leaf.shape, leaf.dtype)
            q = jnp.clip(jnp.floor(leaf / safe + u), -qmax - 1.0, qmax)
            out.append(jnp.where(scale > 0.0, q * safe, leaf))
        return treedef.unflatten(out)


@dataclasses.dataclass(frozen=True)
class PairwiseMask:
    """Pairwise zero-sum secure-aggregation masks (Bonawitz et al.-style,
    simulated).

    Every *ordered* pair of participating clients ``(i, j)`` with
    ``i < j`` shares a PRG stream seeded from the canonical pair key
    ``fold_in(fold_in(key, i), j)``; client ``i`` adds the stream's
    draws and client ``j`` subtracts the SAME draws, so the pair's
    contributions cancel in the server sum.  Exact cancellation is only
    possible in modular integer arithmetic (float addition rounds, so
    ``(a+x) + (b-x) != a+b`` bitwise) — which is why real secure
    aggregation quantizes to a fixed-point lattice and sums mod 2^32,
    and why this simulation does the same: the wire channel carries
    ``round(x * 2^fp_bits) + mask_i  (mod 2^32)`` per leaf as int32, and
    the backend reduce's int32 wraparound sum (associative, order-free)
    returns EXACTLY the summed fixed-point payload — the masks cancel
    bit for bit THROUGH the real vmap/host/psum reduce paths (pinned in
    tests/test_fed_transforms.py against an unmasked quantized sum).

    The float payload rides alongside as the simulator's numeric ground
    truth — ``finish`` hands exactly it to ``server_combine``, which is
    what makes a masked run ``assert_array_equal`` to an unmasked run
    (the bit-identity contract) while the modular channel demonstrates
    the protocol.  ``wire_itemsize`` stays the payload's own (the wire
    ships one int32 lattice element per payload element).

    Caveats (documented limits of the simulation, DESIGN.md §11): masks
    pair within the round's ``members``, so a straggler DROP after mask
    agreement leaves its partners' masks uncancelled (real deployments
    recover via secret sharing — out of scope); values outside the
    ``2^31 / 2^fp_bits`` lattice range saturate; and the uplink is only
    meaningfully protected when the server needs nothing but the SUM —
    one-shot FedGen reads each parameter block individually, so the
    runtime rejects the combination (``additive_only``)."""

    fp_bits: int = 16
    seed: int = dataclasses.field(default=0, compare=False)

    # masking is only meaningful for additive aggregation; the one-shot
    # driver refuses to install this transform (see FedGenStrategy)
    additive_only = True

    def __post_init__(self):
        if not 0 <= int(self.fp_bits) <= 30:
            raise ValueError(
                f"fp_bits must be in [0, 30], got {self.fp_bits}")

    def traced(self):
        """No sweepable knobs: an empty pytree."""
        return ()

    def epsilon_per_round(self) -> float:
        """Masking spends no privacy budget."""
        return 0.0

    def wire_itemsize(self, itemsize: int) -> int:
        """One int32 lattice element replaces each payload element."""
        return 4

    def mask(self, key, payload, idx, members):
        """Client ``idx``'s additive mask: a payload-shaped int32 pytree
        ``sum_j sign(idx, j) * PRG(pair(idx, j))`` over ``members``
        (mod 2^32).  Summed over all members the masks are EXACTLY zero
        — integer wraparound addition is associative, so the reduction
        order cannot matter."""
        leaves, treedef = jax.tree.flatten(payload)
        idx = jnp.asarray(idx)
        members = jnp.asarray(members)
        out = [self._mask_leaf(key, jnp.asarray(leaf), idx, members, t)
               for t, leaf in enumerate(leaves)]
        return treedef.unflatten(out)

    def _mask_leaf(self, key, leaf, idx, members, t):
        def one_pair(j):
            lo = jnp.minimum(idx, j)
            hi = jnp.maximum(idx, j)
            pk = jax.random.fold_in(
                jax.random.fold_in(jax.random.fold_in(key, lo), hi), t)
            draw = jax.lax.bitcast_convert_type(
                jax.random.bits(pk, leaf.shape, jnp.uint32), jnp.int32)
            sign = jnp.where(idx == j, 0,
                             jnp.where(idx < j, 1, -1)).astype(jnp.int32)
            return sign * draw

        return jnp.sum(jax.vmap(one_pair)(members), axis=0,
                       dtype=jnp.int32)

    def _lattice(self, leaf):
        """Fixed-point int32 view of a float leaf (saturating at the
        int32 range; non-float leaves are taken as integers)."""
        leaf = jnp.asarray(leaf)
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf.astype(jnp.int32)
        scaled = jnp.round(leaf * float(2 ** self.fp_bits))
        lo, hi = float(-2**31), float(2**31 - 1)
        return jnp.clip(scaled, lo, hi).astype(jnp.int32)

    def apply(self, key, params, payload, idx, members):
        """Wrap one client's payload with its masked modular channel:
        ``{"payload": floats, "secagg": lattice(payload) + mask}``."""
        masks = self.mask(key, payload, idx, members)
        chan = jax.tree.map(
            lambda leaf, m: self._lattice(leaf) + m, payload, masks)
        return {"payload": payload, "secagg": chan}

    def finish(self, total):
        """Strip the (exactly cancelled) modular channel from the summed
        total and hand the float aggregate to ``server_combine``."""
        return total["payload"]


@dataclasses.dataclass(frozen=True)
class Compose:
    """Apply transforms left to right on the uplink and undo their
    encodings right to left on the reduced total — e.g.
    ``Compose((GaussianDP(...), StochasticQuantize(8), PairwiseMask()))``
    is the realistic deployment: noise, then compress, then mask.

    Stage ``t`` draws from ``fold_in(key, t)`` of the pipeline key; the
    pipeline key is seeded from a deterministic combination of the member
    seeds (:attr:`seed`), so re-seeding ANY member re-seeds the pipeline
    without retracing.  ``wire_itemsize`` folds through the stages (the
    last dtype-changing stage wins) and the per-round epsilon spends
    add."""

    transforms: tuple = ()

    def __post_init__(self):
        for t in self.transforms:
            if not callable(getattr(t, "apply", None)):
                raise TypeError(
                    f"Compose members must be PayloadTransforms, got "
                    f"{type(t).__name__}")

    @property
    def seed(self) -> int:
        """Deterministic combination of the member seeds (ints hash
        stably), so the driver's ``key(transform.seed)`` derivation
        works unchanged."""
        return hash(tuple(int(getattr(t, "seed", 0))
                          for t in self.transforms)) & 0x7FFFFFFF

    @property
    def additive_only(self) -> bool:
        """True when any member only makes sense under an additive
        (summed) aggregate — e.g. :class:`PairwiseMask`."""
        return any(getattr(t, "additive_only", False)
                   for t in self.transforms)

    def traced(self):
        """Tuple of the members' traced knobs, in pipeline order."""
        return tuple(t.traced() for t in self.transforms)

    def epsilon_per_round(self) -> float:
        """Per-round budget spends add across the stages."""
        return sum(t.epsilon_per_round() for t in self.transforms)

    def wire_itemsize(self, itemsize: int) -> int:
        """Fold the per-stage dtype changes; the last change wins."""
        for t in self.transforms:
            itemsize = t.wire_itemsize(itemsize)
        return itemsize

    def apply(self, key, params, payload, idx, members):
        """Chain the member ``apply``s left to right, stage ``t`` keyed
        by ``fold_in(key, t)``."""
        for t, (tr, pr) in enumerate(zip(self.transforms, params)):
            payload = tr.apply(jax.random.fold_in(key, t), pr, payload,
                               idx, members)
        return payload

    def finish(self, total):
        """Undo the member encodings right to left."""
        for tr in reversed(self.transforms):
            total = tr.finish(total)
        return total
