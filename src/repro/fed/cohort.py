"""Cohort sampling and straggler handling for the federation runtime
(DESIGN.md §9, "cohort execution").

Production FL populations are mostly idle: a participation-0.03 round at
C = 1000 touches 30 clients, and the round's cost must scale with those
30, not the 1000. This module owns the two seams the round driver
(``repro.fed.runtime.run_rounds``) threads through every backend:

- a **cohort sampler** — ``cohort(key, rnd) -> (m,)`` sorted global
  client indices, the clients round ``rnd`` actually trains.
  :class:`CyclicSampler` reproduces FedEM's historical deterministic
  window (round r takes clients ``[r·m, r·m + m) mod C`` — pinned
  bit-identical to the PR-6 train-all+zero-mask path in
  ``tests/test_fed_runtime.py``); :class:`UniformSampler` is seeded
  uniform sampling without replacement (Tian et al.'s
  partial-participation regime).
- a **straggler policy** — ``drop_mask(key, rnd, cohort) -> (m,)`` 0/1
  weights over the sampled cohort. :class:`ArrivalStragglers` simulates a
  per-round timeout: every cohort member draws an arrival time, the
  slowest ``drop_frac`` fraction misses the deadline, and the round
  reduces over the survivors only (exact-zero contribution from the
  dropped — the DEM zero-weight masking, driven by arrival order).

Both are frozen hashable dataclasses, because they ride the jitted round
loop as *static* arguments: the membership logic is part of the compiled
program, but the PRNG **seed is deliberately excluded from the hash/eq**
(``compare=False``) and enters the computation through a traced key — so
re-seeding the sampler, and therefore changing which clients participate,
NEVER retraces the loop. Cohort *size* (``m``) is static: one compiled
shape serves all rounds at a fixed m.

Samplers return **sorted ascending** indices. On the vmap backends the
order is erased by the scatter-sum reduction; on the host (source)
backend it fixes the client iteration order, keeping the f32
summation order identical to the historical loop over ``enumerate``
(bit-identity again).

This module is repro-free below ``repro.fed.runtime`` (jax + stdlib
only), so the runtime imports it without cycles.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CyclicSampler:
    """Deterministic cyclic cohorts: round ``rnd`` takes the window
    ``[rnd·m, rnd·m + m) mod C`` — exactly the window FedEM's zero-mask
    path computed per client, now computed once by the driver. Cohorts
    are non-empty, cover every client within one cycle (period
    ``C / gcd(C, m)``), and ignore the PRNG key entirely."""

    num_clients: int
    cohort_size: int

    name = "cyclic"

    def __post_init__(self):
        _validate_sizes(self.num_clients, self.cohort_size)

    def cohort(self, key, rnd):
        c, m = self.num_clients, self.cohort_size
        start = (rnd * m) % c
        idx = (start + jnp.arange(m, dtype=jnp.int32)) % c
        # the window wraps at most once, so sorting restores ascending
        # global order (what the host backend iterates in)
        return jnp.sort(idx)


@dataclasses.dataclass(frozen=True)
class UniformSampler:
    """Seeded uniform sampling without replacement: round ``rnd`` draws
    ``m`` distinct clients from ``fold_in(key, rnd)``. The seed is
    ``compare=False`` — it reaches the computation through the traced key
    the driver builds from it, so re-seeding never recompiles."""

    num_clients: int
    cohort_size: int
    seed: int = dataclasses.field(default=0, compare=False)

    name = "uniform"

    def __post_init__(self):
        _validate_sizes(self.num_clients, self.cohort_size)

    def cohort(self, key, rnd):
        k = jax.random.fold_in(key, rnd)
        idx = jax.random.choice(k, self.num_clients,
                                (self.cohort_size,), replace=False)
        return jnp.sort(idx.astype(jnp.int32))


def _validate_sizes(num_clients: int, cohort_size: int) -> None:
    if int(num_clients) < 1:
        raise ValueError(f"num_clients must be >= 1, got {num_clients}")
    if not 1 <= int(cohort_size) <= int(num_clients):
        raise ValueError(
            f"cohort_size must be in [1, num_clients={num_clients}], "
            f"got {cohort_size}")


def make_sampler(kind: str, num_clients: int, cohort_size: int,
                 seed: int = 0):
    """Sampler factory by name — the spelling the api facades use.
    ``"cyclic"`` (deterministic window) or ``"uniform"`` (seeded,
    without replacement)."""
    if kind == "cyclic":
        return CyclicSampler(int(num_clients), int(cohort_size))
    if kind == "uniform":
        return UniformSampler(int(num_clients), int(cohort_size),
                              seed=int(seed))
    raise ValueError(
        f"cohort sampler must be 'cyclic' or 'uniform', got {kind!r}")


@dataclasses.dataclass(frozen=True)
class PolynomialStaleness:
    """The staleness-weighting rule of the asynchronous driver
    (``repro.fed.run_async``, DESIGN.md §12): an update consumed ``s``
    server versions after its dispatch contributes with weight
    ``(1 + s)^-alpha`` (Xie et al.'s polynomial damping). This is the
    straggler reweight rule generalized from {0, 1} to (0, 1]: the
    weight multiplies the client's additive payload — including its
    ``wsum`` — so the server M-step renormalizes by the *surviving*
    (staleness-discounted) weight mass and stale cohorts shrink toward
    the fresh ones instead of dragging the model backward.

    ``alpha = 0`` weighs every update exactly 1.0 (pure buffering, no
    damping); fresh updates (``s = 0``) weigh exactly 1.0 at any alpha —
    both identities are exact in f32, which is what keeps the async
    driver's zero-staleness configuration bit-identical to the
    synchronous loop."""

    alpha: float = 0.5

    def __post_init__(self):
        if not float(self.alpha) >= 0.0:
            raise ValueError(
                f"staleness alpha must be >= 0, got {self.alpha}")

    def weight(self, staleness: int) -> float:
        """Weight of an update consumed ``staleness`` versions late
        (exactly 1.0 at staleness 0 or alpha 0)."""
        s = int(staleness)
        if s < 0:
            raise ValueError(f"staleness must be >= 0, got {staleness}")
        if s == 0 or self.alpha == 0.0:
            return 1.0
        return float((1.0 + s) ** -float(self.alpha))


@dataclasses.dataclass(frozen=True)
class ArrivalStragglers:
    """Simulated round deadline: each cohort member draws an arrival
    time ``uniform(fold_in(fold_in(key, rnd), client_id))``; the slowest
    ``drop_frac`` fraction of the cohort misses the cutoff and is
    dropped (0 weight — its payload never enters the round sum, and the
    server's M-step renormalizes by the surviving ``wsum``, i.e. the
    reweight-by-survivors rule). At least one client always survives.

    Keying arrival times by *global client id* (not cohort position)
    makes a client's luck independent of which cohort it lands in; the
    seed is ``compare=False`` exactly like the samplers', so re-seeding
    the simulation never retraces the round loop."""

    drop_frac: float
    seed: int = dataclasses.field(default=0, compare=False)

    def __post_init__(self):
        if not 0.0 <= float(self.drop_frac) < 1.0:
            raise ValueError(
                f"drop_frac must be in [0, 1), got {self.drop_frac}")

    def n_keep(self, cohort_size: int) -> int:
        """Survivors per round (static: the cutoff rank is part of the
        compiled program; which *clients* survive is traced)."""
        m = int(cohort_size)
        return max(1, m - int(round(float(self.drop_frac) * m)))

    def drop_mask(self, key, rnd, cohort):
        m = cohort.shape[0]
        keep = self.n_keep(m)
        kr = jax.random.fold_in(key, rnd)
        arrival = jax.vmap(
            lambda i: jax.random.uniform(jax.random.fold_in(kr, i)))(cohort)
        # keep the `keep` earliest arrivals: cutoff = keep-th order stat
        cutoff = jnp.sort(arrival)[keep - 1]
        return (arrival <= cutoff).astype(jnp.float32)
