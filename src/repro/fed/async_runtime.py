"""Asynchronous federation: buffered staleness-weighted rounds and the
concurrent source-client executor (DESIGN.md §12).

The synchronous driver (``repro.fed.runtime.run_rounds``) makes every
round wait for its whole cohort: the slowest client gates the server
combine, and the host-loop source backend runs cohort members strictly
serially. This module opens the staggered regime along two independent
axes:

- :class:`ClientExecutor` — a pool of long-lived worker threads that the
  ``SourceClients`` backend fans per-client steps out to. Each worker
  pulls a client assignment off the pool's queue, dispatches that
  client's (jitted) E-step, and JAX's async dispatch lets one client's
  host-side block prep (padding, mmap reads, prefetch) overlap another's
  device compute. Sync semantics are untouched: the backend reduces the
  per-client payloads in deterministic cohort order regardless of
  completion order, so the f32 sum is bit-identical to the serial loop.

- :func:`run_async` — buffered asynchronous rounds. Clients are
  dispatched against the server model current *at dispatch time* and the
  server combines as soon as ``buffer_size`` updates arrive; with
  ``lookahead > 0`` more clients are kept in flight than one combine
  consumes, so updates arrive for a model ``s`` versions newer than the
  one they trained against. Each update is weighted by the staleness
  rule (:class:`repro.fed.cohort.PolynomialStaleness` — the straggler
  reweight rule generalized from {0, 1} to (0, 1]), the M-step
  renormalizes by the surviving weighted ``wsum``, and the realized
  per-update staleness lands in the ledger
  (:class:`~repro.fed.ledger.RoundPayload`/``CommStats.staleness``).

The determinism contract: arrival order is *dispatch order*, not
wall-clock completion order — the buffer consumes the oldest in-flight
updates first. That makes every run of a seeded configuration
reproducible, and it makes the degenerate configuration
``buffer_size = cohort_size, lookahead = 0`` reproduce the synchronous
driver exactly: every combine then consumes precisely one cohort, all
dispatched at the current version (zero staleness, weight exactly 1.0),
through the same backend reduce — pinned ``assert_array_equal``-identical
to :func:`~repro.fed.runtime.run_rounds` on the split and source
backends in tests/test_fed_async.py.

Like the rest of the runtime this module sits below ``repro.core``
(imports: jax + stdlib + ``repro.fed`` siblings only), so strategy
modules can import it without cycles.
"""
from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
from functools import partial
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.fed.cohort import PolynomialStaleness
from repro.fed.runtime import (_CohortView, _cohort_and_weights,
                               _keep_going, _validate_transform,
                               make_backend)


class ClientExecutor:
    """A pool of long-lived client workers for the source backend.

    Workers pull client assignments off the pool's shared queue (the
    stdlib ``ThreadPoolExecutor`` is exactly that shape — threads live
    for the pool's lifetime, work items queue) and run the per-client
    step; jitted E-steps release the GIL into XLA, so one client's
    host-side block preparation overlaps another's device compute
    instead of serializing in the driver's host loop. The pool is meant
    to be long-lived: build it once and pass it to any number of
    ``run_rounds``/``run_async`` calls (it is reused across rounds, not
    rebuilt per round).

    Determinism: :meth:`map_ordered` returns results in *submission*
    order whatever the completion order, and per-client steps are
    identical jitted computations on identical inputs — so a reduction
    over the returned list is bit-identical to the serial host loop.
    """

    def __init__(self, max_workers: int):
        if int(max_workers) < 1:
            raise ValueError(
                f"max_workers must be >= 1, got {max_workers}")
        self.max_workers = int(max_workers)
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=self.max_workers,
            thread_name_prefix="fed-client")

    def map_ordered(self, fn: Callable[[Any], Any],
                    items: Sequence[Any]) -> list:
        """Run ``fn`` over ``items`` on the worker pool and return the
        results in item order (NOT completion order) — the property the
        backend's deterministic cohort-order reduction relies on."""
        futures = [self._pool.submit(fn, item) for item in items]
        return [f.result() for f in futures]

    def shutdown(self) -> None:
        """Stop the workers (waits for in-flight client steps)."""
        self._pool.shutdown(wait=True)

    def __enter__(self):
        """Context-manager entry: the executor itself."""
        return self

    def __exit__(self, *exc):
        """Context-manager exit: shut the worker pool down."""
        self.shutdown()
        return False


@dataclasses.dataclass(frozen=True)
class AsyncPolicy:
    """The async-execution knob of DEM/FedEM (and ``fit_federated``).

    One frozen bundle of :func:`run_async`'s knobs so the estimator
    facades stay one-argument: ``buffer_size`` updates per server
    combine (None = the cohort size — the sync-equivalent default),
    ``lookahead`` extra in-flight dispatches beyond the buffer (0 = no
    staleness ever arises; ``k·buffer_size`` sustains staleness ~k),
    ``staleness_alpha`` the polynomial damping exponent of
    :class:`~repro.fed.cohort.PolynomialStaleness`, and ``max_workers``
    (> 0 builds a :class:`ClientExecutor` for source-client backends —
    resident backends ignore it)."""

    buffer_size: Optional[int] = None
    lookahead: int = 0
    staleness_alpha: float = 0.5
    max_workers: int = 0

    def __post_init__(self):
        if self.buffer_size is not None and int(self.buffer_size) < 1:
            raise ValueError(
                f"buffer_size must be >= 1, got {self.buffer_size}")
        if int(self.lookahead) < 0:
            raise ValueError(
                f"lookahead must be >= 0, got {self.lookahead}")
        if not float(self.staleness_alpha) >= 0.0:
            raise ValueError(
                f"staleness_alpha must be >= 0, got {self.staleness_alpha}")
        if int(self.max_workers) < 0:
            raise ValueError(
                f"max_workers must be >= 0, got {self.max_workers}")

    def driver_kwargs(self) -> dict:
        """The :func:`run_async` keyword arguments this policy encodes
        (what the cfg-cores splat into the driver call)."""
        return dict(buffer_size=self.buffer_size,
                    lookahead=int(self.lookahead),
                    staleness=PolynomialStaleness(float(self.staleness_alpha)),
                    max_workers=int(self.max_workers))


# ----------------------------------------------------------------------
# Jitted round pieces (resident/sharded backends)
# ----------------------------------------------------------------------
# The host path calls the same compositions eagerly (a DataSource cannot
# live inside jit), mirroring run_rounds' own host/jit duality.

@partial(jax.jit, static_argnames=("strategy", "transform"))
def _round_jit(strategy, backend, state, cohort, weights, transform,
               tparams, rkey):
    """One fresh round as ONE jitted program — reduce, transform
    ``finish``, server combine — structurally ``runtime._round``. Used
    whenever a combine consumes a single zero-staleness group (always,
    in the sync-equivalent configuration), so the compiled computation
    matches the synchronous loop body."""
    total = backend.reduce_clients(strategy.local_step, state, cohort,
                                   weights, transform=transform,
                                   tparams=tparams, tkey=rkey)
    if transform is not None:
        total = transform.finish(total)
    return strategy.server_combine(state, total)


@partial(jax.jit, static_argnames=("strategy", "transform"))
def _group_total_jit(strategy, backend, state, cohort, weights, transform,
                     tparams, rkey):
    """One stale group's weighted payload total (reduced against the
    model version the group was dispatched at — NOT the current one)."""
    return backend.reduce_clients(strategy.local_step, state, cohort,
                                  weights, transform=transform,
                                  tparams=tparams, tkey=rkey)


@partial(jax.jit, static_argnames=("strategy", "transform"))
def _combine_jit(strategy, state, total, transform):
    """Server combine of an already-summed multi-group buffer against
    the CURRENT model state."""
    if transform is not None:
        total = transform.finish(total)
    return strategy.server_combine(state, total)


def _resolve_staleness(staleness):
    """Accept a rule object (``.weight(s)``), a bare alpha, or None
    (default polynomial damping)."""
    if staleness is None:
        return PolynomialStaleness()
    if isinstance(staleness, (int, float)):
        return PolynomialStaleness(float(staleness))
    if not callable(getattr(staleness, "weight", None)):
        raise TypeError(
            f"staleness must be an alpha or a rule with .weight(s), got "
            f"{type(staleness).__name__}")
    return staleness


# One in-flight client update: who, against which model version, at what
# straggler weight, from which dispatch round (the transform/straggler
# round key), and whether its dispatch batch carried no weights at all
# (so the zero-staleness reduce can pass weights=None, exactly like the
# synchronous driver).
_Update = collections.namedtuple(
    "_Update", ("client", "version", "weight", "rnd", "unweighted"))


def _pad_cohort(members: np.ndarray, weights: Optional[np.ndarray],
                size: int, population: int):
    """Pad a group's member indices to the static reduce width with
    distinct unused population slots at weight 0 (distinctness keeps the
    scatter-``set`` well-defined), so every group reduce shares ONE
    compiled shape. A full-width group passes through untouched."""
    pad_n = size - len(members)
    if pad_n == 0:
        return members, weights
    free = np.setdiff1d(np.arange(population, dtype=members.dtype), members)
    padded = np.concatenate([members, free[:pad_n]])
    w = np.ones(len(members), np.float32) if weights is None else weights
    return padded, np.concatenate([w, np.zeros(pad_n, np.float32)])


def run_async(strategy, clients, *, key: Optional[jax.Array] = None,
              state0=None, max_rounds: int = 1, mesh=None,
              axis: str = "data", sampler=None, stragglers=None,
              transform=None, buffer_size: Optional[int] = None,
              lookahead: int = 0, staleness=None, executor=None,
              max_workers: int = 0, progress=None):
    """Buffered asynchronous rounds — the staggered counterpart of
    :func:`~repro.fed.runtime.run_rounds`.

    Client assignments stream from the sampler's cohorts (round-robin
    over the population without one); up to ``buffer_size + lookahead``
    clients are in flight at once, each pinned to the server model
    version current at its dispatch. A server *combine* consumes the
    ``buffer_size`` oldest in-flight updates (dispatch order — the
    determinism contract), weights each by
    ``staleness_rule.weight(current_version - dispatch_version)`` on top
    of its straggler weight, sums group-wise against the stale model
    each group trained on, and M-steps against the current state. With
    ``buffer_size = cohort_size`` and ``lookahead = 0`` every combine is
    one whole fresh cohort — bit-identical to ``run_rounds``.

    ``max_rounds`` bounds server combines (each consumes ``buffer_size``
    updates, so at equal round budgets the async run does
    ``buffer/cohort`` of the synchronous client work per combine — the
    wall-clock-to-target win BENCH_comm.json's ``async`` section
    measures). Convergence predicates, ``post_rounds`` epilogues, the
    sampler/straggler/transform seams and the ledger all behave as in
    ``run_rounds``; in-flight updates left when the loop stops are
    abandoned (never consumed, never accounted).

    ``staleness`` is a rule object with ``.weight(s)``, a bare alpha, or
    None (default :class:`~repro.fed.cohort.PolynomialStaleness`).
    ``executor`` / ``max_workers`` install a :class:`ClientExecutor` on
    a source-client backend. ``progress`` (optional) is called after
    every combine as ``progress(version, state, staleness_tuple)`` —
    instrumentation only (the comm bench snapshots trajectories with
    it).

    Additive-only transforms (secure-agg pairwise masks) need the whole
    cohort in one aggregate, so they are accepted only in the
    sync-equivalent configuration.
    """
    backend = make_backend(clients, mesh, axis)
    if getattr(strategy, "one_shot", False):
        raise ValueError(
            "run_async needs a round structure; one-shot strategies "
            "have nothing to buffer — use run_rounds")
    rule = _resolve_staleness(staleness)
    population = backend.num_clients
    batch_m = population if sampler is None else int(sampler.cohort_size)
    buffer = batch_m if buffer_size is None else int(buffer_size)
    if not 1 <= buffer <= population:
        raise ValueError(
            f"buffer_size must be in [1, population={population}], got "
            f"{buffer}")
    lookahead = int(lookahead)
    if lookahead < 0:
        raise ValueError(f"lookahead must be >= 0, got {lookahead}")
    sync_equivalent = buffer == batch_m and lookahead == 0

    skey = dkey = tkey = tparams = None
    if transform is not None:
        _validate_transform(transform)
        if getattr(transform, "additive_only", False) and not sync_equivalent:
            raise ValueError(
                f"{type(transform).__name__} masks only cancel when one "
                f"aggregate sums the whole cohort; buffered async rounds "
                f"(buffer_size != cohort_size or lookahead > 0) split "
                f"cohorts across combines")
        tkey = jax.random.key(int(getattr(transform, "seed", 0)))
        tparams = transform.traced()
    if sampler is not None:
        if sampler.num_clients != population:
            raise ValueError(
                f"sampler is sized for {sampler.num_clients} clients but "
                f"the backend has {population}")
        skey = jax.random.key(int(getattr(sampler, "seed", 0)))
    if stragglers is not None:
        dkey = jax.random.key(int(getattr(stragglers, "seed", 0)))

    own_executor = None
    if backend.host:
        if executor is None and int(max_workers) > 0:
            executor = own_executor = ClientExecutor(int(max_workers))
        if executor is not None:
            backend.executor = executor

    if state0 is None:
        state0 = strategy.init_state(key, backend)

    try:
        return _drive(strategy, backend, state0, int(max_rounds), sampler,
                      stragglers, transform, tparams, skey, dkey, tkey,
                      buffer, lookahead, rule, sync_equivalent, progress)
    finally:
        if own_executor is not None:
            own_executor.shutdown()


def _drive(strategy, backend, state0, max_rounds, sampler, stragglers,
           transform, tparams, skey, dkey, tkey, buffer, lookahead, rule,
           sync_equivalent, progress):
    """The event loop behind :func:`run_async`: top up the in-flight
    window, consume the oldest ``buffer`` updates, combine, repeat."""
    population = backend.num_clients
    fifo: collections.deque = collections.deque()
    states = {0: state0}          # retained models for in-flight versions
    state = state0
    version = 0                   # server combines so far
    dispatch_rnd = 0              # assignment batches drawn so far
    staleness_counter: collections.Counter = collections.Counter()

    def top_up():
        """Fill the in-flight window with fresh dispatches against the
        CURRENT model version."""
        nonlocal dispatch_rnd
        while len(fifo) < buffer + lookahead:
            cohort, weights = _cohort_and_weights(
                sampler, stragglers, backend, skey, dkey, dispatch_rnd)
            members = np.arange(population, dtype=np.int32) \
                if cohort is None else np.asarray(cohort)
            w = None if weights is None else np.asarray(weights)
            for pos, i in enumerate(members):
                fifo.append(_Update(
                    int(i), version,
                    1.0 if w is None else float(w[pos]),
                    dispatch_rnd, w is None))
            dispatch_rnd += 1

    def group_consumed(consumed):
        """Split one buffer of consumed updates into contiguous
        (version, dispatch round) groups — each group shares the model
        it trained against and its round's transform/straggler key."""
        groups = []
        for u in consumed:
            if groups and (groups[-1][0], groups[-1][1]) == (u.version,
                                                             u.rnd):
                groups[-1][2].append(u)
            else:
                groups.append([u.version, u.rnd, [u]])
        return groups

    def reduce_group(v, rnd, updates, stale_w, whole_buffer):
        """One group's weighted payload total against its dispatch-time
        model ``states[v]``."""
        members = np.asarray([u.client for u in updates], np.int32)
        unweighted = all(u.unweighted for u in updates) and stale_w == 1.0
        weights = None if unweighted else np.asarray(
            [u.weight * stale_w for u in updates], np.float32)
        rkey = None if transform is None else jax.random.fold_in(tkey, rnd)
        # full-population batches mirror run_rounds' cohort=None spelling
        full_pop = sampler is None and len(members) == population
        if backend.host:
            cohort = None if full_pop else members
            w = None if weights is None else jnp.asarray(weights)
            return backend.reduce_clients(
                strategy.local_step, states[v], cohort, w,
                transform=transform, tparams=tparams, tkey=rkey), None
        if full_pop:
            cohort, w = None, None if weights is None \
                else jnp.asarray(weights)
        else:
            padded, pw = _pad_cohort(members, weights, buffer, population)
            cohort = jnp.asarray(padded)
            w = None if pw is None else jnp.asarray(pw)
        fresh_whole = v == version and whole_buffer
        if fresh_whole:
            # single fresh group: reduce + combine as ONE jitted program,
            # the exact shape of the synchronous loop body (bit-parity)
            return None, _round_jit(strategy, backend, states[v], cohort,
                                    w, transform, tparams, rkey)
        return _group_total_jit(strategy, backend, states[v], cohort, w,
                                transform, tparams, rkey), None

    while True:
        top_up()
        consumed = [fifo.popleft() for _ in range(buffer)]
        total = None
        combined = None
        for v, rnd, updates in group_consumed(consumed):
            stale = version - v
            stale_w = rule.weight(stale)
            for u in updates:
                if u.weight != 0.0:
                    staleness_counter[stale] += 1
            g_total, g_state = reduce_group(v, rnd, updates, stale_w,
                                            len(updates) == len(consumed))
            if g_state is not None:
                combined = g_state
                break
            total = g_total if total is None else jax.tree.map(
                jnp.add, total, g_total)
        if combined is not None:
            state = combined
        elif backend.host:
            if transform is not None:
                total = transform.finish(total)
            state = strategy.server_combine(state, total)
        else:
            state = _combine_jit(strategy, state, total, transform)
        version += 1
        states[version] = state
        live = min((u.version for u in fifo), default=version)
        for v in [v for v in states if v < min(live, version)]:
            del states[v]
        if progress is not None:
            progress(version, state,
                     tuple(version - 1 - u.version for u in consumed
                           if u.weight != 0.0))
        if version >= max_rounds or not bool(_keep_going(strategy, state)):
            break

    converged = bool(strategy.converged(state))
    post = getattr(strategy, "post_rounds", None)
    if post is not None:
        state = post(state, backend)

    view = _CohortView(backend, buffer)
    payload = strategy.round_payload(view, state)
    if transform is not None:
        payload = payload._replace(
            uplink_itemsize=transform.wire_itemsize(payload.itemsize),
            epsilon_per_round=float(transform.epsilon_per_round()))
    payload = payload._replace(
        staleness=tuple(sorted(staleness_counter.items())))
    comm = payload.totals(version)
    return strategy.finalize(state, jnp.asarray(version), converged, comm)
