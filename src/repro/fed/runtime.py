"""The federation runtime: one round driver under every federated
algorithm in the repo (DESIGN.md §9).

Tian et al.'s federated EM, Garst et al.'s federated k-means, the paper's
one-shot FedGenGMM and the DEM baseline all decompose into the same
round::

    client-update  ->  uplink  ->  server-combine  ->  broadcast

so this module owns that shape exactly once. A
:class:`FederationStrategy` supplies the algorithm (``local_step`` /
``server_combine`` / ``converged`` / ``round_payload``); a client
*backend* supplies where the clients live (a padded resident
:class:`~repro.core.partition.ClientSplit`, a list of out-of-core
:class:`~repro.data.sources.DataSource` streams, or shards of a device
mesh); and :func:`run_rounds` is the single driver that owns the round
loop, the input-type dispatch, and the communication ledger
(``repro.fed.ledger``). The algorithms in ``repro.core.fedgen`` /
``repro.core.dem`` and the new FedEM / FedKMeans baselines
(``repro.fed.strategies``) are all strategy definitions on this
substrate — none of them carries its own client loop any more.

Execution modes (picked per backend, never per strategy):

- resident clients (split or sharded mesh): the whole round loop runs as
  ONE jitted ``lax.while_loop`` — structurally identical to the
  pre-refactor ``_dem_loop``/``dem_sharded`` loops, which is what keeps
  the re-landed algorithms bit-identical to their history;
- source clients: a host-side round loop (a ``DataSource`` cannot live
  inside jit) with the same state transitions, mirroring the engine's
  ``host_em_loop`` semantics (Python-float convergence arithmetic).

This module deliberately imports nothing from ``repro.core`` at module
top (only ``repro.data.sources``, which is itself repro-free), so
``core/fedgen.py`` and ``core/dem.py`` can import the runtime without
cycles; the one :class:`ClientSplit` isinstance check is a call-time
import.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional, Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.data.sources import DataSource
from repro.fed.ledger import CommStats, RoundPayload


@runtime_checkable
class FederationStrategy(Protocol):
    """The round-based strategy contract (duck-typed; subclassing is not
    required — frozen dataclasses are the idiom, so a strategy can ride
    through jit as a static argument).

    Iterative strategies implement:

    - ``init_state(key, backend) -> state`` — host-side; build round-0
      state (global model, convergence scalars). Numeric knobs that must
      not recompile the loop when swept (tol, reg_covar) belong in the
      *state* (traced), not in strategy fields (static).
    - ``local_step(state, x, w, idx) -> payload`` — ONE client's update:
      an additive pytree (the uplink). Must be traceable; ``x`` is that
      client's rows (array or DataSource), ``w`` its padding mask (None
      for sources), ``idx`` its global client index.
    - ``server_combine(state, total) -> state`` — the server side of the
      round, from the client-summed payload.
    - ``converged(state) -> bool`` — jnp bool under jit, Python bool on
      the host path (state scalars differ accordingly).
    - ``keep_going(state) -> bool`` (optional) — the loop-continuation
      predicate when it is NOT simply ``not converged``. The historical
      EM loops continue on ``delta > tol`` and report convergence as
      ``delta <= tol`` — with a NaN convergence scalar BOTH are false, so
      a degenerate run stops after one more round AND reports
      not-converged instead of spinning to the round budget. Strategies
      with that semantics implement both predicates; the driver falls
      back to ``not converged`` when ``keep_going`` is absent.
    - ``round_payload(backend, state) -> RoundPayload`` — what one round
      moves; the driver multiplies by the realized round count.
    - ``finalize(state, n_rounds, converged, comm) -> result``.

    One-shot strategies (``one_shot = True``) implement ``run_once(state,
    backend) -> state`` instead of ``local_step``/``server_combine``/
    ``converged``: the single round runs host-side (FedGenGMM's local
    fits include Python-level per-client BIC selection).
    """

    one_shot: bool

    def init_state(self, key: jax.Array, backend) -> Any: ...

    def round_payload(self, backend, state) -> RoundPayload: ...

    def finalize(self, state, n_rounds, converged, comm: CommStats): ...


# ----------------------------------------------------------------------
# Client backends: where the clients live
# ----------------------------------------------------------------------
# Each backend exposes the same two faces:
#   - host metadata (kind / num_clients / dim / sizes / the original
#     container) that strategies use in init_state and accounting;
#   - reduce_clients(local_step, state): sum the per-client payload
#     pytrees — a vmap + tree-sum (split), a Python loop (sources), or a
#     shard_map + psum (mesh). The jittable backends are pytrees so the
#     driver can pass them straight through the jitted round loop.


@jax.tree_util.register_pytree_node_class
class SplitClients:
    """Resident padded clients: ``data (C, N, d)``, ``mask (C, N)``."""

    kind = "split"
    host = False

    def __init__(self, data: jax.Array, mask: jax.Array, split=None):
        self.data = data
        self.mask = mask
        self.split = split  # the original ClientSplit (host metadata)

    def tree_flatten(self):
        return (self.data, self.mask), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def num_clients(self) -> int:
        return self.data.shape[0]

    @property
    def dim(self) -> int:
        return self.data.shape[-1]

    @property
    def sizes(self):
        return self.split.sizes if self.split is not None else jnp.sum(
            self.mask, axis=1)

    def reduce_clients(self, local_step, state):
        c = self.data.shape[0]
        idx = jnp.arange(c)
        per = jax.vmap(lambda x, w, i: local_step(state, x, w, i))(
            self.data, self.mask, idx)
        return jax.tree.map(lambda s: jnp.sum(s, axis=0), per)


class SourceClients:
    """Out-of-core clients: one :class:`DataSource` stream each. Rounds
    run host-side (a source cannot live inside jit); per-client block
    loops stay jitted inside the engine."""

    kind = "sources"
    host = True

    def __init__(self, sources: Sequence[DataSource]):
        self.sources = list(sources)

    @property
    def num_clients(self) -> int:
        return len(self.sources)

    @property
    def dim(self) -> int:
        return self.sources[0].dim

    @property
    def sizes(self):
        return [src.num_rows for src in self.sources]

    def reduce_clients(self, local_step, state):
        per = [local_step(state, src, None, i)
               for i, src in enumerate(self.sources)]
        return jax.tree.map(lambda *s: sum(s), *per)


@jax.tree_util.register_pytree_node_class
class ShardedClients:
    """Mesh-sharded clients: the client axis of ``data (C, N, d)`` maps to
    shards of ``axis``; the per-round combine is literally one
    ``jax.lax.psum`` across the mesh — the collective pattern the sharded
    DEM runtime always had, now produced by the same driver as everything
    else."""

    kind = "sharded"
    host = False

    def __init__(self, data: jax.Array, mask: jax.Array, mesh,
                 axis: str = "data"):
        self.data = data
        self.mask = mask
        self.mesh = mesh
        self.axis = axis

    def tree_flatten(self):
        return (self.data, self.mask), (self.mesh, self.axis)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def num_clients(self) -> int:
        return self.data.shape[0]

    @property
    def dim(self) -> int:
        return self.data.shape[-1]

    @property
    def sizes(self):
        return jnp.sum(self.mask, axis=1)

    def reduce_clients(self, local_step, state):
        axis = self.axis
        c = self.data.shape[0]

        def shard_fn(state, idx_s, data_s, mask_s):
            per = jax.vmap(lambda x, w, i: local_step(state, x, w, i))(
                data_s, mask_s, idx_s)
            local = jax.tree.map(lambda s: jnp.sum(s, axis=0), per)
            # === one all-reduce per round ===
            return jax.tree.map(lambda s: jax.lax.psum(s, axis), local)

        fn = shard_map(shard_fn, mesh=self.mesh,
                       in_specs=(P(), P(axis), P(axis), P(axis)),
                       out_specs=P(), check_rep=False)
        return fn(state, jnp.arange(c), self.data, self.mask)


def make_backend(clients, mesh=None, axis: str = "data"):
    """THE client dispatch: ClientSplit -> :class:`SplitClients`, a list
    of DataSources -> :class:`SourceClients`, ``(data, mask)`` arrays with
    a ``mesh`` -> :class:`ShardedClients`."""
    if mesh is not None:
        data, mask = clients
        return ShardedClients(jnp.asarray(data), jnp.asarray(mask), mesh,
                              axis)
    from repro.core.partition import ClientSplit  # call-time: core sits above
    if isinstance(clients, ClientSplit):
        return SplitClients(jnp.asarray(clients.data),
                            jnp.asarray(clients.mask), clients)
    if (isinstance(clients, (list, tuple)) and len(clients) > 0
            and all(isinstance(s, DataSource) for s in clients)):
        return SourceClients(clients)
    raise TypeError(
        f"federated clients must be a ClientSplit, a non-empty list of "
        f"DataSources, or (data, mask) arrays with a mesh; got "
        f"{type(clients).__name__}")


# ----------------------------------------------------------------------
# The round driver
# ----------------------------------------------------------------------

def _round(strategy, state, backend):
    """One full round: client updates -> summed uplink -> server combine."""
    total = backend.reduce_clients(strategy.local_step, state)
    return strategy.server_combine(state, total)


def _keep_going(strategy, state):
    """Loop-continuation predicate: the strategy's own ``keep_going``
    when it has one (EM-style ``delta > tol``, which also halts on a NaN
    scalar exactly like the pre-§9 loops), else ``not converged``."""
    kg = getattr(strategy, "keep_going", None)
    if kg is not None:
        return kg(state)
    return jnp.logical_not(strategy.converged(state))


@partial(jax.jit, static_argnames=("strategy", "max_rounds"))
def _iterate_jit(strategy, backend, state0, max_rounds: int):
    """Resident-client round loop as ONE jitted ``lax.while_loop`` —
    bootstrap round, then iterate while ``keep_going``. Structurally the
    pre-§9 ``_dem_loop``: same state transitions, same cond arithmetic,
    so re-landed strategies reproduce their history bit for bit. The
    strategy is a static argument (hashable frozen dataclass); numeric
    knobs that sweep (tol, reg_covar) ride in ``state0`` as traced
    leaves, so sweeping them does not recompile."""

    def cond(carry):
        state, it = carry
        return jnp.logical_and(it < max_rounds, _keep_going(strategy, state))

    def body(carry):
        state, it = carry
        return _round(strategy, state, backend), it + 1

    state1 = _round(strategy, state0, backend)
    state, it = jax.lax.while_loop(cond, body, (state1, jnp.array(1)))
    return state, it


def run_rounds(strategy, clients, *, key: Optional[jax.Array] = None,
               state0=None, max_rounds: int = 1, mesh=None,
               axis: str = "data"):
    """Run a :class:`FederationStrategy` to convergence — THE round loop.

    Owns everything that used to be copy-pasted per algorithm: the client
    input dispatch (:func:`make_backend`), the round loop (jitted
    while_loop for resident/sharded clients, host loop for sources), the
    bootstrap round, the round budget, and the communication ledger
    (realized rounds x the strategy's :class:`RoundPayload`).

    ``state0`` overrides the strategy's own ``init_state`` (the sharded
    DEM entry point passes externally chosen init centers this way);
    otherwise ``key`` seeds it.
    """
    backend = make_backend(clients, mesh, axis)
    if state0 is None:
        state0 = strategy.init_state(key, backend)

    if getattr(strategy, "one_shot", False):
        state = strategy.run_once(state0, backend)
        rounds, n_rounds, converged = 1, jnp.asarray(1), True
    elif backend.host:
        state = _round(strategy, state0, backend)
        it = 1
        while it < max_rounds and bool(_keep_going(strategy, state)):
            state = _round(strategy, state, backend)
            it += 1
        rounds, n_rounds = it, jnp.asarray(it)
        converged = bool(strategy.converged(state))
    else:
        state, n_rounds = _iterate_jit(strategy, backend, state0, max_rounds)
        rounds = int(n_rounds)
        converged = bool(strategy.converged(state))

    # Optional once-per-run epilogue (e.g. FedKMeans rescoring its final
    # centers); runs eagerly after the loop, before the ledger is drawn up
    # so the strategy's RoundPayload can account for it.
    post = getattr(strategy, "post_rounds", None)
    if post is not None and not getattr(strategy, "one_shot", False):
        state = post(state, backend)

    payload = strategy.round_payload(backend, state)
    comm = payload.totals(rounds)
    return strategy.finalize(state, n_rounds, converged, comm)
