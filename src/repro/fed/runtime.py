"""The federation runtime: one round driver under every federated
algorithm in the repo (DESIGN.md §9).

Tian et al.'s federated EM, Garst et al.'s federated k-means, the paper's
one-shot FedGenGMM and the DEM baseline all decompose into the same
round::

    client-update  ->  uplink  ->  server-combine  ->  broadcast

so this module owns that shape exactly once. A
:class:`FederationStrategy` supplies the algorithm (``local_step`` /
``server_combine`` / ``converged`` / ``round_payload``); a client
*backend* supplies where the clients live (a padded resident
:class:`~repro.core.partition.ClientSplit`, a list of out-of-core
:class:`~repro.data.sources.DataSource` streams, or shards of a device
mesh); and :func:`run_rounds` is the single driver that owns the round
loop, the input-type dispatch, and the communication ledger
(``repro.fed.ledger``). The algorithms in ``repro.core.fedgen`` /
``repro.core.dem`` and the new FedEM / FedKMeans baselines
(``repro.fed.strategies``) are all strategy definitions on this
substrate — none of them carries its own client loop any more.

Execution modes (picked per backend, never per strategy):

- resident clients (split or sharded mesh): the whole round loop runs as
  ONE jitted ``lax.while_loop`` — structurally identical to the
  pre-refactor ``_dem_loop``/``dem_sharded`` loops, which is what keeps
  the re-landed algorithms bit-identical to their history;
- source clients: a host-side round loop (a ``DataSource`` cannot live
  inside jit) with the same state transitions, mirroring the engine's
  ``host_em_loop`` semantics (Python-float convergence arithmetic).

This module deliberately imports nothing from ``repro.core`` at module
top (only ``repro.data.sources``, which is itself repro-free), so
``core/fedgen.py`` and ``core/dem.py`` can import the runtime without
cycles; the one :class:`ClientSplit` isinstance check is a call-time
import.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional, Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.data.sources import DataSource
from repro.fed.ledger import CommStats, RoundPayload


@runtime_checkable
class FederationStrategy(Protocol):
    """The round-based strategy contract (duck-typed; subclassing is not
    required — frozen dataclasses are the idiom, so a strategy can ride
    through jit as a static argument).

    Iterative strategies implement:

    - ``init_state(key, backend) -> state`` — host-side; build round-0
      state (global model, convergence scalars). Numeric knobs that must
      not recompile the loop when swept (tol, reg_covar) belong in the
      *state* (traced), not in strategy fields (static).
    - ``local_step(state, x, w, idx) -> payload`` — ONE client's update:
      an additive pytree (the uplink). Must be traceable; ``x`` is that
      client's rows (array or DataSource), ``w`` its padding mask (None
      for sources), ``idx`` its global client index.
    - ``server_combine(state, total) -> state`` — the server side of the
      round, from the client-summed payload.
    - ``converged(state) -> bool`` — jnp bool under jit, Python bool on
      the host path (state scalars differ accordingly).
    - ``keep_going(state) -> bool`` (optional) — the loop-continuation
      predicate when it is NOT simply ``not converged``. The historical
      EM loops continue on ``delta > tol`` and report convergence as
      ``delta <= tol`` — with a NaN convergence scalar BOTH are false, so
      a degenerate run stops after one more round AND reports
      not-converged instead of spinning to the round budget. Strategies
      with that semantics implement both predicates; the driver falls
      back to ``not converged`` when ``keep_going`` is absent.
    - ``round_payload(backend, state) -> RoundPayload`` — what one round
      moves; the driver multiplies by the realized round count.
    - ``finalize(state, n_rounds, converged, comm) -> result``.

    One-shot strategies (``one_shot = True``) implement ``run_once(state,
    backend) -> state`` instead of ``local_step``/``server_combine``/
    ``converged``: the single round runs host-side (FedGenGMM's local
    fits include Python-level per-client BIC selection).
    """

    one_shot: bool

    def init_state(self, key: jax.Array, backend) -> Any: ...

    def round_payload(self, backend, state) -> RoundPayload: ...

    def finalize(self, state, n_rounds, converged, comm: CommStats): ...


# ----------------------------------------------------------------------
# Client backends: where the clients live
# ----------------------------------------------------------------------
# Each backend exposes the same two faces:
#   - host metadata (kind / num_clients / dim / sizes / the original
#     container) that strategies use in init_state and accounting;
#   - reduce_clients(local_step, state, cohort=None, weights=None): sum
#     the per-client payload pytrees — a vmap + tree-sum (split), a
#     Python loop (sources), or a shard_map + psum (mesh). With a
#     ``cohort`` (sorted (m,) global client indices from the driver's
#     sampler) only the sampled clients compute: the split backend
#     gathers the (m, N, d) cohort slab and vmaps over m (indices are
#     TRACED, so membership changes never retrace; m is static, so one
#     compiled shape serves every round), the source backend iterates
#     only the cohort's streams, and the sharded backend gathers
#     per-shard and psums the realized contributors. ``weights`` (0/1
#     per cohort member, from the driver's straggler policy) zero out
#     clients that missed the round deadline. The jittable backends are
#     pytrees so the driver can pass them through the jitted round loop.


def _weight_bcast(w, s):
    """Reshape per-client weights (m,) to broadcast against a stacked
    per-client payload leaf (m, ...)."""
    return w.reshape(w.shape + (1,) * (s.ndim - 1)).astype(s.dtype)


def _wrap_step(local_step, state, transform, tparams, tkey, members):
    """Per-client step with the uplink transform (§11) applied between
    ``local_step`` and the reduce. Every client's ``apply`` receives the
    SAME round key (``fold_in(key(seed), round)``) — identically on every
    backend — and derives its own streams from it: value-level transforms
    fold in the client index (split and source runs draw the same
    per-client noise), pairwise masking folds in the sorted pair (both
    endpoints of a pair must derive the SAME stream, which is exactly why
    the driver must not pre-fold the client index here). With no
    transform this is exactly the historical step (bit-identity
    preserved)."""
    if transform is None:
        return lambda x, w, i: local_step(state, x, w, i)

    def step(x, w, i):
        payload = local_step(state, x, w, i)
        return transform.apply(tkey, tparams, payload, i, members)

    return step


@jax.tree_util.register_pytree_node_class
class SplitClients:
    """Resident padded clients: ``data (C, N, d)``, ``mask (C, N)``."""

    kind = "split"
    host = False

    def __init__(self, data: jax.Array, mask: jax.Array, split=None):
        self.data = data
        self.mask = mask
        self.split = split  # the original ClientSplit (host metadata)

    def tree_flatten(self):
        return (self.data, self.mask), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def num_clients(self) -> int:
        return self.data.shape[0]

    @property
    def dim(self) -> int:
        return self.data.shape[-1]

    @property
    def sizes(self):
        return self.split.sizes if self.split is not None else jnp.sum(
            self.mask, axis=1)

    @property
    def population_clients(self) -> int:
        return self.num_clients

    def reduce_clients(self, local_step, state, cohort=None, weights=None,
                       transform=None, tparams=None, tkey=None):
        """Vmap the per-client step over the (cohort) slab, apply the
        uplink ``transform`` (if any) per client, and tree-sum."""
        c = self.data.shape[0]
        members = jnp.arange(c) if cohort is None else cohort
        step = _wrap_step(local_step, state, transform, tparams, tkey,
                          members)
        if cohort is None:
            per = jax.vmap(step)(self.data, self.mask, members)
            if weights is not None:
                per = jax.tree.map(
                    lambda s: s * _weight_bcast(weights, s), per)
            return jax.tree.map(lambda s: jnp.sum(s, axis=0), per)
        # Cohort execution: gather the (m, N, d) slab and compute ONLY
        # the sampled clients. The indices are traced (no retrace when
        # membership changes) and m is static (one compiled shape for
        # all rounds).
        per = jax.vmap(step)(
            jnp.take(self.data, cohort, axis=0),
            jnp.take(self.mask, cohort, axis=0), cohort)
        if weights is not None:
            per = jax.tree.map(lambda s: s * _weight_bcast(weights, s), per)
        # Scatter the m payloads into their population slots and reduce
        # over all C: same summation tree as the historical train-all +
        # zero-mask reduction, which is what keeps cyclic-cohort FedEM
        # bit-identical to its PR-6 self (f32 addition is order-
        # sensitive; a direct sum over m would round differently).
        return jax.tree.map(
            lambda s: jnp.sum(
                jnp.zeros((c,) + s.shape[1:], s.dtype).at[cohort].set(s),
                axis=0),
            per)


class SourceClients:
    """Out-of-core clients: one :class:`DataSource` stream each. Rounds
    run host-side (a source cannot live inside jit); per-client block
    loops stay jitted inside the engine.

    ``executor`` (a :class:`repro.fed.async_runtime.ClientExecutor`, or
    anything with ``map_ordered(fn, items) -> list``) overlaps the
    per-client steps: each cohort member's E-step is dispatched from a
    long-lived worker thread, so one client's host-side block prep
    (padding, mmap reads, prefetch) overlaps another's device compute
    instead of serializing in this loop. Determinism is untouched — the
    per-client payloads are identical jitted computations on identical
    inputs, and the reduction below consumes them in cohort order
    regardless of completion order, so the f32 sum is bit-identical to
    the serial loop (pinned in tests/test_fed_async.py)."""

    kind = "sources"
    host = True

    def __init__(self, sources: Sequence[DataSource], executor=None):
        self.sources = list(sources)
        self.executor = executor

    @property
    def num_clients(self) -> int:
        return len(self.sources)

    @property
    def dim(self) -> int:
        return self.sources[0].dim

    @property
    def sizes(self):
        return [src.num_rows for src in self.sources]

    @property
    def population_clients(self) -> int:
        return self.num_clients

    def reduce_clients(self, local_step, state, cohort=None, weights=None,
                       transform=None, tparams=None, tkey=None):
        """Host-loop the per-client step over the (cohort) streams,
        apply the uplink ``transform`` (if any) per client, and sum."""
        if cohort is None:
            members = range(len(self.sources))
            members_arr = jnp.arange(len(self.sources))
        else:
            # ascending order (samplers sort), so the f32 summation
            # order matches the historical full-population loop
            members = [int(i) for i in np.asarray(cohort)]
            members_arr = jnp.asarray(np.asarray(cohort))
        step = _wrap_step(local_step, state, transform, tparams, tkey,
                          members_arr)
        w = None if weights is None else np.asarray(weights)
        # survivors only: a zero-weight (dropped) client's E-step never
        # runs, on the serial and the concurrent path alike
        jobs = [(pos, i) for pos, i in enumerate(members)
                if w is None or w[pos] != 0.0]
        if self.executor is not None and len(jobs) > 1:
            raw = self.executor.map_ordered(
                lambda i: step(self.sources[i], None, i),
                [i for _, i in jobs])
        else:
            raw = [step(self.sources[i], None, i) for _, i in jobs]
        per = []
        for (pos, i), p in zip(jobs, raw):
            if w is not None and w[pos] != 1.0:
                p = jax.tree.map(
                    lambda s: s * jnp.asarray(w[pos], s.dtype), p)
            per.append(p)
        return jax.tree.map(lambda *s: sum(s), *per)


@jax.tree_util.register_pytree_node_class
class ShardedClients:
    """Mesh-sharded clients: the client axis of ``data (C, N, d)`` maps to
    shards of ``axis``; the per-round combine is literally one
    ``jax.lax.psum`` across the mesh — the collective pattern the sharded
    DEM runtime always had, now produced by the same driver as everything
    else."""

    kind = "sharded"
    host = False

    def __init__(self, data: jax.Array, mask: jax.Array, mesh,
                 axis: str = "data"):
        self.data = data
        self.mask = mask
        self.mesh = mesh
        self.axis = axis

    def tree_flatten(self):
        return (self.data, self.mask), (self.mesh, self.axis)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def num_clients(self) -> int:
        return self.data.shape[0]

    @property
    def dim(self) -> int:
        return self.data.shape[-1]

    @property
    def sizes(self):
        return jnp.sum(self.mask, axis=1)

    @property
    def population_clients(self) -> int:
        return self.num_clients

    def reduce_clients(self, local_step, state, cohort=None, weights=None,
                       transform=None, tparams=None, tkey=None):
        """Per-shard vmap of the per-client step (with the uplink
        ``transform``, if any, applied per client — its key and traced
        knobs ride the shard_map replicated), then ONE psum."""
        axis = self.axis
        c = self.data.shape[0]
        # the transform key/params enter shard_fn as replicated operands
        # (shard_map wants operands explicit, not closed over)
        tk = jnp.zeros((), jnp.int32) if tkey is None else tkey
        tp = () if tparams is None else tparams

        if cohort is None:
            def shard_fn(state, idx_s, w_s, data_s, mask_s, tk_r, tp_r):
                step = _wrap_step(local_step, state, transform, tp_r,
                                  tk_r, jnp.arange(c))
                per = jax.vmap(step)(data_s, mask_s, idx_s)
                if weights is not None:
                    per = jax.tree.map(
                        lambda s: s * _weight_bcast(w_s, s), per)
                local = jax.tree.map(lambda s: jnp.sum(s, axis=0), per)
                # === one all-reduce per round ===
                return jax.tree.map(lambda s: jax.lax.psum(s, axis), local)

            w = jnp.ones((c,)) if weights is None else weights
            fn = shard_map(shard_fn, mesh=self.mesh,
                           in_specs=(P(), P(axis), P(axis), P(axis),
                                     P(axis), P(), P()),
                           out_specs=P(), check_rep=False)
            return fn(state, jnp.arange(c), w, self.data, self.mask,
                      tk, tp)

        # Cohort execution: the cohort (and its weights) are replicated;
        # each shard gathers the cohort members IT owns from its local
        # client slab, zero-masks the slots owned elsewhere, and the
        # psum sums the realized contributors. Per-shard compute is
        # O(m), not O(per_shard): membership stays traced, m static.
        m = cohort.shape[0]
        per_shard = c // self.mesh.shape[axis]

        def shard_fn(state, idx_s, cohort_r, w_r, data_s, mask_s, tk_r,
                     tp_r):
            local = cohort_r - idx_s[0]
            owned = (local >= 0) & (local < per_shard)
            safe = jnp.clip(local, 0, per_shard - 1)
            step = _wrap_step(local_step, state, transform, tp_r, tk_r,
                              cohort_r)
            per = jax.vmap(step)(
                jnp.take(data_s, safe, axis=0),
                jnp.take(mask_s, safe, axis=0), cohort_r)
            gate = owned.astype(w_r.dtype) * w_r
            per = jax.tree.map(lambda s: s * _weight_bcast(gate, s), per)
            total = jax.tree.map(lambda s: jnp.sum(s, axis=0), per)
            # === one all-reduce per round ===
            return jax.tree.map(lambda s: jax.lax.psum(s, axis), total)

        w = jnp.ones((m,)) if weights is None else weights
        fn = shard_map(shard_fn, mesh=self.mesh,
                       in_specs=(P(), P(axis), P(), P(), P(axis), P(axis),
                                 P(), P()),
                       out_specs=P(), check_rep=False)
        return fn(state, jnp.arange(c), cohort, w, self.data, self.mask,
                  tk, tp)


def make_backend(clients, mesh=None, axis: str = "data"):
    """THE client dispatch: ClientSplit -> :class:`SplitClients`, a list
    of DataSources -> :class:`SourceClients`, ``(data, mask)`` arrays with
    a ``mesh`` -> :class:`ShardedClients`."""
    if mesh is not None:
        data, mask = clients
        return ShardedClients(jnp.asarray(data), jnp.asarray(mask), mesh,
                              axis)
    from repro.core.partition import ClientSplit  # call-time: core sits above
    if isinstance(clients, ClientSplit):
        return SplitClients(jnp.asarray(clients.data),
                            jnp.asarray(clients.mask), clients)
    if (isinstance(clients, (list, tuple)) and len(clients) > 0
            and all(isinstance(s, DataSource) for s in clients)):
        return SourceClients(clients)
    raise TypeError(
        f"federated clients must be a ClientSplit, a non-empty list of "
        f"DataSources, or (data, mask) arrays with a mesh; got "
        f"{type(clients).__name__}")


# ----------------------------------------------------------------------
# The round driver
# ----------------------------------------------------------------------

def _round(strategy, state, backend, cohort=None, weights=None,
           transform=None, tparams=None, rkey=None):
    """One full round: client updates -> (transformed) uplink -> reduce
    -> transform ``finish`` -> server combine. ``cohort``/``weights``
    come from the driver's sampler and straggler policy (None = full
    participation, everyone on time); ``transform``/``tparams``/``rkey``
    from the driver's uplink-transform seam (§11; ``rkey`` is already
    folded per round)."""
    total = backend.reduce_clients(strategy.local_step, state, cohort,
                                   weights, transform=transform,
                                   tparams=tparams, tkey=rkey)
    if transform is not None:
        total = transform.finish(total)
    return strategy.server_combine(state, total)


def _keep_going(strategy, state):
    """Loop-continuation predicate: the strategy's own ``keep_going``
    when it has one (EM-style ``delta > tol``, which also halts on a NaN
    scalar exactly like the pre-§9 loops), else ``not converged``."""
    kg = getattr(strategy, "keep_going", None)
    if kg is not None:
        return kg(state)
    return jnp.logical_not(strategy.converged(state))


def _cohort_and_weights(sampler, stragglers, backend, skey, dkey, rnd):
    """Resolve round ``rnd``'s cohort indices and straggler weights from
    the driver-owned policies. Keys are traced, policies static: which
    clients participate can change every round (and every reseed)
    without adding a jit cache entry."""
    cohort = None if sampler is None else sampler.cohort(skey, rnd)
    weights = None
    if stragglers is not None:
        members = cohort if cohort is not None \
            else jnp.arange(backend.num_clients)
        weights = stragglers.drop_mask(dkey, rnd, members)
    return cohort, weights


@partial(jax.jit, static_argnames=("strategy", "max_rounds", "sampler",
                                   "stragglers", "transform"))
def _iterate_jit(strategy, backend, state0, max_rounds: int,
                 sampler=None, stragglers=None, transform=None,
                 skey=None, dkey=None, tkey=None, tparams=None):
    """Resident-client round loop as ONE jitted ``lax.while_loop`` —
    bootstrap round, then iterate while ``keep_going``. Structurally the
    pre-§9 ``_dem_loop``: same state transitions, same cond arithmetic,
    so re-landed strategies reproduce their history bit for bit. The
    strategy, sampler, straggler policy and uplink transform are static
    arguments (hashable frozen dataclasses); numeric knobs that sweep
    (tol, reg_covar, the transform's epsilon/delta) ride in ``state0`` /
    ``tparams`` as traced leaves and the sampler/straggler/transform
    PRNG keys (``skey``/``dkey``/``tkey``) are traced, so sweeping knobs
    or reseeding does not recompile."""

    def one_round(state, rnd):
        cohort, weights = _cohort_and_weights(sampler, stragglers, backend,
                                              skey, dkey, rnd)
        rkey = None if transform is None else jax.random.fold_in(tkey, rnd)
        return _round(strategy, state, backend, cohort, weights,
                      transform, tparams, rkey)

    def cond(carry):
        state, it = carry
        return jnp.logical_and(it < max_rounds, _keep_going(strategy, state))

    def body(carry):
        state, it = carry
        return one_round(state, it), it + 1

    state1 = one_round(state0, jnp.array(0))
    state, it = jax.lax.while_loop(cond, body, (state1, jnp.array(1)))
    return state, it


class _CohortView:
    """Accounting proxy the driver hands to ``round_payload`` when a
    sampler is in play: ``num_clients`` is the cohort size m (what a
    round actually moves), ``population_clients`` stays the population C
    (what once-per-run init traffic touches). Strategies keep writing
    per-round arithmetic against ``backend.num_clients`` and it stays
    correct under sampling."""

    def __init__(self, backend, cohort_size: int):
        self._backend = backend
        self.num_clients = int(cohort_size)
        self.population_clients = backend.num_clients
        self.kind = backend.kind
        self.host = backend.host

    @property
    def dim(self) -> int:
        return self._backend.dim


_TRANSFORM_METHODS = ("apply", "finish", "traced", "wire_itemsize",
                      "epsilon_per_round")


def _validate_transform(transform):
    """Duck-type + hashability check of a transform before it becomes a
    static jit argument (an unhashable transform would raise deep inside
    jit with a far worse message)."""
    missing = [m for m in _TRANSFORM_METHODS
               if not callable(getattr(transform, m, None))]
    if missing:
        raise TypeError(
            f"transform {type(transform).__name__} is missing "
            f"{missing}; see repro.fed.transforms.PayloadTransform")
    try:
        hash(transform)
    except TypeError as e:
        raise TypeError(
            f"transform {type(transform).__name__} must be hashable "
            f"(frozen dataclass) to ride the jitted round loop as a "
            f"static argument") from e


def run_rounds(strategy, clients, *, key: Optional[jax.Array] = None,
               state0=None, max_rounds: int = 1, mesh=None,
               axis: str = "data", sampler=None, stragglers=None,
               transform=None, executor=None):
    """Run a :class:`FederationStrategy` to convergence — THE round loop.

    Owns everything that used to be copy-pasted per algorithm: the client
    input dispatch (:func:`make_backend`), the round loop (jitted
    while_loop for resident/sharded clients, host loop for sources), the
    bootstrap round, the round budget, cohort sampling, straggler drops,
    and the communication ledger (realized rounds x the strategy's
    :class:`RoundPayload`).

    ``state0`` overrides the strategy's own ``init_state`` (the sharded
    DEM entry point passes externally chosen init centers this way);
    otherwise ``key`` seeds it.

    ``sampler`` (``repro.fed.cohort``: :class:`CyclicSampler` /
    :class:`UniformSampler`) makes each round compute ONLY its sampled
    cohort — cost scales with m, not the population — and resizes the
    per-round ledger to the cohort. ``stragglers``
    (:class:`ArrivalStragglers`) drops the round's slowest arrivals to
    exact-zero contribution. Both are driver-owned and strategy-agnostic:
    any iterative strategy runs under them unchanged (one-shot strategies
    reject them — there is no round structure to sample).

    ``transform`` (a ``repro.fed.transforms`` :class:`PayloadTransform`,
    §11) is applied to every client's uplink between ``local_step`` and
    the backend reduce — DP noise, stochastic quantization, secure-agg
    masking, or a :class:`~repro.fed.transforms.Compose` of them. The
    transform is a static argument; its seed and swept knobs (epsilon,
    delta) enter as traced leaves, so re-seeding or re-budgeting never
    recompiles. The ledger picks up the transform's uplink dtype and
    cumulative ``epsilon_spent``.

    ``executor`` (a :class:`repro.fed.async_runtime.ClientExecutor`)
    applies to the source-client backend only: the host round loop fans
    each cohort's per-client steps out to the executor's long-lived
    workers and reduces in deterministic cohort order — same bits,
    overlapped wall-clock. Resident/sharded backends (already one fused
    program) ignore it."""
    backend = make_backend(clients, mesh, axis)
    if executor is not None and backend.host:
        backend.executor = executor
    one_shot = getattr(strategy, "one_shot", False)
    skey = dkey = tkey = tparams = None
    if transform is not None:
        _validate_transform(transform)
        if one_shot and getattr(transform, "additive_only", False):
            raise ValueError(
                f"{type(transform).__name__} masks only cancel in an "
                f"additive aggregate; a one-shot strategy's server reads "
                f"each client payload individually, so the combination "
                f"is meaningless")
        tkey = jax.random.key(int(getattr(transform, "seed", 0)))
        tparams = transform.traced()
    if sampler is not None:
        if one_shot:
            raise ValueError(
                "cohort sampling needs a round structure; one-shot "
                "strategies take no sampler")
        if sampler.num_clients != backend.num_clients:
            raise ValueError(
                f"sampler is sized for {sampler.num_clients} clients but "
                f"the backend has {backend.num_clients}")
        skey = jax.random.key(int(getattr(sampler, "seed", 0)))
    if stragglers is not None:
        if one_shot:
            raise ValueError(
                "straggler handling needs a round structure; one-shot "
                "strategies take no straggler policy")
        dkey = jax.random.key(int(getattr(stragglers, "seed", 0)))
    if state0 is None:
        state0 = strategy.init_state(key, backend)

    if one_shot:
        if transform is not None:
            state = strategy.run_once(state0, backend,
                                      transform=transform,
                                      tparams=tparams, tkey=tkey)
        else:
            state = strategy.run_once(state0, backend)
        rounds, n_rounds, converged = 1, jnp.asarray(1), True
    elif backend.host:
        def host_round(state, rnd):
            cohort, weights = _cohort_and_weights(
                sampler, stragglers, backend, skey, dkey, rnd)
            if cohort is not None:
                cohort = np.asarray(cohort)
            rkey = None if transform is None \
                else jax.random.fold_in(tkey, rnd)
            return _round(strategy, state, backend, cohort, weights,
                          transform, tparams, rkey)

        state = host_round(state0, 0)
        it = 1
        while it < max_rounds and bool(_keep_going(strategy, state)):
            state = host_round(state, it)
            it += 1
        rounds, n_rounds = it, jnp.asarray(it)
        converged = bool(strategy.converged(state))
    else:
        state, n_rounds = _iterate_jit(strategy, backend, state0,
                                       max_rounds, sampler, stragglers,
                                       transform, skey, dkey, tkey,
                                       tparams)
        rounds = int(n_rounds)
        converged = bool(strategy.converged(state))

    # Optional once-per-run epilogue (e.g. FedKMeans rescoring its final
    # centers); runs eagerly after the loop, before the ledger is drawn up
    # so the strategy's RoundPayload can account for it.
    post = getattr(strategy, "post_rounds", None)
    if post is not None and not one_shot:
        state = post(state, backend)

    ledger_backend = backend if sampler is None \
        else _CohortView(backend, sampler.cohort_size)
    payload = strategy.round_payload(ledger_backend, state)
    if transform is not None:
        # transform-aware ledger: the uplink direction carries the wire
        # dtype the transform produced, and the accountant's per-round
        # spend scales by the realized rounds into epsilon_spent
        payload = payload._replace(
            uplink_itemsize=transform.wire_itemsize(payload.itemsize),
            epsilon_per_round=float(transform.epsilon_per_round()))
    comm = payload.totals(rounds)
    return strategy.finalize(state, n_rounds, converged, comm)
