"""FedGenGMM activation monitor — the paper's technique attached to any
assigned architecture as a first-class serving feature.

Hidden-state distributions of a served model are a natural unsupervised
anomaly signal (cf. the paper's refs [2] Beitollahi et al. and [9] Dong et
al.: GMMs over model features). Here every data-parallel serving shard is a
"client": it fits a local GMM over pooled hidden states of the traffic it
saw, and the global monitor is aggregated with the one-shot FedGenGMM
round. OOD inputs (domain shift, garbage prompts, adversarial noise) then
score low under the global GMM.

Feature extraction is architecture-agnostic: mean-pooled final hidden
states, projected to a small fixed random basis (stable across clients)
so GMM training stays edge-cheap — exactly the paper's constrained-client
story (Fig. 5) applied to LLM serving.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.em import fit_gmm
from repro.core.fedgen import aggregate
from repro.core.gmm import GMM
from repro.models.transformer import (ModelConfig, _backbone, _embed,
                                      _run_encoder)

FEATURE_DIM = 32


class MonitorConfig(NamedTuple):
    feature_dim: int = FEATURE_DIM
    k_local: int = 4
    k_global: int = 8
    h: int = 100
    seed: int = 0


def feature_projection(cfg: ModelConfig, mcfg: MonitorConfig) -> jax.Array:
    """Fixed random projection (d_model -> feature_dim), identical on every
    client (derived from a shared seed, so no coordination needed)."""
    key = jax.random.key(mcfg.seed)
    return jax.random.normal(key, (cfg.d_model, mcfg.feature_dim),
                             jnp.float32) / np.sqrt(cfg.d_model)


def extract_features(params, cfg: ModelConfig, batch: dict,
                     proj: jax.Array) -> jax.Array:
    """Mean-pooled final hidden states -> (B, feature_dim) float32."""
    tokens = batch["tokens"]
    x = _embed(params, cfg, tokens)
    offset = 0
    if cfg.frontend == "vision" and cfg.n_prefix:
        x = jnp.concatenate([batch["prefix"].astype(cfg.dtype), x], axis=1)
        offset = cfg.n_prefix
    enc_x = None
    if cfg.n_enc_layers:
        enc_x = _run_encoder(params, cfg, batch["src_embeds"])
    positions = jnp.arange(x.shape[1], dtype=jnp.float32)
    h, _, _ = _backbone(params, cfg, x, positions, enc_x)
    pooled = jnp.mean(h[:, offset:].astype(jnp.float32), axis=1)  # (B, D)
    return pooled @ proj


class FedGMMMonitor:
    """One-shot federated anomaly monitor over serving shards."""

    def __init__(self, cfg: ModelConfig, mcfg: MonitorConfig = MonitorConfig()):
        self.cfg = cfg
        self.mcfg = mcfg
        self.proj = feature_projection(cfg, mcfg)
        self._client_feats: dict[int, list[np.ndarray]] = {}
        self.global_gmm: Optional[GMM] = None

    # -- client side ----------------------------------------------------
    def observe(self, client_id: int, params, batch: dict):
        f = extract_features(params, self.cfg, batch, self.proj)
        self._client_feats.setdefault(client_id, []).append(np.asarray(f))

    def local_models(self) -> tuple[list[GMM], list[int]]:
        gmms, sizes = [], []
        for cid, feats in sorted(self._client_feats.items()):
            x = jnp.asarray(np.concatenate(feats))
            res = fit_gmm(jax.random.key(1000 + cid), x, self.mcfg.k_local)
            gmms.append(res.gmm)
            sizes.append(len(x))
        return gmms, sizes

    # -- the one-shot round ---------------------------------------------
    def aggregate(self) -> GMM:
        gmms, sizes = self.local_models()
        res, _ = aggregate(jax.random.key(self.mcfg.seed), gmms,
                           jnp.asarray(sizes, jnp.float32),
                           h=self.mcfg.h, k_global=self.mcfg.k_global)
        self.global_gmm = res.gmm
        return res.gmm

    # -- serving side ----------------------------------------------------
    def score(self, params, batch: dict) -> np.ndarray:
        """Anomaly scores (higher = more anomalous) for a serving batch."""
        assert self.global_gmm is not None, "call aggregate() first"
        f = extract_features(params, self.cfg, batch, self.proj)
        return -np.asarray(self.global_gmm.log_prob(f))
