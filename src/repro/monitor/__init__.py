from repro.monitor.activation_monitor import (FedGMMMonitor, MonitorConfig,
                                              extract_features,
                                              feature_projection)
__all__ = ["FedGMMMonitor", "MonitorConfig", "extract_features",
           "feature_projection"]
