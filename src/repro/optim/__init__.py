from repro.optim.adamw import (AdamWConfig, apply_updates, init_opt_state,
                               opt_state_specs, schedule)
__all__ = ["AdamWConfig", "apply_updates", "init_opt_state",
           "opt_state_specs", "schedule"]
