"""AdamW with global-norm clipping and cosine LR schedule (minimal,
pytree-generic, shardable — optimizer states inherit parameter specs)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def init_opt_state(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params),
            "step": jnp.zeros((), jnp.int32)}


def opt_state_specs(params_specs):
    return {"m": params_specs, "v": params_specs, "step": P()}


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.beta1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.beta2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.beta1 * m + (1 - cfg.beta1) * g
        v = cfg.beta2 * v + (1 - cfg.beta2) * g * g
        mh = m / b1c
        vh = v / b2c
        new_p = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                          + cfg.weight_decay * p)
        return new_p.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
