"""Mixture-of-Experts FFN: GShard/Switch-style capacity-based token routing
(the TPU-native dispatch/combine einsum formulation, which GSPMD lowers to
all-to-all when experts are sharded), top-k gating with load-balance aux
loss, optional always-on shared experts (DeepSeekMoE).

Tokens are routed within fixed-size groups so the dispatch tensor stays
(G, Tg, E, C) with bounded C = ceil(Tg * top_k * capacity_factor / E) —
group size is a tunable memory/quality knob (and a §Perf hillclimb axis).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import activation_fn, dense_init
from repro.models.sharding_ctx import constrain
from repro.models.mlp import mlp_forward, mlp_init, mlp_specs


class MoEDims(NamedTuple):
    n_experts: int
    top_k: int
    d_ff: int                  # per-expert hidden width
    n_shared: int = 0          # DeepSeekMoE shared experts (always on)
    capacity_factor: float = 1.25
    group_size: int = 1024
    expert_sharding: str = "auto"  # "expert" | "tensor" | "auto"


def _expert_axis_sharded(dims: MoEDims, model_axis_size: int) -> bool:
    if dims.expert_sharding == "expert":
        return True
    if dims.expert_sharding == "tensor":
        return False
    return dims.n_experts % model_axis_size == 0


def moe_init(key, d_model: int, dims: MoEDims, dtype=jnp.float32) -> dict:
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    e, f = dims.n_experts, dims.d_ff
    p = {
        "router": dense_init(kr, (d_model, e), d_model, dtype),
        "w_gate": dense_init(kg, (e, d_model, f), d_model, dtype),
        "w_up": dense_init(ku, (e, d_model, f), d_model, dtype),
        "w_down": dense_init(kd, (e, f, d_model), f, dtype),
    }
    if dims.n_shared:
        p["shared"] = mlp_init(ks, d_model, dims.n_shared * f, gated=True,
                               dtype=dtype)
    return p


def moe_specs(dims: MoEDims, model_axis_size: int, fsdp_axis="data") -> dict:
    if _expert_axis_sharded(dims, model_axis_size):
        w = P("model", fsdp_axis, None)   # expert parallelism
        wd = P("model", None, fsdp_axis)
    else:
        w = P(None, fsdp_axis, "model")   # tensor parallelism inside experts
        wd = P(None, "model", fsdp_axis)
    p = {"router": P(fsdp_axis, None), "w_gate": w, "w_up": w, "w_down": wd}
    if dims.n_shared:
        p["shared"] = mlp_specs(gated=True, fsdp_axis=fsdp_axis)
    return p


def moe_forward(params, x, dims: MoEDims, activation: str = "silu"):
    """x (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    tokens = x.reshape(t, d)
    gs = min(dims.group_size, t)
    pad = (-t) % gs
    if pad:  # zero-pad to a group multiple; padded rows are sliced off below
        tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
    g = (t + pad) // gs
    tokens = tokens.reshape(g, gs, d)
    tokens = constrain(tokens, ("batch", None, None))
    e, k = dims.n_experts, dims.top_k
    cap = int(math.ceil(gs * k * dims.capacity_factor / e))
    cap = min(cap, gs)

    logits = (tokens @ params["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (g, gs, E)
    top_w, top_i = jax.lax.top_k(logits, k)                     # (g, gs, K)
    top_w = jax.nn.softmax(top_w, axis=-1)                      # renormalize

    onehot = jax.nn.one_hot(top_i, e, dtype=jnp.float32)        # (g, gs, K, E)
    # priority order: all rank-0 choices first, then rank-1, ...
    prio = onehot.transpose(0, 2, 1, 3).reshape(g, k * gs, e)   # (g, K*gs, E)
    pos = jnp.cumsum(prio, axis=1) - 1.0                        # position in expert
    keep = (pos < cap).astype(jnp.float32) * prio
    slot = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32) \
        * keep[..., None]
    slot = slot.reshape(g, k, gs, e, cap).transpose(0, 2, 1, 3, 4)  # (g,gs,K,E,C)

    dispatch = jnp.sum(slot, axis=2)                            # (g, gs, E, C)
    combine = jnp.sum(slot * top_w[..., None, None], axis=2)    # (g, gs, E, C)

    # ---- expert computation (dispatch/combine einsums = all-to-all) ----
    # bf16 throughout: the dispatch contraction has <= 1 nonzero per
    # (e, c) slot so there is no accumulation error, and keeping outputs
    # bf16 keeps the BACKWARD token tensors (and their collectives) bf16.
    xin = jnp.einsum("gtec,gtd->gecd", dispatch.astype(x.dtype), tokens)
    xin = constrain(xin, ("batch", "expert", None, None))
    act = activation_fn(activation)
    h = act(jnp.einsum("gecd,edf->gecf", xin, params["w_gate"].astype(x.dtype))) \
        * jnp.einsum("gecd,edf->gecf", xin, params["w_up"].astype(x.dtype))
    xout = jnp.einsum("gecf,efd->gecd", h, params["w_down"].astype(x.dtype))
    xout = constrain(xout, ("batch", "expert", None, None))
    out = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), xout)

    # ---- load-balance aux loss (Switch eq. 4, averaged over groups) ----
    frac_dispatched = jnp.mean(jnp.sum(dispatch, axis=-1), axis=1)  # (g, E)
    mean_prob = jnp.mean(probs, axis=1)                             # (g, E)
    aux = e * jnp.mean(jnp.sum(frac_dispatched * mean_prob, axis=-1))

    out = constrain(out, ("batch", None, None))
    out = out.reshape(g * gs, d)[:t].reshape(b, s, d)
    if dims.n_shared:
        out = out + mlp_forward(params["shared"], x, activation)
    return out, aux
