"""Grouped-query attention with RoPE, causal/sliding-window masking,
query-chunked computation (bounded VMEM/HBM transient), and KV-cache decode
(full cache or ring buffer for sliding-window long-context).

Sharding design (see EXPERIMENTS.md #Perf iteration 1):
- TRAIN/PREFILL use a flat-head Megatron layout: q projects directly to
  (B, S, H, hd) with H sharded on the model axis (every assigned arch has
  H divisible by 16); k/v project model-REPLICATED to (B, S, KV, hd), are
  repeated to H flat heads and locally sliced. Scores and attention output
  stay head-sharded with ZERO collectives; the only tensor-parallel
  collective is the canonical row-parallel all-reduce after w_o.
  (The earlier head_dim-sharded layout psum'd the full (cq, Sk) score tile
  every chunk - measured 5e13 collective bytes/device on deepseek-67b
  prefill_32k; this layout removes ~all of it.)
- DECODE keeps the grouped (B, C, KV, hd) cache. Two cache shardings are
  supported by the launcher: "hd" (head_dim on model) and "seq"
  (flash-decoding style: cache length on model, distributed softmax).

Layout conventions:
  activations  x : (B, S, D)
  flat q/k/v     : (B, S, H, hd)   (k/v repeated kv-major: h = kv*G + g)
  kv cache       : {"k": (B, C, KV, hd), "v": ...}
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import apply_rope, dense_init, make_rope
from repro.models.sharding_ctx import constrain

NEG_INF = -1e30


class AttnDims(NamedTuple):
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    window: Optional[int] = None  # sliding window; None = full attention


# ----------------------------------------------------------------------
# Params
# ----------------------------------------------------------------------

def attn_init(key, d_model: int, dims: AttnDims, dtype=jnp.float32) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    h, kvh, hd = dims.n_heads, dims.n_kv_heads, dims.head_dim
    return {
        "wq": dense_init(kq, (d_model, h, hd), d_model, dtype),
        "wk": dense_init(kk, (d_model, kvh, hd), d_model, dtype),
        "wv": dense_init(kv, (d_model, kvh, hd), d_model, dtype),
        "wo": dense_init(ko, (h, hd, d_model), h * hd, dtype),
    }


def attn_specs(fsdp_axis: Optional[str] = "data") -> dict:
    """Flat q heads sharded on model (column-parallel); kv projections
    replicated on model (small: D*KV*hd) so the head repeat is a local
    slice; w_o row-parallel (one all-reduce per layer)."""
    return {
        "wq": P(fsdp_axis, "model", None),
        "wk": P(fsdp_axis, None, None),
        "wv": P(fsdp_axis, None, None),
        "wo": P("model", None, fsdp_axis),
    }


# ----------------------------------------------------------------------
# Core attention math (flat heads)
# ----------------------------------------------------------------------

def _mask(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
          window: Optional[int]) -> jax.Array:
    """(..., Sq, Sk) additive mask from absolute positions."""
    rel = q_pos[..., :, None] - k_pos[..., None, :]
    valid = jnp.ones_like(rel, dtype=jnp.bool_)
    if causal:
        valid &= rel >= 0
    if window is not None:
        valid &= rel < window
    return jnp.where(valid, 0.0, NEG_INF)


def flat_scores_softmax_out(q, k, v, mask):
    """q (B,Sq,H,hd), k/v (B,Sk,H,hd), mask (Bm,Sq,Sk) -> (B,Sq,H,hd).

    Head-sharded end to end; softmax in f32."""
    hd = q.shape[-1]
    scale = 1.0 / math.sqrt(hd)
    # bf16 einsum (TPU accumulates f32 in the MXU regardless); the f32
    # cast happens at the softmax boundary so backward cotangents flow
    # back in bf16 — preferred_element_type=f32 here would make every
    # downstream gradient (and its collectives) f32 (§Perf iteration 3).
    scores = jnp.einsum("bqhe,bshe->bhqs", q, k)
    scores = scores.astype(jnp.float32) * scale + mask[:, None]
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshe->bqhe", w.astype(v.dtype), v)
    return out.astype(q.dtype)


def gqa_scores_softmax_out(q, k, v, mask):
    """Grouped decode form. q (B,Sq,KV,G,hd), k/v (B,Sk,KV,hd),
    mask (Bm,Sq,Sk) -> (B,Sq,KV,G,hd)."""
    hd = q.shape[-1]
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k)
    scores = scores.astype(jnp.float32) * scale + mask[:, None, None]
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w.astype(v.dtype), v)
    return out.astype(q.dtype)


def chunked_causal_attention(q, k, v, q_positions, k_positions, *,
                             causal: bool = True,
                             window: Optional[int] = None,
                             chunk: int = 256) -> jax.Array:
    """Flat-head full-sequence attention, scanned over query chunks so the
    (cq, Sk) score tile (not (Sq, Sk)) is the peak transient."""
    b, sq = q.shape[0], q.shape[1]
    if sq <= chunk:
        mask = _mask(q_positions, k_positions, causal, window)  # (Sq, Sk)
        return flat_scores_softmax_out(q, k, v, mask[None])
    pad = (-sq) % chunk
    if pad:  # pad queries to a chunk multiple (positions repeat the last one)
        q = jnp.pad(q, ((0, 0), (0, pad)) + ((0, 0),) * (q.ndim - 2))
        q_positions = jnp.concatenate(
            [q_positions, jnp.broadcast_to(q_positions[-1], (pad,))])
        out = chunked_causal_attention(q, k, v, q_positions, k_positions,
                                       causal=causal, window=window,
                                       chunk=chunk)
        return out[:, :sq]
    nc = sq // chunk
    qc = q.reshape(b, nc, chunk, *q.shape[2:]).swapaxes(0, 1)
    pc = q_positions.reshape(nc, chunk)

    @jax.checkpoint  # recompute (cq, Sk) scores in backward: flash-style
    def chunk_attn(qi, pi):
        mask = _mask(pi, k_positions, causal, window)            # (cq, Sk)
        return flat_scores_softmax_out(qi, k, v, mask[None])

    def one(_, qp):
        qi, pi = qp
        return None, chunk_attn(qi, pi)

    _, out = jax.lax.scan(one, None, (qc, pc))
    return out.swapaxes(0, 1).reshape(b, sq, *q.shape[2:])


# ----------------------------------------------------------------------
# Block-level API
# ----------------------------------------------------------------------

def _project_q_flat(params, x):
    """x (B,S,D) -> q (B,S,H,hd), head-sharded (column parallel)."""
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"].astype(x.dtype))
    return constrain(q, ("batch", None, "model", None))


def _project_kv(params, x):
    """x (B,S,D) -> k, v (B,S,KV,hd), model-replicated."""
    k = jnp.einsum("bsd,dkh->bskh", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dkh->bskh", x, params["wv"].astype(x.dtype))
    return k, v


def _repeat_heads(kv, g: int):
    """(B,S,KV,hd) -> (B,S,H,hd) flat kv-major; a local slice under the
    head-sharded constraint (kv is model-replicated)."""
    rep = jnp.repeat(kv, g, axis=2)
    return constrain(rep, ("batch", None, "model", None))


def _project_qkv(params, x, dims: AttnDims):
    """Grouped projection (decode path). Returns q (B,S,KV,G,hd),
    k/v (B,S,KV,hd)."""
    b, s, _ = x.shape
    g = dims.n_heads // dims.n_kv_heads
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"].astype(x.dtype))
    q = q.reshape(b, s, dims.n_kv_heads, g, dims.head_dim)
    k, v = _project_kv(params, x)
    return q, k, v


def attention_forward(params, x, positions, dims: AttnDims, *,
                      causal: bool = True, chunk: int = 256,
                      return_kv: bool = False):
    """Training / prefill path (flat heads). positions (S,) absolute.
    Returns (out (B,S,D)[, (k, v) grouped cache material])."""
    g = dims.n_heads // dims.n_kv_heads
    q = _project_q_flat(params, x)                       # (B,S,H,hd)
    k, v = _project_kv(params, x)                        # (B,S,KV,hd)
    cos, sin = make_rope(positions, dims.head_dim, dims.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    kf = _repeat_heads(k, g)
    vf = _repeat_heads(v, g)
    out = chunked_causal_attention(q, kf, vf, positions, positions,
                                   causal=causal, window=dims.window,
                                   chunk=chunk)
    out = constrain(out, ("batch", None, "model", None))
    out = jnp.einsum("bshe,hed->bsd", out, params["wo"].astype(x.dtype))
    out = constrain(out, ("batch", None, None))          # row-parallel psum
    if return_kv:
        return out, (k, v)
    return out


def attention_decode(params, x, pos, cache_k, cache_v, dims: AttnDims, *,
                     ring: bool = False, window: Optional[int] = None):
    """One-token decode. x (B,1,D); pos () int32 absolute position;
    cache_k/v (B, C, KV, hd) hold rotated keys for positions < pos.

    ring=True treats the cache as a ring buffer of size C == window (the
    sub-quadratic long-context variant); otherwise C is the full context
    and the new kv is written at index ``pos``.

    Returns (out (B,1,D), new_cache_k, new_cache_v).
    """
    b = x.shape[0]
    c = cache_k.shape[1]
    q, k, v = _project_qkv(params, x, dims)          # Sq = 1
    cos, sin = make_rope(pos[None].astype(jnp.float32), dims.head_dim,
                         dims.rope_theta)
    q = apply_rope(q.reshape(b, 1, -1, dims.head_dim), cos, sin) \
        .reshape(q.shape)
    k = apply_rope(k, cos, sin)
    slot = pos % c if ring else jnp.minimum(pos, c - 1)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), slot, axis=1)
    idx = jnp.arange(c)
    if ring:
        # entry i holds absolute position pos - ((pos - i) mod C) (>= 0 valid)
        abs_pos = pos - jnp.mod(pos - idx, c)
        valid = abs_pos >= 0
        if window is not None and window < c:
            valid &= (pos - abs_pos) < window
    else:
        valid = idx <= pos
        if window is not None:  # full cache, windowed attention (SWA)
            valid &= idx > pos - window
    mask = jnp.where(valid, 0.0, NEG_INF)[None, None, :]  # (1, 1, C)
    out = gqa_scores_softmax_out(q, cache_k.astype(q.dtype),
                                 cache_v.astype(q.dtype), mask)
    out = out.reshape(b, 1, dims.n_heads, dims.head_dim)
    out = jnp.einsum("bshe,hed->bsd", out, params["wo"].astype(x.dtype))
    return out, cache_k, cache_v
