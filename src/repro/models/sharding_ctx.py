"""Logical-axis sharding constraints for model internals.

GSPMD propagation alone mis-shards attention internals: the fused
(H*hd) projection output is model-sharded, but after the reshape to
(B, S, KV, G, hd) the model axis no longer divides the KV dim for GQA
(e.g. 8 kv heads on a 16-way model axis), so the partitioner drops batch
sharding and falls back to full rematerialization (observed in the
buffer-assignment dump: f32[256,4096,...] global-batch temporaries per
device). The fix is explicit logical constraints: head_dim carries the
model axis, batch carries the data axes.

The launcher configures the logical->mesh axis mapping before tracing;
without a mesh context (CPU smoke tests) constraints are no-ops.
"""
from __future__ import annotations

from typing import Sequence, Union

import jax
from jax.sharding import PartitionSpec as P

Axis = Union[None, str, tuple]

_MAP: dict[str, Axis] = {"batch": None, "model": None, "expert": None}
_ENABLED = False


def set_axes(batch: Axis, model: Axis = "model", expert: Axis = None):
    """Configure logical axes (call before tracing a step function)."""
    global _ENABLED
    _MAP["batch"] = batch
    _MAP["model"] = model
    _MAP["expert"] = expert if expert is not None else model
    _ENABLED = True


def clear_axes():
    global _ENABLED
    _ENABLED = False


def constrain(x, dims: Sequence[Union[str, None]]):
    """Apply a sharding constraint expressed in logical axis names.

    No-op when axes are not configured or no mesh context is active."""
    if not _ENABLED:
        return x
    spec = P(*[_MAP.get(d) if isinstance(d, str) else d for d in dims])
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except RuntimeError:
        return x
