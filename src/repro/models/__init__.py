"""Model substrate: generic transformer stack + per-family blocks."""
from repro.models.transformer import (ModelConfig, cache_specs, decode_step,
                                      init_cache, init_params, param_specs,
                                      prefill_forward, train_forward)
from repro.models.common import count_params

__all__ = ["ModelConfig", "cache_specs", "decode_step", "init_cache",
           "init_params", "param_specs", "prefill_forward", "train_forward",
           "count_params"]
