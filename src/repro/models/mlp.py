"""Dense feed-forward blocks: gated (SwiGLU/GeGLU) and plain."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import activation_fn, dense_init


def mlp_init(key, d_model: int, d_ff: int, gated: bool = True,
             dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(k1, (d_model, d_ff), d_model, dtype),
        "w_down": dense_init(k2, (d_ff, d_model), d_ff, dtype),
    }
    if gated:
        p["w_gate"] = dense_init(k3, (d_model, d_ff), d_model, dtype)
    return p


def mlp_specs(gated: bool = True, fsdp_axis="data") -> dict:
    p = {"w_up": P(fsdp_axis, "model"), "w_down": P("model", fsdp_axis)}
    if gated:
        p["w_gate"] = P(fsdp_axis, "model")
    return p


def mlp_forward(params, x, activation: str = "silu"):
    act = activation_fn(activation)
    up = x @ params["w_up"].astype(x.dtype)
    if "w_gate" in params:
        up = act(x @ params["w_gate"].astype(x.dtype)) * up
    else:
        up = act(up)
    return up @ params["w_down"].astype(x.dtype)
