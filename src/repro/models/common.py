"""Shared model building blocks: norms, RoPE, initializers, activations.

Everything is functional: params are plain nested dicts of jax.Arrays, and
every init function has a twin that returns the matching PartitionSpec
pytree (same code path => specs can't drift from params).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def make_rope(positions: jax.Array, head_dim: int,
              theta: float = 10000.0) -> tuple[jax.Array, jax.Array]:
    """positions (...,) -> cos/sin tables (..., head_dim//2)."""
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, head_dim, 2, dtype=jnp.float32)
        / head_dim)
    angles = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., S, H, hd); cos/sin (..., S, hd//2) broadcast over heads."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(x.dtype)


def activation_fn(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "relu": jax.nn.relu,
        "tanh": jnp.tanh,
    }[name]


# ----------------------------------------------------------------------
# Parameter init helpers (value + spec built together)
# ----------------------------------------------------------------------

def dense_init(key, shape, in_axis_size, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(in_axis_size)
    return jax.random.normal(key, shape, dtype) * scale


def embed_init(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * 0.02


def split_keys(key, names):
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating)
        else a, tree)


def count_params(params) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))
