"""RG-LRU recurrent block (RecurrentGemma / Griffin temporal block).

Structure (Griffin recurrent block):
    x -> [linear -> gelu] gate branch
      -> [linear -> causal depthwise conv1d(w=4) -> RG-LRU] recurrent branch
    out = W_out (gate * recurrent)

RG-LRU:  r_t = sigmoid(W_r x),  i_t = sigmoid(W_i x)
         a_t = exp(-c * softplus(lambda) * r_t)          (c = 8)
         h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

TPU adaptation: training/prefill uses jax.lax.associative_scan (log-depth
parallel prefix) rather than a sequential loop — the recurrence is linear in
h, so the (a, b) affine composition is associative. Decode keeps an O(1)
state: (h (B, d_rnn), conv tail (B, 3, d_rnn)).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import dense_init
from repro.models.sharding_ctx import constrain

_C = 8.0
CONV_W = 4


class RGLRUDims(NamedTuple):
    d_rnn: int


def rglru_init(key, d_model: int, dims: RGLRUDims, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 7)
    dr = dims.d_rnn
    lam = jax.random.uniform(ks[6], (dr,), jnp.float32, 0.9, 0.999)
    return {
        "w_gate_in": dense_init(ks[0], (d_model, dr), d_model, dtype),
        "w_rec_in": dense_init(ks[1], (d_model, dr), d_model, dtype),
        "conv_w": dense_init(ks[2], (CONV_W, dr), CONV_W, dtype),
        "conv_b": jnp.zeros((dr,), dtype),
        "w_r": dense_init(ks[3], (dr, dr), dr, dtype),
        "w_i": dense_init(ks[4], (dr, dr), dr, dtype),
        # lambda parametrized so softplus(log_lambda) spans useful decay range
        "log_lambda": jnp.log(jnp.exp(-jnp.log(lam) / _C) - 1.0).astype(dtype),
        "w_out": dense_init(ks[5], (dr, d_model), dr, dtype),
    }


def rglru_specs(fsdp_axis="data") -> dict:
    return {
        "w_gate_in": P(fsdp_axis, "model"),
        "w_rec_in": P(fsdp_axis, "model"),
        "conv_w": P(None, "model"),
        "conv_b": P("model"),
        "w_r": P(fsdp_axis, "model"),
        "w_i": P(fsdp_axis, "model"),
        "log_lambda": P("model"),
        "w_out": P("model", fsdp_axis),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv width 4 as shifted adds. x (B,S,dr)."""
    out = x * w[CONV_W - 1]
    for j in range(1, CONV_W):
        shifted = jnp.pad(x, ((0, 0), (j, 0), (0, 0)))[:, :-j]
        out = out + shifted * w[CONV_W - 1 - j]
    return out + b


def _gates(params, u):
    r = jax.nn.sigmoid(u @ params["w_r"].astype(u.dtype))
    i = jax.nn.sigmoid(u @ params["w_i"].astype(u.dtype))
    decay = jax.nn.softplus(params["log_lambda"].astype(jnp.float32))
    a = jnp.exp(-_C * decay * r.astype(jnp.float32))
    bterm = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * \
        (i.astype(jnp.float32) * u.astype(jnp.float32))
    return a, bterm


def rglru_forward(params, x):
    """Training / prefill. x (B, S, D) ->
    (out (B,S,D), state {"h": (B,dr) f32, "conv": (B,3,dr) pre-conv tail})."""
    u_pre = constrain(x @ params["w_rec_in"].astype(x.dtype),
                      ("batch", None, "model"))                 # (B,S,dr)
    u = _causal_conv(u_pre, params["conv_w"].astype(u_pre.dtype),
                     params["conv_b"].astype(u_pre.dtype))
    a, bterm = _gates(params, u)
    # h_t = a_t h_{t-1} + b_t  — associative affine composition, log-depth
    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2
    _, h = jax.lax.associative_scan(combine, (a, bterm), axis=1)
    gate = jax.nn.gelu(x @ params["w_gate_in"].astype(x.dtype))
    out = (gate * h.astype(x.dtype)) @ params["w_out"].astype(x.dtype)
    # decode handoff: last hidden state + last 3 pre-conv inputs
    s = x.shape[1]
    if s >= CONV_W - 1:
        tail = u_pre[:, s - (CONV_W - 1):]
    else:
        tail = jnp.pad(u_pre, ((0, 0), (CONV_W - 1 - s, 0), (0, 0)))
    return out, {"h": h[:, -1].astype(jnp.float32), "conv": tail}


def rglru_decode(params, x, h_prev, conv_tail):
    """One-token decode. x (B,1,D); h_prev (B,dr); conv_tail (B,3,dr) holds
    the last 3 *pre-conv* inputs. Returns (out, h, new_conv_tail)."""
    u_new = (x @ params["w_rec_in"].astype(x.dtype))[:, 0]      # (B, dr)
    w = params["conv_w"].astype(u_new.dtype)
    hist = jnp.concatenate([conv_tail.astype(u_new.dtype),
                            u_new[:, None]], axis=1)            # (B, 4, dr)
    u = jnp.einsum("bwd,wd->bd", hist, w) + params["conv_b"].astype(u_new.dtype)
    a, bterm = _gates(params, u)
    h = a * h_prev + bterm                                      # (B, dr) f32
    gate = jax.nn.gelu((x @ params["w_gate_in"].astype(x.dtype))[:, 0])
    out = (gate * h.astype(x.dtype)) @ params["w_out"].astype(x.dtype)
    return out[:, None], h, hist[:, 1:].astype(conv_tail.dtype)
