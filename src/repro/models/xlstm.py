"""xLSTM blocks: mLSTM (matrix memory, parallel/chunked training form) and
sLSTM (scalar memory, sequential scan) — Beck et al. '24 (arXiv:2405.04517).

TPU adaptation notes (DESIGN.md §3):
- mLSTM trains in its parallel quadratic form — structurally the same
  einsum pattern as attention, so it reuses the query-chunked schedule
  (cq x S score tiles) and maps onto the MXU. Decode is O(1) with the
  (C, n, m) matrix-memory state.
- sLSTM is inherently sequential (true recurrence through a nonlinearity);
  training runs a jax.lax.scan over time. This is the faithful semantics —
  there is no parallel form — and is documented as such.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import dense_init
from repro.models.sharding_ctx import constrain


class XLSTMDims(NamedTuple):
    n_heads: int
    head_dim: int     # d_model // n_heads after up-projection
    up_factor: int = 2


# ======================================================================
# mLSTM
# ======================================================================

def mlstm_init(key, d_model: int, dims: XLSTMDims, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 8)
    d_inner = dims.n_heads * dims.head_dim
    return {
        "w_up": dense_init(ks[0], (d_model, 2 * d_inner), d_model, dtype),
        "wq": dense_init(ks[1], (d_inner, dims.n_heads, dims.head_dim),
                         d_inner, dtype),
        "wk": dense_init(ks[2], (d_inner, dims.n_heads, dims.head_dim),
                         d_inner, dtype),
        "wv": dense_init(ks[3], (d_inner, dims.n_heads, dims.head_dim),
                         d_inner, dtype),
        "w_if": dense_init(ks[4], (d_inner, 2 * dims.n_heads), d_inner, dtype),
        "b_if": jnp.concatenate([jnp.zeros((dims.n_heads,), dtype),
                                 jnp.full((dims.n_heads,), 3.0, dtype)]),
        "w_down": dense_init(ks[5], (d_inner, d_model), d_inner, dtype),
    }


def mlstm_specs(fsdp_axis="data") -> dict:
    return {
        "w_up": P(fsdp_axis, "model"),
        "wq": P(fsdp_axis, None, "model"),
        "wk": P(fsdp_axis, None, "model"),
        "wv": P(fsdp_axis, None, "model"),
        "w_if": P(fsdp_axis, None), "b_if": P(None),
        "w_down": P("model", fsdp_axis),
    }


def _mlstm_gates(params, u):
    """u (B,S,d_inner) -> (log_f (B,S,H), i_tilde (B,S,H)) in f32."""
    gf = (u @ params["w_if"].astype(u.dtype)).astype(jnp.float32) + \
        params["b_if"].astype(jnp.float32)
    h = gf.shape[-1] // 2
    i_tilde, f_tilde = gf[..., :h], gf[..., h:]
    log_f = -jax.nn.softplus(-f_tilde)     # log sigmoid(f~)
    return log_f, i_tilde


def mlstm_forward(params, x, chunk: int = 256):
    """Parallel (training/prefill) form. x (B,S,D) -> (out, last_state)."""
    b, s, _ = x.shape
    dims_h = params["w_if"].shape[1] // 2
    up = x @ params["w_up"].astype(x.dtype)
    u, gate = jnp.split(up, 2, axis=-1)                    # (B,S,d_inner)
    d_inner = u.shape[-1]
    hd = d_inner // dims_h
    q = jnp.einsum("bsd,dhe->bshe", u, params["wq"].astype(u.dtype))
    k = jnp.einsum("bsd,dhe->bshe", u, params["wk"].astype(u.dtype))
    v = jnp.einsum("bsd,dhe->bshe", u, params["wv"].astype(u.dtype))
    # flash-style sequence sharding (§Perf iteration 5): with only 4 heads
    # the model axis cannot ride H, and riding head_dim psums the full
    # (cq, S, H) score tile every chunk (measured 384 GiB on prefill_32k).
    # Sharding k/v/gates along S keeps scores local; the contractions over
    # S reduce only (B,cq,H[,hd]) partials.
    q = constrain(q, ("batch", None, None, None))
    k = constrain(k, ("batch", "model", None, None))
    v = constrain(v, ("batch", "model", None, None))
    log_f, i_tilde = _mlstm_gates(params, u)               # (B,S,H)
    lcum = jnp.cumsum(log_f, axis=1)                       # (B,S,H) prefix
    i_tilde = constrain(i_tilde, ("batch", "model", None))
    lcum_s = constrain(lcum, ("batch", "model", None))

    scale = 1.0 / math.sqrt(hd)

    def block(qc, lc, start):
        """qc (B,cq,H,hd); lc (B,cq,H) cumulative logf of the chunk rows."""
        # log D[t, s] = lcum_t - lcum_s + i~_s   for s <= t
        logd = lc[:, :, None, :] - lcum_s[:, None, :, :] \
            + i_tilde[:, None, :, :]
        cq = qc.shape[1]
        t_idx = start + jnp.arange(cq)
        causal = t_idx[:, None] >= jnp.arange(s)[None, :]
        logd = jnp.where(causal[None, :, :, None], logd, -jnp.inf)
        m = jnp.maximum(jnp.max(logd, axis=2), 0.0)        # (B,cq,H) stabilizer
        dmat = jnp.exp(logd - m[:, :, None, :])            # (B,cq,S,H)
        scores = jnp.einsum("bqhe,bshe->bqsh", qc.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        cmat = scores * dmat
        norm = jnp.maximum(jnp.abs(jnp.sum(cmat, axis=2)), jnp.exp(-m))
        out = jnp.einsum("bqsh,bshe->bqhe", cmat / norm[:, :, None, :],
                         v.astype(jnp.float32))
        return out.astype(x.dtype)

    if s <= chunk:
        h = block(q, lcum, 0)
    else:
        pad = (-s) % chunk
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else q
        lp = jnp.pad(lcum, ((0, 0), (0, pad), (0, 0)), mode="edge") \
            if pad else lcum
        nc = (s + pad) // chunk
        qc = qp.reshape(b, nc, chunk, dims_h, hd).swapaxes(0, 1)
        lc = lp.reshape(b, nc, chunk, dims_h).swapaxes(0, 1)
        chunk_blk = jax.checkpoint(block)
        def one(_, args):
            i, qi, li = args
            return None, chunk_blk(qi, li, i * chunk)
        _, hs = jax.lax.scan(one, None, (jnp.arange(nc), qc, lc))
        h = hs.swapaxes(0, 1).reshape(b, s + pad, dims_h, hd)[:, :s]

    h = h.reshape(b, s, d_inner) * jax.nn.silu(gate)
    out = h @ params["w_down"].astype(x.dtype)
    # recurrent state equivalent at t = S (for prefill -> decode handoff)
    state = _mlstm_state_from_seq(k, v, log_f, i_tilde)
    return out, state


def _mlstm_state_from_seq(k, v, log_f, i_tilde):
    """Fold the whole sequence into the (C, n, m) decode state."""
    lcum = jnp.cumsum(log_f, axis=1)
    total = lcum[:, -1:]
    # weight of step t in final state: exp(lcum_S - lcum_t + i~_t - m)
    logw = total - lcum + i_tilde                          # (B,S,H)
    m = jnp.max(logw, axis=1)                              # (B,H)
    w = jnp.exp(logw - m[:, None])
    c = jnp.einsum("bsh,bshe,bshf->bhef", w, k.astype(jnp.float32),
                   v.astype(jnp.float32))
    n = jnp.einsum("bsh,bshe->bhe", w, k.astype(jnp.float32))
    return {"c": c, "n": n, "m": m}


def mlstm_decode(params, x, state):
    """One-token decode. state {c (B,H,hd,hd), n (B,H,hd), m (B,H)}."""
    b = x.shape[0]
    n_heads = params["w_if"].shape[1] // 2
    up = x @ params["w_up"].astype(x.dtype)
    u, gate = jnp.split(up, 2, axis=-1)
    u2, gate = u[:, 0], gate[:, 0]
    d_inner = u2.shape[-1]
    hd = d_inner // n_heads
    q = jnp.einsum("bd,dhe->bhe", u2, params["wq"].astype(u2.dtype))
    k = jnp.einsum("bd,dhe->bhe", u2, params["wk"].astype(u2.dtype))
    v = jnp.einsum("bd,dhe->bhe", u2, params["wv"].astype(u2.dtype))
    log_f, i_tilde = _mlstm_gates(params, u2[:, None])
    log_f, i_tilde = log_f[:, 0], i_tilde[:, 0]            # (B,H)
    m_new = jnp.maximum(log_f + state["m"], i_tilde)
    fp = jnp.exp(log_f + state["m"] - m_new)
    ip = jnp.exp(i_tilde - m_new)
    kf, vf, qf = (t.astype(jnp.float32) for t in (k, v, q))
    c = state["c"] * fp[..., None, None] + \
        ip[..., None, None] * kf[..., :, None] * vf[..., None, :]
    n = state["n"] * fp[..., None] + ip[..., None] * kf
    scale = 1.0 / math.sqrt(hd)
    num = jnp.einsum("bhef,bhe->bhf", c, qf * scale)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhe,bhe->bh", n, qf * scale)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(b, d_inner).astype(x.dtype)
    h = h * jax.nn.silu(gate)
    out = (h @ params["w_down"].astype(x.dtype))[:, None]
    return out, {"c": c, "n": n, "m": m_new}


# ======================================================================
# sLSTM
# ======================================================================

def _up_width(d_model: int) -> int:
    return max(256, (4 * d_model // 3 + 255) // 256 * 256)


def slstm_init(key, d_model: int, dims: XLSTMDims, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    h, hd = dims.n_heads, d_model // dims.n_heads
    return {
        "w_in": dense_init(ks[0], (d_model, 4 * d_model), d_model, dtype),
        # block-diagonal recurrence: per head (hd -> 4*hd)
        "r": dense_init(ks[1], (h, hd, 4 * hd), hd, dtype),
        "b": jnp.zeros((4 * d_model,), dtype),
        # 4/3 up-projection rounded to a shardable multiple of 256
        "w_up": dense_init(ks[2], (d_model, 2 * _up_width(d_model)), d_model,
                           dtype),
        "w_down": dense_init(ks[3], (_up_width(d_model), d_model),
                             _up_width(d_model), dtype),
    }


def slstm_specs(fsdp_axis="data") -> dict:
    """sLSTM weights are REPLICATED over the model axis (§Perf iteration
    5): the per-timestep recurrence is sequential, so tensor-sharded gates
    would emit a (B, 4D) collective EVERY timestep of the scan (measured
    ~8.9 s collective term on prefill_32k). The weights are small
    (~16 MB/layer); keeping them local makes the whole recurrence
    shard-local and batch-parallel. FSDP still shards the storage."""
    return {"w_in": P(fsdp_axis, None), "r": P(None, None, None),
            "b": P(None), "w_up": P(fsdp_axis, None),
            "w_down": P(None, fsdp_axis)}


def _slstm_cell(params, wx_t, state, n_heads):
    """One timestep. wx_t (B, 4D) precomputed input part; state dict of
    (B, D)/(B, H)-shaped f32 tensors."""
    h_prev = state["h"]
    b, d = h_prev.shape
    hd = d // n_heads
    rh = jnp.einsum("bhe,hef->bhf",
                    h_prev.reshape(b, n_heads, hd).astype(params["r"].dtype),
                    params["r"]).reshape(b, 4 * d)
    pre = (wx_t + rh.astype(jnp.float32)
           + params["b"].astype(jnp.float32))
    z, i_t, f_t, o = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o)
    log_f = -jax.nn.softplus(-f_t)
    m_new = jnp.maximum(log_f + state["m"], i_t)
    fp = jnp.exp(log_f + state["m"] - m_new)
    ip = jnp.exp(i_t - m_new)
    c = fp * state["c"] + ip * z
    n = fp * state["n"] + ip
    h = o * c / jnp.maximum(n, 1e-6)
    return {"h": h, "c": c, "n": n, "m": m_new}


def slstm_zero_state(batch: int, d_model: int):
    z = jnp.zeros((batch, d_model), jnp.float32)
    return {"h": z, "c": z, "n": z,
            "m": jnp.full((batch, d_model), -1e30, jnp.float32)}


def slstm_forward(params, x, n_heads: int):
    """Sequential scan over time. x (B,S,D) -> (out, last_state)."""
    b, s, d = x.shape
    wx = constrain((x @ params["w_in"].astype(x.dtype)).astype(jnp.float32),
                   ("batch", None, None))  # (B,S,4D) — local recurrence
    state0 = slstm_zero_state(b, d)

    def step(state, wx_t):
        new = _slstm_cell(params, wx_t, state, n_heads)
        return new, new["h"]

    last, hs = jax.lax.scan(step, state0, wx.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).astype(x.dtype)                  # (B,S,D)
    up = h @ params["w_up"].astype(x.dtype)
    a, g = jnp.split(up, 2, axis=-1)
    out = (jax.nn.gelu(g) * a) @ params["w_down"].astype(x.dtype)
    return out, last


def slstm_decode(params, x, state, n_heads: int):
    wx = (x[:, 0] @ params["w_in"].astype(x.dtype)).astype(jnp.float32)
    new = _slstm_cell(params, wx, state, n_heads)
    h = new["h"].astype(x.dtype)
    up = h @ params["w_up"].astype(x.dtype)
    a, g = jnp.split(up, 2, axis=-1)
    out = ((jax.nn.gelu(g) * a) @ params["w_down"].astype(x.dtype))[:, None]
    return out, new
