"""Generic multi-architecture transformer stack.

One config object describes every assigned architecture: dense decoders
(llama-style GQA), MoE (Mixtral / DeepSeekMoE), hybrid recurrent
(RecurrentGemma: RG-LRU + local attention), xLSTM (mLSTM/sLSTM), VLM
(prefix patch embeddings + decoder), and encoder-decoder audio
(Seamless-style: frame embeddings -> encoder, text decoder w/ cross-attn).

Layers are grouped by the repeating ``pattern`` and scanned with
jax.lax.scan over stacked parameters (rematerialized per group), so a
95-layer model lowers to a compact HLO. Remainder layers that don't fill a
full pattern group run unrolled ("tail"); DeepSeekMoE's leading dense
layers run unrolled ("head").

Three entry points per architecture:
    train_forward   — full-sequence causal LM loss
    prefill_forward — forward + KV/state cache construction
    decode_step     — one token with cache (full, windowed, or ring)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import embed_init, rms_norm
from repro.models.sharding_ctx import constrain


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0
    activation: str = "silu"
    gated_mlp: bool = True
    pattern: tuple = ("attn",)
    window: Optional[int] = None        # SWA window for "swa" layers
    local_window: int = 2048            # window for "local_attn" layers
    rope_theta: float = 10000.0
    embed_scale: bool = False           # gemma-style sqrt(d) embed scaling
    # moe
    moe: Optional[moe_mod.MoEDims] = None
    first_k_dense: int = 0
    first_dense_d_ff: int = 0
    # rglru
    d_rnn: int = 0
    # xlstm
    xlstm: Optional[xlstm_mod.XLSTMDims] = None
    # encoder-decoder
    n_enc_layers: int = 0
    src_ratio: int = 4                  # encoder frames = seq_len // ratio
    # modality frontends (STUB: input_specs provides the embeddings)
    frontend: Optional[str] = None      # "vision" | "audio" | None
    n_prefix: int = 0                   # vision prefix tokens
    # numerics / scheduling
    dtype: Any = jnp.bfloat16
    chunk_q: int = 256
    loss_chunk: int = 512               # seq-chunked loss (0 = single shot)
    long_window: int = 4096             # ring-buffer window for long_500k
    remat: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def attn_dims(self, window=None) -> attn.AttnDims:
        return attn.AttnDims(self.n_heads, self.n_kv_heads, self.hd,
                             self.rope_theta, window)

    @property
    def n_groups(self) -> int:
        return (self.n_layers - self.first_k_dense) // len(self.pattern)

    @property
    def n_tail(self) -> int:
        return (self.n_layers - self.first_k_dense) % len(self.pattern)

    def layer_types(self) -> list[str]:
        body = list(self.pattern) * self.n_groups + \
            list(self.pattern)[: self.n_tail]
        return ["dense_attn"] * self.first_k_dense + body


# ======================================================================
# Parameter init + partition specs (built by the same code path)
# ======================================================================

def _layer_init(key, cfg: ModelConfig, ltype: str, dense_ffn: bool = False):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: dict = {}
    if ltype in ("attn", "swa", "local_attn", "dense_attn", "enc_attn",
                 "xattn"):
        p["ln1"] = jnp.zeros((d,), jnp.float32)
        p["attn"] = attn.attn_init(ks[0], d, cfg.attn_dims())
        if ltype == "xattn":  # decoder layer with cross attention
            p["lnx"] = jnp.zeros((d,), jnp.float32)
            p["xattn"] = attn.attn_init(ks[2], d, cfg.attn_dims())
        p["ln2"] = jnp.zeros((d,), jnp.float32)
        if cfg.moe is not None and not dense_ffn and ltype != "enc_attn":
            p["moe"] = moe_mod.moe_init(ks[1], d, cfg.moe)
        else:
            width = cfg.first_dense_d_ff if dense_ffn and \
                cfg.first_dense_d_ff else cfg.d_ff
            p["ffn"] = mlp_mod.mlp_init(ks[1], d, width, cfg.gated_mlp)
    elif ltype == "rglru":
        p["ln1"] = jnp.zeros((d,), jnp.float32)
        p["rglru"] = rglru_mod.rglru_init(ks[0], d,
                                          rglru_mod.RGLRUDims(cfg.d_rnn))
        p["ln2"] = jnp.zeros((d,), jnp.float32)
        p["ffn"] = mlp_mod.mlp_init(ks[1], d, cfg.d_ff, cfg.gated_mlp)
    elif ltype == "mlstm":
        p["ln"] = jnp.zeros((d,), jnp.float32)
        p["mlstm"] = xlstm_mod.mlstm_init(ks[0], d, cfg.xlstm)
    elif ltype == "slstm":
        p["ln"] = jnp.zeros((d,), jnp.float32)
        p["slstm"] = xlstm_mod.slstm_init(ks[0], d, cfg.xlstm)
    else:
        raise ValueError(ltype)
    return p


def _layer_specs(cfg: ModelConfig, ltype: str, fsdp, model_axis_size: int,
                 dense_ffn: bool = False):
    p: dict = {}
    if ltype in ("attn", "swa", "local_attn", "dense_attn", "enc_attn",
                 "xattn"):
        p["ln1"] = P(None)
        p["attn"] = attn.attn_specs(fsdp)
        if ltype == "xattn":
            p["lnx"] = P(None)
            p["xattn"] = attn.attn_specs(fsdp)
        p["ln2"] = P(None)
        if cfg.moe is not None and not dense_ffn and ltype != "enc_attn":
            p["moe"] = moe_mod.moe_specs(cfg.moe, model_axis_size, fsdp)
        else:
            p["ffn"] = mlp_mod.mlp_specs(cfg.gated_mlp, fsdp)
    elif ltype == "rglru":
        p["ln1"] = P(None)
        p["rglru"] = rglru_mod.rglru_specs(fsdp)
        p["ln2"] = P(None)
        p["ffn"] = mlp_mod.mlp_specs(cfg.gated_mlp, fsdp)
    elif ltype == "mlstm":
        p["ln"] = P(None)
        p["mlstm"] = xlstm_mod.mlstm_specs(fsdp)
    elif ltype == "slstm":
        p["ln"] = P(None)
        p["slstm"] = xlstm_mod.slstm_specs(fsdp)
    return p


def _stack_spec(spec_tree):
    """Prepend a replicated leading (group) axis to every PartitionSpec."""
    return jax.tree.map(lambda s: P(None, *s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def init_params(key, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, 8)
    d, v = cfg.d_model, cfg.vocab_size
    params: dict = {
        "embed": embed_init(keys[0], (v, d)),
        "head": embed_init(keys[1], (d, v)),
        "final_norm": jnp.zeros((d,), jnp.float32),
    }
    # head (unscanned leading dense layers)
    params["head_layers"] = [
        _layer_init(jax.random.fold_in(keys[2], i), cfg,
                    _decoder_ltype(cfg, "dense_attn"),
                    dense_ffn=True) for i in range(cfg.first_k_dense)]
    # scanned pattern groups: stack n_groups copies per pattern position
    blocks = []
    for pidx, ltype in enumerate(cfg.pattern):
        per_group = [
            _layer_init(jax.random.fold_in(keys[3], g * 16 + pidx), cfg,
                        _decoder_ltype(cfg, ltype))
            for g in range(cfg.n_groups)]
        blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_group)
                      if cfg.n_groups else None)
    params["blocks"] = blocks
    params["tail"] = [
        _layer_init(jax.random.fold_in(keys[4], i), cfg,
                    _decoder_ltype(cfg, ltype))
        for i, ltype in enumerate(cfg.pattern[: cfg.n_tail])]
    if cfg.n_enc_layers:
        enc_layers = [
            _layer_init(jax.random.fold_in(keys[5], i), cfg, "enc_attn")
            for i in range(cfg.n_enc_layers)]
        params["encoder"] = {
            "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *enc_layers),
            "final_norm": jnp.zeros((d,), jnp.float32),
        }
    return params


def param_specs(cfg: ModelConfig, fsdp="data", model_axis_size: int = 16):
    specs: dict = {
        "embed": P("model", fsdp),
        "head": P(fsdp, "model"),
        "final_norm": P(None),
    }
    specs["head_layers"] = [
        _layer_specs(cfg, _decoder_ltype(cfg, "dense_attn"), fsdp,
                     model_axis_size, dense_ffn=True)
        for _ in range(cfg.first_k_dense)]
    specs["blocks"] = [
        _stack_spec(_layer_specs(cfg, _decoder_ltype(cfg, ltype), fsdp,
                                 model_axis_size))
        for ltype in cfg.pattern]
    specs["tail"] = [
        _layer_specs(cfg, _decoder_ltype(cfg, ltype), fsdp, model_axis_size)
        for ltype in cfg.pattern[: cfg.n_tail]]
    if cfg.n_enc_layers:
        specs["encoder"] = {
            "blocks": _stack_spec(
                _layer_specs(cfg, "enc_attn", fsdp, model_axis_size)),
            "final_norm": P(None),
        }
    return specs


# ======================================================================
# Layer forward (full sequence)
# ======================================================================

def _decoder_ltype(cfg: ModelConfig, ltype: str) -> str:
    """Decoder layers grow cross-attention in encoder-decoder models."""
    if cfg.n_enc_layers and ltype in ("attn", "swa", "dense_attn"):
        return "xattn"
    return ltype


def _layer_forward(p, cfg: ModelConfig, ltype: str, x, positions,
                   enc_out=None, causal=True, seq_parallel=False):
    """Full-sequence layer. Returns (x, aux, state) — state is the decode
    cache seed (kv / recurrent state) for prefill, else None placeholders.

    With seq_parallel=True (training), the residual stream stays
    seq-sharded on the model axis between ops (Megatron-SP): each sublayer
    gathers its input once and reduce-scatters its output, halving the
    tensor-parallel all-reduce traffic and cutting saved-carry memory 16x.
    """
    aux = jnp.zeros((), jnp.float32)
    state = None

    def gather_in(h):
        return constrain(h, ("batch", None, None)) if seq_parallel else h

    def scatter_out(o):
        return constrain(o, ("batch", "model", None)) if seq_parallel else o

    if ltype in ("attn", "swa", "local_attn", "dense_attn", "enc_attn",
                 "xattn"):
        window = cfg.window if ltype == "swa" else (
            cfg.local_window if ltype == "local_attn" else None)
        dims = cfg.attn_dims(window)
        h = gather_in(rms_norm(x, p["ln1"]))
        out, (k, v) = attn.attention_forward(
            p["attn"], h, positions, dims,
            causal=(ltype != "enc_attn") and causal, chunk=cfg.chunk_q,
            return_kv=True)
        x = x + scatter_out(out)
        state = {"k": k, "v": v}
        if ltype == "xattn":
            hx = rms_norm(x, p["lnx"])
            xq, _, _ = attn._project_qkv(p["xattn"], hx, dims)
            # cross attention: no rope, no mask (encoder memory)
            ek, ev = enc_out
            b, s = hx.shape[:2]
            xout = attn.gqa_scores_softmax_out(
                xq, ek.astype(hx.dtype), ev.astype(hx.dtype),
                jnp.zeros((1, s, ek.shape[1]), jnp.float32))
            xout = xout.reshape(b, s, -1, xout.shape[-1])
            x = x + jnp.einsum("bshe,hed->bsd", xout,
                               p["xattn"]["wo"].astype(hx.dtype))
            state["xk"], state["xv"] = ek, ev  # per-layer cross-attn memory
        h = gather_in(rms_norm(x, p["ln2"]))
        if "moe" in p:
            out, aux = moe_mod.moe_forward(p["moe"], h, cfg.moe,
                                           cfg.activation)
        else:
            out = mlp_mod.mlp_forward(p["ffn"], h, cfg.activation)
        x = x + scatter_out(out)
    elif ltype == "rglru":
        h = gather_in(rms_norm(x, p["ln1"]))
        out, state = rglru_mod.rglru_forward(p["rglru"], h)
        x = x + scatter_out(out)
        x = x + scatter_out(mlp_mod.mlp_forward(
            p["ffn"], gather_in(rms_norm(x, p["ln2"])), cfg.activation))
    elif ltype == "mlstm":
        h = gather_in(rms_norm(x, p["ln"]))
        out, state = xlstm_mod.mlstm_forward(p["mlstm"], h, cfg.chunk_q)
        x = x + scatter_out(out)
    elif ltype == "slstm":
        h = gather_in(rms_norm(x, p["ln"]))
        out, state = xlstm_mod.slstm_forward(p["slstm"], h,
                                             cfg.xlstm.n_heads)
        x = x + scatter_out(out)
    return x, aux, state


# ======================================================================
# Model forward: train
# ======================================================================

def _embed(params, cfg: ModelConfig, tokens):
    x = params["embed"].astype(cfg.dtype)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.dtype)
    return constrain(x, ("batch",) + (None,) * (x.ndim - 1))


def _run_encoder(params, cfg: ModelConfig, src_embeds):
    """Bidirectional encoder over frame embeddings. Returns (B,Ssrc,D)."""
    x = src_embeds.astype(cfg.dtype)
    s = x.shape[1]
    positions = jnp.arange(s, dtype=jnp.float32)

    def body(x, p):
        x, _, _ = _layer_forward(p, cfg, "enc_attn", x, positions,
                                 causal=False)
        return x, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["encoder"]["blocks"])
    return rms_norm(x, params["encoder"]["final_norm"])


def _enc_kv(params_layer, cfg: ModelConfig, enc_x):
    """Precompute cross-attention K/V from encoder output for one layer."""
    dims = cfg.attn_dims()
    b, s, _ = enc_x.shape
    k = jnp.einsum("bsd,dkh->bskh", enc_x,
                   params_layer["xattn"]["wk"].astype(enc_x.dtype))
    v = jnp.einsum("bsd,dkh->bskh", enc_x,
                   params_layer["xattn"]["wv"].astype(enc_x.dtype))
    return k, v


def _backbone(params, cfg: ModelConfig, x, positions, enc_x=None,
              collect_states: bool = False, seq_parallel: bool = True):
    """Run all decoder layers. Returns (x, aux_total, states or None)."""
    aux_total = jnp.zeros((), jnp.float32)
    states: dict = {"head": [], "blocks": [], "tail": []}

    for p in params["head_layers"]:
        lt = _decoder_ltype(cfg, "dense_attn")
        enc_kv = _enc_kv(p, cfg, enc_x) if lt == "xattn" else None
        x, aux, st = _layer_forward(p, cfg, lt, x, positions, enc_kv,
                                    seq_parallel=seq_parallel)
        aux_total += aux
        states["head"].append(st)

    if cfg.n_groups:
        def group_body(carry, gparams):
            x, aux_total = carry
            sts = []
            for pidx, ltype in enumerate(cfg.pattern):
                lt = _decoder_ltype(cfg, ltype)
                enc_kv = _enc_kv(gparams[pidx], cfg, enc_x) \
                    if lt == "xattn" else None
                x, aux, st = _layer_forward(gparams[pidx], cfg, lt, x,
                                            positions, enc_kv,
                                            seq_parallel=seq_parallel)
                aux_total += aux
                sts.append(st)
            # Megatron-style sequence parallelism on the inter-group
            # residual: the scan saves this carry per group for backward,
            # so sharding its seq dim over the model axis cuts the largest
            # training buffer by the model-axis size (16x). TRAIN-ONLY:
            # prefill saves no residuals, so the constraint would only add
            # an all-gather per group (§Perf iteration 2).
            if seq_parallel:
                x = constrain(x, ("batch", "model", None))
            return (x, aux_total), sts if collect_states else None

        body = jax.checkpoint(group_body) if cfg.remat else group_body
        (x, aux_total), block_states = jax.lax.scan(
            body, (x, aux_total), params["blocks"])
        states["blocks"] = block_states

    for i, p in enumerate(params["tail"]):
        lt = _decoder_ltype(cfg, cfg.pattern[i])
        enc_kv = _enc_kv(p, cfg, enc_x) if lt == "xattn" else None
        x, aux, st = _layer_forward(p, cfg, lt, x, positions, enc_kv,
                                    seq_parallel=seq_parallel)
        aux_total += aux
        states["tail"].append(st)

    x = rms_norm(x, params["final_norm"])
    return x, aux_total, (states if collect_states else None)


def train_forward(params, cfg: ModelConfig, batch: dict):
    """batch: tokens (B,S) [, prefix (B,P,D) | src_embeds (B,Ss,D)],
    targets (B,S), mask (B,S). Returns (loss, metrics)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = _embed(params, cfg, tokens)
    offset = 0
    if cfg.frontend == "vision" and cfg.n_prefix:
        x = jnp.concatenate([batch["prefix"].astype(cfg.dtype), x], axis=1)
        offset = cfg.n_prefix
    enc_x = None
    if cfg.n_enc_layers:
        enc_x = _run_encoder(params, cfg, batch["src_embeds"])
    positions = jnp.arange(offset + s, dtype=jnp.float32)
    x, aux, _ = _backbone(params, cfg, x, positions, enc_x)
    x = x[:, offset:]
    nll_sum = _chunked_nll(params, cfg, x, batch["targets"], batch["mask"])
    denom = jnp.maximum(jnp.sum(batch["mask"]), 1.0)
    loss = nll_sum / denom
    if cfg.moe is not None:
        loss = loss + 0.01 * aux / max(cfg.n_layers, 1)
    return loss, {"nll": nll_sum / denom, "aux": aux}


def _nll_block(params, cfg: ModelConfig, xc, tc, mc):
    """Summed NLL of one sequence block. xc (B,cs,D), tc/mc (B,cs)."""
    logits = (xc @ params["head"].astype(cfg.dtype)).astype(jnp.float32)
    logits = constrain(logits, ("batch", None, "model"))
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    # shard-friendly gold-logit extraction: a gather over the (sharded)
    # vocab axis would force GSPMD to replicate the logits; the masked
    # reduce below keeps the vocab axis sharded end-to-end.
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    gold = jnp.sum(jnp.where(vocab_iota == tc[..., None], logits, 0.0),
                   axis=-1)
    return jnp.sum((logz - gold) * mc)


def _chunked_nll(params, cfg: ModelConfig, x, targets, mask):
    """Total NLL, scanned over sequence chunks with rematerialization so
    only one (B, chunk, V) logits block is ever live (forward AND backward).
    The vocab head is the single largest activation in every assigned
    config — this is the memory-term optimization that keeps train_4k
    under the per-device HBM budget."""
    b, s, d = x.shape
    cs = cfg.loss_chunk
    if not cs or s <= cs or s % cs:
        return _nll_block(params, cfg, x, targets, mask)
    nc = s // cs
    xs = x.reshape(b, nc, cs, d).swapaxes(0, 1)
    ts = targets.reshape(b, nc, cs).swapaxes(0, 1)
    ms = mask.reshape(b, nc, cs).swapaxes(0, 1)
    blk = jax.checkpoint(lambda xc, tc, mc: _nll_block(params, cfg, xc, tc,
                                                       mc))

    def body(acc, args):
        return acc + blk(*args), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ts, ms))
    return total


# ======================================================================
# Prefill + decode
# ======================================================================

def _cache_from_state(cfg: ModelConfig, ltype: str, st, capacity: int,
                      ring: bool):
    """Convert a prefill layer state into a fixed-capacity decode cache."""
    if st is None:
        return None
    if "k" in st:  # attention kv: place the last `capacity` positions
        k, v = st["k"], st["v"]
        s = k.shape[1]
        if s >= capacity:
            k, v = k[:, s - capacity:], v[:, s - capacity:]
            if ring and s % capacity:
                # ring slot invariant: abs position p lives at p % capacity
                k = jnp.roll(k, s % capacity, axis=1)
                v = jnp.roll(v, s % capacity, axis=1)
        else:
            pad = capacity - s
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        out = {"k": k, "v": v}
        for extra in ("xk", "xv"):
            if extra in st:
                out[extra] = st[extra]
        return out
    return st


def decode_step(params, cfg: ModelConfig, cache: dict, token: jax.Array,
                pos: jax.Array, *, ring: bool = False):
    """One-token decode. token (B,) int32; pos () int32 absolute position.
    cache layout mirrors params layout (head/blocks/tail lists + optional
    cross-attention memory). ``ring=True`` treats attention caches as ring
    buffers (sub-quadratic long-context decode).
    Returns (logits (B, V), new_cache)."""
    x = _embed(params, cfg, token[:, None])

    new_cache: dict = {}
    new_cache["head"] = []
    for p, st in zip(params["head_layers"], cache["head"]):
        x, new_st = _decode_layer(p, cfg, _decoder_ltype(cfg, "dense_attn"),
                                  st, x, pos, cache, ring)
        new_cache["head"].append(new_st)

    if cfg.n_groups:
        def group_body(x_carry, args):
            gparams, gcache = args
            new_sts = []
            xx = x_carry
            for pidx, ltype in enumerate(cfg.pattern):
                lt = _decoder_ltype(cfg, ltype)
                xx, new_st = _decode_layer(gparams[pidx], cfg, lt,
                                           gcache[pidx], xx, pos, cache,
                                           ring)
                new_sts.append(new_st)
            return xx, new_sts

        x, block_caches = jax.lax.scan(group_body, x,
                                       (params["blocks"], cache["blocks"]))
        new_cache["blocks"] = block_caches
    else:
        new_cache["blocks"] = cache.get("blocks", [])

    new_cache["tail"] = []
    for i, p in enumerate(params["tail"]):
        lt = _decoder_ltype(cfg, cfg.pattern[i])
        x, st = _decode_layer(p, cfg, lt, cache["tail"][i], x, pos, cache,
                              ring)
        new_cache["tail"].append(st)

    x = rms_norm(x, params["final_norm"])
    logits = (x[:, 0] @ params["head"].astype(cfg.dtype)).astype(jnp.float32)
    logits = constrain(logits, ("batch", "model"))
    return logits, new_cache


def _decode_layer(p, cfg: ModelConfig, lt: str, st, x, pos, cache, ring):
    """One layer of decode (pure function of (x, state))."""
    if lt in ("attn", "swa", "local_attn", "dense_attn", "xattn"):
        window = cfg.window if lt == "swa" else (
            cfg.local_window if lt == "local_attn" else None)
        dims = cfg.attn_dims(window)
        h = rms_norm(x, p["ln1"])
        out, ck, cv = attn.attention_decode(p["attn"], h, pos, st["k"],
                                            st["v"], dims, ring=ring,
                                            window=window)
        x = x + out
        new_st = {"k": ck, "v": cv}
        if lt == "xattn":
            hx = rms_norm(x, p["lnx"])
            q, _, _ = attn._project_qkv(p["xattn"], hx, dims)
            b = hx.shape[0]
            xo = attn.gqa_scores_softmax_out(
                q, st["xk"].astype(hx.dtype), st["xv"].astype(hx.dtype),
                jnp.zeros((1, 1, st["xk"].shape[1]), jnp.float32))
            xo = xo.reshape(b, 1, -1, xo.shape[-1])
            x = x + jnp.einsum("bshe,hed->bsd", xo,
                               p["xattn"]["wo"].astype(hx.dtype))
            new_st["xk"], new_st["xv"] = st["xk"], st["xv"]
        h2 = rms_norm(x, p["ln2"])
        if "moe" in p:
            out, _ = moe_mod.moe_forward(p["moe"], h2, cfg.moe,
                                         cfg.activation)
        else:
            out = mlp_mod.mlp_forward(p["ffn"], h2, cfg.activation)
        x = x + out
        return x, new_st
    if lt == "rglru":
        h = rms_norm(x, p["ln1"])
        out, hh, tail = rglru_mod.rglru_decode(p["rglru"], h, st["h"],
                                               st["conv"])
        x = x + out
        x = x + mlp_mod.mlp_forward(p["ffn"], rms_norm(x, p["ln2"]),
                                    cfg.activation)
        return x, {"h": hh, "conv": tail}
    if lt == "mlstm":
        h = rms_norm(x, p["ln"])
        out, new = xlstm_mod.mlstm_decode(p["mlstm"], h, st)
        return x + out, new
    if lt == "slstm":
        h = rms_norm(x, p["ln"])
        out, new = xlstm_mod.slstm_decode(p["slstm"], h, st,
                                          cfg.xlstm.n_heads)
        return x + out, new
    raise ValueError(lt)


# ======================================================================
# Prefill
# ======================================================================

def prefill_forward(params, cfg: ModelConfig, batch: dict, capacity: int,
                    ring: bool = False):
    """Full-sequence forward that also builds the decode cache.

    Returns (last_logits (B, V), cache). capacity = cache size (>= S for
    full attention; == window for ring buffers)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = _embed(params, cfg, tokens)
    offset = 0
    if cfg.frontend == "vision" and cfg.n_prefix:
        x = jnp.concatenate([batch["prefix"].astype(cfg.dtype), x], axis=1)
        offset = cfg.n_prefix
    enc_x = None
    cache: dict = {}
    if cfg.n_enc_layers:
        enc_raw = _run_encoder(params, cfg, batch["src_embeds"])
        enc_x = enc_raw
    positions = jnp.arange(offset + s, dtype=jnp.float32)
    x, _, states = _backbone(params, cfg, x, positions, enc_x,
                             collect_states=True, seq_parallel=False)

    def conv(st):
        return _cache_from_state(cfg, "", st, capacity, ring)

    cache["head"] = [conv(st) for st in states["head"]]
    cache["blocks"] = jax.tree.map(
        lambda *a: a[0], states["blocks"],
        is_leaf=lambda z: False) if False else states["blocks"]
    # stacked block states: kv leaves are (G, B, S, KV, hd) — trim/pad S
    if cfg.n_groups:
        def conv_stacked(st):
            if st is None:
                return None
            if "k" in st:
                k, v = st["k"], st["v"]
                sl = k.shape[2]
                if sl >= capacity:
                    k = k[:, :, sl - capacity:]
                    v = v[:, :, sl - capacity:]
                    if ring and sl % capacity:
                        k = jnp.roll(k, sl % capacity, axis=2)
                        v = jnp.roll(v, sl % capacity, axis=2)
                else:
                    pad = capacity - sl
                    k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                    v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                out = {"k": k, "v": v}
                for extra in ("xk", "xv"):
                    if extra in st:
                        out[extra] = st[extra]
                return out
            return st
        cache["blocks"] = [conv_stacked(st) for st in states["blocks"]]
    else:
        cache["blocks"] = []
    cache["tail"] = [conv(st) for st in states["tail"]]
    logits = (x[:, -1] @ params["head"].astype(cfg.dtype)).astype(jnp.float32)
    return logits, cache


# ======================================================================
# Decode cache construction + partition specs
# ======================================================================

def _zero_state(cfg: ModelConfig, ltype: str, b: int, capacity: int,
                enc_len: int = 0):
    dims = cfg.attn_dims()
    kvh, hd = dims.n_kv_heads, dims.head_dim
    if ltype in ("attn", "swa", "local_attn", "dense_attn", "xattn"):
        z = jnp.zeros((b, capacity, kvh, hd), cfg.dtype)
        st = {"k": z, "v": z}
        if ltype == "xattn":
            ze = jnp.zeros((b, enc_len, kvh, hd), cfg.dtype)
            st["xk"], st["xv"] = ze, ze
        return st
    if ltype == "rglru":
        return {"h": jnp.zeros((b, cfg.d_rnn), jnp.float32),
                "conv": jnp.zeros((b, rglru_mod.CONV_W - 1, cfg.d_rnn),
                                  cfg.dtype)}
    if ltype == "mlstm":
        xh, xd = cfg.xlstm.n_heads, cfg.xlstm.head_dim
        return {"c": jnp.zeros((b, xh, xd, xd), jnp.float32),
                "n": jnp.zeros((b, xh, xd), jnp.float32),
                "m": jnp.full((b, xh), -30.0, jnp.float32)}
    if ltype == "slstm":
        z = jnp.zeros((b, cfg.d_model), jnp.float32)
        return {"h": z, "c": z, "n": z,
                "m": jnp.full((b, cfg.d_model), -30.0, jnp.float32)}
    raise ValueError(ltype)


def _state_specs(cfg: ModelConfig, ltype: str, batch_axis, seq_axis,
                 cache_mode: str = "hd"):
    """Partition specs matching _zero_state. batch_axis shards B (or None
    when B is too small); seq_axis optionally shards the cache length (used
    for long-context B=1 decode). cache_mode:
      "hd"  — head_dim on the model axis (baseline),
      "seq" — cache length on the model axis (flash-decoding style:
              per-shard partial softmax, tiny psums; see §Perf)."""
    if ltype in ("attn", "swa", "local_attn", "dense_attn", "xattn"):
        if cache_mode == "seq":
            # flash-decoding: cache length on model (and on data too when
            # the batch is unshardable, e.g. B=1 long-context)
            seq_entry = "model" if batch_axis else ("data", "model")
            s = P(batch_axis, seq_entry, None, None)
        else:
            s = P(batch_axis, seq_axis, None, "model")
        st = {"k": s, "v": s}
        if ltype == "xattn":
            st["xk"] = P(batch_axis, None, None, "model")
            st["xv"] = P(batch_axis, None, None, "model")
        return st
    if ltype == "rglru":
        return {"h": P(batch_axis, "model"),
                "conv": P(batch_axis, None, "model")}
    if ltype == "mlstm":
        return {"c": P(batch_axis, None, "model", None),
                "n": P(batch_axis, None, "model"),
                "m": P(batch_axis, None)}
    if ltype == "slstm":
        s = P(batch_axis, "model")
        return {"h": s, "c": s, "n": s, "m": s}
    raise ValueError(ltype)


def init_cache(cfg: ModelConfig, b: int, capacity: int,
               enc_len: int = 0) -> dict:
    """Zero decode cache (the dry-run serve_step input)."""
    cache: dict = {
        "head": [_zero_state(cfg, _decoder_ltype(cfg, "dense_attn"), b,
                             capacity, enc_len)
                 for _ in range(cfg.first_k_dense)],
        "blocks": [
            jax.tree.map(
                lambda a: jnp.broadcast_to(a[None],
                                           (cfg.n_groups,) + a.shape),
                _zero_state(cfg, _decoder_ltype(cfg, lt), b, capacity,
                            enc_len))
            for lt in cfg.pattern] if cfg.n_groups else [],
        "tail": [_zero_state(cfg, _decoder_ltype(cfg, lt), b, capacity,
                             enc_len)
                 for lt in cfg.pattern[: cfg.n_tail]],
    }
    return cache


def cache_specs(cfg: ModelConfig, batch_axis, seq_axis=None,
                cache_mode: str = "hd") -> dict:
    def stack(s):
        return jax.tree.map(lambda q: P(None, *q), s,
                            is_leaf=lambda x: isinstance(x, P))

    specs: dict = {
        "head": [_state_specs(cfg, _decoder_ltype(cfg, "dense_attn"),
                              batch_axis, seq_axis, cache_mode)
                 for _ in range(cfg.first_k_dense)],
        "blocks": [stack(_state_specs(cfg, _decoder_ltype(cfg, lt),
                                      batch_axis, seq_axis, cache_mode))
                   for lt in cfg.pattern] if cfg.n_groups else [],
        "tail": [_state_specs(cfg, _decoder_ltype(cfg, lt), batch_axis,
                              seq_axis, cache_mode)
                 for lt in cfg.pattern[: cfg.n_tail]],
    }
    return specs
