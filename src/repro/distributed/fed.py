"""Federated GMM learning as mesh collectives (DESIGN.md §3).

Clients map to shards of the ``data`` mesh axis. The two algorithms become
two collective patterns:

  FedGenGMM (one-shot):  local EM runs with ZERO cross-shard communication,
      then the single communication round of the paper is literally ONE
      jax.lax.all_gather of the (K, 2d+1) parameter blocks + dataset sizes.
      The server-side merge/sample/refit then runs replicated (every shard
      computes the same global model, as a real parameter server would
      broadcast it anyway).

  DEM (iterative):       every EM iteration psums the sufficient statistics
      across the data axis — one all-reduce PER ROUND. The dry-run
      collective analysis makes Table 4 visible in HLO bytes.

Client counts larger than the axis size are handled by placing multiple
clients per shard (the client axis is reshaped to (shards, per_shard)).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.config import FitConfig
from repro.core.em import (SufficientStats, e_step_stats, fit_gmm_cfg,
                           init_from_means, m_step)
from repro.core.gmm import GMM, merge_gmms_stacked
from repro.data.sources import SyntheticGMMSource


class ShardedFedResult(NamedTuple):
    global_gmm: GMM
    local_weights: jax.Array   # (C, K)
    local_means: jax.Array     # (C, K, d)
    local_covs: jax.Array      # (C, K, d)


def fedgen_sharded(mesh, key, data, mask, k: int, k_global: int,
                   h: int = 100, max_iter: int = 200, tol: float = 1e-3,
                   estep_backend: str = "auto",
                   chunk_size: int | None = None,
                   synthetic: str = "resident",
                   config: FitConfig | None = None):
    """One-shot FedGenGMM over a device mesh.

    data: (C, N, d), mask: (C, N) with C divisible by the data-axis size.
    Returns ShardedFedResult (global model replicated).
    ``config`` (a :class:`FitConfig`) selects the E-step engine for both
    the per-shard local fits and the replicated server refit; the loose
    ``max_iter``/``tol``/``estep_backend``/``chunk_size`` knobs are the
    legacy spelling and are folded into one config (ignored when
    ``config`` is given).

    ``synthetic="source"`` makes the replicated server refit out-of-core:
    the synthetic replay set |S| = H·K·C — the one dataset in this runtime
    that *grows with the client count* — is consumed as a seeded
    :class:`SyntheticGMMSource` block stream instead of being materialized
    (DESIGN.md §7). The collective pattern is untouched: the all_gather
    payload is parameters either way.
    """
    if synthetic not in ("resident", "source"):
        raise ValueError(f"synthetic must be 'resident' or 'source', "
                         f"got {synthetic!r}")
    cfg = config if config is not None else FitConfig.from_legacy(
        backend=estep_backend, chunk_size=chunk_size, tol=tol,
        max_iter=max_iter)
    axis = "data"
    n_shards = mesh.shape[axis]
    c = data.shape[0]
    assert c % n_shards == 0, (c, n_shards)

    def local_part(key, data_shard, mask_shard):
        """Runs per shard: train this shard's clients, no communication."""
        nc = data_shard.shape[0]
        keys = jax.random.split(key[0], nc)

        def one(kk, x, w):
            res = fit_gmm_cfg(kk, x, k, cfg, sample_weight=w)
            return res.gmm.weights, res.gmm.means, res.gmm.covs

        w, mu, cov = jax.vmap(one)(keys, data_shard, mask_shard)
        sizes = jnp.sum(mask_shard, axis=1)
        # === THE single communication round of the paper ===
        w_all = jax.lax.all_gather(w, axis, tiled=True)
        mu_all = jax.lax.all_gather(mu, axis, tiled=True)
        cov_all = jax.lax.all_gather(cov, axis, tiled=True)
        sz_all = jax.lax.all_gather(sizes, axis, tiled=True)
        return w_all, mu_all, cov_all, sz_all

    keys = jax.random.split(key, n_shards)
    spec = P(axis)
    fn = shard_map(local_part, mesh=mesh,
                   in_specs=(P(axis), spec, spec),
                   out_specs=(P(), P(), P(), P()), check_rep=False)
    w_all, mu_all, cov_all, sz_all = fn(keys, data, mask)

    # server side (replicated): merge -> sample -> refit
    merged = merge_gmms_stacked(w_all, mu_all, cov_all, sz_all)
    n_synth = h * k * c
    k_sample, k_fit = jax.random.split(jax.random.fold_in(key, 1))
    if synthetic == "source":
        synth = SyntheticGMMSource(merged, n_synth, k_sample)
    else:
        synth = merged.sample(k_sample, n_synth)
    res = fit_gmm_cfg(k_fit, synth, k_global, cfg)
    return ShardedFedResult(res.gmm, w_all, mu_all, cov_all)


def dem_sharded(mesh, key, data, mask, k: int, init_centers,
                max_rounds: int = 100, tol: float = 1e-3,
                reg_covar: float = 1e-6,
                estep_backend: str = "auto",
                chunk_size: int | None = None,
                config: FitConfig | None = None) -> tuple[GMM, jax.Array]:
    """Distributed EM over the mesh: one psum of sufficient statistics per
    EM round (the iterative baseline's communication pattern).

    With an integer chunk size (via ``config.chunk_size`` or the legacy
    ``chunk_size`` knob), each shard streams its clients' rows through
    the engine (``e_step_stats`` owns the full-batch/chunked dispatch) so
    per-round shard memory is bounded by (chunk_size, K) rather than
    (N, K) — the psum payload is unchanged (SufficientStats is already the
    reduced form).
    """
    cfg = config if config is not None else FitConfig.from_legacy(
        backend=estep_backend, chunk_size=chunk_size, tol=tol,
        max_iter=max_rounds, reg_covar=reg_covar)
    max_rounds, reg_covar = cfg.max_iter, cfg.reg_covar
    tol, backend = cfg.tol, cfg.backend
    cs = cfg.resolve_chunk(source=False)
    axis = "data"
    d = data.shape[-1]

    def sharded_round(gmm_leaves, data_shard, mask_shard):
        gmm = GMM(*gmm_leaves)
        per = jax.vmap(
            lambda x, w: e_step_stats(gmm, x, w, backend, cs))(
            data_shard, mask_shard)
        local = jax.tree.map(lambda s: jnp.sum(s, axis=0), per)
        # === one all-reduce per EM round ===
        return jax.tree.map(lambda s: jax.lax.psum(s, axis), local)

    spec = P(axis)
    round_fn = shard_map(
        sharded_round, mesh=mesh,
        in_specs=((P(), P(), P()), spec, spec),
        out_specs=SufficientStats(P(), P(), P(), P(), P()),
        check_rep=False)

    flat = data.reshape(-1, d)
    flat_w = mask.reshape(-1)
    gmm0 = init_from_means(init_centers, flat, flat_w, reg_covar=reg_covar)

    def cond(state):
        _, prev_ll, ll, it = state
        return jnp.logical_and(it < max_rounds, jnp.abs(ll - prev_ll) > tol)

    def body(state):
        gmm, _, ll, it = state
        stats = round_fn((gmm.weights, gmm.means, gmm.covs), data, mask)
        new_gmm = m_step(stats, reg_covar)
        new_ll = stats.loglik / jnp.maximum(stats.wsum, 1e-12)
        return new_gmm, ll, new_ll, it + 1

    stats0 = round_fn((gmm0.weights, gmm0.means, gmm0.covs), data, mask)
    gmm1 = m_step(stats0, reg_covar)
    ll0 = stats0.loglik / jnp.maximum(stats0.wsum, 1e-12)
    state = (gmm1, jnp.array(-jnp.inf, data.dtype), ll0, jnp.array(1))
    gmm, _, ll, rounds = jax.lax.while_loop(cond, body, state)
    return gmm, rounds
