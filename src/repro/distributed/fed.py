"""Federated GMM learning as mesh collectives (DESIGN.md §3/§9).

Clients map to shards of the ``data`` mesh axis. The algorithms become
collective patterns:

  FedGenGMM (one-shot):  local EM runs with ZERO cross-shard communication,
      then the single communication round of the paper is literally ONE
      jax.lax.all_gather of the (K, 2d+1) parameter blocks + dataset sizes.
      The server-side merge/sample/refit then runs replicated (every shard
      computes the same global model, as a real parameter server would
      broadcast it anyway).

  DEM / FedEM / FedKMeans (iterative): every round psums the per-client
      payload (EM sufficient statistics, or k-means label statistics)
      across the data axis — one all-reduce PER ROUND. The dry-run
      collective analysis makes Table 4 visible in HLO bytes.

Since the §9 refactor the iterative entry points here carry NO round loop
of their own: shard_map is just a *client backend*
(``repro.fed.runtime.ShardedClients`` — vmap over the shard's clients,
psum across the axis) under the same ``run_rounds`` driver that runs the
single-process strategies, so the mesh runtime and the reference
semantics cannot drift apart.

Client counts larger than the axis size are handled by placing multiple
clients per shard (the client axis is reshaped to (shards, per_shard)).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.config import FitConfig, resolve_backend
from repro.core.dem import DEMStrategy, _resolve_init
from repro.core.em import fit_gmm_cfg, init_from_means
from repro.core.gmm import GMM, merge_gmms_stacked
from repro.data.sources import SyntheticGMMSource
from repro.fed.cohort import make_sampler
from repro.fed.runtime import run_rounds
from repro.fed.strategies import (FedEMResult, FedEMStrategy,
                                  FedKMeansResult, FedKMeansStrategy,
                                  _resolve_fedkmeans_init)


class ShardedFedResult(NamedTuple):
    global_gmm: GMM
    local_weights: jax.Array   # (C, K)
    local_means: jax.Array     # (C, K, d)
    local_covs: jax.Array      # (C, K, d)


def fedgen_sharded(mesh, key, data, mask, k: int, k_global: int,
                   h: int = 100, max_iter: int = 200, tol: float = 1e-3,
                   estep_backend: str = "auto",
                   chunk_size: int | None = None,
                   synthetic: str = "resident",
                   config: FitConfig | None = None):
    """One-shot FedGenGMM over a device mesh.

    data: (C, N, d), mask: (C, N) with C divisible by the data-axis size.
    Returns ShardedFedResult (global model replicated).
    ``config`` (a :class:`FitConfig`) selects the E-step engine for both
    the per-shard local fits and the replicated server refit; the loose
    ``max_iter``/``tol``/``estep_backend``/``chunk_size`` knobs are the
    legacy spelling and are folded into one config (ignored when
    ``config`` is given).

    ``synthetic="source"`` makes the replicated server refit out-of-core:
    the synthetic replay set |S| = H·K·C — the one dataset in this runtime
    that *grows with the client count* — is consumed as a seeded
    :class:`SyntheticGMMSource` block stream instead of being materialized
    (DESIGN.md §7). The collective pattern is untouched: the all_gather
    payload is parameters either way.
    """
    if synthetic not in ("resident", "source"):
        raise ValueError(f"synthetic must be 'resident' or 'source', "
                         f"got {synthetic!r}")
    cfg = config if config is not None else FitConfig.from_legacy(
        backend=estep_backend, chunk_size=chunk_size, tol=tol,
        max_iter=max_iter)
    axis = "data"
    n_shards = mesh.shape[axis]
    c = data.shape[0]
    assert c % n_shards == 0, (c, n_shards)

    def local_part(key, data_shard, mask_shard):
        """Runs per shard: train this shard's clients, no communication."""
        nc = data_shard.shape[0]
        keys = jax.random.split(key[0], nc)

        def one(kk, x, w):
            res = fit_gmm_cfg(kk, x, k, cfg, sample_weight=w)
            return res.gmm.weights, res.gmm.means, res.gmm.covs

        w, mu, cov = jax.vmap(one)(keys, data_shard, mask_shard)
        sizes = jnp.sum(mask_shard, axis=1)
        # === THE single communication round of the paper ===
        w_all = jax.lax.all_gather(w, axis, tiled=True)
        mu_all = jax.lax.all_gather(mu, axis, tiled=True)
        cov_all = jax.lax.all_gather(cov, axis, tiled=True)
        sz_all = jax.lax.all_gather(sizes, axis, tiled=True)
        return w_all, mu_all, cov_all, sz_all

    keys = jax.random.split(key, n_shards)
    spec = P(axis)
    fn = shard_map(local_part, mesh=mesh,
                   in_specs=(P(axis), spec, spec),
                   out_specs=(P(), P(), P(), P()), check_rep=False)
    w_all, mu_all, cov_all, sz_all = fn(keys, data, mask)

    # server side (replicated): merge -> sample -> refit
    merged = merge_gmms_stacked(w_all, mu_all, cov_all, sz_all)
    n_synth = h * k * c
    k_sample, k_fit = jax.random.split(jax.random.fold_in(key, 1))
    if synthetic == "source":
        synth = SyntheticGMMSource(merged, n_synth, k_sample)
    else:
        synth = merged.sample(k_sample, n_synth)
    res = fit_gmm_cfg(k_fit, synth, k_global, cfg)
    return ShardedFedResult(res.gmm, w_all, mu_all, cov_all)


def dem_sharded(mesh, key, data, mask, k: int, init_centers,
                max_rounds: int = 100, tol: float = 1e-3,
                reg_covar: float = 1e-6,
                estep_backend: str = "auto",
                chunk_size: int | None = None,
                config: FitConfig | None = None,
                transform=None) -> tuple[GMM, jax.Array]:
    """Distributed EM over the mesh: one psum of sufficient statistics per
    EM round (the iterative baseline's communication pattern).

    Since §9 this is a :class:`~repro.core.dem.DEMStrategy` on the shared
    round driver — shard_map is the client backend, not a third copy of
    the loop. ``init_centers`` are the caller-chosen global centers (the
    scheme inits live in :func:`repro.core.dem.dem_cfg`); ``key`` is
    unused on this path and kept for signature stability. With an integer
    chunk size each shard streams its clients' rows through the engine so
    per-round shard memory is bounded by (chunk_size, K) rather than
    (N, K) — the psum payload is unchanged (SufficientStats is already
    the reduced form).
    """
    cfg = config if config is not None else FitConfig.from_legacy(
        backend=estep_backend, chunk_size=chunk_size, tol=tol,
        max_iter=max_rounds, reg_covar=reg_covar)
    data, mask = jnp.asarray(data), jnp.asarray(mask)
    d = data.shape[-1]
    strategy = DEMStrategy(
        k=k, covariance_type=cfg.covariance_type, backend=cfg.backend,
        chunk=cfg.resolve_chunk(source=False), host=False,
        tol=cfg.resolve_tol("em"), reg_covar=cfg.reg_covar)
    flat = data.reshape(-1, d)
    flat_w = mask.reshape(-1)
    gmm0 = init_from_means(init_centers, flat, flat_w,
                           covariance_type=cfg.covariance_type,
                           reg_covar=cfg.reg_covar)
    res = run_rounds(strategy, (data, mask), mesh=mesh,
                     state0=strategy.state_from_gmm(gmm0, dtype=data.dtype),
                     max_rounds=cfg.resolve_max_iter("em"),
                     transform=transform)
    return res.global_gmm, res.n_rounds


def fedem_sharded(mesh, key, data, mask, k: int, *,
                  participation: float = 1.0, local_epochs: int = 1,
                  cohort: str = "cyclic", cohort_seed: int = 0,
                  stragglers=None, init_centers=None,
                  config: FitConfig | None = None,
                  transform=None) -> FedEMResult:
    """Iterative federated EM (Tian et al.) over the mesh: DEM's psum
    pattern with the partial-participation / local-epochs knobs. Under
    ``participation < 1`` the driver samples a cohort per round
    (``cohort``: "cyclic" or seeded "uniform") and each shard computes
    ONLY the cohort members it owns — per-shard round cost is O(m), not
    O(clients/shard). The result carries the populated communication
    ledger (cohort-sized uplink per round, init traffic included).
    ``init_centers`` overrides the scheme init from ``config.init``
    (which resolves exactly as in single-process FedEM: "auto" ->
    one-shot fed-kmeans)."""
    cfg = config if config is not None else FitConfig()
    data, mask = jnp.asarray(data), jnp.asarray(mask)
    strategy = FedEMStrategy(
        k=k, covariance_type=cfg.covariance_type, backend=cfg.backend,
        chunk=cfg.resolve_chunk(source=False),
        init=_resolve_init(cfg.init, sources=False), host=False,
        tol=cfg.resolve_tol("em"), reg_covar=cfg.reg_covar,
        participation=float(participation), local_epochs=int(local_epochs),
        n_clients=data.shape[0])
    sampler = None
    if strategy.participation < 1.0:
        sampler = make_sampler(cohort, data.shape[0],
                               strategy.cohort_size(), seed=cohort_seed)
    state0 = None
    if init_centers is not None:
        d = data.shape[-1]
        gmm0 = init_from_means(init_centers, data.reshape(-1, d),
                               mask.reshape(-1),
                               covariance_type=cfg.covariance_type,
                               reg_covar=cfg.reg_covar)
        state0 = strategy.state_from_gmm(gmm0, dtype=data.dtype)
    return run_rounds(strategy, (data, mask), key=key, mesh=mesh,
                      state0=state0,
                      max_rounds=cfg.resolve_max_iter("em"),
                      sampler=sampler, stragglers=stragglers,
                      transform=transform)


def fed_kmeans_sharded(mesh, key, data, mask, k: int, *,
                       config: FitConfig | None = None,
                       transform=None) -> FedKMeansResult:
    """Iterative federated k-means (Garst et al.) over the mesh: one psum
    of per-center label statistics (counts, sums, inertia) per round —
    the same collective as DEM with responsibilities replaced by hard
    labels."""
    cfg = config if config is not None else FitConfig()
    data, mask = jnp.asarray(data), jnp.asarray(mask)
    strategy = FedKMeansStrategy(
        k=k, assign_backend=resolve_backend(cfg.backend),
        chunk=cfg.resolve_chunk(source=False),
        init=_resolve_fedkmeans_init(cfg.init), host=False,
        tol=cfg.resolve_tol("kmeans"))
    return run_rounds(strategy, (data, mask), key=key, mesh=mesh,
                      max_rounds=cfg.resolve_max_iter("kmeans"),
                      transform=transform)
