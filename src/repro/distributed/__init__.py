"""Distributed federated runtime: the paper's communication patterns as
mesh collectives (one-shot all_gather vs per-round psum)."""
from repro.distributed.fed import (ShardedFedResult, dem_sharded,
                                   fedgen_sharded)
__all__ = ["ShardedFedResult", "dem_sharded", "fedgen_sharded"]
