"""Distributed federated runtime: the paper's communication patterns as
mesh collectives (one-shot all_gather vs per-round psum), all iterative
loops served by the shared round driver (``repro.fed.runtime``)."""
from repro.distributed.fed import (ShardedFedResult, dem_sharded,
                                   fed_kmeans_sharded, fedem_sharded,
                                   fedgen_sharded)
__all__ = ["ShardedFedResult", "dem_sharded", "fed_kmeans_sharded",
           "fedem_sharded", "fedgen_sharded"]
