"""repro — FedGenGMM (one-shot federated Gaussian Mixture Models) in JAX.

Subpackages:
  api          THE public surface: FitConfig + estimator facades
               (GMMEstimator/KMeansEstimator/FedGenGMM/DEM) dispatching
               on input type (array | DataSource | ClientSplit | sources)
  core         the paper's contribution: GMM/EM/FedGenGMM/DEM (+ DP,
               continual, split-merge extensions) — internal entry points
  data         dataset analogues, PCA, scaling, token pipeline
  kernels      Pallas TPU kernels for the EM hot path
  models       multi-architecture transformer substrate
  configs      the 10 assigned architectures
  distributed  federated runtime as mesh collectives
  monitor      FedGenGMM activation monitor for serving
  launch       meshes, step functions, trainer, serving loop, dry-run
  optim        AdamW;  checkpoint: npz checkpointing
"""
