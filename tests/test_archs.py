"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU asserting output shapes + no NaNs, plus prefill->decode consistency
against the full-sequence forward for representative families."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import (decode_step, init_cache, init_params,
                          prefill_forward, train_forward)

ARCHS = list_archs()
B, S = 2, 32


def make_batch(cfg, b=B, s=S, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                               jnp.int32),
        "mask": jnp.ones((b, s), jnp.float32),
    }
    if cfg.frontend == "vision":
        batch["prefix"] = jnp.asarray(
            rng.normal(0, 0.02, (b, cfg.n_prefix, cfg.d_model)), cfg.dtype)
    if cfg.n_enc_layers:
        batch["src_embeds"] = jnp.asarray(
            rng.normal(0, 0.02, (b, s // cfg.src_ratio, cfg.d_model)),
            cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    """One forward + backward on the reduced config: finite loss + grads."""
    cfg = get_config(arch, "smoke")
    params = init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg)

    def loss_fn(p):
        loss, metrics = train_forward(p, cfg, batch)
        return loss, metrics

    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(loss_fn, has_aux=True))(params)
    assert jnp.isfinite(loss), arch
    # a loss near ln(V) is sane for random init
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < \
        3.0 * np.log(cfg.vocab_size), (arch, float(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat), arch
    # gradient must reach the embedding and at least one block param
    assert float(jnp.abs(grads["embed"]).max()) > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_shapes(arch):
    cfg = get_config(arch, "smoke")
    params = init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg)
    logits, cache = jax.jit(
        lambda p, b: prefill_forward(p, cfg, b, capacity=S + 8))(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_runs(arch):
    """serve_step on a zero cache: shape + finiteness (full consistency is
    covered for representative families below)."""
    cfg = get_config(arch, "smoke")
    params = init_params(jax.random.key(0), cfg)
    enc_len = (S // cfg.src_ratio) if cfg.n_enc_layers else 0
    cache = init_cache(cfg, B, capacity=S, enc_len=enc_len)
    token = jnp.zeros((B,), jnp.int32)
    logits, new_cache = jax.jit(
        lambda p, c, t: decode_step(p, cfg, c, t, jnp.asarray(4)))(
        params, cache, token)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    # cache structure is preserved
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


CONSISTENCY_ARCHS = ["yi-6b", "mixtral-8x7b", "recurrentgemma-9b",
                     "xlstm-350m", "internvl2-26b", "seamless-m4t-medium"]


@pytest.mark.parametrize("arch", CONSISTENCY_ARCHS)
def test_prefill_decode_matches_full_forward(arch):
    """prefill(S) + decode(token_S) logits == full forward(S+1) last-token
    logits — the cache-correctness invariant, in float32.

    MoE archs use a drop-free capacity factor here: capacity-based routing
    is context-dependent (tokens compete for expert slots within a group),
    so with drops enabled prefill and decode are *expected* to differ —
    that is documented GShard/Switch behaviour, not a cache bug."""
    cfg = dataclasses.replace(get_config(arch, "smoke"), dtype=jnp.float32)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=cfg.moe._replace(capacity_factor=float(cfg.moe.n_experts)))
    params = init_params(jax.random.key(1), cfg)
    rng = np.random.default_rng(3)
    s_total = S + 1
    full = make_batch(cfg, B, s_total, seed=3)
    prefill_batch = dict(full)
    prefill_batch["tokens"] = full["tokens"][:, :S]
    prefill_batch.pop("targets"), prefill_batch.pop("mask")

    capacity = s_total + (cfg.n_prefix if cfg.frontend == "vision" else 0)
    _, cache = jax.jit(lambda p, b: prefill_forward(p, cfg, b, capacity))(
        params, prefill_batch)
    pos = jnp.asarray(S + (cfg.n_prefix if cfg.frontend == "vision" else 0),
                      jnp.int32)
    dec_logits, _ = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t, pos))(
        params, cache, full["tokens"][:, S])

    # full forward over S+1 tokens; compare last position pre-loss logits
    from repro.models.transformer import _backbone, _embed, _run_encoder

    def full_logits(p):
        x = _embed(p, cfg, full["tokens"])
        off = 0
        if cfg.frontend == "vision":
            x = jnp.concatenate([full["prefix"].astype(cfg.dtype), x], 1)
            off = cfg.n_prefix
        enc = _run_encoder(p, cfg, full["src_embeds"]) \
            if cfg.n_enc_layers else None
        positions = jnp.arange(off + s_total, dtype=jnp.float32)
        h, _, _ = _backbone(p, cfg, x, positions, enc)
        return (h[:, -1] @ p["head"].astype(cfg.dtype)).astype(jnp.float32)

    ref = jax.jit(full_logits)(params)
    np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_ring_cache_decode_matches_windowed():
    """For a SWA arch, ring-buffer decode == full-cache windowed decode."""
    cfg = dataclasses.replace(get_config("mixtral-8x7b", "smoke"),
                              dtype=jnp.float32, window=16)
    params = init_params(jax.random.key(2), cfg)
    batch = make_batch(cfg, B, S, seed=5)
    prefill_batch = {"tokens": batch["tokens"]}
    # full cache
    _, cache_full = jax.jit(
        lambda p, b: prefill_forward(p, cfg, b, capacity=S + 1))(
        params, prefill_batch)
    # ring cache of exactly the window
    _, cache_ring = jax.jit(
        lambda p, b: prefill_forward(p, cfg, b, capacity=cfg.window,
                                     ring=True))(params, prefill_batch)
    token = batch["tokens"][:, -1]
    pos = jnp.asarray(S, jnp.int32)
    lf, _ = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t, pos))(
        params, cache_full, token)
    lr, _ = jax.jit(
        lambda p, c, t: decode_step(p, cfg, c, t, pos, ring=True))(
        params, cache_ring, token)
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lr), rtol=2e-3,
                               atol=2e-3)
