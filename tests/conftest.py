import numpy as np
import pytest

# NOTE: do NOT set XLA_FLAGS / device-count overrides here — smoke tests and
# benches must see the single real CPU device. Only launch/dryrun.py forces
# the 512-device placeholder topology (in its own process).


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def planted_gmm_data(rng, n=1500, d=4, k=3, spread=4.0, std=0.5):
    """Well-separated planted mixture + labels."""
    mus = rng.normal(0, spread, size=(k, d))
    y = rng.integers(0, k, n)
    x = mus[y] + rng.normal(0, std, size=(n, d))
    return x.astype(np.float32), y.astype(np.int64), mus.astype(np.float32)


@pytest.fixture
def planted():
    r = np.random.default_rng(42)
    return planted_gmm_data(r)
