import numpy as np
import pytest

# NOTE: do NOT set XLA_FLAGS / device-count overrides here — smoke tests and
# benches must see the single real CPU device. Only launch/dryrun.py forces
# the 512-device placeholder topology (in its own process).

# Property tests use hypothesis; this container is offline, so when the real
# library is absent we register the deterministic shim under the same module
# name before any test module runs its `from hypothesis import ...`.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_shim
    _hypothesis_shim.install()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def planted_gmm_data(rng, n=1500, d=4, k=3, spread=4.0, std=0.5,
                     min_sep_sigma=0.0):
    """Planted mixture + labels. ``min_sep_sigma`` resamples the component
    means until every pair is at least that many noise-sigmas apart (0
    disables the check and keeps draws bit-identical to legacy callers)."""
    mus = rng.normal(0, spread, size=(k, d))
    for attempt in range(1000):
        if not (min_sep_sigma > 0 and k > 1) or min(
                np.linalg.norm(mus[i] - mus[j])
                for i in range(k) for j in range(i + 1, k)) >= min_sep_sigma * std:
            break
        mus = rng.normal(0, spread, size=(k, d))
    else:
        raise ValueError(
            f"could not draw {k} means {min_sep_sigma} sigma apart with "
            f"spread={spread}, std={std} in 1000 attempts")
    y = rng.integers(0, k, n)
    x = mus[y] + rng.normal(0, std, size=(n, d))
    return x.astype(np.float32), y.astype(np.int64), mus.astype(np.float32)


@pytest.fixture(scope="session")
def planted():
    """Session-scoped: the arrays are read-only and identical shapes keep
    jit caches warm across test modules (recompilation dominated runtime).

    min_sep_sigma makes the "well-separated" promise real: seed 42's raw
    draw puts two means ~3.4 sigma apart, close enough that EM's recovery
    of the planted means is not identifiable (a latent flaw masked while
    this module failed at collection on the missing hypothesis import).
    """
    r = np.random.default_rng(42)
    arrays = planted_gmm_data(r, min_sep_sigma=8.0)
    for a in arrays:  # make the session-shared arrays actually read-only
        a.flags.writeable = False
    return arrays
