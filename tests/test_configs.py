"""Assigned-architecture config checks: every full config must carry the
EXACT dimensions from the assignment table (vocab padding documented)."""
import pytest

from repro.configs import get_citation, get_config, list_archs

# arch -> (L, d_model, H, kv, d_ff, vocab_as_assigned, citation)
ASSIGNED = {
    "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000, "2401.04088"),
    "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400, "2401.06066"),
    "yi-6b": (32, 4096, 32, 4, 11008, 64000, "2403.04652"),
    "gemma-7b": (28, 3072, 16, 16, 24576, 256000, "2403.08295"),
    "deepseek-67b": (95, 8192, 64, 8, 22016, 102400, "2401.02954"),
    "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000, "2402.19427"),
    "internvl2-26b": (48, 6144, 48, 8, 16384, 92553, "2404.16821"),
    "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544, "2403.17297"),
    "xlstm-350m": (24, 1024, 4, 4, 0, 50304, "2405.04517"),
    "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206, "2308.11596"),
}

# vocab padded up to a multiple of the 16-way model axis where needed
VOCAB_PAD = {"internvl2-26b": 92672, "seamless-m4t-medium": 256256}


def test_all_archs_registered():
    assert sorted(list_archs()) == sorted(ASSIGNED)


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_assigned_dimensions(arch):
    L, d, h, kv, ff, vocab, cite = ASSIGNED[arch]
    cfg = get_config(arch)
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == h
    assert cfg.n_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == VOCAB_PAD.get(arch, vocab)
    assert cite in get_citation(arch)


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_smoke_variant_reduced(arch):
    cfg = get_config(arch, "smoke")
    assert cfg.n_layers <= 3
    assert cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4


def test_family_specifics():
    mix = get_config("mixtral-8x7b")
    assert mix.moe.n_experts == 8 and mix.moe.top_k == 2
    assert mix.window == 4096 and mix.pattern == ("swa",)
    dsm = get_config("deepseek-moe-16b")
    assert dsm.moe.n_experts == 64 and dsm.moe.top_k == 6
    assert dsm.moe.n_shared == 2 and dsm.first_k_dense == 1
    rg = get_config("recurrentgemma-9b")
    assert rg.pattern == ("rglru", "rglru", "local_attn")
    assert rg.n_groups == 12 and rg.n_tail == 2  # 38 = 12*3 + 2
    xl = get_config("xlstm-350m")
    assert xl.pattern == ("mlstm", "slstm")
    sm = get_config("seamless-m4t-medium")
    assert sm.n_enc_layers == 12 and sm.frontend == "audio"
    vl = get_config("internvl2-26b")
    assert vl.frontend == "vision" and vl.n_prefix == 256
    gm = get_config("gemma-7b")
    assert gm.head_dim == 256 and gm.embed_scale


def test_head_counts_shardable():
    """Every attention arch must have H divisible by the 16-way model axis
    (the flat-head layout depends on it)."""
    for arch in sorted(ASSIGNED):
        cfg = get_config(arch)
        if any(t in ("attn", "swa", "local_attn")
               for t in cfg.pattern) or cfg.first_k_dense:
            assert cfg.n_heads % 16 == 0, arch
        assert cfg.vocab_size % 16 == 0, arch
