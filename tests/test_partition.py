"""Partitioning invariants (hypothesis property tests)."""
import numpy as np
from hypothesis import given, settings, strategies as hst

from repro.core.partition import (partition_dirichlet, partition_quantity)


def make_data(rng, n=600, d=3, n_classes=5):
    y = rng.integers(0, n_classes, n)
    x = rng.normal(0, 1, (n, d)).astype(np.float32) + y[:, None]
    return x, y.astype(np.int64)


@settings(max_examples=15, deadline=None)
@given(alpha=hst.floats(0.05, 10.0), n_clients=hst.integers(2, 12),
       seed=hst.integers(0, 10**6))
def test_dirichlet_conserves_data(alpha, n_clients, seed):
    rng = np.random.default_rng(seed)
    x, y = make_data(rng)
    s = partition_dirichlet(rng, x, y, n_clients, alpha)
    assert s.sizes.sum() == len(x)                      # no loss, no dup
    assert (s.mask.sum(axis=1) == s.sizes).all()        # mask consistent
    assert s.class_counts.sum() == len(x)
    # padded region is zero
    for c in range(n_clients):
        assert not s.data[c, int(s.sizes[c]):].any()


@settings(max_examples=15, deadline=None)
@given(alpha=hst.integers(1, 5), n_clients=hst.integers(2, 12),
       seed=hst.integers(0, 10**6))
def test_quantity_conserves_data(alpha, n_clients, seed):
    rng = np.random.default_rng(seed)
    x, y = make_data(rng)
    s = partition_quantity(rng, x, y, n_clients, alpha)
    assert s.sizes.sum() == len(x)
    assert (s.mask.sum(axis=1) == s.sizes).all()
    # each client has ~alpha classes; the coverage backstop may add extras
    # when alpha*n_clients < n_classes (data conservation), bounded by the
    # number of uncovered classes
    n_classes = s.class_counts.shape[1]
    max_extra = -(-n_classes // n_clients)  # ceil(M / C)
    assert ((s.class_counts > 0).sum(axis=1) <= alpha + max_extra).all()
    # every class is assigned somewhere (global distribution preserved)
    assert ((s.class_counts.sum(axis=0) > 0)).all()


def test_dirichlet_heterogeneity_increases_with_small_alpha():
    """Fig. 1 semantics: smaller alpha => a class concentrates on few
    clients. Measured by the mean max-share of a class on one client."""
    rng = np.random.default_rng(0)
    x, y = make_data(rng, n=4000, n_classes=8)
    shares = {}
    for alpha in (0.1, 100.0):
        s = partition_dirichlet(np.random.default_rng(1), x, y, 10, alpha)
        frac = s.class_counts / np.maximum(s.class_counts.sum(0, keepdims=True), 1)
        shares[alpha] = frac.max(axis=0).mean()
    assert shares[0.1] > shares[100.0] + 0.2, shares
