"""Metric tests: AUC-PR against hand-computed values + properties."""
import numpy as np
from hypothesis import given, settings, strategies as hst

from repro.core.metrics import auc_pr, precision_recall_curve


def test_perfect_separation():
    scores = np.array([0.1, 0.2, 0.3, 0.9, 0.95])
    labels = np.array([0, 0, 0, 1, 1])
    assert auc_pr(scores, labels) == 1.0


def test_worst_case_ranking():
    scores = np.array([0.9, 0.8, 0.1, 0.05])
    labels = np.array([0, 0, 1, 1])
    # positives ranked last: AP = (1/3)*(... ) computed by hand:
    # thresholds descending: after 3rd item recall=1/2 precision=1/3,
    # after 4th recall=1 precision=1/2 -> AP = .5*(1/3) + .5*(1/2)
    np.testing.assert_allclose(auc_pr(scores, labels), 0.5 / 3 + 0.25)


def test_random_scores_ap_near_prevalence():
    rng = np.random.default_rng(0)
    labels = (rng.uniform(size=20000) < 0.1).astype(int)
    scores = rng.uniform(size=20000)
    ap = auc_pr(scores, labels)
    assert abs(ap - 0.1) < 0.02


def test_ties_handled():
    scores = np.array([0.5, 0.5, 0.5, 0.5])
    labels = np.array([1, 0, 1, 0])
    ap = auc_pr(scores, labels)
    assert 0.0 < ap <= 1.0


def test_pr_curve_monotone_recall():
    rng = np.random.default_rng(1)
    scores = rng.normal(size=300)
    labels = (rng.uniform(size=300) < 0.3).astype(int)
    p, r, t = precision_recall_curve(scores, labels)
    assert (np.diff(r) >= -1e-12).all()
    assert r[0] == 0.0 and abs(r[-1] - 1.0) < 1e-12


@settings(max_examples=25, deadline=None)
@given(seed=hst.integers(0, 10**6), n=hst.integers(10, 300))
def test_auc_pr_bounds_property(seed, n):
    rng = np.random.default_rng(seed)
    scores = rng.normal(size=n)
    labels = rng.integers(0, 2, n)
    if labels.sum() == 0:
        labels[0] = 1
    ap = auc_pr(scores, labels)
    assert 0.0 <= ap <= 1.0


@settings(max_examples=15, deadline=None)
@given(seed=hst.integers(0, 10**6))
def test_shifting_anomaly_scores_up_improves_ap(seed):
    rng = np.random.default_rng(seed)
    n = 400
    labels = (rng.uniform(size=n) < 0.2).astype(int)
    if labels.sum() == 0:
        labels[0] = 1
    base = rng.normal(size=n)
    better = base + labels * 3.0  # push anomalies up the ranking
    assert auc_pr(better, labels) >= auc_pr(base, labels) - 1e-9
