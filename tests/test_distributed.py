"""Sharded federated runtime tests. These need >1 device, so they run in a
subprocess with a forced 8-device host platform (the main test process must
keep the single real device)."""
import json
import subprocess
import sys
import textwrap

import pytest

# end-to-end fits: multi-second EM training loops on CPU
pytestmark = pytest.mark.slow

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core import fit_gmm, partition, fedgengmm
    from repro.core.dem import fed_kmeans_centers
    from repro.distributed import (dem_sharded, fed_kmeans_sharded,
                                   fedem_sharded, fedgen_sharded)

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    mus = np.array([[0,0,0],[5,5,5],[-5,5,-5]], np.float32)
    y = rng.integers(0, 3, 4000)
    x = (mus[y] + rng.normal(0, .5, (4000,3))).astype(np.float32)
    split = partition(rng, x, y, 16, "dirichlet", 0.5)
    data = jnp.asarray(split.data); mask = jnp.asarray(split.mask)
    xj = jnp.asarray(x)

    out = {}
    res = fedgen_sharded(mesh, jax.random.key(0), data, mask, k=3,
                         k_global=3, h=60)
    out["fed_ll"] = float(res.global_gmm.score(xj))

    centers = fed_kmeans_centers(jax.random.key(1), split, 3)
    gmm, rounds = dem_sharded(mesh, jax.random.key(2), data, mask, 3,
                              centers)
    out["dem_ll"] = float(gmm.score(xj))
    out["dem_rounds"] = int(rounds)

    bench = fit_gmm(jax.random.key(3), xj, 3)
    out["central_ll"] = float(bench.gmm.score(xj))

    # single-process (unsharded) reference for parity
    fr = fedgengmm(jax.random.key(0), split, k_clients=3, k_global=3, h=60)
    out["fed_ll_ref"] = float(fr.global_gmm.score(xj))

    # the iterative baselines on the SAME driver, mesh as client backend
    fe = fedem_sharded(mesh, jax.random.key(4), data, mask, 3,
                       participation=0.5, local_epochs=2)
    out["fedem_ll"] = float(fe.global_gmm.score(xj))
    out["fedem_rounds"] = int(fe.n_rounds)
    out["fedem_uplink"] = int(fe.comm.uplink_floats)
    out["fedem_itemsize"] = int(fe.comm.itemsize)

    km = fed_kmeans_sharded(mesh, jax.random.key(5), data, mask, 3)
    out["km_rounds"] = int(km.n_rounds)
    out["km_uplink"] = int(km.comm.uplink_floats)
    c = np.asarray(km.centers)
    out["km_center_err"] = float(max(
        min(np.linalg.norm(c - m, axis=1)) for m in mus))
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def sharded_results():
    proc = subprocess.run([sys.executable, "-c", SCRIPT],
                          capture_output=True, text=True, timeout=900,
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                               "HOME": "/root"})
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_sharded_fedgen_close_to_centralized(sharded_results):
    r = sharded_results
    assert r["fed_ll"] > r["central_ll"] - 0.3, r


def test_sharded_dem_close_to_centralized(sharded_results):
    r = sharded_results
    assert r["dem_ll"] > r["central_ll"] - 0.3, r
    assert r["dem_rounds"] >= 2


def test_sharded_matches_single_process(sharded_results):
    """Mesh execution is a faithful implementation of the same algorithm."""
    r = sharded_results
    assert abs(r["fed_ll"] - r["fed_ll_ref"]) < 0.25, r


def test_sharded_fedem_fits_with_cohort_ledger(sharded_results):
    """FedEM under the mesh backend: partial participation still reaches
    a good fit, and the ledger is cohort-sized (8 of 16 clients per
    round, diag stats for k=3, d=3: 3 + 9 + 9 + 2 floats each)."""
    r = sharded_results
    assert r["fedem_ll"] > r["central_ll"] - 0.5, r
    # per-round cohort traffic + the one-shot fed-kmeans warm start the
    # whole population uplinks before round 0 (16 * (k*d + k) floats)
    assert r["fedem_uplink"] == \
        r["fedem_rounds"] * 8 * (3 + 9 + 9 + 2) + 16 * (9 + 3), r
    assert r["fedem_itemsize"] == 4


def test_sharded_fed_kmeans_recovers_centers(sharded_results):
    """FedKMeans under the mesh backend: per-center label stats psum'd
    per round (16 clients x (k + k*d + 1) floats), planted centers
    recovered. The post-rounds inertia rescore ships one extra scalar
    per client, once."""
    r = sharded_results
    assert r["km_center_err"] < 0.5, r
    # per-round label stats + the rescore scalar per client + the
    # fed-kmeans warm-start parameter uplink (16 * (k*d + k))
    assert r["km_uplink"] == \
        r["km_rounds"] * 16 * (3 + 9 + 1) + 16 + 16 * (9 + 3), r
