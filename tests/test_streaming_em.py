"""Parity tests for the backend-dispatching streaming EM engine.

Three equivalence claims, each load-bearing for the hot-path rewiring:
  1. the fused Pallas E-step (interpret mode on CPU) == reference E-step,
     including odd shapes that are not multiples of the kernel tile sizes;
  2. the chunked (lax.scan) E-step == full-batch E-step for any chunk size,
     including chunk sizes that do not divide N;
  3. full training runs (fit_gmm / the streaming GMMEstimator facade /
     fedgengmm / dem_sharded) are backend- and chunking-invariant.
Plus the regression test for train_locals_bic dropping covariance_type.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as hst

from repro.api import GMMEstimator
from repro.core.em import (e_step_stats, e_step_stats_chunked, fit_gmm,
                           init_from_kmeans, resolve_estep_backend)
from repro.core.fedgen import fedgengmm, train_locals_bic
from repro.core.gmm import GMM
from repro.core.partition import partition

from conftest import planted_gmm_data

# Deliberately awkward shapes: N, K, d not multiples of the kernel's tile
# sizes (block_n=512, lanes=128), plus degenerate K=1 / d=1.
ODD_SHAPES = [  # (N, d, K)
    (37, 3, 2),
    (129, 5, 7),
    (513, 11, 5),
    (1000, 24, 30),
    (61, 1, 1),
]


def random_diag_gmm(rng, k, d):
    return GMM(jnp.asarray(rng.dirichlet(np.ones(k)), jnp.float32),
               jnp.asarray(rng.normal(0, 2, (k, d)), jnp.float32),
               jnp.asarray(rng.uniform(0.1, 2.0, (k, d)), jnp.float32))


def assert_stats_close(a, b, rtol=1e-4, atol=1e-4):
    for name, u, v in zip(a._fields, a, b):
        np.testing.assert_allclose(np.asarray(u), np.asarray(v), rtol=rtol,
                                   atol=atol, err_msg=f"field {name}")


class TestBackendResolution:
    def test_full_covariance_always_reference(self):
        assert resolve_estep_backend("fused", is_diagonal=False) == "reference"
        assert resolve_estep_backend("auto", is_diagonal=False) == "reference"

    def test_auto_is_reference_off_tpu(self):
        if jax.default_backend() != "tpu":
            assert resolve_estep_backend("auto", True) == "reference"

    def test_invalid_backend_rejected(self):
        with pytest.raises(ValueError, match="estep_backend"):
            resolve_estep_backend("cuda", True)
        x = jnp.zeros((8, 2), jnp.float32)
        with pytest.raises(ValueError, match="estep_backend"):
            fit_gmm(jax.random.key(0), x, 1, estep_backend="typo")


class TestFusedVsReference:
    @pytest.mark.parametrize("n,d,k", ODD_SHAPES)
    def test_dispatch_parity_odd_shapes(self, n, d, k):
        rng = np.random.default_rng(n * 7 + d * 3 + k)
        gmm = random_diag_gmm(rng, k, d)
        x = jnp.asarray(rng.normal(0, 2, (n, d)), jnp.float32)
        w = jnp.asarray(rng.uniform(0, 1, n), jnp.float32)
        ref = e_step_stats(gmm, x, w, estep_backend="reference")
        fused = e_step_stats(gmm, x, w, estep_backend="fused")
        assert_stats_close(ref, fused, rtol=1e-4, atol=1e-4)

    def test_default_weights(self):
        rng = np.random.default_rng(0)
        gmm = random_diag_gmm(rng, 4, 6)
        x = jnp.asarray(rng.normal(0, 2, (321, 6)), jnp.float32)
        ref = e_step_stats(gmm, x, estep_backend="reference")
        fused = e_step_stats(gmm, x, estep_backend="fused")
        assert_stats_close(ref, fused)


class TestChunkedVsFullBatch:
    # includes dividing (250), non-dividing (333, 64), >N (2048) and 1
    @pytest.mark.parametrize("chunk_size", [1, 64, 250, 333, 999, 2048])
    def test_chunk_size_invariance(self, chunk_size):
        rng = np.random.default_rng(1)
        gmm = random_diag_gmm(rng, 5, 7)
        x = jnp.asarray(rng.normal(0, 2, (1000, 7)), jnp.float32)
        w = jnp.asarray(rng.uniform(0, 1, 1000), jnp.float32)
        full = e_step_stats(gmm, x, w, estep_backend="reference")
        chunked = e_step_stats_chunked(gmm, x, w, chunk_size=chunk_size,
                                       estep_backend="reference")
        assert_stats_close(full, chunked, rtol=1e-4, atol=2e-3)

    def test_full_covariance_chunked(self):
        rng = np.random.default_rng(2)
        k, d = 3, 4
        a = rng.normal(0, 1, (k, d, d))
        covs = (a @ np.transpose(a, (0, 2, 1)) + 0.7 * np.eye(d))
        gmm = GMM(jnp.asarray(rng.dirichlet(np.ones(k)), jnp.float32),
                  jnp.asarray(rng.normal(0, 2, (k, d)), jnp.float32),
                  jnp.asarray(covs, jnp.float32))
        x = jnp.asarray(rng.normal(0, 2, (700, d)), jnp.float32)
        full = e_step_stats(gmm, x)
        chunked = e_step_stats_chunked(gmm, x, chunk_size=128)
        assert chunked.s2.shape == (k, d, d)
        assert_stats_close(full, chunked, rtol=1e-4, atol=2e-3)

    def test_chunked_fused_backend(self):
        """Chunked accumulation composes with the fused kernel per chunk."""
        rng = np.random.default_rng(3)
        gmm = random_diag_gmm(rng, 3, 5)
        x = jnp.asarray(rng.normal(0, 2, (450, 5)), jnp.float32)
        full = e_step_stats(gmm, x, estep_backend="reference")
        chunked = e_step_stats_chunked(gmm, x, chunk_size=200,
                                       estep_backend="fused")
        assert_stats_close(full, chunked, rtol=1e-4, atol=2e-3)

    def test_rejects_bad_chunk_size(self):
        rng = np.random.default_rng(4)
        gmm = random_diag_gmm(rng, 2, 3)
        x = jnp.asarray(rng.normal(0, 1, (10, 3)), jnp.float32)
        with pytest.raises(ValueError, match="chunk_size"):
            e_step_stats_chunked(gmm, x, chunk_size=0)

    # width 2 divides the 8-chunk stack, 3 leaves a ragged super-chunk
    @pytest.mark.parametrize("scan_width", [2, 3, 8])
    def test_two_level_scan_matches_width_one(self, scan_width):
        """The 2-level scan (vmapped super-chunks) changes reduction
        *order*, not value: f32-rounding-level agreement with the serial
        width-1 scan, which stays the reproducibility default."""
        rng = np.random.default_rng(5)
        gmm = random_diag_gmm(rng, 5, 7)
        x = jnp.asarray(rng.normal(0, 2, (1000, 7)), jnp.float32)
        w = jnp.asarray(rng.uniform(0, 1, 1000), jnp.float32)
        serial = e_step_stats(gmm, x, w, estep_backend="reference",
                              chunk_size=128)
        wide = e_step_stats(gmm, x, w, estep_backend="reference",
                            chunk_size=128, scan_width=scan_width)
        assert_stats_close(serial, wide, rtol=1e-3, atol=1e-2)


@pytest.mark.slow
class TestEndToEndParity:
    def test_fit_gmm_fused_matches_reference(self, planted):
        x, _, _ = planted
        xj = jnp.asarray(x)
        init = init_from_kmeans(jax.random.key(0), xj, 3)
        ref = fit_gmm(jax.random.key(0), xj, 3, init_gmm=init,
                      estep_backend="reference")
        fused = fit_gmm(jax.random.key(0), xj, 3, init_gmm=init,
                        estep_backend="fused")
        assert abs(float(ref.log_likelihood) - float(fused.log_likelihood)) \
            < 1e-4
        np.testing.assert_allclose(np.asarray(ref.gmm.means),
                                   np.asarray(fused.gmm.means),
                                   rtol=1e-3, atol=1e-3)

    @pytest.mark.parametrize("chunk_size", [128, 500, 4096])
    def test_streaming_facade_matches_reference(self, planted, chunk_size):
        x, _, _ = planted
        xj = jnp.asarray(x)
        ref = fit_gmm(jax.random.key(0), xj, 3)
        stream = GMMEstimator(3, chunk_size=chunk_size,
                              backend="reference").fit(
            xj, key=jax.random.key(0)).result_
        assert abs(float(ref.log_likelihood) - float(stream.log_likelihood)) \
            < 1e-4
        np.testing.assert_allclose(np.asarray(ref.gmm.means),
                                   np.asarray(stream.gmm.means),
                                   rtol=1e-3, atol=1e-3)

    def test_streaming_facade_chunk_invariance(self, planted):
        """End-to-end invariance to chunk_size with the chunked init path:
        k-means, label stats and EM all stream, and any two chunkings
        agree up to float-summation reordering."""
        x, _, _ = planted
        xj = jnp.asarray(x)
        a = GMMEstimator(3, chunk_size=128).fit(
            xj, key=jax.random.key(5)).result_
        b = GMMEstimator(3, chunk_size=1024).fit(
            xj, key=jax.random.key(5)).result_
        assert abs(float(a.log_likelihood) - float(b.log_likelihood)) < 1e-4
        np.testing.assert_allclose(np.asarray(a.gmm.means),
                                   np.asarray(b.gmm.means),
                                   rtol=1e-3, atol=1e-3)

    def test_fedgengmm_chunked_runs(self):
        x, y, _ = planted_gmm_data(np.random.default_rng(6), n=900, d=3, k=3,
                                   spread=6.0, std=0.5, min_sep_sigma=8.0)
        split = partition(np.random.default_rng(0), x, y, 3, "dirichlet", 5.0)
        full = fedgengmm(jax.random.key(0), split, k_clients=3, k_global=3,
                         h=30)
        chunked = fedgengmm(jax.random.key(0), split, k_clients=3, k_global=3,
                            h=30, chunk_size=100, estep_backend="reference")
        ll_full = float(full.global_gmm.score(jnp.asarray(x)))
        ll_chunk = float(chunked.global_gmm.score(jnp.asarray(x)))
        assert abs(ll_full - ll_chunk) < 5e-2, (ll_full, ll_chunk)

    def test_dem_chunked_matches(self):
        from repro.core import dem
        x, y, _ = planted_gmm_data(np.random.default_rng(7), n=800, d=3, k=3,
                                   spread=6.0, std=0.5, min_sep_sigma=8.0)
        split = partition(np.random.default_rng(4), x, y, 4, "dirichlet", 1.0)
        full = dem(jax.random.key(0), split, 3, init=3)
        chunked = dem(jax.random.key(0), split, 3, init=3, chunk_size=128,
                      estep_backend="reference")
        assert int(full.n_rounds) == int(chunked.n_rounds)
        np.testing.assert_allclose(np.asarray(full.global_gmm.means),
                                   np.asarray(chunked.global_gmm.means),
                                   rtol=1e-4, atol=1e-4)

    def test_dem_sharded_chunked_matches(self):
        from repro.core.dem import fed_kmeans_centers
        from repro.distributed import dem_sharded
        mesh = jax.make_mesh((1,), ("data",))
        x, y, _ = planted_gmm_data(np.random.default_rng(8), n=800, d=3, k=3,
                                   spread=6.0, std=0.5, min_sep_sigma=8.0)
        split = partition(np.random.default_rng(1), x, y, 4, "dirichlet", 1.0)
        data, mask = jnp.asarray(split.data), jnp.asarray(split.mask)
        centers = fed_kmeans_centers(jax.random.key(1), split, 3)
        g_full, r_full = dem_sharded(mesh, jax.random.key(2), data, mask, 3,
                                     centers)
        g_chunk, r_chunk = dem_sharded(mesh, jax.random.key(2), data, mask, 3,
                                       centers, chunk_size=96)
        assert int(r_full) == int(r_chunk)
        np.testing.assert_allclose(np.asarray(g_full.means),
                                   np.asarray(g_chunk.means),
                                   rtol=1e-4, atol=1e-4)


class TestTrainLocalsBicCovarianceType:
    """Regression: train_locals_bic used to drop covariance_type, silently
    training diagonal local models on the heterogeneous-K path."""

    @pytest.mark.slow
    def test_covariance_type_threaded(self):
        x, y, _ = planted_gmm_data(np.random.default_rng(9), n=600, d=3, k=2,
                                   spread=5.0, std=0.5, min_sep_sigma=8.0)
        split = partition(np.random.default_rng(2), x, y, 2, "dirichlet", 5.0)
        results = train_locals_bic(jax.random.key(0), split, [2],
                                   max_iter=30, covariance_type="full")
        for r in results:
            assert not r.gmm.is_diagonal, "full covariance was dropped"
            assert r.gmm.covs.shape[-1] == r.gmm.covs.shape[-2] == 3

    @pytest.mark.slow
    def test_fedgengmm_full_covariance_locals(self):
        x, y, _ = planted_gmm_data(np.random.default_rng(10), n=600, d=3, k=2,
                                   spread=5.0, std=0.5, min_sep_sigma=8.0)
        split = partition(np.random.default_rng(3), x, y, 2, "dirichlet", 5.0)
        fr = fedgengmm(jax.random.key(0), split, k_candidates=[2], k_global=2,
                       h=30, max_iter=30, covariance_type="full")
        assert all(not g.is_diagonal for g in fr.local_gmms)
        assert not fr.global_gmm.is_diagonal


@settings(max_examples=8, deadline=None)
@given(n=hst.integers(16, 400), k=hst.integers(1, 9),
       chunk=hst.integers(1, 450), seed=hst.integers(0, 10**6))
def test_chunked_equivalence_property(n, k, chunk, seed):
    """Chunk-sum == batch-sum for arbitrary (n, k, chunk_size)."""
    rng = np.random.default_rng(seed)
    gmm = random_diag_gmm(rng, k, 3)
    x = jnp.asarray(rng.normal(0, 2, (n, 3)), jnp.float32)
    full = e_step_stats(gmm, x, estep_backend="reference")
    chunked = e_step_stats_chunked(gmm, x, chunk_size=chunk,
                                   estep_backend="reference")
    assert_stats_close(full, chunked, rtol=1e-3, atol=2e-3)
