"""Split-merge EM alternative local trainer (the paper's §4.1 modularity
claim, demonstrated)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregate, fit_gmm
from repro.core.splitmerge import split_merge_fit
from conftest import planted_gmm_data

# end-to-end fits: multi-second EM training loops on CPU
pytestmark = pytest.mark.slow


def test_split_merge_never_worse():
    x, _, _ = planted_gmm_data(np.random.default_rng(3), n=2000, k=4,
                               spread=5.0, std=0.5)
    xj = jnp.asarray(x)
    base = fit_gmm(jax.random.key(0), xj, 4)
    sm = split_merge_fit(jax.random.key(0), xj, 4)
    assert float(sm.log_likelihood) >= float(base.log_likelihood) - 1e-5


def test_split_merge_escapes_bad_init():
    """Construct a hard case: overlapping + one tiny far cluster; split-merge
    should match or beat standard EM across seeds on average."""
    rng = np.random.default_rng(11)
    a = rng.normal([0, 0], 0.4, (900, 2))
    b = rng.normal([1.2, 0], 0.4, (900, 2))
    c = rng.normal([8, 8], 0.3, (60, 2))
    x = jnp.asarray(np.concatenate([a, b, c]), jnp.float32)
    base_ll, sm_ll = [], []
    for s in range(4):
        base_ll.append(float(fit_gmm(jax.random.key(s), x, 3)
                             .log_likelihood))
        sm_ll.append(float(split_merge_fit(jax.random.key(s), x, 3)
                           .log_likelihood))
    assert np.mean(sm_ll) >= np.mean(base_ll) - 1e-6


def test_drop_in_for_federated_local_training():
    """The modularity claim: split-merge locals feed the unchanged
    aggregation path."""
    x, y, _ = planted_gmm_data(np.random.default_rng(5), n=1600, k=3)
    from repro.core.partition import partition
    split = partition(np.random.default_rng(0), x, y, 4, "dirichlet", 0.5)
    gmms, sizes = [], []
    for c in range(4):
        n = int(split.sizes[c])
        res = split_merge_fit(jax.random.key(c),
                              jnp.asarray(split.data[c][:n]), 3)
        gmms.append(res.gmm)
        sizes.append(n)
    res, _ = aggregate(jax.random.key(9), gmms, jnp.asarray(sizes,
                                                            jnp.float32),
                       h=50, k_global=3)
    xj = jnp.asarray(x)
    bench = fit_gmm(jax.random.key(10), xj, 3)
    assert float(res.gmm.score(xj)) > float(bench.gmm.score(xj)) - 0.4
