"""Continual one-shot FL tests (beyond-paper extension of the paper's
stated future work)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fit_gmm, partition
from repro.core.continual import continual_round, init_state

# end-to-end fits: multi-second EM training loops on CPU
pytestmark = pytest.mark.slow


def make_window(rng, mus, active, n=900):
    """Data drawn only from the ``active`` subset of components."""
    y = rng.choice(active, size=n)
    x = (mus[y] + rng.normal(0, 0.5, (n, mus.shape[1]))).astype(np.float32)
    return x, y.astype(np.int64)


@pytest.fixture(scope="module")
def drift_setup():
    rng = np.random.default_rng(0)
    mus = rng.normal(0, 6, (4, 4)).astype(np.float32)
    return rng, mus


def run_windows(rng, mus, actives, memory, k_clients=3, h=50):
    state = init_state()
    for i, active in enumerate(actives):
        x, y = make_window(rng, mus, active)
        split = partition(np.random.default_rng(i), x, y, 4, "dirichlet",
                          1.0)
        state = continual_round(
            jax.random.key(i), state, jnp.asarray(split.data),
            jnp.asarray(split.mask), split.sizes, k_clients=k_clients,
            k_global=4, h=h, memory=memory)
    return state


def test_one_round_per_window(drift_setup):
    rng, mus = drift_setup
    state = run_windows(rng, mus, [[0, 1], [2, 3]], memory=0.5)
    assert state.rounds_total == 2 and state.window == 2


def test_memory_retains_old_modes(drift_setup):
    """After drift from modes {0,1} to {2,3}, memory>0 must keep the old
    modes in the global model; memory=0 (stateless) forgets them."""
    rng, mus = drift_setup
    old_data = jnp.asarray(
        make_window(np.random.default_rng(7), mus, [0, 1])[0])

    remember = run_windows(np.random.default_rng(1), mus,
                           [[0, 1], [2, 3], [2, 3]], memory=0.6)
    forget = run_windows(np.random.default_rng(1), mus,
                         [[0, 1], [2, 3], [2, 3]], memory=0.0)
    ll_mem = float(remember.global_gmm.score(old_data))
    ll_forget = float(forget.global_gmm.score(old_data))
    assert ll_mem > ll_forget + 2.0, (ll_mem, ll_forget)


def test_stationary_converges_to_batch(drift_setup):
    """On a stationary stream the continual model approaches the batch
    (all-data, centralized) fit."""
    rng, mus = drift_setup
    # local models must be able to represent all active modes
    # (k_clients=4); under-parameterized locals (k=3) compound a ~2-nat
    # gap through re-aggregation — a useful negative result, see module
    state = run_windows(np.random.default_rng(2), mus,
                        [[0, 1, 2, 3]] * 3, memory=0.5, k_clients=4, h=80)
    x_all = jnp.asarray(
        make_window(np.random.default_rng(9), mus, [0, 1, 2, 3], n=3000)[0])
    bench = fit_gmm(jax.random.key(9), x_all, 4)
    ll_cont = float(state.global_gmm.score(x_all))
    ll_batch = float(bench.gmm.score(x_all))
    assert ll_cont > ll_batch - 0.5, (ll_cont, ll_batch)
