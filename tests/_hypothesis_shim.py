"""Minimal offline stand-in for the ``hypothesis`` property-testing API.

This container has no network access, so the real library cannot be
installed. The shim implements the small surface our test suite uses —
``given``, ``settings``, ``assume`` and the ``strategies`` combinators —
backed by *deterministic* seeded draws: each test function gets its own
RNG seeded from its qualified name, so runs are reproducible and failures
are replayable, at the cost of hypothesis' adaptive shrinking.

``install()`` registers the shim as the ``hypothesis`` /
``hypothesis.strategies`` modules in ``sys.modules``; ``tests/conftest.py``
calls it only when the real library is missing, so an environment that does
have hypothesis uses the real thing untouched.
"""
from __future__ import annotations

import inspect
import random
import sys
import types
import zlib

__all__ = ["given", "settings", "assume", "HealthCheck", "install",
           "strategies"]

DEFAULT_MAX_EXAMPLES = 25


class UnsatisfiedAssumption(Exception):
    """Raised by assume(False); the current example is silently skipped."""


def assume(condition) -> bool:
    if not condition:
        raise UnsatisfiedAssumption()
    return True


class HealthCheck:
    """Accept-anything placeholder for settings(suppress_health_check=...)."""
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"
    function_scoped_fixture = "function_scoped_fixture"


# ----------------------------------------------------------------------
# Strategies
# ----------------------------------------------------------------------

class SearchStrategy:
    """A strategy is just a named wrapper around draw(rng) -> value."""

    def __init__(self, draw, name="strategy"):
        self._draw = draw
        self._name = name

    def example(self, rng: random.Random):
        return self._draw(rng)

    def map(self, fn):
        return SearchStrategy(lambda rng: fn(self._draw(rng)),
                              f"{self._name}.map")

    def filter(self, pred, max_tries: int = 100):
        def draw(rng):
            for _ in range(max_tries):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise UnsatisfiedAssumption(f"filter on {self._name} never held")
        return SearchStrategy(draw, f"{self._name}.filter")

    def __repr__(self):
        return f"<shim {self._name}>"


def integers(min_value=None, max_value=None) -> SearchStrategy:
    lo = -(2 ** 31) if min_value is None else int(min_value)
    hi = 2 ** 31 if max_value is None else int(max_value)
    return SearchStrategy(lambda rng: rng.randint(lo, hi),
                          f"integers({lo}, {hi})")


def floats(min_value=None, max_value=None, allow_nan=False,
           allow_infinity=False, width=64) -> SearchStrategy:
    lo = -1e9 if min_value is None else float(min_value)
    hi = 1e9 if max_value is None else float(max_value)

    def draw(rng):
        # Edge values matter more than the bulk for property tests: hit the
        # endpoints with small probability instead of only sampling uniform.
        r = rng.random()
        if r < 0.05:
            return lo
        if r < 0.10:
            return hi
        return rng.uniform(lo, hi)

    return SearchStrategy(draw, f"floats({lo}, {hi})")


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.random() < 0.5, "booleans()")


def sampled_from(elements) -> SearchStrategy:
    elements = list(elements)
    if not elements:
        raise ValueError("sampled_from requires a non-empty collection")
    return SearchStrategy(lambda rng: elements[rng.randrange(len(elements))],
                          f"sampled_from(<{len(elements)}>)")


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng: value, f"just({value!r})")


def one_of(*strats) -> SearchStrategy:
    flat = []
    for s in strats:  # hypothesis accepts one_of([a, b]) and one_of(a, b)
        flat.extend(s if isinstance(s, (list, tuple)) else [s])
    return SearchStrategy(
        lambda rng: flat[rng.randrange(len(flat))].example(rng),
        f"one_of(<{len(flat)}>)")


def lists(elements: SearchStrategy, min_size=0, max_size=None) -> SearchStrategy:
    hi = (min_size + 10) if max_size is None else max_size
    return SearchStrategy(
        lambda rng: [elements.example(rng)
                     for _ in range(rng.randint(min_size, hi))],
        f"lists({elements._name})")


def tuples(*strats) -> SearchStrategy:
    return SearchStrategy(lambda rng: tuple(s.example(rng) for s in strats),
                          "tuples")


# ----------------------------------------------------------------------
# given / settings
# ----------------------------------------------------------------------

class settings:
    """Decorator recording (max_examples,); everything else is accepted and
    ignored — deadlines and health checks have no meaning for seeded draws."""

    def __init__(self, max_examples: int = DEFAULT_MAX_EXAMPLES,
                 deadline=None, derandomize=False, **_ignored):
        self.max_examples = int(max_examples)
        self.deadline = deadline

    def __call__(self, fn):
        fn._shim_settings = self
        return fn

    # no-op profile API, for conftests that configure the real library
    _profiles: dict = {}

    @classmethod
    def register_profile(cls, name, profile=None, **kwargs):
        cls._profiles[name] = profile or kwargs

    @classmethod
    def load_profile(cls, name):
        pass


def given(*arg_strategies, **kw_strategies):
    """Keyword-strategy ``@given``: runs the wrapped test once per example
    with deterministic draws (seed = crc32 of the test's qualified name)."""
    if arg_strategies:
        raise TypeError(
            "the offline hypothesis shim supports keyword strategies only, "
            "e.g. @given(k=st.integers(1, 5))")
    for name, strat in kw_strategies.items():
        if not isinstance(strat, SearchStrategy):
            raise TypeError(f"{name}={strat!r} is not a shim strategy")

    def decorate(fn):
        sig = inspect.signature(fn)
        unknown = set(kw_strategies) - set(sig.parameters)
        if unknown:
            raise TypeError(f"@given strategies {sorted(unknown)} do not "
                            f"match parameters of {fn.__name__}")

        def wrapper(*args, **kwargs):
            # @settings may sit above @given (tags the wrapper) or below it
            # (tags the inner fn); honor both like real hypothesis does
            s = (getattr(wrapper, "_shim_settings", None)
                 or getattr(fn, "_shim_settings", None))
            n = s.max_examples if s is not None else DEFAULT_MAX_EXAMPLES
            seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())
            rng = random.Random(seed)
            ran = 0
            for _ in range(n * 5):  # head-room for assume() rejections
                if ran >= n:
                    break
                drawn = {name: strat.example(rng)
                         for name, strat in kw_strategies.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except UnsatisfiedAssumption:
                    continue
                except Exception as e:
                    raise AssertionError(
                        f"property falsified on example {drawn!r}: {e}"
                    ) from e
                ran += 1
            if ran == 0:
                # mirror hypothesis' FailedHealthCheck: a test whose every
                # example was rejected must not silently pass
                raise AssertionError(
                    f"{fn.__qualname__}: assume()/filter rejected all "
                    f"{n * 5} drawn examples; property was never checked")

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        if hasattr(fn, "pytestmark"):  # marks applied below @given
            wrapper.pytestmark = fn.pytestmark
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        # Hide the strategy parameters from pytest so it doesn't look for
        # fixtures named after them; remaining parameters stay visible.
        remaining = [p for name, p in sig.parameters.items()
                     if name not in kw_strategies]
        wrapper.__signature__ = sig.replace(parameters=remaining)
        return wrapper

    return decorate


# ----------------------------------------------------------------------
# Module registration
# ----------------------------------------------------------------------

def install():
    """Register the shim as ``hypothesis`` (+``.strategies``) in sys.modules.
    Idempotent; returns the module object."""
    if "hypothesis" in sys.modules:
        return sys.modules["hypothesis"]
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "just",
                 "one_of", "lists", "tuples", "SearchStrategy"):
        setattr(st, name, globals()[name])
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.HealthCheck = HealthCheck
    hyp.strategies = st
    hyp.__version__ = "0.0.0-offline-shim"
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
    return hyp


strategies = sys.modules[__name__]  # allow `from _hypothesis_shim import strategies`
