"""The federation runtime (DESIGN.md §9): bit-identity of the re-landed
strategies against frozen pre-refactor round loops, the FedEM/FedKMeans
baselines on every client backend, and the dtype-aware comm ledger.

The bit-identity classes carry verbatim copies of the PRE-§9 round loops
(the fused ``_dem_loop`` while_loop and the ``host_em_loop`` source path)
as frozen references: the runtime's generic driver must reproduce them to
the bit, so results are compared with ``assert_array_equal``, never
``allclose``.
"""
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import DEM, FedEM, FedKMeans, FitConfig, fit_federated
from repro.core.dem import dem, dem_cfg, max_separated_centers
from repro.core.em import (SufficientStats, e_step_stats, host_em_loop,
                           init_from_means, m_step)
from repro.core.fedgen import (aggregate_cfg, fedgengmm_cfg,
                               train_locals_cfg, train_locals_sources_cfg)
from repro.core.gmm import GMM
from repro.core.kmeans import kmeans
from repro.core.partition import partition
from repro.fed import (ArrivalStragglers, CommStats, CyclicSampler,
                       RoundPayload, UniformSampler, label_payload_floats,
                       make_backend, make_sampler, run_rounds,
                       stats_payload_floats)
from repro.fed.strategies import FedEMStrategy
from repro.data.sources import ArraySource, ConcatSource
from conftest import planted_gmm_data

# end-to-end fits: multi-second EM training loops on CPU
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(21)
    x, y, mus = planted_gmm_data(rng, n=1800, d=4, k=3, spread=5.0, std=0.5,
                                 min_sep_sigma=8.0)
    return x, y, mus


@pytest.fixture(scope="module")
def split(data):
    x, y, _ = data
    return partition(np.random.default_rng(0), x, y, 6, "dirichlet", 0.5)


@pytest.fixture(scope="module")
def shards(data):
    x, _, _ = data
    xj = jnp.asarray(x)
    return [ArraySource(xj[:600]), ArraySource(xj[600:1300]),
            ArraySource(xj[1300:])]


def assert_same_gmm(g1, g2):
    for f in ("weights", "means", "covs"):
        np.testing.assert_array_equal(np.asarray(getattr(g1, f)),
                                      np.asarray(getattr(g2, f)))


# ----------------------------------------------------------------------
# Frozen pre-refactor DEM loops (verbatim copies of the PR-4-era code)
# ----------------------------------------------------------------------

@partial(jax.jit, static_argnames=("max_rounds", "estep_backend",
                                   "chunk_size"))
def _dem_loop_frozen(gmm0, data, mask, tol, reg_covar, max_rounds,
                     estep_backend="auto", chunk_size=None):
    def global_stats(gmm):
        per = jax.vmap(
            lambda x, w: e_step_stats(gmm, x, w, estep_backend, chunk_size))(
            data, mask)
        return jax.tree.map(lambda s: jnp.sum(s, axis=0), per)

    def cond(state):
        _, prev_ll, ll, it = state
        return jnp.logical_and(it < max_rounds, jnp.abs(ll - prev_ll) > tol)

    def body(state):
        gmm, _, ll, it = state
        stats = global_stats(gmm)
        new_gmm = m_step(stats, reg_covar)
        new_ll = stats.loglik / jnp.maximum(stats.wsum, 1e-12)
        return new_gmm, ll, new_ll, it + 1

    stats0 = global_stats(gmm0)
    gmm1 = m_step(stats0, reg_covar)
    ll0 = stats0.loglik / jnp.maximum(stats0.wsum, 1e-12)
    neg_inf = jnp.array(-jnp.inf, data.dtype)
    state = (gmm1, neg_inf, ll0, jnp.array(1))
    gmm, prev_ll, ll, rounds = jax.lax.while_loop(cond, body, state)
    converged = jnp.abs(ll - prev_ll) <= tol
    return gmm, ll, rounds, converged


def _dem_split_frozen(key, split, k, covariance_type="diag", tol=1e-3,
                      max_rounds=200, reg=1e-6):
    """Pre-refactor `_dem_split_cfg` with the 'separated' init."""
    data = jnp.asarray(split.data)
    mask = jnp.asarray(split.mask)
    d = data.shape[-1]
    k_init, _ = jax.random.split(key)
    centers = max_separated_centers(k_init, k, d)
    flat = data.reshape(-1, d)
    flat_w = mask.reshape(-1)
    gmm0 = init_from_means(centers, flat, flat_w,
                           covariance_type=covariance_type, reg_covar=reg)
    return _dem_loop_frozen(gmm0, data, mask, jnp.asarray(tol, data.dtype),
                            reg, max_rounds, "auto", None)


def _dem_sources_frozen(key, sources, k, tol=1e-3, max_rounds=200, reg=1e-6,
                        cs=65536):
    """Pre-refactor `_dem_sources_cfg` with the 'separated' init."""
    d = sources[0].dim
    k_init, _ = jax.random.split(key)
    centers = max_separated_centers(k_init, k, d)
    union = ConcatSource(sources)
    gmm0 = init_from_means(centers, union, covariance_type="diag",
                           reg_covar=reg, chunk_size=cs)

    def step(gmm):
        per = [e_step_stats(gmm, src, None, "auto", cs) for src in sources]
        stats = jax.tree.map(lambda *s: sum(s), *per)
        avg_ll = float(stats.loglik / jnp.maximum(stats.wsum, 1e-12))
        return m_step(stats, reg), avg_ll

    return host_em_loop(step, gmm0, tol, max_rounds)


class TestDEMBitIdentity:
    """dem_cfg through run_rounds == the pre-refactor loops, to the bit."""

    def test_split_matches_frozen_loop(self, split):
        g_ref, ll_ref, r_ref, c_ref = _dem_split_frozen(
            jax.random.key(4), split, 3)
        dr = dem(jax.random.key(4), split, 3, init=1)
        assert_same_gmm(g_ref, dr.global_gmm)
        np.testing.assert_array_equal(np.asarray(ll_ref),
                                      np.asarray(dr.log_likelihood))
        assert int(r_ref) == int(dr.n_rounds)
        assert bool(c_ref) == bool(dr.converged)

    def test_split_full_covariance_matches_frozen_loop(self, split):
        g_ref, ll_ref, r_ref, _ = _dem_split_frozen(
            jax.random.key(5), split, 2, covariance_type="full",
            max_rounds=25)
        dr = DEM(2, init="separated", covariance_type="full",
                 max_iter=25).run(split, key=jax.random.key(5))
        assert_same_gmm(g_ref, dr.global_gmm)
        assert int(r_ref) == int(dr.n_rounds)

    def test_sources_match_frozen_host_loop(self, shards):
        g_ref, ll_ref, r_ref, c_ref = _dem_sources_frozen(
            jax.random.key(7), shards, 3)
        dr = dem_cfg(jax.random.key(7), shards, FitConfig(init="separated"),
                     3)
        assert_same_gmm(g_ref, dr.global_gmm)
        np.testing.assert_array_equal(np.asarray(ll_ref),
                                      np.asarray(dr.log_likelihood))
        assert int(r_ref) == int(dr.n_rounds)
        assert bool(c_ref) == bool(dr.converged)


class TestFedGenBitIdentity:
    """fedgengmm_cfg through run_rounds == the pre-refactor composition
    (same key splits, same building blocks, same order)."""

    def test_split(self, split):
        cfg = FitConfig()
        key = jax.random.key(3)
        k_local, k_agg = jax.random.split(key)
        stacked, lls, _ = train_locals_cfg(
            k_local, jnp.asarray(split.data), jnp.asarray(split.mask), 3,
            cfg)
        local_gmms = [GMM(stacked.weights[i], stacked.means[i],
                          stacked.covs[i])
                      for i in range(split.data.shape[0])]
        res, synth = aggregate_cfg(k_agg, local_gmms, split.sizes, cfg,
                                   h=40, k_global=3, synthetic="resident")
        fr = fedgengmm_cfg(key, split, cfg, k_clients=3, k_global=3, h=40)
        assert_same_gmm(res.gmm, fr.global_gmm)
        np.testing.assert_array_equal(np.asarray(synth),
                                      np.asarray(fr.synthetic))
        assert fr.comm.rounds == 1

    def test_sources(self, shards):
        cfg = FitConfig()
        key = jax.random.key(9)
        k_local, k_agg = jax.random.split(key)
        local = train_locals_sources_cfg(k_local, shards, cfg, k=2)
        res, _ = aggregate_cfg(k_agg, [r.gmm for r in local],
                               [s.num_rows for s in shards], cfg, h=20,
                               k_global=2, synthetic="source")
        fr = fedgengmm_cfg(key, shards, cfg, k_clients=2, k_global=2, h=20)
        assert_same_gmm(res.gmm, fr.global_gmm)


# ----------------------------------------------------------------------
# FedEM: DEM generalized (Tian et al.)
# ----------------------------------------------------------------------

class TestFedEM:
    def test_default_knobs_reduce_to_dem_bitwise_split(self, split):
        dr = DEM(3, init="separated").run(split, key=jax.random.key(4))
        fr = FedEM(3, init="separated").run(split, key=jax.random.key(4))
        assert_same_gmm(dr.global_gmm, fr.global_gmm)
        np.testing.assert_array_equal(np.asarray(dr.log_likelihood),
                                      np.asarray(fr.log_likelihood))
        assert int(dr.n_rounds) == int(fr.n_rounds)
        assert dr.comm == fr.comm

    def test_default_knobs_reduce_to_dem_bitwise_sources(self, shards):
        dr = DEM(3, init="separated").run(shards, key=jax.random.key(5))
        fr = FedEM(3, init="separated").run(shards, key=jax.random.key(5))
        assert_same_gmm(dr.global_gmm, fr.global_gmm)
        assert dr.comm == fr.comm

    def test_partial_participation_ledger_is_cohort_sized(self, split):
        c, k, d = split.data.shape[0], 3, split.data.shape[-1]
        fr = FedEM(k, participation=0.5, local_epochs=2, init="separated",
                   max_iter=12).run(split, key=jax.random.key(6))
        m = max(1, round(0.5 * c))
        per_round = m * stats_payload_floats(k, d, True)
        assert fr.comm.uplink_floats == fr.comm.rounds * per_round
        # per-round downlink is cohort-sized too; the init broadcast
        # touches the whole population exactly once
        gmm_floats = k + k * d + k * d
        assert fr.comm.downlink_floats == \
            fr.comm.rounds * m * gmm_floats + c * gmm_floats
        assert fr.comm.rounds == int(fr.n_rounds)
        assert bool(jnp.all(jnp.isfinite(fr.global_gmm.means)))

    def test_partial_participation_converges_before_budget(self, split):
        """Regression: with participation < 1 the old convergence check
        compared consecutive rounds' log-likelihoods across *different*
        cohorts, so cohort-composition noise swamped the tol and every
        partial-participation run burned its full ``max_iter`` budget.
        The per-cohort history fix compares same-cohort log-likelihoods
        one cycle apart; on a well-separated planted mixture the run must
        now terminate well before the budget, converged."""
        fr = FedEM(3, participation=0.5, init="separated",
                   max_iter=60).run(split, key=jax.random.key(7))
        assert bool(fr.converged)
        assert int(fr.n_rounds) < 60

    def test_local_epochs_still_fit_well(self, data, split):
        """Local epochs change the trajectory, not the destination: the
        fit stays in the centralized ballpark."""
        x, _, _ = data
        fr = FedEM(3, local_epochs=3, init="separated",
                   max_iter=60).run(split, key=jax.random.key(8))
        dr = DEM(3, init="separated", max_iter=60).run(
            split, key=jax.random.key(8))
        xj = jnp.asarray(x)
        assert float(fr.global_gmm.score(xj)) > \
            float(dr.global_gmm.score(xj)) - 0.3

    def test_validation(self):
        with pytest.raises(ValueError, match="participation"):
            FedEM(3, participation=0.0)
        with pytest.raises(ValueError, match="participation"):
            FedEM(3, participation=1.5)
        with pytest.raises(ValueError, match="local_epochs"):
            FedEM(3, local_epochs=0)
        with pytest.raises(ValueError, match="single-model GMM init"):
            FedEM(3, init="kmeans")


# ----------------------------------------------------------------------
# FedKMeans: iterative federated Lloyd (Garst et al.)
# ----------------------------------------------------------------------

class TestFedKMeans:
    def test_recovers_planted_centers_split_and_sources(self, data, split,
                                                        shards):
        _, _, mus = data
        for clients in (split, shards):
            res = FedKMeans(3).run(clients, key=jax.random.key(6))
            c = np.asarray(res.centers)
            worst = max(min(np.linalg.norm(c - m, axis=1)) for m in mus)
            assert worst < 0.5, worst
            assert bool(res.converged)
            assert res.comm.rounds == int(res.n_rounds)

    def test_ledger_is_label_stats_sized(self, split):
        c, k, d = split.data.shape[0], 3, split.data.shape[-1]
        res = FedKMeans(k, init="separated", max_iter=50).run(
            split, key=jax.random.key(2))
        # + c: the post-rounds inertia rescore ships one scalar per client
        assert res.comm.uplink_floats == \
            res.comm.rounds * c * label_payload_floats(k, d) + c
        # + c·k·d: the round-0 center broadcast (init traffic rides the
        # ledger since the cohort-execution PR)
        assert res.comm.downlink_floats == \
            res.comm.rounds * c * k * d + c * k * d

    def test_warm_start_init_traffic_is_charged(self, split):
        """The fed-kmeans warm start used to ride the ledger for free;
        now it charges each client's k local centers + k sizes uplink on
        top of the separated-init baseline."""
        c, k, d = split.data.shape[0], 3, split.data.shape[-1]
        warm = FedKMeans(k, init="fed-kmeans", max_iter=50).run(
            split, key=jax.random.key(2))
        assert warm.comm.uplink_floats == \
            warm.comm.rounds * c * label_payload_floats(k, d) + c \
            + c * (k * d + k)

    def test_separated_init_iterates(self, split):
        """Cold-start centers need several rounds — the iterative rounds
        are real, not an artifact of the warm start."""
        res = FedKMeans(3, init="separated", max_iter=50).run(
            split, key=jax.random.key(2))
        assert int(res.n_rounds) >= 2

    def test_matches_centralized_kmeans_inertia(self, data, split):
        x, _, _ = data
        xj = jnp.asarray(x)
        res = FedKMeans(3).run(split, key=jax.random.key(3))
        bench = kmeans(jax.random.key(3), xj, 3)
        # the federated run never sees the union; compare inertia of its
        # centers scored on the union against the centralized fit
        from repro.core.kmeans import lloyd_round_stats
        _, _, fed_inertia = lloyd_round_stats(res.centers, xj)
        assert float(fed_inertia) < 1.1 * float(bench.inertia)

    def test_inertia_is_rescored_against_returned_centers(self, split):
        """Regression: ``FedKMeansResult.inertia`` used to be the
        *pre-update* inertia of the last round (each round scores the
        broadcast centers, then moves them), so it never described the
        returned centers. The post-rounds rescore pins it to a streamed
        sweep of the final centers — reproduced here client-by-client,
        exactly as the backend reduces it."""
        from repro.core.kmeans import lloyd_round_stats
        res = FedKMeans(3).run(split, key=jax.random.key(3))
        per = jax.vmap(
            lambda x, w: lloyd_round_stats(res.centers, x, w)[2])(
            split.data, split.mask)
        np.testing.assert_array_equal(np.asarray(res.inertia),
                                      np.asarray(jnp.sum(per)))

    def test_init_validation(self):
        with pytest.raises(ValueError, match="FedKMeans init"):
            FedKMeans(3, init="pilot")
        with pytest.raises(ValueError, match="FedKMeans init"):
            FedKMeans(3, init="kmeans")


# ----------------------------------------------------------------------
# The ledger (dtype-aware) and the runtime dispatch
# ----------------------------------------------------------------------

class TestCommLedger:
    def test_dem_full_covariance_uplink_pinned(self, split):
        """The PR-4 satellite debt: full-covariance DEM uplink accounting
        was threaded but never asserted. s2 is (K, d, d) on this path, so
        one client-round ships k + k·d + k·d² + 2 floats."""
        c, k, d = split.data.shape[0], 2, split.data.shape[-1]
        dr = DEM(k, init="separated", covariance_type="full",
                 max_iter=20).run(split, key=jax.random.key(1))
        per_round = k + k * d + k * d * d + 2
        assert dr.comm.uplink_floats == dr.comm.rounds * c * per_round
        # downlink broadcasts the full-covariance parameter block every
        # round plus once for the round-0 init model
        assert dr.comm.downlink_floats == \
            (dr.comm.rounds + 1) * c * (k + k * d + k * d * d)

    def test_dem_init_phase_traffic_pinned(self, split):
        """Init-phase accounting (the 'warm starts ride free' debt):
        fed-kmeans init adds each client's k·d local centers + k sizes
        to the uplink; every init scheme adds one population-wide model
        broadcast to the downlink."""
        c, k, d = split.data.shape[0], 3, split.data.shape[-1]
        sep = DEM(k, init="separated", max_iter=15).run(
            split, key=jax.random.key(1))
        warm = DEM(k, init="fed-kmeans", max_iter=15).run(
            split, key=jax.random.key(1))
        per_up = stats_payload_floats(k, d, True)
        assert sep.comm.uplink_floats == sep.comm.rounds * c * per_up
        assert warm.comm.uplink_floats == \
            warm.comm.rounds * c * per_up + c * (k * d + k)
        gmm_floats = k + k * d + k * d
        assert sep.comm.downlink_floats == \
            (sep.comm.rounds + 1) * c * gmm_floats

    def test_payload_bytes_and_total_mb_are_dtype_aware(self):
        s = CommStats(rounds=2, uplink_floats=1000, downlink_floats=500)
        assert s.itemsize == 4  # f32 default keeps old constructors valid
        assert s.payload_bytes == 1500 * 4
        assert s.total_mb == 1500 * 4 / 2**20
        s64 = CommStats(rounds=2, uplink_floats=1000, downlink_floats=500,
                        itemsize=8)
        assert s64.payload_bytes == 2 * s.payload_bytes

    def test_round_payload_totals(self):
        p = RoundPayload(uplink_floats=10, downlink_floats=4, itemsize=8)
        assert p.totals(3) == CommStats(3, 30, 12, 8)
        # once-per-run extras (rescore uplink, init-broadcast downlink)
        # are added exactly once, independent of the round count
        p2 = RoundPayload(uplink_floats=10, downlink_floats=4, itemsize=8,
                          extra_uplink_floats=7, extra_downlink_floats=9)
        assert p2.totals(3) == CommStats(3, 37, 21, 8)
        assert p2.totals(5) == CommStats(5, 57, 29, 8)

    def test_asymmetric_uplink_downlink_itemsizes(self):
        # int8-quantized uplink under a float32 broadcast: per-direction
        # overrides keep the byte accounting honest without touching the
        # float counts (the unit Table 4 compares)
        s = CommStats(rounds=2, uplink_floats=1000, downlink_floats=500,
                      itemsize=4, uplink_itemsize=1)
        assert s.uplink_bytes == 1000 * 1
        assert s.downlink_bytes == 500 * 4  # None -> inherit itemsize
        assert s.payload_bytes == 1000 + 2000
        p = RoundPayload(uplink_floats=10, downlink_floats=4,
                         uplink_itemsize=1, epsilon_per_round=0.5)
        t = p.totals(4)
        assert t.uplink_bytes == 40 * 1 and t.downlink_bytes == 16 * 4
        assert t.epsilon_spent == 2.0
        # pre-transform constructors keep their meaning (defaults None/0)
        assert CommStats(3, 30, 12, 8) == RoundPayload(10, 4, 8).totals(3)

    def test_run_ledgers_carry_f32_itemsize(self, split):
        dr = DEM(2, init="separated", max_iter=10).run(
            split, key=jax.random.key(0))
        assert dr.comm.itemsize == 4
        assert dr.comm.payload_bytes == \
            (dr.comm.uplink_floats + dr.comm.downlink_floats) * 4


class TestConvergencePredicates:
    def test_nan_halts_and_reports_not_converged(self):
        """The historical EM-loop semantics, kept through the refactor: a
        NaN convergence scalar makes BOTH predicates false, so the driver
        stops after one more round instead of spinning to max_rounds, and
        the run reports not-converged."""
        from repro.core.dem import DEMState, DEMStrategy
        from repro.fed.strategies import FedKMeansState, FedKMeansStrategy
        s = DEMStrategy(k=2)
        nan = float("nan")
        state = DEMState(gmm=None, prev_ll=-1.0, ll=nan, tol=1e-3,
                         reg_covar=1e-6)
        assert not s.keep_going(state)
        assert not s.converged(state)
        km = FedKMeansStrategy(k=2)
        km_state = FedKMeansState(centers=None, shift=nan, inertia=0.0,
                                  tol=1e-4)
        assert not km.keep_going(km_state)
        assert not km.converged(km_state)

    def test_strategy_level_validation(self):
        """Direct strategy construction (the fit_federated seam) is
        validated too, not just the facades."""
        from repro.fed.strategies import FedEMStrategy
        with pytest.raises(ValueError, match="n_clients"):
            FedEMStrategy(k=3, participation=0.5)  # window needs C
        with pytest.raises(ValueError, match="local_epochs"):
            FedEMStrategy(k=3, local_epochs=0)
        with pytest.raises(ValueError, match="participation"):
            FedEMStrategy(k=3, participation=2.0)


class TestRuntimeDispatch:
    def test_make_backend_rejects_junk(self, data):
        x, _, _ = data
        with pytest.raises(TypeError, match="federated clients"):
            make_backend(jnp.asarray(x))
        with pytest.raises(TypeError, match="federated clients"):
            make_backend([np.asarray(x[:10])])

    def test_backend_kinds(self, split, shards):
        assert make_backend(split).kind == "split"
        assert make_backend(shards).kind == "sources"

    def test_fit_federated_rejects_unknown_name(self, split):
        with pytest.raises(ValueError, match="unknown strategy"):
            fit_federated(split, strategy="fedavg", k=3)

    def test_fit_federated_rejects_non_strategy(self, split):
        with pytest.raises(TypeError, match="FederationStrategy"):
            fit_federated(split, strategy=object())

    def test_fit_federated_named_runs_match_facades(self, split):
        r1 = fit_federated(split, strategy="dem", k=3, init="separated",
                           max_iter=10, key=jax.random.key(0))
        r2 = DEM(3, init="separated", max_iter=10).run(
            split, key=jax.random.key(0))
        assert_same_gmm(r1.global_gmm, r2.global_gmm)

    def test_fit_federated_custom_strategy_instance(self, split):
        """A hand-built strategy instance runs directly on the driver —
        the seam scenario PRs plug into."""
        from repro.core.dem import DEMStrategy
        strat = DEMStrategy(k=2, init="separated", tol=1e-3)
        res = fit_federated(split, strategy=strat, max_rounds=10,
                            key=jax.random.key(0))
        assert bool(jnp.all(jnp.isfinite(res.global_gmm.means)))
        assert res.comm.rounds == int(res.n_rounds) <= 10

    def test_fit_federated_custom_strategy_takes_sampler(self, split):
        """The driver's cohort seam is reachable for custom strategies:
        any iterative strategy runs under a sampler unchanged, with the
        ledger resized to the cohort."""
        from repro.core.dem import DEMStrategy
        c = split.data.shape[0]
        strat = DEMStrategy(k=2, init="separated", tol=1e-3)
        res = fit_federated(split, strategy=strat, max_rounds=10,
                            sampler=CyclicSampler(c, 2),
                            key=jax.random.key(0))
        assert bool(jnp.all(jnp.isfinite(res.global_gmm.means)))
        k, d = 2, split.data.shape[-1]
        assert res.comm.uplink_floats == \
            res.comm.rounds * 2 * stats_payload_floats(k, d, True)


# ----------------------------------------------------------------------
# Cohort execution: sample-then-train (this PR's tentpole)
# ----------------------------------------------------------------------

@partial(dataclasses.dataclass, frozen=True)
class _ZeroMaskFedEM(FedEMStrategy):
    """Verbatim frozen copy of the PR-6 FedEM participation path:
    train-all + zero-mask (every client computes, non-members multiply
    their stats by 0; host-path non-members short-circuit to exact-zero
    stats). The cohort-execution rewrite must reproduce it to the bit."""

    def _zero_stats(self, gmm):
        dt = gmm.means.dtype
        return SufficientStats(jnp.zeros(gmm.weights.shape, dt),
                               jnp.zeros(gmm.means.shape, dt),
                               jnp.zeros(gmm.covs.shape, dt),
                               jnp.zeros((), dt), jnp.zeros((), dt))

    def local_step(self, state, x, w, idx):
        active = None
        if self.participation < 1.0:
            c, m = self.n_clients, self.cohort_size()
            start = (state.rnd * m) % c
            active = ((idx - start) % c) < m
            if self.host and not active:
                return self._zero_stats(state.gmm)
        gmm = state.gmm
        stats = e_step_stats(gmm, x, w, self.backend, self.chunk)
        for _ in range(self.local_epochs - 1):
            gmm = m_step(stats, state.reg_covar)
            stats = e_step_stats(gmm, x, w, self.backend, self.chunk)
        if active is not None and not self.host:
            stats = jax.tree.map(
                lambda s: s * jnp.asarray(active, s.dtype), stats)
        return stats


def _fedem_strategy(cls, k, cfg, sources, participation, local_epochs,
                    n_clients):
    from repro.core.dem import _resolve_init
    return cls(
        k=k, covariance_type=cfg.covariance_type, backend=cfg.backend,
        chunk=cfg.resolve_chunk(source=sources),
        init=_resolve_init(cfg.init, sources), host=sources,
        tol=cfg.resolve_tol("em"), reg_covar=cfg.reg_covar,
        participation=participation, local_epochs=local_epochs,
        n_clients=n_clients)


class TestCohortBitIdentity:
    """Cyclic-cohort FedEM (gather m, compute m, scatter-sum into C
    slots) == the PR-6 train-all + zero-mask path, to the bit, on both
    single-process backends. The scatter-sum reduction exists exactly
    for this: f32 addition is order-sensitive, and scattering the cohort
    payloads back into their population slots before the sum reproduces
    the historical summation tree."""

    def test_split_matches_zero_mask_frozen(self, split):
        cfg = FitConfig(max_iter=30)
        frozen = _fedem_strategy(_ZeroMaskFedEM, 3, cfg, False, 0.5, 2,
                                 split.data.shape[0])
        base = run_rounds(frozen, split, key=jax.random.key(4),
                          max_rounds=30)
        new = FedEM(3, participation=0.5, local_epochs=2,
                    max_iter=30).run(split, key=jax.random.key(4))
        assert_same_gmm(base.global_gmm, new.global_gmm)
        np.testing.assert_array_equal(np.asarray(base.log_likelihood),
                                      np.asarray(new.log_likelihood))
        assert int(base.n_rounds) == int(new.n_rounds)
        assert bool(base.converged) == bool(new.converged)

    def test_sources_match_zero_mask_frozen(self, shards):
        cfg = FitConfig(max_iter=12, init="separated")
        frozen = _fedem_strategy(_ZeroMaskFedEM, 3, cfg, True, 0.5, 2,
                                 len(shards))
        base = run_rounds(frozen, shards, key=jax.random.key(4),
                          max_rounds=12)
        new = FedEM(3, participation=0.5, local_epochs=2, init="separated",
                    max_iter=12).run(shards, key=jax.random.key(4))
        assert_same_gmm(base.global_gmm, new.global_gmm)
        assert int(base.n_rounds) == int(new.n_rounds)


class TestCohortSampler:
    def test_cyclic_is_the_historical_window(self):
        s = CyclicSampler(num_clients=10, cohort_size=4)
        key = jax.random.key(0)
        for rnd in range(7):
            got = np.asarray(s.cohort(key, rnd))
            start = (rnd * 4) % 10
            want = np.sort((start + np.arange(4)) % 10)
            np.testing.assert_array_equal(got, want)

    def test_cyclic_covers_every_client_within_a_cycle(self):
        s = CyclicSampler(num_clients=10, cohort_size=4)
        seen = set()
        for rnd in range(5):   # period = 10 / gcd(10, 4) = 5
            seen.update(np.asarray(s.cohort(jax.random.key(0), rnd)))
        assert seen == set(range(10))

    def test_uniform_is_sorted_unique_in_range_and_deterministic(self):
        s = UniformSampler(num_clients=50, cohort_size=8, seed=3)
        key = jax.random.key(3)
        cohorts = [np.asarray(s.cohort(key, rnd)) for rnd in range(6)]
        for c in cohorts:
            assert c.shape == (8,)
            assert len(set(c.tolist())) == 8
            assert (np.sort(c) == c).all()
            assert c.min() >= 0 and c.max() < 50
        again = [np.asarray(s.cohort(key, rnd)) for rnd in range(6)]
        for a, b in zip(cohorts, again):
            np.testing.assert_array_equal(a, b)
        # different rounds draw different cohorts (fold_in on rnd)
        assert any((a != b).any() for a, b in zip(cohorts[:-1], cohorts[1:]))

    def test_uniform_cohort_fedem_fits(self, data, split):
        x, _, _ = data
        fr = FedEM(3, participation=0.5, cohort="uniform", cohort_seed=5,
                   init="separated", max_iter=40).run(
            split, key=jax.random.key(6))
        assert float(fr.global_gmm.score(jnp.asarray(x))) > -8.0
        m = max(1, round(0.5 * split.data.shape[0]))
        k, d = 3, split.data.shape[-1]
        assert fr.comm.uplink_floats == \
            fr.comm.rounds * m * stats_payload_floats(k, d, True)

    def test_sampler_validation(self):
        with pytest.raises(ValueError, match="cohort_size"):
            CyclicSampler(num_clients=5, cohort_size=6)
        with pytest.raises(ValueError, match="cohort_size"):
            UniformSampler(num_clients=5, cohort_size=0)
        with pytest.raises(ValueError, match="cyclic"):
            make_sampler("random", 10, 2)
        with pytest.raises(ValueError, match="cohort"):
            FedEM(3, cohort="shuffled")

    def test_sampler_backend_size_mismatch_rejected(self, split):
        from repro.core.dem import DEMStrategy
        strat = DEMStrategy(k=2, init="separated")
        with pytest.raises(ValueError, match="sized for"):
            run_rounds(strat, split, key=jax.random.key(0), max_rounds=5,
                       sampler=CyclicSampler(split.data.shape[0] + 1, 2))

    def test_one_shot_rejects_sampler_and_stragglers(self, split):
        from repro.core.fedgen import FedGenStrategy
        strat = FedGenStrategy(config=FitConfig(), k_clients=2,
                               k_global=2, h=10)
        with pytest.raises(ValueError, match="one-shot"):
            run_rounds(strat, split, key=jax.random.key(0),
                       sampler=CyclicSampler(split.data.shape[0], 2))
        with pytest.raises(ValueError, match="one-shot"):
            run_rounds(strat, split, key=jax.random.key(0),
                       stragglers=ArrivalStragglers(0.5))


class TestStragglers:
    def test_drop_mask_keeps_exactly_n_keep(self):
        pol = ArrivalStragglers(drop_frac=0.3, seed=0)
        cohort = jnp.arange(10, dtype=jnp.int32)
        for rnd in range(5):
            mask = np.asarray(pol.drop_mask(jax.random.key(0), rnd, cohort))
            assert mask.shape == (10,)
            assert set(mask.tolist()) <= {0.0, 1.0}
            assert mask.sum() == pol.n_keep(10) == 7

    def test_at_least_one_survivor(self):
        pol = ArrivalStragglers(drop_frac=0.99)
        mask = np.asarray(pol.drop_mask(jax.random.key(0), 0,
                                        jnp.arange(3, dtype=jnp.int32)))
        assert mask.sum() >= 1

    def test_deterministic_and_keyed_by_client_id(self):
        pol = ArrivalStragglers(drop_frac=0.5, seed=2)
        key = jax.random.key(2)
        cohort = jnp.asarray([3, 7, 11, 20], jnp.int32)
        m1 = np.asarray(pol.drop_mask(key, 4, cohort))
        m2 = np.asarray(pol.drop_mask(key, 4, cohort))
        np.testing.assert_array_equal(m1, m2)

    def test_zero_drop_frac_is_a_bitwise_noop(self, split):
        """drop_frac=0 keeps everyone: weights are exact 1.0, and
        multiplying by 1.0 is an IEEE identity — the run must equal the
        no-policy run to the bit."""
        base = FedEM(3, participation=0.5, init="separated",
                     max_iter=20).run(split, key=jax.random.key(6))
        wired = FedEM(3, participation=0.5, init="separated", max_iter=20,
                      stragglers=ArrivalStragglers(0.0)).run(
            split, key=jax.random.key(6))
        assert_same_gmm(base.global_gmm, wired.global_gmm)
        assert int(base.n_rounds) == int(wired.n_rounds)

    def test_fedem_survives_drops_on_all_backends(self, data, split,
                                                  shards):
        """Dropping 1/3 of each cohort still fits: the M-step
        renormalizes by the surviving wsum (the reweight rule), and the
        host path skips dropped sources' E-steps entirely."""
        x, _, _ = data
        xj = jnp.asarray(x)
        pol = ArrivalStragglers(drop_frac=0.34, seed=7)
        for clients in (split, shards):
            fr = FedEM(3, participation=0.67, init="separated",
                       max_iter=40, stragglers=pol).run(
                clients, key=jax.random.key(8))
            assert bool(jnp.all(jnp.isfinite(fr.global_gmm.means)))
            assert float(fr.global_gmm.score(xj)) > -8.0

    def test_validation(self):
        with pytest.raises(ValueError, match="drop_frac"):
            ArrivalStragglers(drop_frac=1.0)
        with pytest.raises(ValueError, match="drop_frac"):
            ArrivalStragglers(drop_frac=-0.1)
