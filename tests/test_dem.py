"""DEM baseline tests: distributed stats aggregation == centralized EM,
all three inits converge."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dem, e_step_stats, fit_gmm, partition
from repro.core.dem import (fed_kmeans_centers, max_separated_centers,
                            pilot_subset_centers)
from repro.core.em import init_from_means
from conftest import planted_gmm_data

# end-to-end fits: multi-second EM training loops on CPU
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(21)
    x, y, _ = planted_gmm_data(rng, n=2400, d=4, k=3, spread=5.0, std=0.5)
    split = partition(np.random.default_rng(0), x, y, 6, "dirichlet", 0.5)
    return x, y, split


class TestDEMEquivalence:
    def test_distributed_estep_equals_centralized(self, setup):
        """sum of per-client sufficient stats == stats on the union —
        the correctness core of DEM (and of the sharded runtime psum)."""
        x, y, split = setup
        g = init_from_means(max_separated_centers(jax.random.key(0), 3, 4),
                            jnp.asarray(x))
        per = [e_step_stats(g, jnp.asarray(split.data[c]),
                            jnp.asarray(split.mask[c]))
               for c in range(split.data.shape[0])]
        agg = jax.tree.map(lambda *s: sum(s), *per)
        cen = e_step_stats(g, jnp.asarray(x))
        np.testing.assert_allclose(np.asarray(agg.s0), np.asarray(cen.s0),
                                   rtol=1e-3)
        np.testing.assert_allclose(np.asarray(agg.s1), np.asarray(cen.s1),
                                   rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(float(agg.loglik), float(cen.loglik),
                                   rtol=1e-4)

    def test_dem_matches_centralized_fit(self, setup):
        x, y, split = setup
        dr = dem(jax.random.key(0), split, 3, init=3)
        bench = fit_gmm(jax.random.key(1), jnp.asarray(x), 3)
        ll_dem = float(dr.global_gmm.score(jnp.asarray(x)))
        ll_cen = float(bench.gmm.score(jnp.asarray(x)))
        assert ll_dem > ll_cen - 0.3, (ll_dem, ll_cen)


class TestInits:
    @pytest.mark.parametrize("init", [1, 2, 3])
    def test_all_inits_converge(self, setup, init):
        x, y, split = setup
        dr = dem(jax.random.key(init), split, 3, init=init)
        assert bool(dr.converged)
        assert bool(jnp.all(jnp.isfinite(dr.global_gmm.means)))
        assert int(dr.n_rounds) >= 2  # iterative, unlike one-shot

    def test_max_separated_centers_spread(self):
        c = max_separated_centers(jax.random.key(0), 8, 5)
        assert c.shape == (8, 5)
        assert bool(jnp.all((c >= 0) & (c <= 1)))
        # pairwise distances all nonzero
        d2 = jnp.sum((c[:, None] - c[None]) ** 2, -1) + jnp.eye(8)
        assert float(d2.min()) > 1e-3

    def test_pilot_subset_ignores_padding(self, setup):
        x, y, split = setup
        centers = pilot_subset_centers(jax.random.key(0), split, 3)
        # all centers within data range (padding rows are zero but excluded)
        assert bool(jnp.all(jnp.isfinite(centers)))

    def test_fed_kmeans_centers_shape(self, setup):
        x, y, split = setup
        centers = fed_kmeans_centers(jax.random.key(0), split, 3)
        assert centers.shape == (3, 4)

    def test_comm_rounds_grow_with_iterations(self, setup):
        x, y, split = setup
        dr = dem(jax.random.key(0), split, 3, init=1)
        assert dr.comm.rounds == int(dr.n_rounds)
        assert dr.comm.uplink_floats > dr.comm.rounds  # per-round stats
