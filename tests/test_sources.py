"""DataSource layer unit tests (DESIGN.md §7): block contracts, re-chunking,
chunk-invariance of the synthetic stream, mmap round-trips, validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gmm import GMM
from repro.data.sources import (ArraySource, ConcatSource, DataSource,
                                NpyFileSource, ShuffledSource,
                                SyntheticGMMSource, as_source)


@pytest.fixture(scope="module")
def rows():
    return np.random.default_rng(0).normal(size=(1000, 5)).astype(np.float32)


def blocks_of(source, chunk):
    return [np.asarray(b) for b in source.iter_blocks(chunk)]


class TestArraySource:
    def test_protocol(self, rows):
        s = ArraySource(rows)
        assert (s.num_rows, s.dim, len(s)) == (1000, 5, 1000)
        assert s.dtype == jnp.float32

    def test_block_shapes_ragged_tail(self, rows):
        shapes = [b.shape for b in blocks_of(ArraySource(rows), 256)]
        assert shapes == [(256, 5)] * 3 + [(232, 5)]

    def test_materialize_round_trip(self, rows):
        np.testing.assert_array_equal(
            np.asarray(ArraySource(rows).materialize(256)), rows)

    def test_restartable(self, rows):
        s = ArraySource(rows)
        first, second = blocks_of(s, 300), blocks_of(s, 300)
        for a, b in zip(first, second):
            np.testing.assert_array_equal(a, b)

    def test_num_blocks(self, rows):
        s = ArraySource(rows)
        assert s.num_blocks(256) == 4
        assert s.num_blocks(1000) == 1
        assert s.num_blocks(7000) == 1

    def test_rejects_bad_shapes(self, rows):
        with pytest.raises(ValueError):
            ArraySource(rows[:, 0])
        with pytest.raises(ValueError):
            ArraySource(rows[:0])
        with pytest.raises(ValueError):
            list(ArraySource(rows).iter_blocks(0))

    def test_as_source(self, rows):
        assert isinstance(as_source(rows), ArraySource)
        s = ArraySource(rows)
        assert as_source(s) is s


class TestNpyFileSource:
    def test_mmap_round_trip(self, rows, tmp_path):
        path = tmp_path / "rows.npy"
        np.save(path, rows)
        s = NpyFileSource(path)
        assert (s.num_rows, s.dim) == rows.shape
        np.testing.assert_array_equal(np.asarray(s.materialize(300)), rows)

    def test_blocks_match_array_source(self, rows, tmp_path):
        path = tmp_path / "rows.npy"
        np.save(path, rows)
        for a, b in zip(blocks_of(NpyFileSource(path), 256),
                        blocks_of(ArraySource(rows), 256)):
            np.testing.assert_array_equal(a, b)

    def test_rejects_non_2d(self, tmp_path):
        path = tmp_path / "bad.npy"
        np.save(path, np.zeros((4, 3, 2), np.float32))
        with pytest.raises(ValueError):
            NpyFileSource(path)


class TestConcatSource:
    def test_ragged_shards_rechunk_to_array_partition(self, rows):
        """Blocks must be bit-identical to an ArraySource over the
        concatenated rows regardless of shard boundaries — that is what
        makes ConcatSource fits match single-source fits exactly."""
        shards = [rows[:311], rows[311:312], rows[312:700], rows[700:]]
        c = ConcatSource([ArraySource(s) for s in shards])
        assert c.num_rows == 1000
        got = blocks_of(c, 256)
        want = blocks_of(ArraySource(rows), 256)
        assert [g.shape for g in got] == [w.shape for w in want]
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w)

    def test_nested_and_mixed_children(self, rows, tmp_path):
        path = tmp_path / "tail.npy"
        np.save(path, rows[600:])
        c = ConcatSource([
            ConcatSource([ArraySource(rows[:100]), ArraySource(rows[100:600])]),
            NpyFileSource(path)])
        np.testing.assert_array_equal(np.asarray(c.materialize(128)), rows)

    def test_rejects_dim_mismatch_and_empty(self, rows):
        with pytest.raises(ValueError):
            ConcatSource([ArraySource(rows), ArraySource(rows[:, :3])])
        with pytest.raises(ValueError):
            ConcatSource([])

    def test_rejects_dtype_mismatch(self, rows):
        """Mixed dtypes would make a straddling block's dtype depend on the
        chunk partition — rejected up front like a dim mismatch."""
        ints = np.ones((10, 5), np.int32)
        with pytest.raises(ValueError, match="dtype"):
            ConcatSource([ArraySource(rows), ArraySource(ints)])


class TestSyntheticGMMSource:
    @pytest.fixture(scope="class")
    def gmm(self):
        return GMM(jnp.array([0.25, 0.75]),
                   jnp.array([[-4.0, 0.0, 1.0], [4.0, 2.0, -1.0]]),
                   jnp.array([[0.5, 1.0, 0.25], [1.5, 0.5, 1.0]]))

    def test_chunk_invariance(self, gmm):
        """Row i's draw is keyed by i, never by block position: the stream
        is one fixed virtual dataset whatever the chunking."""
        s = SyntheticGMMSource(gmm, 1000, jax.random.key(7))
        m64 = np.asarray(s.materialize(64))
        np.testing.assert_array_equal(m64, np.asarray(s.materialize(97)))
        np.testing.assert_array_equal(m64, np.asarray(s.materialize(1000)))

    def test_deterministic_per_key(self, gmm):
        a = SyntheticGMMSource(gmm, 200, jax.random.key(1)).materialize(64)
        b = SyntheticGMMSource(gmm, 200, jax.random.key(1)).materialize(64)
        c = SyntheticGMMSource(gmm, 200, jax.random.key(2)).materialize(64)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert not np.array_equal(np.asarray(a), np.asarray(c))

    def test_moments_match_mixture(self, gmm):
        x = np.asarray(SyntheticGMMSource(gmm, 20000,
                                          jax.random.key(3)).materialize(4096))
        want_mean = np.asarray(gmm.weights @ gmm.means)
        np.testing.assert_allclose(x.mean(0), want_mean, atol=0.1)
        # law of total variance, diagonal case
        mu, w = np.asarray(gmm.means), np.asarray(gmm.weights)
        want_var = (w @ np.asarray(gmm.covs)
                    + w @ (mu - want_mean) ** 2)
        np.testing.assert_allclose(x.var(0), want_var, rtol=0.1)

    def test_full_covariance(self):
        cov = jnp.array([[[1.0, 0.8], [0.8, 1.0]]])
        g = GMM(jnp.array([1.0]), jnp.zeros((1, 2)), cov)
        x = np.asarray(SyntheticGMMSource(g, 20000,
                                          jax.random.key(5)).materialize(4096))
        got = np.cov(x.T)
        np.testing.assert_allclose(got, np.asarray(cov[0]), atol=0.08)

    def test_rejects_zero_rows(self, gmm):
        with pytest.raises(ValueError):
            SyntheticGMMSource(gmm, 0, jax.random.key(0))


class TestShuffledSource:
    CHUNK = 128  # 1000 rows -> 7 full blocks + 104-row ragged tail

    def test_protocol_passthrough(self, rows):
        src = ShuffledSource(ArraySource(rows), jax.random.key(1))
        assert (src.num_rows, src.dim) == (1000, 5)
        assert src.dtype == jnp.float32
        assert src.epoch == 0

    def test_epoch_shuffles_rows_but_keeps_partition(self, rows):
        base = ArraySource(rows)
        plain = blocks_of(base, self.CHUNK)
        shuf = blocks_of(ShuffledSource(base, jax.random.key(1), epoch=1),
                         self.CHUNK)
        # identical block-size partition (the engine pads per shape, so a
        # shuffle must never invent new block shapes) ...
        assert [b.shape for b in shuf] == [b.shape for b in plain]
        # ... identical row multiset ...
        sorted_rows = lambda bs: np.sort(np.concatenate(bs), axis=0)
        np.testing.assert_array_equal(sorted_rows(shuf), sorted_rows(plain))
        # ... but an actually different order
        assert not all(np.array_equal(a, b) for a, b in zip(plain, shuf))

    def test_epochs_are_deterministic_and_distinct(self, rows):
        base = ArraySource(rows)
        src = ShuffledSource(base, jax.random.key(1), epoch=2)
        again = ShuffledSource(base, jax.random.key(1)).with_epoch(2)
        for a, b in zip(blocks_of(src, self.CHUNK),
                        blocks_of(again, self.CHUNK)):
            np.testing.assert_array_equal(a, b)
        other = blocks_of(src.with_epoch(3), self.CHUNK)
        assert not all(np.array_equal(a, b) for a, b in
                       zip(blocks_of(src, self.CHUNK), other))

    def test_shuffle_is_windowed_not_global(self, rows):
        """Rows only move within windows of ``window_blocks`` blocks —
        the O(window · chunk) buffer bound, pinned behaviorally."""
        src = ShuffledSource(ArraySource(rows), jax.random.key(5), epoch=1,
                             window_blocks=2)
        shuf = np.concatenate(blocks_of(src, self.CHUNK))
        window_rows = 2 * self.CHUNK
        for start in range(0, 1000, window_rows):
            got = shuf[start:start + window_rows]
            want = rows[start:start + window_rows]
            np.testing.assert_array_equal(np.sort(got, axis=0),
                                          np.sort(want, axis=0))

    def test_validation(self, rows):
        with pytest.raises(ValueError, match="epoch"):
            ShuffledSource(ArraySource(rows), jax.random.key(0), epoch=-1)
        with pytest.raises(ValueError, match="window_blocks"):
            ShuffledSource(ArraySource(rows), jax.random.key(0),
                           window_blocks=0)


class TestEngineValidation:
    def test_sample_weight_rejected_with_source(self, rows):
        from repro.core.em import e_step_stats, fit_gmm
        g = GMM(jnp.full((2,), 0.5), jnp.zeros((2, 5)), jnp.ones((2, 5)))
        s = ArraySource(rows)
        w = jnp.ones(1000)
        with pytest.raises(ValueError, match="sample_weight"):
            e_step_stats(g, s, w)
        with pytest.raises(ValueError, match="sample_weight"):
            fit_gmm(jax.random.key(0), s, 2, sample_weight=w)

    def test_zero_chunk_rejected_not_defaulted(self, rows):
        """chunk_size=0 is a caller bug (integer division gone wrong), not
        a request for DEFAULT_SOURCE_CHUNK's working set."""
        from repro.core.em import fit_gmm, resolve_source_chunk
        with pytest.raises(ValueError, match="positive"):
            resolve_source_chunk(0)
        with pytest.raises(ValueError, match="positive"):
            fit_gmm(jax.random.key(0), ArraySource(rows), 2, chunk_size=0)

    def test_empty_iteration_guard(self):
        from repro.core.em import streaming_reduce

        class Hollow(DataSource):
            num_rows = 4
            dim = 2

            def iter_blocks(self, chunk_size):
                return iter(())

        with pytest.raises(ValueError, match="no blocks"):
            streaming_reduce(lambda xb: jnp.sum(xb), Hollow(), 2)


class TestPrefetchDepthDefault:
    """PREFETCH_DEPTH is auto-sized at import (DESIGN.md §7): 0 on hosts
    without a spare core for the producer thread, 2 otherwise, with
    REPRO_PREFETCH_DEPTH as the explicit override."""

    def test_heuristic_tracks_core_count(self, monkeypatch):
        from repro.data import sources
        monkeypatch.delenv("REPRO_PREFETCH_DEPTH", raising=False)
        for cpus, want in ((1, 0), (2, 0), (3, 2), (16, 2), (None, 0)):
            monkeypatch.setattr(sources.os, "cpu_count", lambda c=cpus: c)
            assert sources.default_prefetch_depth() == want

    def test_env_override_wins(self, monkeypatch):
        from repro.data import sources
        monkeypatch.setattr(sources.os, "cpu_count", lambda: 16)
        monkeypatch.setenv("REPRO_PREFETCH_DEPTH", "0")
        assert sources.default_prefetch_depth() == 0
        monkeypatch.setenv("REPRO_PREFETCH_DEPTH", "5")
        assert sources.default_prefetch_depth() == 5

    def test_negative_override_rejected(self, monkeypatch):
        from repro.data import sources
        monkeypatch.setenv("REPRO_PREFETCH_DEPTH", "-1")
        with pytest.raises(ValueError, match="REPRO_PREFETCH_DEPTH"):
            sources.default_prefetch_depth()
