"""Async federation runtime (DESIGN.md §12): the sync-equivalence
bit-identity contract, the concurrent client executor's deterministic
reduction, staleness weighting/accounting, and the AsyncPolicy facade.

The load-bearing pin is sync parity: ``run_async`` with
``buffer_size = cohort_size, lookahead = 0`` must be
``assert_array_equal``-identical to ``run_rounds`` on the split AND
source backends — that is what licenses routing estimator facades
through the async driver at all.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import planted_gmm_data
from repro.api import DEM, FedEM, FitConfig, fit_federated
from repro.core.dem import DEMStrategy
from repro.core.partition import partition
from repro.data.sources import ArraySource
from repro.fed import (ArrivalStragglers, AsyncPolicy, ClientExecutor,
                       CyclicSampler, GaussianDP, PairwiseMask,
                       PolynomialStaleness, SourceClients,
                       StochasticQuantize, UniformSampler, run_async,
                       run_rounds)


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(21)
    x, y, mus = planted_gmm_data(rng, n=2400, d=4, k=3, spread=5.0,
                                 std=0.5, min_sep_sigma=8.0)
    return x, y, mus


@pytest.fixture(scope="module")
def split(data):
    x, y, _ = data
    return partition(np.random.default_rng(0), x, y, 8, "dirichlet", 0.5)


@pytest.fixture(scope="module")
def shards(data):
    x, _, _ = data
    xj = jnp.asarray(x)
    return [ArraySource(xj[:700]), ArraySource(xj[700:1500]),
            ArraySource(xj[1500:])]


def assert_same_gmm(a, b):
    for field in ("weights", "means", "covs"):
        np.testing.assert_array_equal(np.asarray(getattr(a, field)),
                                      np.asarray(getattr(b, field)))


STRAT = DEMStrategy(k=3, init="separated", tol=1e-6)
KEY = jax.random.key(7)


class TestSyncEquivalence:
    """buffer_size = cohort_size, lookahead = 0 reproduces run_rounds to
    the bit — every combine is one whole fresh cohort through the same
    backend reduce."""

    def test_split_backend_bit_identical(self, split):
        rs = run_rounds(STRAT, split, key=KEY, max_rounds=6)
        ra = run_async(STRAT, split, key=KEY, max_rounds=6)
        assert_same_gmm(rs.global_gmm, ra.global_gmm)
        assert int(rs.n_rounds) == int(ra.n_rounds)
        assert bool(rs.converged) == bool(ra.converged)

    def test_source_backend_bit_identical(self, shards):
        rs = run_rounds(STRAT, shards, key=KEY, max_rounds=6)
        ra = run_async(STRAT, shards, key=KEY, max_rounds=6)
        assert_same_gmm(rs.global_gmm, ra.global_gmm)
        assert int(rs.n_rounds) == int(ra.n_rounds)

    @pytest.mark.parametrize("sampler_cls", [CyclicSampler, UniformSampler])
    def test_sampled_cohorts_bit_identical(self, split, sampler_cls):
        sampler = sampler_cls(8, 4)
        rs = run_rounds(STRAT, split, key=KEY, max_rounds=5, sampler=sampler)
        ra = run_async(STRAT, split, key=KEY, max_rounds=5, sampler=sampler)
        assert_same_gmm(rs.global_gmm, ra.global_gmm)

    def test_stragglers_bit_identical(self, split):
        kw = dict(key=KEY, max_rounds=5, sampler=UniformSampler(8, 4, seed=3),
                  stragglers=ArrivalStragglers(0.25, seed=9))
        assert_same_gmm(run_rounds(STRAT, split, **kw).global_gmm,
                        run_async(STRAT, split, **kw).global_gmm)

    @pytest.mark.parametrize("transform", [
        GaussianDP(epsilon=5.0, rounds=5, seed=5),
        StochasticQuantize(bits=16, seed=5),
        PairwiseMask(seed=11),
    ], ids=lambda t: type(t).__name__)
    def test_transforms_bit_identical(self, split, transform):
        kw = dict(key=KEY, max_rounds=5, transform=transform)
        rs = run_rounds(STRAT, split, **kw)
        ra = run_async(STRAT, split, **kw)
        assert_same_gmm(rs.global_gmm, ra.global_gmm)
        assert rs.comm.uplink_itemsize == ra.comm.uplink_itemsize

    def test_zero_staleness_recorded(self, split):
        ra = run_async(STRAT, split, key=KEY, max_rounds=4)
        # every update trained on the current model: the whole histogram
        # sits in the zero-staleness bucket
        assert ra.comm.staleness == ((0, 4 * 8),)
        assert ra.comm.mean_staleness == 0.0


class TestClientExecutor:
    def test_reduction_bit_identical_to_serial_loop(self, shards):
        """The worker pool returns per-client payloads in submission
        order, so the ascending-member sum is the serial loop's sum to
        the bit — whatever order clients actually finish in."""
        serial = run_rounds(STRAT, shards, key=KEY, max_rounds=6)
        with ClientExecutor(max_workers=3) as ex:
            pooled = run_rounds(STRAT, shards, key=KEY, max_rounds=6,
                                executor=ex)
            pooled_async = run_async(STRAT, shards, key=KEY, max_rounds=6,
                                     executor=ex)
        assert_same_gmm(serial.global_gmm, pooled.global_gmm)
        assert_same_gmm(serial.global_gmm, pooled_async.global_gmm)

    def test_map_ordered_is_submission_order(self):
        import time
        with ClientExecutor(max_workers=4) as ex:
            # later items finish first; results must not be reordered
            got = ex.map_ordered(
                lambda i: (time.sleep(0.02 * (4 - i)), i)[1], range(4))
        assert got == [0, 1, 2, 3]

    def test_run_async_owns_pool_via_max_workers(self, shards):
        serial = run_async(STRAT, shards, key=KEY, max_rounds=4)
        pooled = run_async(STRAT, shards, key=KEY, max_rounds=4,
                           max_workers=2)
        assert_same_gmm(serial.global_gmm, pooled.global_gmm)

    def test_rejects_zero_workers(self):
        with pytest.raises(ValueError, match="max_workers"):
            ClientExecutor(max_workers=0)


class TestStalenessWeighting:
    def test_polynomial_rule_values(self):
        rule = PolynomialStaleness(alpha=0.5)
        assert rule.weight(0) == 1.0               # fresh: exact identity
        assert rule.weight(3) == (1.0 + 3) ** -0.5
        assert PolynomialStaleness(alpha=0.0).weight(9) == 1.0
        with pytest.raises(ValueError):
            PolynomialStaleness(alpha=-1.0)
        with pytest.raises(ValueError):
            rule.weight(-1)

    def test_staleness_weights_sum_to_surviving_wsum(self, split):
        """The combined payload's wsum is exactly the staleness-weighted
        sum of the consumed clients' row counts — the M-step renormalizes
        by surviving weighted mass, nothing is silently dropped."""
        sizes = np.asarray(jnp.sum(split.mask, axis=1))  # rows per client

        @dataclasses.dataclass(frozen=True)
        class WsumProbe:
            """Minimal strategy whose state IS the combined wsum."""
            one_shot: bool = False

            def init_state(self, key, backend):
                return jnp.zeros(())

            def local_step(self, state, x, w, idx):
                return jnp.sum(w)                  # this client's row count

            def server_combine(self, state, total):
                return total

            def converged(self, state):
                return jnp.asarray(False)

            def round_payload(self, backend, state):
                from repro.fed.ledger import RoundPayload
                return RoundPayload(uplink_floats=backend.num_clients,
                                    downlink_floats=1)

            def finalize(self, state, n_rounds, converged, comm):
                return state

        probe = WsumProbe()
        rule = PolynomialStaleness(alpha=0.5)
        seen = []
        run_async(probe, split, key=KEY, max_rounds=6, buffer_size=4,
                  lookahead=8, staleness=rule,
                  progress=lambda v, s, st: seen.append(
                      (float(s), tuple(st))))
        # dispatch order is round-robin over the population in cohorts of
        # buffer+lookahead // ... — reconstruct expected weighted wsums
        # from the recorded per-update staleness
        consumed = 0
        for combined_wsum, stales in seen:
            members = [(consumed + j) % 8 for j in range(4)]
            want = sum(rule.weight(s) * sizes[m]
                       for m, s in zip(members, stales))
            np.testing.assert_allclose(combined_wsum, want, rtol=1e-6)
            consumed += 4

    def test_staleness_histogram_in_ledger(self, split):
        ra = run_async(STRAT, split, key=KEY, max_rounds=6, buffer_size=4,
                       lookahead=8)
        hist = dict(ra.comm.staleness)
        assert sum(hist.values()) == 6 * 4        # one entry per update
        assert max(hist) > 0                      # staleness actually arose
        assert ra.comm.mean_staleness > 0.0

    def test_steady_state_staleness_is_lookahead_over_buffer(self, split):
        """With lookahead = k * buffer and dispatch batches of buffer
        size, the in-flight window holds k combines' worth of older
        dispatches: steady-state staleness is exactly k."""
        seen = []
        run_async(STRAT, split, key=KEY, max_rounds=8, buffer_size=4,
                  lookahead=8, sampler=CyclicSampler(8, 4),
                  progress=lambda v, s, st: seen.append(st))
        assert set(seen[-1]) == {2}               # k = 8 / 4

    def test_dropped_stragglers_excluded_from_histogram(self, split):
        ra = run_async(STRAT, split, key=KEY, max_rounds=4,
                       sampler=UniformSampler(8, 4, seed=3),
                       stragglers=ArrivalStragglers(0.25, seed=9))
        pol = ArrivalStragglers(0.25, seed=9)
        surviving = 4 * pol.n_keep(4)
        assert sum(n for _, n in ra.comm.staleness) == surviving


class TestValidationAndPolicy:
    def test_one_shot_rejected(self, split):
        from repro.core.fedgen import FedGenStrategy
        strat = FedGenStrategy(config=FitConfig(), k_clients=2,
                               k_global=2, h=10)
        with pytest.raises(ValueError, match="one-shot"):
            run_async(strat, split, key=KEY)

    def test_buffer_bounds_enforced(self, split):
        with pytest.raises(ValueError, match="buffer_size"):
            run_async(STRAT, split, key=KEY, buffer_size=0)
        with pytest.raises(ValueError, match="buffer_size"):
            run_async(STRAT, split, key=KEY, buffer_size=9)
        with pytest.raises(ValueError, match="lookahead"):
            run_async(STRAT, split, key=KEY, lookahead=-1)

    def test_additive_only_transform_needs_sync_equivalence(self, split):
        with pytest.raises(ValueError, match="whole cohort"):
            run_async(STRAT, split, key=KEY, transform=PairwiseMask(),
                      buffer_size=4)
        with pytest.raises(ValueError, match="whole cohort"):
            run_async(STRAT, split, key=KEY, transform=PairwiseMask(),
                      lookahead=4)

    def test_policy_validation(self):
        with pytest.raises(ValueError):
            AsyncPolicy(buffer_size=0)
        with pytest.raises(ValueError):
            AsyncPolicy(lookahead=-1)
        with pytest.raises(ValueError):
            AsyncPolicy(staleness_alpha=-0.5)
        with pytest.raises(ValueError):
            AsyncPolicy(max_workers=-1)
        kw = AsyncPolicy(buffer_size=4, lookahead=8,
                         staleness_alpha=0.25).driver_kwargs()
        assert kw["buffer_size"] == 4 and kw["lookahead"] == 8
        assert kw["staleness"] == PolynomialStaleness(0.25)

    def test_staleness_argument_forms(self, split):
        a = run_async(STRAT, split, key=KEY, max_rounds=3, buffer_size=4,
                      lookahead=4, staleness=0.5)
        b = run_async(STRAT, split, key=KEY, max_rounds=3, buffer_size=4,
                      lookahead=4, staleness=PolynomialStaleness(0.5))
        assert_same_gmm(a.global_gmm, b.global_gmm)
        with pytest.raises(TypeError, match="weight"):
            run_async(STRAT, split, key=KEY, staleness="fast")


class TestFacadeRouting:
    def test_dem_facade_sync_policy_bit_identical(self, split):
        cfg = FitConfig(init="separated", max_iter=5)
        plain = DEM(3, config=cfg).run(split, key=KEY)
        routed = DEM(3, config=cfg, async_policy=AsyncPolicy()).run(
            split, key=KEY)
        assert_same_gmm(plain.global_gmm, routed.global_gmm)

    def test_fedem_facade_sync_policy_bit_identical(self, split):
        cfg = FitConfig(init="separated", max_iter=5)
        kw = dict(participation=0.5, cohort="cyclic", config=cfg)
        plain = FedEM(3, **kw).run(split, key=KEY)
        routed = FedEM(3, async_policy=AsyncPolicy(), **kw).run(split,
                                                                key=KEY)
        assert_same_gmm(plain.global_gmm, routed.global_gmm)

    def test_fedem_async_policy_runs_buffered(self, split):
        cfg = FitConfig(init="separated", max_iter=8)
        r = FedEM(3, participation=0.5, cohort="cyclic", config=cfg,
                  async_policy=AsyncPolicy(buffer_size=2, lookahead=4)).run(
            split, key=KEY)
        assert dict(r.comm.staleness) and max(dict(r.comm.staleness)) > 0

    def test_fit_federated_named_and_custom(self, split):
        cfg = FitConfig(init="separated", max_iter=4)
        named = fit_federated(split, strategy="dem", key=KEY, config=cfg,
                              k=3, async_policy=AsyncPolicy())
        custom = fit_federated(split, strategy=STRAT, key=KEY, max_rounds=4,
                               async_policy=AsyncPolicy())
        assert_same_gmm(named.global_gmm, custom.global_gmm)

    def test_fit_federated_rejects_async_for_one_shot_names(self, split):
        with pytest.raises(TypeError, match="iterative"):
            fit_federated(split, strategy="fedgen", key=KEY, k=3,
                          async_policy=AsyncPolicy())
