"""K-means tests: recovery, weighting invariant, federated variant."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kmeans import (federated_kmeans, kmeans, kmeans_multi,
                               kmeans_plusplus)
from conftest import planted_gmm_data


def test_kmeans_recovers_planted_centers():
    # multi-restart k-means (the library's EM-init path) recovers planted
    # centers; a single init can legitimately land in a bad local optimum
    x, y, mus = planted_gmm_data(np.random.default_rng(1), n=1800, k=3,
                                 spread=6.0, std=0.4)
    res = kmeans_multi(jax.random.key(0), jnp.asarray(x), 3, n_init=6)
    got = np.sort(np.asarray(res.centers), axis=0)
    np.testing.assert_allclose(got, np.sort(mus, axis=0), atol=0.2)
    assert int(res.n_iter) < 50


def test_kmeans_inertia_decreases_vs_random():
    x, _, _ = planted_gmm_data(np.random.default_rng(2), n=900, k=4)
    res = kmeans(jax.random.key(0), jnp.asarray(x), 4)
    rand_centers = jnp.asarray(np.random.default_rng(0).normal(0, 4, (4, 4)),
                               jnp.float32)
    from repro.core.kmeans import _sq_dists
    rand_inertia = float(jnp.sum(jnp.min(_sq_dists(jnp.asarray(x),
                                                   rand_centers), axis=1)))
    assert float(res.inertia) < rand_inertia


def test_weighted_kmeans_ignores_zero_weight_rows():
    x, _, _ = planted_gmm_data(np.random.default_rng(3), n=1000, k=2,
                               spread=8.0)
    xj = jnp.asarray(x)
    # poison the second half with garbage, zero its weight
    poisoned = xj.at[500:].set(1e3)
    w = jnp.asarray(np.r_[np.ones(500), np.zeros(500)], jnp.float32)
    res = kmeans(jax.random.key(0), poisoned, 2, sample_weight=w)
    ref = kmeans(jax.random.key(0), xj[:500], 2)
    np.testing.assert_allclose(np.sort(np.asarray(res.centers), 0),
                               np.sort(np.asarray(ref.centers), 0), atol=0.3)


def test_kmeans_plusplus_picks_data_points():
    x, _, _ = planted_gmm_data(np.random.default_rng(4), n=500, k=3)
    c = kmeans_plusplus(jax.random.key(0), jnp.asarray(x), 3)
    # every seed must be an actual data row
    d2 = jnp.min(jnp.sum((jnp.asarray(x)[None] - c[:, None]) ** 2, -1), axis=1)
    assert float(d2.max()) < 1e-8


def test_federated_kmeans_close_to_centralized():
    x, y, mus = planted_gmm_data(np.random.default_rng(5), n=2000, k=3,
                                 spread=7.0, std=0.4)
    # 4 clients, heterogeneous
    from repro.core.partition import partition_dirichlet
    split = partition_dirichlet(np.random.default_rng(0), x, y, 4, 0.3)
    centers = federated_kmeans(jax.random.key(0), jnp.asarray(split.data), 3,
                               client_weights=jnp.asarray(split.mask))
    got = np.sort(np.asarray(centers), axis=0)
    np.testing.assert_allclose(got, np.sort(mus, axis=0), atol=0.4)
