"""Partition-spec consistency: for every assigned architecture the spec
pytrees must structurally match the actual param/cache pytrees (this is
exactly what jit in_shardings dies on at 512 devices — caught here on CPU
with eval_shape, no allocation)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.models.transformer import (cache_specs, init_cache, init_params,
                                      param_specs)

ARCHS = list_archs()


def _struct(tree):
    return jax.tree.structure(
        tree, is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("fsdp", ["data", ("pod", "data"), None])
def test_param_specs_match_tree(arch, fsdp):
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))
    specs = param_specs(cfg, fsdp=fsdp, model_axis_size=16)
    assert jax.tree.structure(shapes) == _struct(specs)
    # every sharded dim must divide the tensor dim (16-way model axis,
    # and up to 32-way fsdp)
    for s, spec in zip(jax.tree.leaves(shapes),
                       jax.tree.leaves(specs,
                                       is_leaf=lambda x: isinstance(x, P))):
        for dim, entry in zip(s.shape, spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = 1
            for a in axes:
                size *= {"model": 16, "data": 16, "pod": 2}[a]
            assert dim % size == 0, (arch, s.shape, spec)


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("mode", ["hd", "seq"])
def test_cache_specs_match_tree(arch, mode):
    cfg = get_config(arch)
    enc_len = 8192 if cfg.n_enc_layers else 0
    shapes = jax.eval_shape(
        lambda: init_cache(cfg, 128, 32768, enc_len=enc_len))
    specs = cache_specs(cfg, "data", None, cache_mode=mode)
    assert jax.tree.structure(shapes) == _struct(specs)
    for s, spec in zip(jax.tree.leaves(shapes),
                       jax.tree.leaves(specs,
                                       is_leaf=lambda x: isinstance(x, P))):
        assert len(spec) <= len(s.shape), (arch, s.shape, spec)
        for dim, entry in zip(s.shape, spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = 1
            for a in axes:
                size *= {"model": 16, "data": 16, "pod": 2}[a]
            assert dim % size == 0, (arch, s.shape, spec)
