"""DP uplink tests (beyond-paper feature; paper §4.4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregate, fedgengmm, fit_gmm, partition
from repro.core.privacy import DPConfig, privatize_clients, privatize_gmm
from conftest import planted_gmm_data


@pytest.fixture(scope="module")
def planted_norm():
    """Planted mixture normalized to [0,1] (DP sensitivity assumption)."""
    rng = np.random.default_rng(5)
    x, y, _ = planted_gmm_data(rng, n=3000, d=4, k=3, spread=4.0, std=0.4)
    lo, hi = x.min(0), x.max(0)
    return ((x - lo) / (hi - lo)).astype(np.float32), y


def test_privatized_gmm_valid(planted_norm):
    x, y = planted_norm
    res = fit_gmm(jax.random.key(0), jnp.asarray(x), 3)
    priv = privatize_gmm(jax.random.key(1), res.gmm, len(x),
                         DPConfig(epsilon=1.0))
    np.testing.assert_allclose(float(priv.weights.sum()), 1.0, rtol=1e-5)
    assert bool(jnp.all(priv.covs > 0))
    assert bool(jnp.all((priv.means >= 0) & (priv.means <= 1)))


def test_noise_decreases_with_epsilon(planted_norm):
    x, y = planted_norm
    res = fit_gmm(jax.random.key(0), jnp.asarray(x), 3)

    def dist(eps, seed=2):
        priv = privatize_gmm(jax.random.key(seed), res.gmm, len(x),
                             DPConfig(epsilon=eps))
        return float(jnp.mean(jnp.abs(priv.means - res.gmm.means)))

    loose = np.mean([dist(10.0, s) for s in range(5)])
    tight = np.mean([dist(0.05, s) for s in range(5)])
    assert tight > loose


def test_dp_pipeline_still_learns(planted_norm):
    """End-to-end: DP uplink at moderate epsilon still yields a usable
    global model (degrades gracefully vs non-private)."""
    x, y = planted_norm
    rng = np.random.default_rng(0)
    split = partition(rng, x, y, 5, "dirichlet", 1.0)
    fr = fedgengmm(jax.random.key(0), split, k_clients=3, k_global=3, h=60)
    priv_gmms = privatize_clients(jax.random.key(1), fr.local_gmms,
                                  split.sizes, DPConfig(epsilon=5.0))
    res, _ = aggregate(jax.random.key(2), priv_gmms, split.sizes, h=60,
                       k_global=3)
    xj = jnp.asarray(x)
    ll_priv = float(res.gmm.score(xj))
    ll_nonpriv = float(fr.global_gmm.score(xj))
    bench = fit_gmm(jax.random.key(3), xj, 3)
    ll_central = float(bench.gmm.score(xj))
    assert ll_priv > ll_central - 2.0, (ll_priv, ll_nonpriv, ll_central)
    assert ll_priv <= ll_nonpriv + 0.2  # noise should not help
