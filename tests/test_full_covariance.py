"""Full-covariance federated path (paper §4.3 discusses both covariance
types; experiments use diag — here the full path is exercised end-to-end)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fedgengmm, fit_gmm, partition


@pytest.fixture(scope="module")
def correlated_data():
    """Planted mixture with strong within-component correlations — diag
    covariance is misspecified here, full is not."""
    rng = np.random.default_rng(4)
    covs = []
    for _ in range(3):
        a = rng.normal(0, 1, (3, 3))
        covs.append(a @ a.T * 0.1 + 0.05 * np.eye(3))
    mus = rng.normal(0, 5, (3, 3))
    y = rng.integers(0, 3, 3000)
    x = np.stack([rng.multivariate_normal(mus[c], covs[c]) for c in y]) \
        .astype(np.float32)
    return x, y.astype(np.int64)


def test_fedgen_full_covariance_end_to_end(correlated_data):
    x, y = correlated_data
    split = partition(np.random.default_rng(0), x, y, 5, "dirichlet", 0.5)
    # f32 full covariance wants stronger regularization than sklearn's
    # f64 default (1e-6): near-degenerate client components otherwise
    # poison the synthetic refit set
    fr = fedgengmm(jax.random.key(0), split, k_clients=3, k_global=3, h=60,
                   covariance_type="full", reg_covar=1e-4)
    assert not fr.global_gmm.is_diagonal
    assert fr.global_gmm.covs.shape == (3, 3, 3)
    bench = fit_gmm(jax.random.key(1), jnp.asarray(x), 3,
                    covariance_type="full")
    xj = jnp.asarray(x)
    assert float(fr.global_gmm.score(xj)) > \
        float(bench.gmm.score(xj)) - 0.5


def test_full_beats_diag_on_correlated_data(correlated_data):
    x, y = correlated_data
    split = partition(np.random.default_rng(1), x, y, 5, "dirichlet", 1.0)
    xj = jnp.asarray(x)
    full = fedgengmm(jax.random.key(0), split, k_clients=3, k_global=3,
                     h=60, covariance_type="full", reg_covar=1e-4)
    diag = fedgengmm(jax.random.key(0), split, k_clients=3, k_global=3,
                     h=60, covariance_type="diag")
    assert float(full.global_gmm.score(xj)) > \
        float(diag.global_gmm.score(xj)) + 0.1


def test_uplink_accounting_full(correlated_data):
    x, y = correlated_data
    split = partition(np.random.default_rng(2), x, y, 4, "dirichlet", 1.0)
    fr = fedgengmm(jax.random.key(0), split, k_clients=2, k_global=3, h=40,
                   covariance_type="full", reg_covar=1e-4)
    d = x.shape[1]
    per_client = 2 + 2 * d + 2 * d * d + 1  # full cov payload
    assert fr.comm.uplink_floats == 4 * per_client
