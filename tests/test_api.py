"""The `repro.api` facade (DESIGN.md §8): FitConfig validation, input-type
dispatch, bit-identity against the legacy entry-point families, the
covariance_type threading regression class, and the deprecation shims.

Bit-identity is the acceptance bar of the PR-4 refactor: the facade and
the legacy keyword entry points must run literally the same cfg-core code,
so results are compared with assert_array_equal, never allclose.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (DEM, FedGenGMM, FitConfig, GMMEstimator,
                       KMeansEstimator, bic, log_prob, score)
from repro.core import dem as dem_legacy
from repro.core import (fedgengmm, fedgengmm_from_sources, fit_gmm,
                        fit_gmm_streaming, kmeans, partition)
from repro.core.dem import dem_from_sources
from repro.core.em import fit_gmm_bic
from repro.data.sources import ArraySource, ConcatSource
from conftest import planted_gmm_data

CHUNK = 512  # deliberately not dividing the fixtures below


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(11)
    x, y, mus = planted_gmm_data(rng, n=1500, d=4, k=3, spread=5.0, std=0.5,
                                 min_sep_sigma=8.0)
    return x, y, mus


@pytest.fixture(scope="module")
def split(data):
    x, y, _ = data
    return partition(np.random.default_rng(5), x, y, 4, "dirichlet", 1.0)


@pytest.fixture(scope="module")
def shards(data):
    x, _, _ = data
    xj = jnp.asarray(x)
    return [ArraySource(xj[:500]), ArraySource(xj[500:1100]),
            ArraySource(xj[1100:])]


def assert_same_gmm(g1, g2):
    for f in ("weights", "means", "covs"):
        np.testing.assert_array_equal(np.asarray(getattr(g1, f)),
                                      np.asarray(getattr(g2, f)))


# ----------------------------------------------------------------------
# FitConfig validation (construction-time, once)
# ----------------------------------------------------------------------

class TestFitConfigValidation:
    def test_chunk_size_none_is_an_error_with_guidance(self):
        """The PR-3 footgun: None meant full batch for arrays but 65536
        for sources. FitConfig refuses it and names the fix."""
        with pytest.raises(ValueError, match="chunk_size='auto'"):
            FitConfig(chunk_size=None)

    def test_chunk_size_rejects_nonpositive_and_junk(self):
        with pytest.raises(ValueError, match="positive"):
            FitConfig(chunk_size=0)
        with pytest.raises(ValueError, match="positive"):
            FitConfig(chunk_size=-4)
        with pytest.raises(ValueError, match="'auto'"):
            FitConfig(chunk_size="streaming")

    def test_auto_resolution_matches_legacy_defaults(self):
        cfg = FitConfig()
        assert cfg.resolve_chunk(source=False) is None      # full batch
        assert cfg.resolve_chunk(source=True) == 65536      # source default
        assert FitConfig(chunk_size=128).resolve_chunk(True) == 128
        assert FitConfig(chunk_size=128).resolve_chunk(False) == 128

    @pytest.mark.parametrize("bad,match", [
        (dict(backend="cuda"), "estep_backend"),
        (dict(covariance_type="spherical"), "covariance_type"),
        (dict(init="bogus"), "init"),
        (dict(max_iter=0), "max_iter"),
        (dict(reg_covar=-1.0), "reg_covar"),
        (dict(tol=-1e-3), "tol"),
    ])
    def test_field_validation(self, bad, match):
        with pytest.raises(ValueError, match=match):
            FitConfig(**bad)

    def test_facade_rejects_unknown_override(self):
        with pytest.raises(TypeError, match="unknown FitConfig field"):
            GMMEstimator(3, chunksize=128)

    def test_legacy_none_maps_to_auto(self):
        assert FitConfig.from_legacy(chunk_size=None).chunk_size == "auto"
        assert FitConfig.from_legacy(chunk_size=256).chunk_size == 256

    def test_chunk_size_rejects_non_integral(self):
        """Silently truncating 8192.5 would mask the division-gone-wrong
        caller bugs the validation exists for; integral floats are fine."""
        with pytest.raises(ValueError, match="positive int"):
            FitConfig(chunk_size=8192.5)
        with pytest.raises(ValueError, match="positive int"):
            FitConfig(chunk_size=True)
        assert FitConfig(chunk_size=8192.0).chunk_size == 8192
        with pytest.raises(ValueError, match="integer"):
            FitConfig(max_iter=2.5)
        with pytest.raises(ValueError, match="integer"):
            FitConfig(seed=0.5)


# ----------------------------------------------------------------------
# sample_weight is array-path-only (single actionable error)
# ----------------------------------------------------------------------

class TestSampleWeightRule:
    def test_facade_source_weight_error_is_actionable(self, data):
        x, _, _ = data
        src = ArraySource(jnp.asarray(x))
        w = jnp.ones(len(x))
        for est in (GMMEstimator(3), KMeansEstimator(3)):
            with pytest.raises(ValueError) as ei:
                est.fit(src, sample_weight=w)
            msg = str(ei.value)
            # the one message: names the rule AND the ragged-shard fix
            assert "array" in msg and "ConcatSource" in msg

    def test_scorers_enforce_the_same_rule(self, data):
        x, _, _ = data
        est = GMMEstimator(3, max_iter=5).fit(jnp.asarray(x))
        src = ArraySource(jnp.asarray(x))
        with pytest.raises(ValueError, match="ConcatSource"):
            score(est.gmm_, src, sample_weight=jnp.ones(len(x)))
        with pytest.raises(ValueError, match="ConcatSource"):
            bic(est.gmm_, src, sample_weight=jnp.ones(len(x)))


# ----------------------------------------------------------------------
# Input-type dispatch
# ----------------------------------------------------------------------

class TestDispatch:
    def test_single_model_estimators_reject_client_containers(self, split,
                                                              shards):
        with pytest.raises(TypeError, match="GMMEstimator.fit accepts"):
            GMMEstimator(3).fit(split)
        with pytest.raises(TypeError, match="KMeansEstimator.fit accepts"):
            KMeansEstimator(3).fit(shards)

    def test_federated_runners_reject_single_inputs(self, data):
        x, _, _ = data
        with pytest.raises(TypeError, match="FedGenGMM.run accepts"):
            FedGenGMM(k_clients=3, k_global=3).run(jnp.asarray(x))
        with pytest.raises(TypeError, match="DEM.run accepts"):
            DEM(3).run(ArraySource(jnp.asarray(x)))

    def test_mixed_list_is_rejected_with_guidance(self, data):
        x, _, _ = data
        with pytest.raises(TypeError, match="ArraySource"):
            FedGenGMM(k_clients=3, k_global=3).run([np.asarray(x[:100]),
                                                    np.asarray(x[100:])])

    def test_empty_client_list_names_the_real_problem(self):
        with pytest.raises(TypeError, match="least one client"):
            FedGenGMM(k_clients=3, k_global=3).run([])
        with pytest.raises(TypeError, match="least one client"):
            DEM(3).run([])
        # non-federated facades must not steer toward client lists
        with pytest.raises(TypeError, match=r"array or a DataSource"):
            GMMEstimator(3).fit([])

    def test_facade_scalars_reject_non_integral(self):
        with pytest.raises(ValueError, match="k must be an integer"):
            KMeansEstimator(3.7)
        with pytest.raises(ValueError, match="n_init"):
            KMeansEstimator(3, n_init=2.9)
        with pytest.raises(ValueError, match="k must be an integer"):
            GMMEstimator(3.5)
        with pytest.raises(ValueError, match="h must be an integer"):
            FedGenGMM(k_clients=3, k_global=3, h=50.5)
        with pytest.raises(ValueError, match="k must be an integer"):
            DEM(2.5)

    def test_nonempty_list_error_respects_accept_set(self):
        with pytest.raises(TypeError, match=r"array or a DataSource"):
            GMMEstimator(2).fit([[0.0, 1.0], [2.0, 3.0]])

    def test_init_strategy_validated_per_estimator(self):
        with pytest.raises(ValueError, match="k-means init"):
            FedGenGMM(k_clients=3, k_global=3, init="pilot")
        with pytest.raises(ValueError, match="single-model GMM init"):
            DEM(3, init="kmeans")
        with pytest.raises(ValueError, match="'auto' or 'kmeans'"):
            GMMEstimator(3, init="separated")

    def test_seed_stays_out_of_the_jit_cache_key(self, split):
        """config.seed only feeds key derivation, never the traced graph:
        sweeping seeds through the facade must not recompile the vmap'd
        local-EM loop once per seed."""
        from repro.core.fedgen import _train_locals_jit
        if not hasattr(_train_locals_jit, "_cache_size"):
            pytest.skip("jit cache introspection not available")
        before = _train_locals_jit._cache_size()
        for seed in (101, 102):
            FedGenGMM(k_clients=2, k_global=2, h=10, seed=seed,
                      max_iter=3).run(split)
        grown = _train_locals_jit._cache_size() - before
        assert grown <= 1, f"seed sweep added {grown} cache entries"

    def test_seed_policy(self, data):
        """config.seed drives the PRNG unless an explicit key is passed."""
        x, _, _ = data
        xj = jnp.asarray(x)
        a = GMMEstimator(3, seed=9, max_iter=5).fit(xj)
        b = GMMEstimator(3, max_iter=5).fit(xj, key=jax.random.key(9))
        assert_same_gmm(a.gmm_, b.gmm_)


# ----------------------------------------------------------------------
# Bit-identity: facade == legacy entry points (array AND source inputs)
# ----------------------------------------------------------------------

@pytest.mark.slow
class TestFacadeBitIdentity:
    def test_gmm_array(self, data):
        x, _, _ = data
        xj = jnp.asarray(x)
        ref = fit_gmm(jax.random.key(0), xj, 3)
        est = GMMEstimator(3).fit(xj, key=jax.random.key(0))
        assert_same_gmm(ref.gmm, est.gmm_)
        assert int(ref.n_iter) == int(est.result_.n_iter)

    def test_gmm_array_chunked(self, data):
        x, _, _ = data
        xj = jnp.asarray(x)
        ref = fit_gmm(jax.random.key(0), xj, 3, chunk_size=CHUNK)
        est = GMMEstimator(3, chunk_size=CHUNK).fit(xj, key=jax.random.key(0))
        assert_same_gmm(ref.gmm, est.gmm_)

    def test_gmm_source(self, data):
        x, _, _ = data
        src = ArraySource(jnp.asarray(x))
        ref = fit_gmm(jax.random.key(0), src, 3, chunk_size=CHUNK)
        est = GMMEstimator(3, chunk_size=CHUNK).fit(src,
                                                    key=jax.random.key(0))
        assert_same_gmm(ref.gmm, est.gmm_)

    def test_gmm_bic_selection(self, data):
        x, _, _ = data
        xj = jnp.asarray(x)
        ref, bics_ref = fit_gmm_bic(jax.random.key(1), xj, [2, 3])
        est = GMMEstimator(k_candidates=[2, 3]).fit(xj, key=jax.random.key(1))
        assert_same_gmm(ref.gmm, est.gmm_)
        assert est.bics_ == bics_ref

    def test_kmeans_array(self, data):
        x, _, _ = data
        xj = jnp.asarray(x)
        ref = kmeans(jax.random.key(2), xj, 3, max_iter=100, tol=1e-4)
        est = KMeansEstimator(3, max_iter=100, tol=1e-4).fit(
            xj, key=jax.random.key(2))
        np.testing.assert_array_equal(np.asarray(ref.centers),
                                      np.asarray(est.centers_))
        np.testing.assert_array_equal(np.asarray(ref.assignments),
                                      np.asarray(est.assignments_))

    def test_kmeans_defaults_agree_without_pinning(self, data):
        """The PR-4 caveat, closed: tol/max_iter="auto" resolve to the
        k-means defaults (1e-4/100) at config-resolution time, so a
        DEFAULT facade config matches the legacy kmeans() entry point
        bit for bit — no manual pinning."""
        x, _, _ = data
        xj = jnp.asarray(x)
        ref = kmeans(jax.random.key(2), xj, 3)
        est = KMeansEstimator(3).fit(xj, key=jax.random.key(2))
        np.testing.assert_array_equal(np.asarray(ref.centers),
                                      np.asarray(est.centers_))
        np.testing.assert_array_equal(np.asarray(ref.assignments),
                                      np.asarray(est.assignments_))
        np.testing.assert_array_equal(np.asarray(ref.inertia),
                                      np.asarray(est.inertia_))

    def test_auto_tol_resolution_is_per_algorithm(self):
        cfg = FitConfig()
        assert cfg.resolve_tol("em") == 1e-3
        assert cfg.resolve_tol("kmeans") == 1e-4
        assert cfg.resolve_max_iter("em") == 200
        assert cfg.resolve_max_iter("kmeans") == 100
        pinned = FitConfig(tol=5e-3, max_iter=7)
        assert pinned.resolve_tol("kmeans") == 5e-3
        assert pinned.resolve_max_iter("kmeans") == 7

    def test_fedgen_split(self, split):
        ref = fedgengmm(jax.random.key(3), split, k_clients=3, k_global=3,
                        h=40)
        fr = FedGenGMM(k_clients=3, k_global=3, h=40).run(
            split, key=jax.random.key(3))
        assert_same_gmm(ref.global_gmm, fr.global_gmm)
        assert ref.comm == fr.comm

    def test_dem_split(self, split):
        ref = dem_legacy(jax.random.key(4), split, 3, init=3, max_rounds=30)
        dr = DEM(3, max_iter=30).run(split, key=jax.random.key(4))
        assert_same_gmm(ref.global_gmm, dr.global_gmm)
        assert int(ref.n_rounds) == int(dr.n_rounds)


# ----------------------------------------------------------------------
# covariance_type threading (regression class for the PR-1
# train_locals_bic covariance drop)
# ----------------------------------------------------------------------

@pytest.mark.slow
class TestCovarianceThreading:
    """Every facade entry point must carry covariance_type end to end:
    'full' fits produce (K, d, d) covariances everywhere a model comes
    back. The PR-1 bug class was a knob silently dropped on one path."""

    @pytest.mark.parametrize("covariance_type,ndim", [("diag", 2),
                                                      ("full", 3)])
    def test_gmm_array_and_source(self, data, covariance_type, ndim):
        x, _, _ = data
        xj = jnp.asarray(x)
        cfg = FitConfig(covariance_type=covariance_type, max_iter=10,
                        chunk_size=CHUNK)
        for inp in (xj, ArraySource(xj)):
            est = GMMEstimator(2, config=cfg).fit(inp)
            assert est.gmm_.covs.ndim == ndim
            assert est.gmm_.is_diagonal == (covariance_type == "diag")

    @pytest.mark.parametrize("covariance_type,ndim", [("diag", 2),
                                                      ("full", 3)])
    def test_gmm_bic_path(self, data, covariance_type, ndim):
        """The original PR-1 regression: train_locals_bic dropped
        covariance_type on the BIC-selection path."""
        x, _, _ = data
        est = GMMEstimator(k_candidates=[2],
                           covariance_type=covariance_type,
                           max_iter=10).fit(jnp.asarray(x))
        assert est.gmm_.covs.ndim == ndim

    @pytest.mark.parametrize("covariance_type,ndim", [("diag", 2),
                                                      ("full", 3)])
    def test_fedgen_split_and_sources(self, split, shards, covariance_type,
                                      ndim):
        fed = FedGenGMM(k_clients=2, k_global=2, h=20,
                        covariance_type=covariance_type, max_iter=10,
                        chunk_size=CHUNK)
        for clients in (split, shards):
            fr = fed.run(clients)
            assert fr.global_gmm.covs.ndim == ndim
            assert all(g.covs.ndim == ndim for g in fr.local_gmms)

    @pytest.mark.parametrize("covariance_type,ndim", [("diag", 2),
                                                      ("full", 3)])
    def test_dem_split_and_sources(self, split, shards, covariance_type,
                                   ndim):
        runner = DEM(2, covariance_type=covariance_type, max_iter=8,
                     chunk_size=CHUNK)
        for clients in (split, shards):
            dr = runner.run(clients)
            assert dr.global_gmm.covs.ndim == ndim

    def test_fedgen_bic_clients_keep_covariance(self, split):
        """Heterogeneous-K clients (the exact PR-1 bug site) under the
        facade: per-client BIC selection must not drop 'full'."""
        fr = FedGenGMM(k_candidates=[2], k_global=2, h=20,
                       covariance_type="full", max_iter=10).run(split)
        assert all(not g.is_diagonal for g in fr.local_gmms)
        assert not fr.global_gmm.is_diagonal


# ----------------------------------------------------------------------
# Deprecation shims: old call sites warn AND stay bit-identical
# ----------------------------------------------------------------------

@pytest.mark.slow
class TestDeprecationShims:
    def test_fit_gmm_streaming_forwards_bit_identically(self, data):
        x, _, _ = data
        xj = jnp.asarray(x)
        with pytest.warns(DeprecationWarning, match="GMMEstimator"):
            old = fit_gmm_streaming(jax.random.key(0), xj, 3,
                                    chunk_size=CHUNK)
        new = GMMEstimator(3, chunk_size=CHUNK).fit(xj,
                                                    key=jax.random.key(0))
        assert_same_gmm(old.gmm, new.gmm_)
        assert int(old.n_iter) == int(new.result_.n_iter)

    def test_fedgengmm_from_sources_forwards_bit_identically(self, shards):
        with pytest.warns(DeprecationWarning, match="FedGenGMM"):
            old = fedgengmm_from_sources(jax.random.key(1), shards,
                                         k_clients=2, k_global=2, h=20,
                                         chunk_size=CHUNK)
        new = FedGenGMM(k_clients=2, k_global=2, h=20,
                        chunk_size=CHUNK).run(shards, key=jax.random.key(1))
        assert_same_gmm(old.global_gmm, new.global_gmm)

    def test_dem_from_sources_forwards_bit_identically(self, shards):
        with pytest.warns(DeprecationWarning, match="DEM"):
            old = dem_from_sources(jax.random.key(2), shards, 2, init=1,
                                   max_rounds=10, chunk_size=CHUNK)
        new = DEM(2, init="separated", max_iter=10,
                  chunk_size=CHUNK).run(shards, key=jax.random.key(2))
        assert_same_gmm(old.global_gmm, new.global_gmm)
        assert old.comm == new.comm

    def test_every_shim_warns_exactly_once(self, data, shards):
        """One call, one DeprecationWarning — a shim that warns twice (or
        triggers another shim) spams real migration logs."""
        from repro.core.fedgen import train_locals_from_sources
        from repro.core.kmeans import federated_kmeans_from_sources
        x, _, _ = data
        xj = jnp.asarray(x)
        key = jax.random.key(0)
        calls = {
            "fit_gmm_streaming": lambda: fit_gmm_streaming(
                key, xj, 2, max_iter=3, chunk_size=CHUNK),
            "fedgengmm_from_sources": lambda: fedgengmm_from_sources(
                key, shards, k_clients=2, k_global=2, h=10, max_iter=3),
            "dem_from_sources": lambda: dem_from_sources(
                key, shards, 2, init=1, max_rounds=3),
            "train_locals_from_sources": lambda: train_locals_from_sources(
                key, shards, k=2, max_iter=3),
            "federated_kmeans_from_sources":
                lambda: federated_kmeans_from_sources(key, shards, 2,
                                                      max_iter=3),
        }
        for name, call in calls.items():
            with warnings.catch_warnings(record=True) as caught:
                warnings.simplefilter("always")
                call()
            dep = [w for w in caught
                   if issubclass(w.category, DeprecationWarning)]
            assert len(dep) == 1, (name, [str(w.message) for w in dep])
            assert name in str(dep[0].message)


# ----------------------------------------------------------------------
# Facade scoring helpers
# ----------------------------------------------------------------------

@pytest.mark.slow
class TestScoringHelpers:
    def test_score_log_prob_bic_match_model_methods(self, data):
        x, _, _ = data
        xj = jnp.asarray(x)
        est = GMMEstimator(3, max_iter=10).fit(xj)
        g = est.gmm_
        np.testing.assert_allclose(float(score(g, xj)), float(g.score(xj)),
                                   rtol=1e-6)
        np.testing.assert_allclose(float(bic(g, xj)), float(g.bic(xj)),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(log_prob(g, xj)),
                                   np.asarray(g.log_prob(xj)), rtol=1e-5)

    def test_scorers_accept_sources(self, data):
        x, _, _ = data
        xj = jnp.asarray(x)
        est = GMMEstimator(3, max_iter=10, chunk_size=CHUNK).fit(xj)
        src = ConcatSource([ArraySource(xj[:701]), ArraySource(xj[701:])])
        cfg = FitConfig(chunk_size=CHUNK)
        np.testing.assert_allclose(
            float(score(est.gmm_, src, config=cfg)),
            float(score(est.gmm_, xj, config=cfg)), rtol=1e-6)
        assert log_prob(est.gmm_, src, config=cfg).shape == (len(x),)
