"""Roofline analytic-model validation: the parameter-count formula must
match the ACTUAL parameter tree (eval_shape — no allocation) for every
full-size assigned architecture; FLOPs formulas sanity-checked for
monotonicity/positivity."""
import sys

import jax
import pytest

sys.path.insert(0, ".")  # benchmarks package lives at repo root

from benchmarks.roofline import forward_flops_per_token, n_params, step_flops
from repro.configs import get_config, list_archs
from repro.models import init_params


@pytest.mark.parametrize("arch", list_archs())
def test_n_params_matches_actual_tree(arch):
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))
    actual = sum(int(s.size) for s in jax.tree.leaves(shapes))
    analytic = n_params(cfg)
    # norms/biases are excluded from the analytic model -> tiny slack
    assert abs(actual - analytic) / actual < 0.01, \
        (arch, actual, analytic)


@pytest.mark.parametrize("arch", list_archs())
def test_flops_positive_and_ordered(arch):
    cfg = get_config(arch)
    f_train = step_flops(cfg, "train_4k")
    f_prefill = step_flops(cfg, "prefill_32k")
    f_decode = step_flops(cfg, "decode_32k")
    f_long = step_flops(cfg, "long_500k")
    assert f_train > 0 and f_prefill > 0 and f_decode > 0 and f_long > 0
    # one-token decode is orders below full-batch train
    assert f_decode < f_train / 100, arch
    # a longer context can't be cheaper per token at equal batch
    assert forward_flops_per_token(cfg, 32768) >= \
        forward_flops_per_token(cfg, 1024)


def test_moe_active_params_smaller():
    cfg = get_config("mixtral-8x7b")
    assert n_params(cfg, active_only=True) < 0.5 * n_params(cfg)


def test_known_param_counts():
    """Anchor the formula against the models' published sizes."""
    known = {"deepseek-67b": 67e9, "mixtral-8x7b": 46.7e9,
             "internlm2-1.8b": 1.89e9, "yi-6b": 6.06e9,
             "gemma-7b": 8.5e9}  # gemma counts embeddings (256k vocab)
    for arch, expect in known.items():
        got = n_params(get_config(arch))
        assert abs(got - expect) / expect < 0.12, (arch, got, expect)
