"""Public-API snapshot: the ``repro.api`` surface and the ``FitConfig``
field table are frozen here so accidental drift fails the tier-1 lane.

Growing the surface is fine — do it deliberately by updating BOTH the
code and these snapshots (and DESIGN.md §8, which documents the same
table). Removing or renaming anything here is a breaking change to every
facade caller (examples, benchmarks, downstream scenarios) and must say
so in the PR.
"""
import dataclasses
import inspect

import repro.api as api
from repro.api import (DEM, FedEM, FedGenGMM, FedKMeans, FitConfig,
                       GMMEstimator, KMeansEstimator)

# The one public surface (DESIGN.md §8/§9). Sorted to make diffs readable.
EXPECTED_EXPORTS = sorted([
    "FitConfig",
    "DPConfig",
    "GMMEstimator",
    "KMeansEstimator",
    "FedGenGMM",
    "DEM",
    "FedEM",
    "FedKMeans",
    "fit_federated",
    "score",
    "log_prob",
    "bic",
    "Scorer",
    "DEFAULT_SOURCE_CHUNK",
])

# FitConfig field table: (name, default) in declaration order — the §8
# contract. A changed default silently changes every facade fit, so it is
# pinned as hard as the names. tol/max_iter default "auto" = per-algorithm
# resolution (EM 1e-3/200, k-means 1e-4/100 — TOL_DEFAULTS /
# MAX_ITER_DEFAULTS in repro.core.config).
EXPECTED_FITCONFIG_FIELDS = [
    ("backend", "auto"),
    ("chunk_size", "auto"),
    ("covariance_type", "diag"),
    ("reg_covar", 1e-6),
    ("tol", "auto"),
    ("max_iter", "auto"),
    ("init", "auto"),
    ("seed", 0),
]

# Deprecation shims must never leak into the facade: they live in
# repro.core, warn on use, and forward — the public surface stays the
# estimator/runner set above.
SHIM_NAMES = [
    "fit_gmm_streaming",
    "fedgengmm_from_sources",
    "dem_from_sources",
    "train_locals_from_sources",
    "federated_kmeans_from_sources",
]


class TestSurface:
    def test_all_matches_snapshot(self):
        assert sorted(api.__all__) == EXPECTED_EXPORTS

    def test_exports_resolve(self):
        for name in api.__all__:
            assert getattr(api, name, None) is not None, name

    def test_no_extra_public_names(self):
        """Anything public-looking in the module must be declared in
        __all__ — the facade cannot grow a shadow surface."""
        public = {n for n in dir(api)
                  if not n.startswith("_")
                  and n not in ("estimators", "serving")}
        # submodule imports that back the package are not surface
        assert public - set(api.__all__) == set()


class TestFitConfigFields:
    def test_field_table(self):
        fields = [(f.name, f.default) for f in dataclasses.fields(FitConfig)]
        assert fields == EXPECTED_FITCONFIG_FIELDS

    def test_frozen_and_hashable(self):
        cfg = FitConfig()
        try:
            cfg.tol = 1.0
            raise AssertionError("FitConfig must be frozen")
        except dataclasses.FrozenInstanceError:
            pass
        assert hash(FitConfig(chunk_size=64)) == hash(FitConfig(chunk_size=64))
        assert FitConfig() == FitConfig()


class TestFacadeShape:
    """The estimator-style contract every future scenario PR plugs into."""

    def test_fit_signatures(self):
        for cls in (GMMEstimator, KMeansEstimator):
            params = inspect.signature(cls.fit).parameters
            assert "data" in params and "key" in params
            assert "sample_weight" in params

    def test_run_signatures(self):
        for cls in (FedGenGMM, DEM, FedEM, FedKMeans):
            params = inspect.signature(cls.run).parameters
            assert "clients" in params and "key" in params

    def test_constructors_take_config(self):
        for cls in (GMMEstimator, KMeansEstimator, FedGenGMM, DEM, FedEM,
                    FedKMeans):
            assert "config" in inspect.signature(cls.__init__).parameters

    def test_strategy_seam_signature(self):
        params = inspect.signature(api.fit_federated).parameters
        assert "clients" in params and "strategy" in params
        assert "config" in params and "key" in params


class TestNoShimLeak:
    """The `*_from_sources` / `fit_gmm_streaming` deprecation shims are
    internal: none may appear in the facade's exports or attributes, and
    none may appear as a FitConfig field (the snapshot above would catch
    a field, this catches the names)."""

    def test_shims_not_exported(self):
        for name in SHIM_NAMES:
            assert name not in api.__all__, name
            assert not hasattr(api, name), name

    def test_shims_not_fitconfig_fields(self):
        fields = {f.name for f in dataclasses.fields(FitConfig)}
        assert fields.isdisjoint(SHIM_NAMES)
