"""Public-API snapshot: the ``repro.api`` surface and the ``FitConfig``
field table are frozen here so accidental drift fails the tier-1 lane.

Growing the surface is fine — do it deliberately by updating BOTH the
code and these snapshots (and DESIGN.md §8, which documents the same
table). Removing or renaming anything here is a breaking change to every
facade caller (examples, benchmarks, downstream scenarios) and must say
so in the PR.
"""
import dataclasses
import inspect

import repro.api as api
from repro.api import DEM, FedGenGMM, FitConfig, GMMEstimator, KMeansEstimator

# The one public surface (DESIGN.md §8). Sorted to make diffs readable.
EXPECTED_EXPORTS = sorted([
    "FitConfig",
    "GMMEstimator",
    "KMeansEstimator",
    "FedGenGMM",
    "DEM",
    "score",
    "log_prob",
    "bic",
    "DEFAULT_SOURCE_CHUNK",
])

# FitConfig field table: (name, default) in declaration order — the §8
# contract. A changed default silently changes every facade fit, so it is
# pinned as hard as the names.
EXPECTED_FITCONFIG_FIELDS = [
    ("backend", "auto"),
    ("chunk_size", "auto"),
    ("covariance_type", "diag"),
    ("reg_covar", 1e-6),
    ("tol", 1e-3),
    ("max_iter", 200),
    ("init", "auto"),
    ("seed", 0),
]


class TestSurface:
    def test_all_matches_snapshot(self):
        assert sorted(api.__all__) == EXPECTED_EXPORTS

    def test_exports_resolve(self):
        for name in api.__all__:
            assert getattr(api, name, None) is not None, name

    def test_no_extra_public_names(self):
        """Anything public-looking in the module must be declared in
        __all__ — the facade cannot grow a shadow surface."""
        public = {n for n in dir(api)
                  if not n.startswith("_") and n not in ("estimators",)}
        # submodule imports that back the package are not surface
        assert public - set(api.__all__) == set()


class TestFitConfigFields:
    def test_field_table(self):
        fields = [(f.name, f.default) for f in dataclasses.fields(FitConfig)]
        assert fields == EXPECTED_FITCONFIG_FIELDS

    def test_frozen_and_hashable(self):
        cfg = FitConfig()
        try:
            cfg.tol = 1.0
            raise AssertionError("FitConfig must be frozen")
        except dataclasses.FrozenInstanceError:
            pass
        assert hash(FitConfig(chunk_size=64)) == hash(FitConfig(chunk_size=64))
        assert FitConfig() == FitConfig()


class TestFacadeShape:
    """The estimator-style contract every future scenario PR plugs into."""

    def test_fit_signatures(self):
        for cls in (GMMEstimator, KMeansEstimator):
            params = inspect.signature(cls.fit).parameters
            assert "data" in params and "key" in params
            assert "sample_weight" in params

    def test_run_signatures(self):
        for cls in (FedGenGMM, DEM):
            params = inspect.signature(cls.run).parameters
            assert "clients" in params and "key" in params

    def test_constructors_take_config(self):
        for cls in (GMMEstimator, KMeansEstimator, FedGenGMM, DEM):
            assert "config" in inspect.signature(cls.__init__).parameters
