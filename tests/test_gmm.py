"""GMM primitive tests: log densities vs scipy, sampling moments, BIC."""
import jax
import jax.numpy as jnp
import numpy as np
import scipy.stats as st
from hypothesis import given, settings, strategies as hst

from repro.core.gmm import GMM, merge_gmms, merge_gmms_stacked


def random_gmm(rng, k=3, d=4, full=False):
    w = rng.dirichlet(np.ones(k)).astype(np.float32)
    mu = rng.normal(0, 2, (k, d)).astype(np.float32)
    if full:
        a = rng.normal(0, 1, (k, d, d))
        cov = (a @ np.transpose(a, (0, 2, 1)) + 0.5 * np.eye(d)).astype(np.float32)
    else:
        cov = rng.uniform(0.2, 2.0, (k, d)).astype(np.float32)
    return GMM(jnp.asarray(w), jnp.asarray(mu), jnp.asarray(cov))


class TestLogProb:
    def test_diag_matches_scipy(self, rng):
        g = random_gmm(rng)
        x = rng.normal(0, 2, (50, 4)).astype(np.float32)
        ours = np.asarray(g.log_prob(jnp.asarray(x)))
        dens = np.zeros(50)
        for k in range(3):
            dens += float(g.weights[k]) * st.multivariate_normal(
                np.asarray(g.means[k]), np.diag(np.asarray(g.covs[k]))).pdf(x)
        np.testing.assert_allclose(ours, np.log(dens), rtol=2e-4, atol=2e-4)

    def test_full_matches_scipy(self, rng):
        g = random_gmm(rng, full=True)
        x = rng.normal(0, 2, (50, 4)).astype(np.float32)
        ours = np.asarray(g.log_prob(jnp.asarray(x)))
        dens = np.zeros(50)
        for k in range(3):
            dens += float(g.weights[k]) * st.multivariate_normal(
                np.asarray(g.means[k]), np.asarray(g.covs[k])).pdf(x)
        np.testing.assert_allclose(ours, np.log(dens), rtol=2e-3, atol=2e-3)

    def test_responsibilities_sum_to_one(self, rng):
        g = random_gmm(rng)
        x = jnp.asarray(rng.normal(0, 3, (40, 4)), jnp.float32)
        r = g.responsibilities(x)
        np.testing.assert_allclose(np.asarray(r.sum(1)), 1.0, rtol=1e-5)
        assert (np.asarray(r) >= 0).all()

    def test_density_integrates_lowdim(self, rng):
        # 1-d numeric integration of exp(log_prob) ~= 1
        g = GMM(jnp.array([0.3, 0.7]), jnp.array([[-1.0], [2.0]]),
                jnp.array([[0.5], [1.5]]))
        xs = jnp.linspace(-15, 15, 20001)[:, None]
        p = jnp.exp(g.log_prob(xs))
        integral = float(jnp.trapezoid(p[:, ], dx=30 / 20000))
        assert abs(integral - 1.0) < 1e-3


class TestSampling:
    def test_sample_moments_diag(self, rng):
        g = random_gmm(rng, k=2, d=3)
        x = np.asarray(g.sample(jax.random.key(0), 200_000))
        w = np.asarray(g.weights)
        mu = np.asarray(g.means)
        expected_mean = w @ mu
        np.testing.assert_allclose(x.mean(0), expected_mean, atol=0.03)
        ex2 = w @ (np.asarray(g.covs) + mu ** 2)
        np.testing.assert_allclose((x ** 2).mean(0), ex2, rtol=0.02, atol=0.02)

    def test_sample_moments_full(self, rng):
        g = random_gmm(rng, k=2, d=3, full=True)
        x = np.asarray(g.sample(jax.random.key(1), 200_000))
        w = np.asarray(g.weights)
        mu = np.asarray(g.means)
        np.testing.assert_allclose(x.mean(0), w @ mu, atol=0.05)

    def test_sample_shape_dtype(self, rng):
        g = random_gmm(rng)
        x = g.sample(jax.random.key(0), 17)
        assert x.shape == (17, 4) and x.dtype == jnp.float32


class TestBIC:
    def test_n_free_params(self):
        g = GMM(jnp.ones(5) / 5, jnp.zeros((5, 7)), jnp.ones((5, 7)))
        assert g.n_free_params() == 4 + 35 + 35
        gf = GMM(jnp.ones(5) / 5, jnp.zeros((5, 7)),
                 jnp.broadcast_to(jnp.eye(7), (5, 7, 7)))
        assert gf.n_free_params() == 4 + 35 + 5 * 7 * 8 // 2

    def test_bic_penalizes_complexity_equal_ll(self, rng):
        # duplicate-component GMM has same density but worse (higher) BIC
        g1 = GMM(jnp.array([1.0]), jnp.zeros((1, 2)), jnp.ones((1, 2)))
        g2 = GMM(jnp.array([0.5, 0.5]), jnp.zeros((2, 2)), jnp.ones((2, 2)))
        x = jnp.asarray(rng.normal(0, 1, (500, 2)), jnp.float32)
        assert float(g2.bic(x)) > float(g1.bic(x))


class TestMerge:
    def test_merge_weights_proportional_to_sizes(self, rng):
        g1, g2 = random_gmm(rng), random_gmm(rng)
        m = merge_gmms([g1, g2], jnp.array([100.0, 300.0]))
        assert m.n_components == 6
        np.testing.assert_allclose(float(m.weights.sum()), 1.0, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(m.weights[:3]),
                                   np.asarray(g1.weights) * 0.25, rtol=1e-5)

    def test_merge_stacked_equivalent(self, rng):
        gs = [random_gmm(rng) for _ in range(4)]
        sizes = jnp.array([10.0, 20.0, 30.0, 40.0])
        a = merge_gmms(gs, sizes)
        b = merge_gmms_stacked(jnp.stack([g.weights for g in gs]),
                               jnp.stack([g.means for g in gs]),
                               jnp.stack([g.covs for g in gs]), sizes)
        np.testing.assert_allclose(np.asarray(a.weights), np.asarray(b.weights),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(a.means), np.asarray(b.means))

    def test_merged_density_is_size_weighted_mixture(self, rng):
        g1, g2 = random_gmm(rng), random_gmm(rng)
        m = merge_gmms([g1, g2], jnp.array([1.0, 3.0]))
        x = jnp.asarray(rng.normal(0, 2, (20, 4)), jnp.float32)
        expect = jnp.log(0.25 * jnp.exp(g1.log_prob(x))
                         + 0.75 * jnp.exp(g2.log_prob(x)))
        np.testing.assert_allclose(np.asarray(m.log_prob(x)),
                                   np.asarray(expect), rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(k=hst.integers(1, 8), d=hst.integers(1, 16), seed=hst.integers(0, 10**6))
def test_logprob_finite_property(k, d, seed):
    r = np.random.default_rng(seed)
    g = GMM(jnp.asarray(r.dirichlet(np.ones(k)), jnp.float32),
            jnp.asarray(r.normal(0, 3, (k, d)), jnp.float32),
            jnp.asarray(r.uniform(0.05, 5, (k, d)), jnp.float32))
    x = jnp.asarray(r.normal(0, 5, (32, d)), jnp.float32)
    lp = g.log_prob(x)
    assert bool(jnp.all(jnp.isfinite(lp)))
    r_ = g.responsibilities(x)
    assert bool(jnp.all(jnp.isfinite(r_)))
