"""Compilation-count regression suite for the out-of-core engine.

The per-block dispatch tax this PR kills had two components: re-tracing
(the ragged tail block used to arrive at its own shape, so every stage
compiled twice per stream — and per-N on top for the host-loop helpers)
and per-block dispatch overhead. The pad-and-mask contract
(``prefetch_blocks`` pads every block to ONE static shape per stream and
hands the engine a 0/1 row mask) makes compile counts O(1) in the number
of blocks *and* in the number of distinct non-dividing source lengths.
These tests pin that: the module-level jitted per-block kernels must not
gain cache entries when the same pipeline runs over sources whose length
does not divide the chunk size.

Also pinned here: bit-identity of the prefetching loader against a
synchronous block loop (depth must never reorder or alter blocks), and of
``ShuffledSource`` at epoch 0 against its inner source.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import importlib

import repro.core.em as em

# `import repro.core.kmeans as km` would bind repro.core's re-exported
# `kmeans` *function* (package attribute wins over submodule) — resolve
# the module itself to reach the jitted per-block helpers.
km = importlib.import_module("repro.core.kmeans")
from repro.core.em import e_step_stats, fit_gmm, init_from_kmeans
from repro.core.gmm import GMM
from repro.data.sources import (ArraySource, ShuffledSource, pad_target,
                                prefetch_blocks)

CHUNK = 512  # never divides the Ns below -> every stream has a ragged tail
NS = (2_999, 3_000, 3_001)
D, K = 4, 3


def _make_x(n, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, 2.0, (n, D)).astype(np.float32))


def _gmm():
    rng = np.random.default_rng(1)
    return GMM(jnp.full((K,), 1.0 / K),
               jnp.asarray(rng.normal(0, 2.0, (K, D)).astype(np.float32)),
               jnp.ones((K, D), jnp.float32))


class TestCompileCounts:
    def test_estep_blocks_compile_once_across_ragged_sources(self):
        gmm = _gmm()
        e_step_stats(gmm, ArraySource(_make_x(NS[0])), chunk_size=CHUNK)
        baseline = em._estep_block_reference._cache_size()
        for n in NS[1:]:
            e_step_stats(gmm, ArraySource(_make_x(n)), chunk_size=CHUNK)
        assert em._estep_block_reference._cache_size() == baseline

    def test_fit_gmm_source_blocks_compile_once_across_ragged_sources(self):
        fit_gmm(jax.random.key(0), ArraySource(_make_x(NS[0])), K,
                max_iter=3, chunk_size=CHUNK)
        baseline = em._estep_block_reference._cache_size()
        for n in NS[1:]:
            fit_gmm(jax.random.key(0), ArraySource(_make_x(n)), K,
                    max_iter=3, chunk_size=CHUNK)
        assert em._estep_block_reference._cache_size() == baseline

    def test_kmeans_source_blocks_compile_once_across_ragged_sources(self):
        km.kmeans_source(jax.random.key(0), ArraySource(_make_x(NS[0])),
                             K, max_iter=3, chunk_size=CHUNK)
        lloyd = km._lloyd_block._cache_size()
        seed = km._seed_block._cache_size()
        for n in NS[1:]:
            km.kmeans_source(jax.random.key(0), ArraySource(_make_x(n)),
                                 K, max_iter=3, chunk_size=CHUNK)
        assert km._lloyd_block._cache_size() == lloyd
        assert km._seed_block._cache_size() == seed

    def test_init_from_kmeans_source_compiles_once_across_ragged_sources(
            self):
        init_from_kmeans(jax.random.key(0), ArraySource(_make_x(NS[0])), K,
                         chunk_size=CHUNK)
        label = km.kmeans_label_block._cache_size()
        for n in NS[1:]:
            init_from_kmeans(jax.random.key(0), ArraySource(_make_x(n)), K,
                             chunk_size=CHUNK)
        assert km.kmeans_label_block._cache_size() == label

    def test_every_block_shares_one_padded_shape(self):
        x = _make_x(NS[0])
        shapes = {xb.shape for xb, _ in
                  prefetch_blocks(ArraySource(x), CHUNK)}
        assert shapes == {(CHUNK, D)}

    def test_tiny_source_pads_to_its_64_bucket_not_the_chunk(self):
        x = _make_x(70)
        (xb, wb), = list(prefetch_blocks(ArraySource(x), CHUNK))
        assert xb.shape == (pad_target(70, CHUNK), D) == (128, D)
        assert float(jnp.sum(wb)) == 70.0


class TestLoaderParity:
    @pytest.mark.parametrize("depth", [1, 2, 3])
    def test_prefetch_depth_is_bit_identical_to_sync_loop(self, depth):
        src = ArraySource(_make_x(NS[0]))
        sync = list(prefetch_blocks(src, CHUNK, depth=0))
        pre = list(prefetch_blocks(src, CHUNK, depth=depth))
        assert len(sync) == len(pre)
        for (xs, ws), (xp, wp) in zip(sync, pre):
            np.testing.assert_array_equal(np.asarray(xs), np.asarray(xp))
            np.testing.assert_array_equal(np.asarray(ws), np.asarray(wp))

    def test_abandoned_prefetch_iterator_shuts_down(self):
        src = ArraySource(_make_x(NS[0]))
        it = prefetch_blocks(src, CHUNK, depth=2)
        next(it)
        it.close()  # must not deadlock on the producer thread

    def test_shuffled_epoch0_is_bit_identical_passthrough(self):
        src = ArraySource(_make_x(NS[0]))
        shuffled = ShuffledSource(src, jax.random.key(3), epoch=0)
        plain = list(src.iter_blocks(CHUNK))
        wrapped = list(shuffled.iter_blocks(CHUNK))
        assert len(plain) == len(wrapped)
        for a, b in zip(plain, wrapped):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_shuffled_epoch0_estep_is_bit_identical(self):
        gmm = _gmm()
        src = ArraySource(_make_x(NS[0]))
        base = e_step_stats(gmm, src, chunk_size=CHUNK)
        shuf = e_step_stats(gmm, ShuffledSource(src, jax.random.key(3)),
                            chunk_size=CHUNK)
        for a, b in zip(base, shuf):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestFederatedCompileCounts:
    """Cohort membership is traced, cohort size is static: the jitted
    round loop must hold ONE cache entry per (strategy, m) no matter how
    many rounds run, which clients each round samples, or how the
    sampler/stragglers are re-seeded (seeds are ``compare=False`` fields
    that enter via traced keys)."""

    def _split(self, seed=0):
        from repro.core.partition import partition
        rng = np.random.default_rng(7)
        x = rng.normal(0, 2.0, (900, 3)).astype(np.float32)
        y = rng.integers(0, 3, 900)
        return partition(np.random.default_rng(seed), x, y, 12,
                         "dirichlet", 0.5)

    def test_reseeding_uniform_cohorts_never_retraces(self):
        from repro.api import FedEM
        import repro.fed.runtime as rt
        split = self._split()
        kw = dict(participation=0.25, cohort="uniform", init="separated",
                  max_iter=8)
        FedEM(2, cohort_seed=0, **kw).run(split, key=jax.random.key(0))
        baseline = rt._iterate_jit._cache_size()
        for seed in (1, 2, 3):
            FedEM(2, cohort_seed=seed, **kw).run(split,
                                                 key=jax.random.key(seed))
        assert rt._iterate_jit._cache_size() == baseline

    def test_cyclic_cohorts_share_one_entry_across_keys(self):
        from repro.api import FedEM
        import repro.fed.runtime as rt
        split = self._split()
        kw = dict(participation=0.25, init="separated", max_iter=8)
        FedEM(2, **kw).run(split, key=jax.random.key(0))
        baseline = rt._iterate_jit._cache_size()
        for seed in (4, 5):
            FedEM(2, **kw).run(split, key=jax.random.key(seed))
        assert rt._iterate_jit._cache_size() == baseline

    def test_straggler_reseed_never_retraces(self):
        from repro.api import FedEM
        from repro.fed import ArrivalStragglers
        import repro.fed.runtime as rt
        split = self._split()
        kw = dict(participation=0.5, cohort="uniform", init="separated",
                  max_iter=8)
        FedEM(2, stragglers=ArrivalStragglers(0.25, seed=0), **kw).run(
            split, key=jax.random.key(0))
        baseline = rt._iterate_jit._cache_size()
        for seed in (1, 2):
            FedEM(2, stragglers=ArrivalStragglers(0.25, seed=seed),
                  **kw).run(split, key=jax.random.key(0))
        assert rt._iterate_jit._cache_size() == baseline

    def test_transform_budget_sweep_never_retraces(self):
        # the §11 contract: epsilon/delta/rounds/seed are compare=False
        # and enter the graph as traced leaves, so a budget sweep holds
        # ONE cache entry — the whole point of the static/traced split
        from repro.api import DEM
        from repro.fed import GaussianDP
        import repro.fed.runtime as rt
        split = self._split()
        kw = dict(init="separated", max_iter=6)
        DEM(2, transform=GaussianDP(epsilon=1.0, seed=0), **kw).run(
            split, key=jax.random.key(0))
        baseline = rt._iterate_jit._cache_size()
        for eps, rounds, seed in ((0.5, 1, 1), (2.0, 6, 2), (8.0, 3, 3)):
            DEM(2, transform=GaussianDP(epsilon=eps, rounds=rounds,
                                        seed=seed), **kw).run(
                split, key=jax.random.key(0))
        assert rt._iterate_jit._cache_size() == baseline

    def test_quantize_and_mask_reseed_never_retrace(self):
        from repro.api import DEM
        from repro.fed import PairwiseMask, StochasticQuantize
        import repro.fed.runtime as rt
        split = self._split()
        kw = dict(init="separated", max_iter=6)
        for make in (lambda s: StochasticQuantize(bits=8, seed=s),
                     lambda s: PairwiseMask(seed=s)):
            DEM(2, transform=make(0), **kw).run(split,
                                                key=jax.random.key(0))
            baseline = rt._iterate_jit._cache_size()
            for seed in (1, 2):
                DEM(2, transform=make(seed), **kw).run(
                    split, key=jax.random.key(0))
            assert rt._iterate_jit._cache_size() == baseline

    def test_installing_a_transform_adds_at_most_one_entry(self):
        # None -> Identity is a legitimate retrace (different static
        # arg); swapping between transform FAMILIES is too — but each
        # family holds exactly one entry
        from repro.api import DEM
        from repro.fed import GaussianDP, Identity
        import repro.fed.runtime as rt
        split = self._split()
        kw = dict(init="separated", max_iter=6)
        DEM(2, **kw).run(split, key=jax.random.key(0))
        n0 = rt._iterate_jit._cache_size()
        DEM(2, transform=Identity(), **kw).run(split,
                                               key=jax.random.key(0))
        n1 = rt._iterate_jit._cache_size()
        assert n1 <= n0 + 1
        DEM(2, transform=GaussianDP(), **kw).run(split,
                                                 key=jax.random.key(0))
        n2 = rt._iterate_jit._cache_size()
        assert n2 <= n1 + 1
        DEM(2, transform=GaussianDP(epsilon=5.0), **kw).run(
            split, key=jax.random.key(0))
        assert rt._iterate_jit._cache_size() == n2
