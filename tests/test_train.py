"""Trainer substrate tests: optimizer, schedule, checkpoint roundtrip, and
an end-to-end loss-decrease run on a tiny arch."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.optim import (AdamWConfig, apply_updates, init_opt_state,
                         schedule)


class TestAdamW:
    def test_minimizes_quadratic(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                          total_steps=200)
        params = {"w": jnp.array([5.0, -3.0])}
        state = init_opt_state(params)
        for _ in range(150):
            grads = {"w": 2 * params["w"]}
            params, state, _ = apply_updates(params, grads, state, cfg)
        assert float(jnp.abs(params["w"]).max()) < 0.1

    def test_clip_norm(self):
        cfg = AdamWConfig(lr=1e-3, clip_norm=1.0)
        params = {"w": jnp.zeros(3)}
        state = init_opt_state(params)
        _, _, m = apply_updates(params, {"w": jnp.full(3, 1e6)}, state, cfg)
        assert float(m["grad_norm"]) > 1.0  # pre-clip norm reported

    def test_schedule_warmup_and_decay(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
        assert float(schedule(cfg, jnp.asarray(5))) < 1.0
        peak = float(schedule(cfg, jnp.asarray(10)))
        end = float(schedule(cfg, jnp.asarray(100)))
        assert peak > end
        assert end >= 0.1 * cfg.lr - 1e-6  # floor at 10%

    def test_weight_decay_shrinks(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.5, warmup_steps=1,
                          total_steps=10)
        params = {"w": jnp.array([10.0])}
        state = init_opt_state(params)
        p2, _, _ = apply_updates(params, {"w": jnp.zeros(1)}, state, cfg)
        assert float(p2["w"][0]) < 10.0


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "nested": {"b": jnp.ones(4, jnp.bfloat16)},
                "lst": [jnp.zeros(2), jnp.full((1,), 7.0)]}
        save_checkpoint(str(tmp_path / "ck"), tree, {"step": 3})
        restored, meta = load_checkpoint(str(tmp_path / "ck"), tree)
        assert meta["step"] == 3
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
            assert a.dtype == b.dtype


def test_train_loss_decreases():
    from repro.launch.train import train
    _, losses = train("internlm2-1.8b", "smoke", steps=15, batch_size=4,
                      seq_len=64, log_every=100)
    assert losses[-1] < losses[0], losses
    assert np.isfinite(losses).all()
