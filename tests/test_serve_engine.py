"""The serving engine contract (DESIGN.md §10): continuous batching over
one compiled slab shape, scores bit-identical to ``repro.api`` scoring,
and the drain-and-install hot swap — version flips at exactly one
boundary, no request dropped, every result tagged with the one model
that scored it. Plus the versioned checkpoint publish/subscribe seam the
swap rides on (atomicity by write-then-rename, bf16 round-trip, loader
errors that name the offending leaf).
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import GMMEstimator, Scorer, log_prob
from repro.checkpoint import (latest_version, load_checkpoint,
                              load_published, publish_checkpoint,
                              save_checkpoint)
from repro.core.gmm import GMM
from repro.serve import (ModelStore, ScoreConfig, ScoreRequest,
                         ScoringEngine, SlotPool)

DIM = 5


@pytest.fixture(scope="module")
def fitted():
    """Two distinct fitted models over the same feature space — the
    swap's before/after pair — plus a held-out scoring stream."""
    rng = np.random.default_rng(3)
    x = np.concatenate([rng.normal(m, 1.0, (400, DIM))
                        for m in (0.0, 5.0, 9.0)]).astype(np.float32)
    gmm_a = GMMEstimator(k=3, seed=0).fit(x).gmm_
    gmm_b = GMMEstimator(k=3, seed=7).fit(x[::2] + 0.25).gmm_
    return gmm_a, gmm_b, x


def _requests(rng, sizes):
    return [ScoreRequest(i, rng.normal(2.0, 3.0, (n, DIM)))
            for i, n in enumerate(sizes)]


# ----------------------------------------------------------------------
# Correctness: engine scores == repro.api scores, bit for bit
# ----------------------------------------------------------------------

class TestEngineScores:
    # 130/700 stream across micro-batches (> rows_per_slot), 64 fills a
    # slot exactly, 1 and 5 pad, 0 never occupies a slot.
    SIZES = (130, 5, 64, 700, 1, 0)

    def test_bit_identical_to_api_log_prob(self, fitted):
        gmm, _, _ = fitted
        reqs = _requests(np.random.default_rng(11), self.SIZES)
        eng = ScoringEngine(gmm, ScoreConfig(slots=3, rows_per_slot=64))
        got = {r.rid: r for r in eng.run(reqs)}
        assert len(got) == len(reqs)
        for req in reqs:
            res = got[req.rid]
            assert res.scores.shape == (req.num_rows,)
            assert res.scores.dtype == np.float32
            if req.num_rows:
                ref = np.asarray(log_prob(gmm, req.rows))
                np.testing.assert_array_equal(res.scores, ref)

    def test_slot_geometry_invariant(self, fitted):
        """Scores cannot depend on pool geometry: (3 slots x 64 rows)
        and (1 slot x 256 rows) produce identical bits."""
        gmm, _, _ = fitted
        reqs = _requests(np.random.default_rng(12), self.SIZES)
        a = {r.rid: r.scores for r in ScoringEngine(
            gmm, ScoreConfig(slots=3, rows_per_slot=64)).run(reqs)}
        b = {r.rid: r.scores for r in ScoringEngine(
            gmm, ScoreConfig(slots=1, rows_per_slot=256)).run(reqs)}
        for rid in a:
            np.testing.assert_array_equal(a[rid], b[rid])

    def test_anomaly_is_negated_log_prob(self, fitted):
        gmm, _, _ = fitted
        reqs = _requests(np.random.default_rng(13), (40, 3))
        eng = ScoringEngine(gmm, ScoreConfig(mode="anomaly", slots=2,
                                             rows_per_slot=32))
        for res in eng.run(reqs):
            ref = np.asarray(log_prob(gmm, reqs[res.rid].rows))
            np.testing.assert_array_equal(res.scores, -ref)

    def test_responsibilities_mode(self, fitted):
        gmm, _, _ = fitted
        reqs = _requests(np.random.default_rng(14), (70, 0, 9))
        eng = ScoringEngine(gmm, ScoreConfig(mode="responsibilities",
                                             slots=2, rows_per_slot=32))
        for res in eng.run(reqs):
            n = reqs[res.rid].num_rows
            assert res.scores.shape == (n, 3)
            if n:
                ref = np.asarray(
                    gmm.responsibilities(jnp.asarray(reqs[res.rid].rows)))
                np.testing.assert_allclose(res.scores, ref, atol=1e-6)
                np.testing.assert_allclose(res.scores.sum(axis=1), 1.0,
                                           atol=1e-5)

    def test_continuous_admission_mid_flight(self, fitted):
        """A request submitted while another streams through its slot is
        admitted into a free slot immediately — no lockstep waves."""
        gmm, _, _ = fitted
        eng = ScoringEngine(gmm, ScoreConfig(slots=2, rows_per_slot=16))
        rng = np.random.default_rng(15)
        long = ScoreRequest(0, rng.normal(size=(100, DIM)))  # 7 steps
        eng.submit(long)
        eng.step()
        late = ScoreRequest(1, rng.normal(size=(8, DIM)))
        eng.submit(late)
        finished = eng.step()  # late rides the free slot this very step
        assert [r.rid for r in finished] == [1]
        (rest,) = eng.drain()
        assert rest.rid == 0 and rest.scores.shape == (100,)

    def test_single_compile_across_admissions(self, fitted):
        """The hot path traces once per engine config — admitting,
        retiring and re-seeding requests never retraces."""
        gmm, _, _ = fitted
        cfg = ScoreConfig(slots=2, rows_per_slot=32)
        eng = ScoringEngine(gmm, cfg)
        reqs = _requests(np.random.default_rng(16), (100, 10, 33, 1))
        with jax.log_compiles():  # smoke: must not crash
            eng.run(reqs)
        from repro.serve.engine import _score_slab
        before = _score_slab._cache_size()
        eng.run(_requests(np.random.default_rng(17), (64, 2, 90)))
        assert _score_slab._cache_size() == before

    def test_submit_validates(self, fitted):
        gmm, _, _ = fitted
        eng = ScoringEngine(gmm)
        with pytest.raises(TypeError, match="ScoreRequest"):
            eng.submit(np.zeros((3, DIM)))
        with pytest.raises(ValueError, match="dim"):
            eng.submit(ScoreRequest(0, np.zeros((3, DIM + 1))))

    def test_config_validation(self):
        with pytest.raises(ValueError, match="mode"):
            ScoreConfig(mode="density")
        with pytest.raises(ValueError, match="backend"):
            ScoreConfig(backend="pallas")
        with pytest.raises(ValueError, match="slots"):
            ScoreConfig(slots=0)
        with pytest.raises(ValueError, match="rows must be"):
            ScoreRequest(0, np.zeros(DIM))


# ----------------------------------------------------------------------
# Hot swap: drain-and-install
# ----------------------------------------------------------------------

class TestHotSwap:
    def test_idle_swap_is_immediate(self, fitted):
        gmm_a, gmm_b, _ = fitted
        eng = ScoringEngine(gmm_a, version=1)
        eng.install(gmm_b, 2)
        assert eng.version == 2 and not eng.swap_pending
        assert eng.swaps == 1

    def test_swap_boundary_exact(self, fitted):
        """The full guarantee, mid-stream: every result is bit-identical
        to a fresh single-model engine holding its tagged version, the
        version tag flips at exactly one admission boundary, and no
        request is lost."""
        gmm_a, gmm_b, _ = fitted
        rng = np.random.default_rng(21)
        sizes = (50, 40, 33, 20, 10, 7, 64, 1)
        reqs = _requests(rng, sizes)
        cfg = ScoreConfig(slots=2, rows_per_slot=16)

        eng = ScoringEngine(gmm_a, cfg, version=1)
        for req in reqs[:4]:
            eng.submit(req)
        results = eng.step()          # slots busy, cursors mid-request
        eng.install(gmm_b, 2)         # swap lands mid-flight
        assert eng.swap_pending
        for req in reqs[4:]:
            eng.submit(req)           # queued behind the drain
        results += eng.drain()
        assert not eng.swap_pending and eng.version == 2
        assert eng.swaps == 1 and len(eng.swap_pauses) == 1

        # no request lost, each scored by exactly one model
        assert sorted(r.rid for r in results) == list(range(len(reqs)))
        by_rid = {r.rid: r for r in results}
        ref = {1: {r.rid: r.scores for r in ScoringEngine(
                   gmm_a, cfg, version=1).run(reqs)},
               2: {r.rid: r.scores for r in ScoringEngine(
                   gmm_b, cfg, version=2).run(reqs)}}
        for rid, res in by_rid.items():
            np.testing.assert_array_equal(
                res.scores, ref[res.model_version][rid])

        # the tag flips exactly once across the admission order (rids
        # were submitted in order and admission is FIFO)
        versions = [by_rid[rid].model_version for rid in range(len(reqs))]
        assert versions == sorted(versions)       # 1...1 then 2...2
        assert set(versions) == {1, 2}
        # exactly the requests ADMITTED before the install (the 2 slots'
        # occupants) stayed on the old model; the still-queued tail and
        # everything submitted later ride the new one
        assert versions[:2] == [1, 1] and versions[2:] == [2] * 6

    def test_admission_stalls_only_while_draining(self, fitted):
        gmm_a, gmm_b, _ = fitted
        eng = ScoringEngine(gmm_a, ScoreConfig(slots=1, rows_per_slot=8),
                            version=1)
        rng = np.random.default_rng(22)
        eng.submit(ScoreRequest(0, rng.normal(size=(24, DIM))))
        eng.step()
        eng.install(gmm_b, 2)
        eng.submit(ScoreRequest(1, rng.normal(size=(4, DIM))))
        stalled = eng.step()          # old request still draining
        assert [r.rid for r in stalled] == []
        assert eng.queued == 1 and eng.swap_pending
        rest = eng.drain()
        assert [r.model_version for r in rest] == [1, 2]
        assert eng.swap_pauses[0] >= 0.0

    def test_latest_wins_while_pending(self, fitted):
        gmm_a, gmm_b, _ = fitted
        eng = ScoringEngine(gmm_a, ScoreConfig(slots=1, rows_per_slot=4),
                            version=1)
        eng.submit(ScoreRequest(0, np.zeros((9, DIM), np.float32)))
        eng.step()
        eng.install(gmm_b, 2)
        eng.install(gmm_a, 3)         # replaces the pending install
        eng.drain()
        assert eng.version == 3 and eng.swaps == 1

    def test_swap_rejects_dim_change(self, fitted):
        gmm_a, _, _ = fitted
        other = GMM(jnp.ones(2) / 2, jnp.zeros((2, DIM + 1)),
                    jnp.ones((2, DIM + 1)))
        eng = ScoringEngine(gmm_a)
        with pytest.raises(ValueError, match="feature"):
            eng.install(other, 2)


# ----------------------------------------------------------------------
# ModelStore: versioned publish/subscribe
# ----------------------------------------------------------------------

class TestModelStore:
    def test_publish_poll_roundtrip(self, fitted, tmp_path):
        gmm_a, _, _ = fitted
        store = ModelStore(tmp_path)
        assert store.latest() is None and store.poll() is None
        v = store.publish(gmm_a, {"round": 0})
        assert v == 1 and store.latest_version() == 1
        published = store.poll()
        assert published.version == 1
        assert published.metadata["round"] == 0
        for got, want in zip(jax.tree_util.tree_leaves(published.gmm),
                             jax.tree_util.tree_leaves(gmm_a)):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert store.poll() is None   # seen — fires once

    def test_poll_jumps_to_latest(self, fitted, tmp_path):
        gmm_a, gmm_b, _ = fitted
        store = ModelStore(tmp_path)
        store.publish(gmm_a)
        store.publish(gmm_b)
        store.publish(gmm_a)
        assert store.poll().version == 3  # intermediates skipped
        assert store.poll() is None

    def test_subscriber_cursors_are_independent(self, fitted, tmp_path):
        gmm_a, _, _ = fitted
        pub, sub = ModelStore(tmp_path), ModelStore(tmp_path)
        pub.publish(gmm_a)
        assert pub.poll() is not None
        assert sub.poll() is not None  # its own cursor

    def test_load_errors(self, fitted, tmp_path):
        gmm_a, _, _ = fitted
        store = ModelStore(tmp_path)
        with pytest.raises(FileNotFoundError):
            store.load(None)
        store.publish(gmm_a)
        with pytest.raises(ValueError, match="never published"):
            store.load(5)
        with pytest.raises(TypeError, match="GMM"):
            store.publish(np.zeros(3))

    def test_engine_follows_store(self, fitted, tmp_path):
        """End to end: publish round 1, serve, publish round 2 mid-stream
        — the engine hot-swaps in and tags results correctly."""
        gmm_a, gmm_b, _ = fitted
        store = ModelStore(tmp_path)
        store.publish(gmm_a)
        eng = ScoringEngine.from_store(
            ModelStore(tmp_path), ScoreConfig(slots=1, rows_per_slot=8))
        assert eng.version == 1
        rng = np.random.default_rng(31)
        rows0 = rng.normal(size=(20, DIM)).astype(np.float32)
        rows1 = rng.normal(size=(4, DIM)).astype(np.float32)
        eng.submit(ScoreRequest(0, rows0))
        eng.step()
        store.publish(gmm_b)          # a new round lands mid-request
        eng.submit(ScoreRequest(1, rows1))
        results = {r.rid: r for r in eng.drain()}
        assert results[0].model_version == 1
        assert results[1].model_version == 2
        np.testing.assert_array_equal(results[0].scores,
                                      np.asarray(log_prob(gmm_a, rows0)))
        np.testing.assert_array_equal(results[1].scores,
                                      np.asarray(log_prob(gmm_b, rows1)))

    def test_from_store_empty_raises(self, tmp_path):
        with pytest.raises(FileNotFoundError, match="no published"):
            ScoringEngine.from_store(ModelStore(tmp_path))


# ----------------------------------------------------------------------
# Scorer facade
# ----------------------------------------------------------------------

class TestScorerFacade:
    def test_from_checkpoint_and_follow(self, fitted, tmp_path):
        gmm_a, gmm_b, x = fitted
        store = ModelStore(tmp_path)
        store.publish(gmm_a)
        scorer = Scorer.from_checkpoint(tmp_path, "anomaly", slots=2)
        assert scorer.model_version == 1
        got = scorer.score(x[:33])
        np.testing.assert_array_equal(got, -np.asarray(log_prob(gmm_a,
                                                                x[:33])))
        store.publish(gmm_b)          # next batch served by round 2
        got2 = scorer.score(x[:33])
        assert scorer.model_version == 2
        np.testing.assert_array_equal(got2, -np.asarray(log_prob(gmm_b,
                                                                 x[:33])))

    def test_pinned_version_never_follows(self, fitted, tmp_path):
        gmm_a, gmm_b, x = fitted
        store = ModelStore(tmp_path)
        store.publish(gmm_a)
        store.publish(gmm_b)
        scorer = Scorer.from_checkpoint(tmp_path, version=1)
        store.publish(gmm_b)
        scorer.score(x[:5])
        assert scorer.model_version == 1

    def test_empty_store_raises(self, tmp_path):
        with pytest.raises(FileNotFoundError, match="no published"):
            Scorer.from_checkpoint(tmp_path)


# ----------------------------------------------------------------------
# Checkpoint store: loader errors + dtype round-trip + atomicity
# ----------------------------------------------------------------------

class TestCheckpointStore:
    def test_missing_leaf_names_key(self, tmp_path):
        tree = {"w": jnp.ones(3), "mu": jnp.zeros((3, 2))}
        path = tmp_path / "ckpt"
        save_checkpoint(path, {"w": tree["w"]})
        with pytest.raises(ValueError, match=r"missing pytree leaf 'mu'"):
            load_checkpoint(path, tree)

    def test_shape_mismatch_names_key(self, tmp_path):
        path = tmp_path / "ckpt"
        save_checkpoint(path, {"w": jnp.ones(3)})
        with pytest.raises(ValueError,
                           match=r"leaf 'w' has shape \(3,\)"):
            load_checkpoint(path, {"w": jnp.ones(4)})

    def test_bf16_roundtrip_exact(self, tmp_path):
        """bf16 -> f32 npz -> bf16 is exact (f32 holds every bf16 value),
        and the restored leaf keeps the template dtype."""
        rng = np.random.default_rng(5)
        w = jnp.asarray(rng.normal(0, 3, (4, 7)).astype(np.float32)
                        ).astype(jnp.bfloat16)
        path = tmp_path / "ckpt"
        save_checkpoint(path, {"w": w})
        restored, _ = load_checkpoint(path, {"w": jnp.zeros((4, 7),
                                                            jnp.bfloat16)})
        assert restored["w"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(restored["w"], np.float32),
                                      np.asarray(w, np.float32))

    def test_publish_is_versioned_and_atomic(self, fitted, tmp_path):
        gmm_a, _, _ = fitted
        assert latest_version(tmp_path) is None
        v1 = publish_checkpoint(tmp_path, gmm_a, {"round": 1})
        v2 = publish_checkpoint(tmp_path, gmm_a, {"round": 2})
        assert (v1, v2) == (1, 2)
        # no tmp litter: the write-then-rename protocol leaves only the
        # published artifacts
        names = sorted(p.name for p in tmp_path.iterdir())
        assert names == ["LATEST", "model-000001.json", "model-000001.npz",
                         "model-000002.json", "model-000002.npz"]
        gmm, meta, v = load_published(tmp_path, gmm_a)
        assert v == 2 and meta["round"] == 2 and meta["version"] == 2
        assert set(meta["leaves"]) == {"0", "1", "2"}
        with pytest.raises(ValueError, match="never published"):
            load_published(tmp_path, gmm_a, version=9)

    def test_publish_survives_stale_latest(self, fitted, tmp_path):
        """A torn LATEST pointer (crash between renames) must not wedge
        the stream: the next publish scans and moves past it."""
        gmm_a, _, _ = fitted
        publish_checkpoint(tmp_path, gmm_a)
        os.remove(tmp_path / "LATEST")
        v = publish_checkpoint(tmp_path, gmm_a)
        assert v == 2
        assert json.loads((tmp_path / "LATEST").read_text())["version"] == 2


# ----------------------------------------------------------------------
# SlotPool bookkeeping
# ----------------------------------------------------------------------

class TestSlotPool:
    def test_admit_overflow_raises(self):
        pool = SlotPool(1, 4, DIM)
        from repro.serve.slots import InFlight
        pool.admit(InFlight(ScoreRequest(0, np.zeros((2, DIM))), 0.0, 1))
        assert pool.free == 0
        with pytest.raises(RuntimeError, match="full"):
            pool.admit(InFlight(ScoreRequest(1, np.zeros((2, DIM))),
                                0.0, 1))

    def test_geometry_validation(self):
        with pytest.raises(ValueError, match="positive"):
            SlotPool(0, 4, DIM)
