"""The uplink-transform seam (DESIGN.md §11): bit-identity anchors,
mask cancellation through the real backend reduces, DP mechanics and the
epsilon accountant, quantization, composition, and validation.

The bit-identity classes are the §11 contract's teeth: a run under
``Identity`` — and under ``PairwiseMask``, whose modular channel must
cancel exactly — is compared to a no-transform run with
``assert_array_equal``, never ``allclose``, on the split AND source
backends (the sharded backend is pinned in a forced-8-device subprocess,
mirroring tests/test_distributed.py).
"""
import dataclasses
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as hst

from repro.api import (DEM, DPConfig, FedEM, FedGenGMM, FedKMeans,
                       FitConfig, fit_federated)
from repro.core.em import SufficientStats
from repro.core.gmm import GMM
from repro.core.partition import partition
from repro.core.privacy import privatize_clients, privatize_gmm
from repro.data.sources import ArraySource
from repro.fed import (Compose, GaussianDP, Identity, PairwiseMask,
                       PayloadTransform, StochasticQuantize)
from repro.fed.runtime import _validate_transform
from repro.fed.transforms import (VAR_MAX, VAR_MIN, WEIGHT_FLOOR,
                                  clip_variances, gaussian_sigma,
                                  project_simplex)

# end-to-end fits: multi-second EM training loops on CPU
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def split():
    # features in [0,1]^d — the normalization the DP sensitivities assume
    rng = np.random.default_rng(7)
    x = rng.uniform(0.05, 0.95, size=(600, 3)).astype(np.float32)
    y = rng.integers(0, 2, size=600)
    return partition(rng, x, y, 4, "dirichlet", 100.0)


@pytest.fixture(scope="module")
def sources(split):
    parts = [np.asarray(split.data[i])[np.asarray(split.mask[i]) > 0.0]
             for i in range(split.data.shape[0])]
    assert all(len(p) for p in parts)
    return [ArraySource(p) for p in parts]


def assert_same_gmm(g1, g2):
    for f in ("weights", "means", "covs"):
        np.testing.assert_array_equal(np.asarray(getattr(g1, f)),
                                      np.asarray(getattr(g2, f)))


def _gmm(k=2, d=3, seed=0):
    rng = np.random.default_rng(seed)
    w = rng.dirichlet(np.ones(k)).astype(np.float32)
    mu = rng.uniform(0.1, 0.9, (k, d)).astype(np.float32)
    var = rng.uniform(0.01, 0.2, (k, d)).astype(np.float32)
    return GMM(jnp.asarray(w), jnp.asarray(mu), jnp.asarray(var))


def _stats(k=2, d=3, seed=0):
    rng = np.random.default_rng(seed)
    return SufficientStats(
        s0=jnp.asarray(rng.uniform(1, 50, (k,)).astype(np.float32)),
        s1=jnp.asarray(rng.uniform(0, 30, (k, d)).astype(np.float32)),
        s2=jnp.asarray(rng.uniform(0, 20, (k, d)).astype(np.float32)),
        loglik=jnp.float32(-123.5), wsum=jnp.float32(100.0))


# ----------------------------------------------------------------------
# Bit-identity anchors: Identity and PairwiseMask leave fits untouched
# ----------------------------------------------------------------------

class TestBitIdentity:
    @pytest.mark.parametrize("transform", [Identity(), PairwiseMask()],
                             ids=["identity", "mask"])
    def test_dem_split_backend(self, split, transform):
        base = DEM(2, max_iter=4).run(split, key=jax.random.key(0))
        got = DEM(2, max_iter=4, transform=transform).run(
            split, key=jax.random.key(0))
        assert_same_gmm(base.global_gmm, got.global_gmm)
        assert int(base.n_rounds) == int(got.n_rounds)

    @pytest.mark.parametrize("transform", [Identity(), PairwiseMask()],
                             ids=["identity", "mask"])
    def test_dem_source_backend(self, sources, transform):
        base = DEM(2, max_iter=4).run(sources, key=jax.random.key(0))
        got = DEM(2, max_iter=4, transform=transform).run(
            sources, key=jax.random.key(0))
        assert_same_gmm(base.global_gmm, got.global_gmm)

    @pytest.mark.parametrize("transform", [Identity(), PairwiseMask()],
                             ids=["identity", "mask"])
    def test_fedem_split_backend(self, split, transform):
        kw = dict(participation=0.5, local_epochs=2, cohort="cyclic")
        base = FedEM(2, max_iter=6, **kw).run(split, key=jax.random.key(1))
        got = FedEM(2, max_iter=6, transform=transform, **kw).run(
            split, key=jax.random.key(1))
        assert_same_gmm(base.global_gmm, got.global_gmm)

    def test_fedkmeans_identity(self, split):
        base = FedKMeans(2, max_iter=4).run(split, key=jax.random.key(2))
        got = FedKMeans(2, max_iter=4, transform=Identity()).run(
            split, key=jax.random.key(2))
        np.testing.assert_array_equal(np.asarray(base.centers),
                                      np.asarray(got.centers))

    def test_fedgen_identity(self, split):
        base = FedGenGMM(k_clients=2, k_global=2).run(
            split, key=jax.random.key(3))
        got = FedGenGMM(k_clients=2, k_global=2, transform=Identity()).run(
            split, key=jax.random.key(3))
        assert_same_gmm(base.global_gmm, got.global_gmm)

    def test_sharded_backend_subprocess(self):
        script = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = \
                "--xla_force_host_platform_device_count=8"
            import json
            import jax, jax.numpy as jnp
            import numpy as np
            from repro.core.partition import partition
            from repro.distributed import dem_sharded
            from repro.core.dem import fed_kmeans_centers
            from repro.fed import GaussianDP, Identity, PairwiseMask

            mesh = jax.make_mesh((8,), ("data",))
            rng = np.random.default_rng(0)
            x = rng.uniform(0.05, 0.95, (1600, 3)).astype(np.float32)
            y = rng.integers(0, 2, 1600)
            split = partition(rng, x, y, 16, "dirichlet", 100.0)
            data = jnp.asarray(split.data); mask = jnp.asarray(split.mask)
            centers = fed_kmeans_centers(jax.random.key(1), split, 2)

            def run(t):
                g, r = dem_sharded(mesh, jax.random.key(2), data, mask, 2,
                                   centers, max_rounds=4, transform=t)
                return [np.asarray(g.weights).tolist(),
                        np.asarray(g.means).tolist(),
                        np.asarray(g.covs).tolist()]

            base = run(None)
            out = {
                "identity_same": run(Identity()) == base,
                "mask_same": run(PairwiseMask()) == base,
                "dp_differs": run(GaussianDP(epsilon=2.0, rounds=4))
                              != base,
            }
            print(json.dumps(out))
        """)
        res = subprocess.run([sys.executable, "-c", script],
                             capture_output=True, text=True, check=True)
        out = json.loads(res.stdout.strip().splitlines()[-1])
        assert out["identity_same"], "sharded Identity run drifted"
        assert out["mask_same"], "sharded PairwiseMask run drifted"
        assert out["dp_differs"], "sharded GaussianDP run did not perturb"


# ----------------------------------------------------------------------
# Mask cancellation: exactly zero through modular integer summation
# ----------------------------------------------------------------------

class TestMaskCancellation:
    def test_masks_sum_to_exact_zero(self):
        t = PairwiseMask(seed=3)
        key = jax.random.key(3)
        members = jnp.arange(5)
        payload = {"a": jnp.ones((4, 2), jnp.float32),
                   "b": jnp.zeros((3,), jnp.float32)}
        total = None
        for i in range(5):
            # every client derives from the SAME round key — that is
            # what lets pair (i, j) agree on the stream to cancel
            m = t.mask(key, payload, i, members)
            total = m if total is None else jax.tree.map(
                jnp.add, total, m)
        for leaf in jax.tree.leaves(total):
            np.testing.assert_array_equal(np.asarray(leaf), 0)

    def test_masked_channel_sum_equals_unmasked_lattice_sum(self):
        t = PairwiseMask(seed=9)
        key = jax.random.key(9)
        members = jnp.arange(4)
        rng = np.random.default_rng(1)
        payloads = [jnp.asarray(rng.normal(0, 1, (3, 2)).astype(np.float32))
                    for _ in range(4)]
        wires = [t.apply(key, (), p, i, members)
                 for i, p in enumerate(payloads)]
        masked_sum = sum(w["secagg"] for w in wires)
        plain_sum = sum(t._lattice(p) for p in payloads)
        np.testing.assert_array_equal(np.asarray(masked_sum),
                                      np.asarray(plain_sum))

    def test_single_wire_is_not_the_plain_lattice(self):
        # the whole point: one client's wire is masked (differs from its
        # own lattice) even though the SUM is exact
        t = PairwiseMask(seed=9)
        members = jnp.arange(4)
        p = jnp.ones((3, 2), jnp.float32)
        w = t.apply(jax.random.key(9), (), p, 0, members)
        assert np.any(np.asarray(w["secagg"]) != np.asarray(t._lattice(p)))

    def test_finish_strips_the_channel(self):
        t = PairwiseMask()
        total = {"payload": jnp.arange(3.0), "secagg": jnp.zeros(3,
                                                                 jnp.int32)}
        np.testing.assert_array_equal(np.asarray(t.finish(total)),
                                      np.asarray(jnp.arange(3.0)))


# ----------------------------------------------------------------------
# GaussianDP mechanics and the epsilon accountant
# ----------------------------------------------------------------------

class TestGaussianDP:
    def test_gmm_release_respects_projections(self):
        t = GaussianDP(epsilon=0.5)
        rel, n = t.apply(jax.random.key(0), t.traced(), (_gmm(), 200.0),
                         0, None)
        w = np.asarray(rel.weights)
        assert np.isclose(w.sum(), 1.0, atol=1e-6)
        assert (w > 0).all()
        mu = np.asarray(rel.means)
        assert (mu >= 0.0).all() and (mu <= 1.0).all()
        var = np.asarray(rel.covs)
        assert (var >= VAR_MIN).all() and (var <= VAR_MAX).all()
        assert float(n) == 200.0

    def test_noise_shrinks_with_epsilon(self):
        g = _gmm()
        key = jax.random.key(1)

        def err(eps):
            t = GaussianDP(epsilon=eps)
            rel, _ = t.apply(key, t.traced(), (g, 500.0), 0, None)
            return float(jnp.mean(jnp.abs(rel.means - g.means)))

        assert err(100.0) < err(0.2)

    def test_stats_release_floors_and_telemetry(self):
        t = GaussianDP(epsilon=1.0)
        s = _stats()
        rel = t.apply(jax.random.key(2), t.traced(), s, 0, None)
        assert (np.asarray(rel.s0) >= 0.0).all()
        assert (np.asarray(rel.s2) >= 0.0).all()
        assert np.any(np.asarray(rel.s1) != np.asarray(s.s1))
        # loglik / wsum are convergence telemetry, not model payload
        np.testing.assert_array_equal(np.asarray(rel.loglik),
                                      np.asarray(s.loglik))
        np.testing.assert_array_equal(np.asarray(rel.wsum),
                                      np.asarray(s.wsum))

    def test_unknown_payload_raises(self):
        t = GaussianDP()
        with pytest.raises(TypeError, match="SufficientStats"):
            t.apply(jax.random.key(0), t.traced(), jnp.zeros(3), 0, None)

    def test_accountant_depletes_across_rounds(self, split):
        # iterative run: each round spends epsilon/rounds; the ledger
        # reports spend at the REALIZED round count
        t = GaussianDP(epsilon=4.0, rounds=4)
        res = DEM(2, max_iter=4, tol=0.0, transform=t).run(
            split, key=jax.random.key(0))
        assert int(res.n_rounds) == 4
        assert np.isclose(res.comm.epsilon_spent, 4.0)
        assert np.isclose(res.comm.epsilon_spent,
                          t.epsilon_per_round() * int(res.n_rounds))

    def test_one_shot_spends_whole_budget_once(self, split):
        res = FedGenGMM(k_clients=2, k_global=2,
                        dp=DPConfig(epsilon=4.0)).run(
            split, key=jax.random.key(0))
        assert int(res.comm.rounds) == 1
        assert np.isclose(res.comm.epsilon_spent, 4.0)

    def test_dp_perturbs_but_preserves_structure(self, split):
        base = DEM(2, max_iter=4).run(split, key=jax.random.key(0))
        noisy = DEM(2, max_iter=4,
                    transform=GaussianDP(epsilon=2.0, rounds=4)).run(
            split, key=jax.random.key(0))
        assert np.any(np.asarray(noisy.global_gmm.means) !=
                      np.asarray(base.global_gmm.means))
        assert (np.asarray(noisy.global_gmm.covs) > 0).all()
        w = np.asarray(noisy.global_gmm.weights)
        assert np.isclose(w.sum(), 1.0, atol=1e-5)


# ----------------------------------------------------------------------
# Stochastic quantization
# ----------------------------------------------------------------------

class TestStochasticQuantize:
    def test_seeded_determinism_and_unbiased_grid(self):
        t = StochasticQuantize(bits=8)
        x = jnp.asarray(np.random.default_rng(0).normal(
            0, 1, (64, 8)).astype(np.float32))
        a = t.apply(jax.random.key(5), (), x, 0, None)
        b = t.apply(jax.random.key(5), (), x, 0, None)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        c = t.apply(jax.random.key(6), (), x, 0, None)
        assert np.any(np.asarray(a) != np.asarray(c))
        # grid step bounds the per-element error
        step = float(jnp.max(jnp.abs(x))) / 127.0
        assert float(jnp.max(jnp.abs(a - x))) <= step + 1e-6

    def test_zero_and_int_leaves_pass_through(self):
        t = StochasticQuantize(bits=8)
        payload = {"z": jnp.zeros((4,), jnp.float32),
                   "i": jnp.arange(3, dtype=jnp.int32)}
        out = t.apply(jax.random.key(0), (), payload, 0, None)
        np.testing.assert_array_equal(np.asarray(out["z"]), 0.0)
        np.testing.assert_array_equal(np.asarray(out["i"]),
                                      np.asarray(payload["i"]))

    def test_ledger_reports_honest_wire_bytes(self, split):
        base = DEM(2, max_iter=4).run(split, key=jax.random.key(0))
        q8 = DEM(2, max_iter=4, transform=StochasticQuantize(bits=8)).run(
            split, key=jax.random.key(0))
        q16 = DEM(2, max_iter=4,
                  transform=StochasticQuantize(bits=16)).run(
            split, key=jax.random.key(0))
        assert q8.comm.uplink_itemsize == 1
        assert q16.comm.uplink_itemsize == 2
        # downlink (broadcast) stays f32 — the asymmetric-wire case
        assert q8.comm.downlink_bytes == q8.comm.downlink_floats * 4
        if int(q8.comm.rounds) == int(base.comm.rounds):
            assert q8.comm.uplink_bytes * 4 == base.comm.uplink_bytes

    def test_bits_is_structural_seed_is_not(self):
        assert StochasticQuantize(bits=8) != StochasticQuantize(bits=16)
        assert StochasticQuantize(seed=0) == StochasticQuantize(seed=9)
        assert hash(StochasticQuantize(seed=0)) == \
            hash(StochasticQuantize(seed=9))

    def test_validates_bits(self):
        with pytest.raises(ValueError, match="bits"):
            StochasticQuantize(bits=12)


# ----------------------------------------------------------------------
# Composition
# ----------------------------------------------------------------------

class TestCompose:
    def test_accounting_folds_through_stages(self):
        c = Compose((GaussianDP(epsilon=2.0, rounds=2),
                     StochasticQuantize(bits=8), PairwiseMask()))
        assert np.isclose(c.epsilon_per_round(), 1.0)
        assert c.wire_itemsize(4) == 4   # mask's int32 lattice wins
        assert c.additive_only
        c2 = Compose((GaussianDP(), StochasticQuantize(bits=16)))
        assert c2.wire_itemsize(4) == 2
        assert not c2.additive_only

    def test_member_reseed_does_not_change_equality(self):
        a = Compose((GaussianDP(seed=1), StochasticQuantize(bits=8)))
        b = Compose((GaussianDP(seed=2), StochasticQuantize(bits=8)))
        assert a == b and hash(a) == hash(b)
        assert a.seed != b.seed  # ...but the pipeline key differs

    def test_identity_mask_pipeline_is_bit_identical(self, split):
        base = DEM(2, max_iter=4).run(split, key=jax.random.key(0))
        got = DEM(2, max_iter=4,
                  transform=Compose((Identity(), PairwiseMask()))).run(
            split, key=jax.random.key(0))
        assert_same_gmm(base.global_gmm, got.global_gmm)

    def test_dp_then_quantize_runs(self, split):
        t = Compose((GaussianDP(epsilon=8.0, rounds=4),
                     StochasticQuantize(bits=16)))
        res = DEM(2, max_iter=4, transform=t).run(split,
                                                  key=jax.random.key(0))
        assert res.comm.uplink_itemsize == 2
        assert res.comm.epsilon_spent > 0.0

    def test_rejects_non_transform_members(self):
        with pytest.raises(TypeError, match="Compose members"):
            Compose((GaussianDP(), 42))


# ----------------------------------------------------------------------
# Property tests (offline hypothesis shim)
# ----------------------------------------------------------------------

class TestProperties:
    @settings(max_examples=25, deadline=None)
    @given(w=hst.lists(hst.floats(min_value=-2.0, max_value=2.0,
                                  allow_nan=False),
                       min_size=2, max_size=8))
    def test_project_simplex(self, w):
        out = np.asarray(project_simplex(jnp.asarray(w, jnp.float32)))
        assert np.isclose(out.sum(), 1.0, atol=1e-5)
        assert (out > 0.0).all()

    @settings(max_examples=25, deadline=None)
    @given(v=hst.lists(hst.floats(min_value=-10.0, max_value=10.0,
                                  allow_nan=False),
                       min_size=1, max_size=8))
    def test_clip_variances(self, v):
        out = np.asarray(clip_variances(jnp.asarray(v, jnp.float32)))
        assert (out >= VAR_MIN).all() and (out <= VAR_MAX).all()

    @settings(max_examples=10, deadline=None)
    @given(seed=hst.integers(min_value=0, max_value=2**31 - 1),
           eps=hst.floats(min_value=0.1, max_value=50.0))
    def test_seeded_release_is_deterministic(self, seed, eps):
        t = GaussianDP(epsilon=eps)
        key = jax.random.key(seed)
        a, _ = t.apply(key, t.traced(), (_gmm(), 100.0), 0, None)
        b, _ = t.apply(key, t.traced(), (_gmm(), 100.0), 0, None)
        assert_same_gmm(a, b)

    def test_sigma_matches_host_closed_form(self):
        import math
        got = float(gaussian_sigma(2.0, 0.5, 1e-5))
        want = math.sqrt(2.0 * math.log(1.25 / 1e-5)) * 2.0 / 0.5
        assert np.isclose(got, want, rtol=1e-6)


# ----------------------------------------------------------------------
# Validation and rejection
# ----------------------------------------------------------------------

class TestValidation:
    @pytest.mark.parametrize("kw,msg", [
        (dict(epsilon=0.0), "epsilon"),
        (dict(epsilon=-1.0), "epsilon"),
        (dict(delta=0.0), "delta"),
        (dict(delta=1.0), "delta"),
        (dict(min_count=0.0), "min_count"),
    ])
    def test_dpconfig_validates_at_construction(self, kw, msg):
        with pytest.raises(ValueError, match=msg):
            DPConfig(**kw)

    @pytest.mark.parametrize("kw,msg", [
        (dict(epsilon=0.0), "epsilon"),
        (dict(delta=2.0), "delta"),
        (dict(rounds=0), "rounds"),
        (dict(min_count=-1.0), "min_count"),
    ])
    def test_gaussian_dp_validates_at_construction(self, kw, msg):
        with pytest.raises(ValueError, match=msg):
            GaussianDP(**kw)

    def test_numeric_knobs_are_not_structural(self):
        # the zero-retrace contract's static half: eps/delta/rounds/seed
        # sweeps keep the transform equal and hash-equal
        assert GaussianDP(epsilon=1.0) == GaussianDP(epsilon=9.0, seed=3,
                                                     rounds=7)
        assert hash(GaussianDP(epsilon=1.0)) == \
            hash(GaussianDP(epsilon=9.0, seed=3, rounds=7))

    def test_full_covariance_release_raises_named_error(self):
        g = GMM(jnp.full((2,), 0.5),
                jnp.zeros((2, 3)), jnp.tile(jnp.eye(3), (2, 1, 1)))
        with pytest.raises(ValueError, match="full"):
            privatize_gmm(jax.random.key(0), g, 100.0, DPConfig())

    def test_privatize_clients_matches_transform(self):
        # the legacy entry point IS the transform: same key, same release
        g = _gmm()
        dp = DPConfig(epsilon=2.0)
        [rel] = privatize_clients(jax.random.key(4), [g], [150.0], dp)
        t = GaussianDP(epsilon=2.0, rounds=1)
        want, _ = t.apply(jax.random.fold_in(jax.random.key(4), 0),
                          t.traced(), (g, 150.0), 0, None)
        assert_same_gmm(rel, want)

    def test_run_rounds_rejects_non_transform(self, split):
        with pytest.raises(TypeError, match="PayloadTransform"):
            DEM(2, max_iter=2, transform=object()).run(
                split, key=jax.random.key(0))
        _validate_transform(Identity())  # and the real thing passes

    def test_one_shot_rejects_additive_only(self, split):
        with pytest.raises(ValueError, match="additive"):
            FedGenGMM(k_clients=2, k_global=2,
                      transform=PairwiseMask()).run(
                split, key=jax.random.key(0))
        with pytest.raises(ValueError, match="additive"):
            fit_federated(split, strategy="fedgen", k_clients=2,
                          k_global=2,
                          transform=Compose((PairwiseMask(),)),
                          key=jax.random.key(0))

    def test_dp_and_transform_are_exclusive(self):
        with pytest.raises(ValueError, match="not both"):
            FedGenGMM(k_clients=2, k_global=2, dp=DPConfig(),
                      transform=Identity())
        with pytest.raises(TypeError, match="DPConfig"):
            FedGenGMM(k_clients=2, k_global=2, dp=1.0)

    def test_builtins_satisfy_the_protocol(self):
        for t in (Identity(), GaussianDP(), StochasticQuantize(),
                  PairwiseMask(), Compose((Identity(),))):
            assert isinstance(t, PayloadTransform)
            assert dataclasses.is_dataclass(t)
            hash(t)  # static-arg requirement


# ----------------------------------------------------------------------
# The api seam end to end
# ----------------------------------------------------------------------

class TestApiSeam:
    def test_fit_federated_named_with_transform(self, split):
        base = fit_federated(split, strategy="dem", k=2,
                             config=FitConfig(max_iter=4),
                             key=jax.random.key(0))
        got = fit_federated(split, strategy="dem", k=2,
                            config=FitConfig(max_iter=4),
                            transform=Identity(), key=jax.random.key(0))
        assert_same_gmm(base.global_gmm, got.global_gmm)

    def test_fit_federated_custom_with_transform(self, split):
        from repro.core.dem import DEMStrategy
        strat = DEMStrategy(k=2, tol=1e-3)
        base = fit_federated(split, strategy=strat, max_rounds=4,
                             key=jax.random.key(0))
        got = fit_federated(split, strategy=strat, max_rounds=4,
                            transform=PairwiseMask(),
                            key=jax.random.key(0))
        assert_same_gmm(base.global_gmm, got.global_gmm)

    def test_same_seed_same_noise_across_backends(self, split, sources):
        # the per-client key derivation is backend-independent, so the
        # SAME DP draws land on split and source runs (float reduction
        # order may differ; the model must agree to f32 tolerance)
        t = GaussianDP(epsilon=3.0, rounds=4, seed=42)
        rs = DEM(2, max_iter=4, transform=t).run(split,
                                                 key=jax.random.key(0))
        ro = DEM(2, max_iter=4, transform=t).run(sources,
                                                 key=jax.random.key(0))
        np.testing.assert_allclose(np.asarray(rs.global_gmm.means),
                                   np.asarray(ro.global_gmm.means),
                                   atol=1e-4)

    def test_reseed_changes_noise(self, split):
        a = DEM(2, max_iter=4,
                transform=GaussianDP(epsilon=2.0, seed=0)).run(
            split, key=jax.random.key(0))
        b = DEM(2, max_iter=4,
                transform=GaussianDP(epsilon=2.0, seed=1)).run(
            split, key=jax.random.key(0))
        assert np.any(np.asarray(a.global_gmm.means) !=
                      np.asarray(b.global_gmm.means))
