"""EM tests: planted-mixture recovery, monotonic loglik, weighted EM ==
subset EM, BIC model selection, full-covariance path."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as hst

from repro.core.em import (e_step_stats, e_step_stats_fused, em_step, fit_gmm,
                           fit_gmm_bic, init_from_kmeans, init_from_means,
                           m_step)
from repro.core.gmm import GMM

from conftest import planted_gmm_data


class TestFitGMM:
    def test_recovers_planted_means(self, planted):
        x, y, mus = planted
        res = fit_gmm(jax.random.key(0), jnp.asarray(x), 3)
        assert bool(res.converged)
        got = np.sort(np.asarray(res.gmm.means), axis=0)
        np.testing.assert_allclose(got, np.sort(mus, axis=0), atol=0.15)

    def test_recovers_weights(self):
        r = np.random.default_rng(3)
        mus = np.array([[-5.0, 0.0], [5.0, 0.0]], np.float32)
        y = (r.uniform(size=4000) < 0.75).astype(int)
        x = (mus[y] + r.normal(0, 0.5, (4000, 2))).astype(np.float32)
        res = fit_gmm(jax.random.key(0), jnp.asarray(x), 2)
        w = np.sort(np.asarray(res.gmm.weights))
        np.testing.assert_allclose(w, [0.25, 0.75], atol=0.03)

    def test_loglik_monotonic(self, planted):
        x, _, _ = planted
        xj = jnp.asarray(x)
        gmm = init_from_kmeans(jax.random.key(0), xj, 3)
        lls = []
        for _ in range(10):
            gmm, ll = em_step(gmm, xj)
            lls.append(float(ll))
        assert all(b >= a - 1e-4 for a, b in zip(lls, lls[1:])), lls

    def test_weighted_equals_subset(self, planted):
        """EM on (x, weight mask) == EM on x[mask] — the ragged-client
        representation invariant everything federated relies on."""
        x, _, _ = planted
        xj = jnp.asarray(x)
        n = x.shape[0]
        mask = jnp.asarray((np.arange(n) % 3 != 0), jnp.float32)
        sub = xj[np.asarray(mask) > 0]
        g0 = init_from_kmeans(jax.random.key(1), sub, 3)
        a, lla = em_step(g0, xj, sample_weight=mask)
        b, llb = em_step(g0, sub)
        np.testing.assert_allclose(float(lla), float(llb), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(a.means), np.asarray(b.means),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(a.covs), np.asarray(b.covs),
                                   rtol=1e-3, atol=1e-5)

    def test_full_covariance(self):
        r = np.random.default_rng(5)
        cov = np.array([[1.0, 0.8], [0.8, 1.0]])
        x = r.multivariate_normal([0, 0], cov, 3000).astype(np.float32)
        res = fit_gmm(jax.random.key(0), jnp.asarray(x), 1,
                      covariance_type="full")
        np.testing.assert_allclose(np.asarray(res.gmm.covs[0]), cov, atol=0.08)

    def test_respects_max_iter(self, planted):
        x, _, _ = planted
        res = fit_gmm(jax.random.key(0), jnp.asarray(x), 3, max_iter=2,
                      tol=0.0)
        assert int(res.n_iter) <= 2

    def test_variances_positive(self, planted):
        x, _, _ = planted
        res = fit_gmm(jax.random.key(0), jnp.asarray(x), 8)
        assert bool(jnp.all(res.gmm.covs > 0))


class TestEStep:
    def test_estep_stats_shapes(self, planted):
        x, _, _ = planted
        g = init_from_kmeans(jax.random.key(0), jnp.asarray(x), 3)
        s = e_step_stats(g, jnp.asarray(x))
        assert s.s0.shape == (3,) and s.s1.shape == (3, 4) and s.s2.shape == (3, 4)
        np.testing.assert_allclose(float(s.s0.sum()), x.shape[0], rtol=1e-5)

    def test_fused_kernel_matches(self, planted):
        x, _, _ = planted
        xj = jnp.asarray(x)
        g = init_from_kmeans(jax.random.key(0), xj, 3)
        w = jnp.asarray(np.random.default_rng(0).uniform(size=x.shape[0]),
                        jnp.float32)
        a = e_step_stats(g, xj, w)
        b = e_step_stats_fused(g, xj, w, interpret=True)
        np.testing.assert_allclose(np.asarray(a.s0), np.asarray(b.s0), rtol=1e-4)
        np.testing.assert_allclose(np.asarray(a.s1), np.asarray(b.s1),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(a.s2), np.asarray(b.s2),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(float(a.loglik), float(b.loglik), rtol=1e-5)

    def test_mstep_weights_normalized(self, planted):
        x, _, _ = planted
        g = init_from_kmeans(jax.random.key(0), jnp.asarray(x), 5)
        stats = e_step_stats(g, jnp.asarray(x))
        g2 = m_step(stats)
        np.testing.assert_allclose(float(g2.weights.sum()), 1.0, rtol=1e-6)


class TestBICSelection:
    def test_bic_selects_true_k(self):
        x, _, _ = planted_gmm_data(np.random.default_rng(7), n=3000, k=3,
                                   spread=6.0, std=0.4)
        res, bics = fit_gmm_bic(jax.random.key(0), jnp.asarray(x), [1, 2, 3, 4, 5])
        assert res.gmm.n_components == 3, bics


class TestInits:
    def test_init_from_means_uniform_weights(self, planted):
        x, _, _ = planted
        centers = jnp.zeros((4, 4))
        g = init_from_means(centers, jnp.asarray(x))
        np.testing.assert_allclose(np.asarray(g.weights), 0.25, rtol=1e-6)
        assert bool(jnp.all(g.covs > 0))


@settings(max_examples=10, deadline=None)
@given(k=hst.integers(1, 5), seed=hst.integers(0, 10**6))
def test_em_loglik_never_decreases_property(k, seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(0, 1, (400, 3)) + r.integers(0, 2, (400, 1)) * 4,
                    jnp.float32)
    g = init_from_kmeans(jax.random.key(seed), x, k)
    prev = -np.inf
    for _ in range(6):
        g, ll = em_step(g, x)
        assert float(ll) >= prev - 1e-3
        prev = float(ll)
