"""FedGenGMM activation-monitor integration test: the paper's technique
wired to a transformer — OOD token streams must score higher than
in-distribution streams."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.monitor import FedGMMMonitor, MonitorConfig


@pytest.fixture(scope="module")
def setup():
    import jax
    cfg = get_config("internlm2-1.8b", "smoke")
    params = init_params(jax.random.key(0), cfg)
    return cfg, params


def _batch(tokens):
    return {"tokens": jnp.asarray(tokens, jnp.int32)}


def test_monitor_end_to_end(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    mon = FedGMMMonitor(cfg, MonitorConfig(k_local=2, k_global=4, h=50))
    # 4 "clients" observe in-distribution traffic (low-id zipf-ish tokens)
    for cid in range(4):
        for _ in range(4):
            toks = rng.zipf(1.5, size=(8, 32)).clip(0, 99)
            mon.observe(cid, params, _batch(toks))
    g = mon.aggregate()
    assert g.n_components == 4
    # ID traffic scores low, OOD traffic (uniform high-id tokens) higher
    id_scores = mon.score(params, _batch(
        rng.zipf(1.5, size=(16, 32)).clip(0, 99)))
    ood_scores = mon.score(params, _batch(
        rng.integers(400, cfg.vocab_size, (16, 32))))
    assert np.median(ood_scores) > np.median(id_scores), \
        (np.median(id_scores), np.median(ood_scores))


def test_monitor_features_shape(setup):
    cfg, params = setup
    from repro.monitor import extract_features, feature_projection
    proj = feature_projection(cfg, MonitorConfig())
    f = extract_features(params, cfg,
                         _batch(np.zeros((4, 16), np.int32)), proj)
    assert f.shape == (4, 32)
    assert bool(jnp.all(jnp.isfinite(f)))
