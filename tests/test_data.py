"""Data substrate tests: generators, PCA, scaler, token pipeline."""
import numpy as np
import pytest

from repro.data import (batches, fit_minmax, fit_pca, load,
                        synthetic_stream, transform_pca)

EXPECTED = {  # name -> (d, n_classes, scheme, K, clients)  [Tables 1 & 3]
    "mnist": (24, 10, "dirichlet", 30, 20),
    "covertype": (10, 7, "dirichlet", 15, 20),
    "rwhar": (16, 13, "dirichlet", 15, 20),
    "wadi": (84, 10, "quantity", 10, 20),
    "vehicle": (11, 3, "quantity", 15, 12),
    "smd": (38, 28, "dirichlet", 10, 20),
}


@pytest.mark.parametrize("name", list(EXPECTED))
def test_dataset_schema(name):
    d, ncls, scheme, k, clients = EXPECTED[name]
    ds = load(name, np.random.default_rng(0))
    assert ds.x_train.shape[1] == d
    assert ds.n_classes == ncls and ds.scheme == scheme
    assert ds.k_global == k and ds.n_clients == clients
    assert ds.x_train.min() >= 0.0 and ds.x_train.max() <= 1.0
    assert ds.y_train.max() < ncls and ds.y_train.min() >= 0
    assert np.isfinite(ds.x_test_in).all() and np.isfinite(ds.x_test_ood).all()
    assert len(ds.x_test_ood) > 0


@pytest.mark.parametrize("name", list(EXPECTED))
def test_dataset_reproducible(name):
    a = load(name, np.random.default_rng(7))
    b = load(name, np.random.default_rng(7))
    np.testing.assert_array_equal(a.x_train, b.x_train)


def test_pca_reconstruction_ordering():
    rng = np.random.default_rng(0)
    # low-rank data: PCA should capture it
    w = rng.normal(size=(5, 20))
    x = rng.normal(size=(500, 5)) @ w + 0.01 * rng.normal(size=(500, 20))
    pca = fit_pca(x, 5)
    z = transform_pca(pca, x)
    assert z.shape == (500, 5)
    assert (np.diff(pca.explained_variance) <= 1e-6).all()  # sorted desc
    # 5 components capture nearly all variance
    assert pca.explained_variance.sum() > 0.95 * x.var(0).sum()


def test_minmax_scaler():
    rng = np.random.default_rng(1)
    x = rng.normal(2, 5, (100, 4))
    s = fit_minmax(x)
    z = s.transform(x)
    assert z.min() >= 0 and z.max() <= 1
    np.testing.assert_allclose(z.min(0), 0, atol=1e-7)
    np.testing.assert_allclose(z.max(0), 1, atol=1e-7)
    # out-of-range data is clipped
    assert s.transform(x + 100).max() <= 1.0


def test_token_stream_properties():
    s = synthetic_stream(0, 1000, 50_000)
    assert s.min() >= 0 and s.max() < 1000
    # zipf-ish: most common token much more frequent than median
    counts = np.bincount(s, minlength=1000)
    assert counts.max() > 10 * np.median(counts[counts > 0])


def test_batches_shapes_and_shift():
    bs = list(batches(0, 500, batch_size=4, seq_len=16, n_batches=3))
    assert len(bs) == 3
    for b in bs:
        assert b.tokens.shape == (4, 16) and b.targets.shape == (4, 16)
        np.testing.assert_array_equal(b.tokens[:, 1:], b.targets[:, :-1])
