"""Out-of-core training parity (DESIGN.md §7): source-backed fits must be
bit-identical across source types holding the same rows, agree with the
resident-array engine to f32 rounding, and hold an O(chunk) working set
independent of N — asserted live against jax's buffer registry at 1M rows.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import DEM, FedGenGMM
from repro.core import dem
from repro.core.em import (bic_streaming, e_step_stats, fit_gmm, fit_gmm_bic,
                           init_from_kmeans, init_from_means,
                           log_prob_chunked, score_streaming)
from repro.core.gmm import GMM
from repro.core.kmeans import kmeans_source
from repro.data.sources import (ArraySource, ConcatSource, DataSource,
                                NpyFileSource, SyntheticGMMSource)
from conftest import planted_gmm_data

# end-to-end fits: multi-second EM training loops on CPU
pytestmark = pytest.mark.slow

CHUNK = 512  # deliberately not dividing the 3000-row fixture


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(17)
    x, y, mus = planted_gmm_data(rng, n=3000, d=4, k=3, spread=5.0, std=0.5,
                                 min_sep_sigma=8.0)
    return x, mus


def params(res):
    g = res.gmm if hasattr(res, "gmm") else res
    return [np.asarray(g.weights), np.asarray(g.means), np.asarray(g.covs)]


class TestSourceVsSourceBitwise:
    """Same rows + same chunk partition -> identical block loop -> the fits
    must match bit for bit, whatever storage backs the stream."""

    def test_npy_and_concat_match_array_source(self, setup, tmp_path):
        x, _ = setup
        path = tmp_path / "x.npy"
        np.save(path, x)
        ragged = ConcatSource([ArraySource(x[:700]), ArraySource(x[700:701]),
                               ArraySource(x[701:2050]), ArraySource(x[2050:])])
        base = fit_gmm(jax.random.key(0), ArraySource(x), 3, chunk_size=CHUNK)
        for src in (NpyFileSource(path), ragged):
            res = fit_gmm(jax.random.key(0), src, 3, chunk_size=CHUNK)
            for a, b in zip(params(base), params(res)):
                np.testing.assert_array_equal(a, b)
            assert int(res.n_iter) == int(base.n_iter)

    def test_synthetic_matches_materialized(self, setup):
        _, mus = setup
        truth = GMM(jnp.full((3,), 1 / 3), jnp.asarray(mus),
                    jnp.full((3, 4), 0.25))
        src = SyntheticGMMSource(truth, 3000, jax.random.key(9))
        res_stream = fit_gmm(jax.random.key(1), src, 3, chunk_size=CHUNK)
        res_resident = fit_gmm(jax.random.key(1),
                               ArraySource(src.materialize(CHUNK)), 3,
                               chunk_size=CHUNK)
        for a, b in zip(params(res_stream), params(res_resident)):
            np.testing.assert_array_equal(a, b)


class TestSourceVsResidentEngine:
    """The host block loop vs the lax.scan/full-batch resident paths: same
    math, possibly different XLA fusions — f32-rounding agreement."""

    def test_estep_stats_match(self, setup):
        x, _ = setup
        g = init_from_kmeans(jax.random.key(2), jnp.asarray(x), 3)
        src_stats = e_step_stats(g, ArraySource(x), chunk_size=CHUNK)
        for resident in (e_step_stats(g, jnp.asarray(x), chunk_size=CHUNK),
                         e_step_stats(g, jnp.asarray(x))):
            np.testing.assert_allclose(np.asarray(src_stats.s0),
                                       np.asarray(resident.s0), rtol=1e-5)
            np.testing.assert_allclose(np.asarray(src_stats.s1),
                                       np.asarray(resident.s1),
                                       rtol=1e-5, atol=1e-3)
            np.testing.assert_allclose(float(src_stats.loglik),
                                       float(resident.loglik), rtol=1e-5)
        assert float(src_stats.wsum) == float(len(x))

    def test_fit_same_init_tracks_resident(self, setup):
        x, _ = setup
        init = init_from_kmeans(jax.random.key(3), jnp.asarray(x), 3)
        res_src = fit_gmm(jax.random.key(0), ArraySource(x), 3,
                          init_gmm=init, chunk_size=CHUNK)
        res_arr = fit_gmm(jax.random.key(0), jnp.asarray(x), 3,
                          init_gmm=init, chunk_size=CHUNK)
        np.testing.assert_allclose(float(res_src.log_likelihood),
                                   float(res_arr.log_likelihood), atol=1e-4)
        for a, b in zip(params(res_src), params(res_arr)):
            np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)

    def test_scoring_matches_gmm_methods(self, setup):
        x, _ = setup
        res = fit_gmm(jax.random.key(4), jnp.asarray(x), 3)
        xs, xj = ArraySource(x), jnp.asarray(x)
        np.testing.assert_allclose(
            float(score_streaming(res.gmm, xs, chunk_size=CHUNK)),
            float(res.gmm.score(xj)), rtol=1e-5)
        np.testing.assert_allclose(
            float(bic_streaming(res.gmm, xs, chunk_size=CHUNK)),
            float(res.gmm.bic(xj)), rtol=1e-5)
        np.testing.assert_allclose(
            np.asarray(log_prob_chunked(res.gmm, xs, chunk_size=CHUNK)),
            np.asarray(res.gmm.log_prob(xj)), rtol=1e-4, atol=1e-4)

    def test_init_from_means_streams_moments(self, setup):
        x, _ = setup
        centers = jnp.asarray(x[:3])
        g_src = init_from_means(centers, ArraySource(x))
        g_arr = init_from_means(centers, jnp.asarray(x))
        np.testing.assert_allclose(np.asarray(g_src.covs),
                                   np.asarray(g_arr.covs), rtol=1e-3)

    def test_bic_selection_agrees(self, setup):
        x, _ = setup
        best_src, bics_src = fit_gmm_bic(jax.random.key(5), ArraySource(x),
                                         [2, 3, 4], chunk_size=CHUNK)
        _, bics_arr = fit_gmm_bic(jax.random.key(5), jnp.asarray(x),
                                  [2, 3, 4], chunk_size=CHUNK)
        assert min(bics_src, key=bics_src.get) == \
            min(bics_arr, key=bics_arr.get) == 3
        assert best_src.gmm.n_components == 3

    def test_kmeans_source_recovers_planted_centers(self, setup):
        x, mus = setup
        res = kmeans_source(jax.random.key(6), ArraySource(x), 3,
                            chunk_size=CHUNK)
        assert res.assignments is None  # the one O(N) output, not collected
        got = np.asarray(res.centers)
        dists = np.linalg.norm(got[:, None] - mus[None], axis=-1)
        assert dists.min(axis=0).max() < 0.5
        assert float(jnp.sum(res.cluster_sizes)) == float(len(x))


class TestFederatedSources:
    def test_fedgen_from_ragged_sources(self, setup):
        x, _ = setup
        cuts = [0, 450, 1300, 1999, 3000]
        sources = [ArraySource(x[a:b]) for a, b in zip(cuts, cuts[1:])]
        fr = FedGenGMM(k_clients=3, k_global=3, h=40,
                       chunk_size=CHUNK).run(sources, key=jax.random.key(0))
        bench = fit_gmm(jax.random.key(1), jnp.asarray(x), 3)
        ll_fed = float(fr.global_gmm.score(jnp.asarray(x)))
        ll_cen = float(bench.gmm.score(jnp.asarray(x)))
        assert ll_fed > ll_cen - 0.35, (ll_fed, ll_cen)
        assert isinstance(fr.synthetic, DataSource)  # replay never resident
        assert fr.synthetic.num_rows == 40 * 3 * 4
        assert fr.comm.rounds == 1

    def test_dem_on_sources_matches_resident_dem(self, setup):
        from repro.core.partition import ClientSplit
        x, _ = setup
        cuts = [0, 800, 1600, 2400, 3000]
        shards = [x[a:b] for a, b in zip(cuts, cuts[1:])]
        sources = [ArraySource(s) for s in shards]
        # equal-size resident split so dem() needs no padding weights
        n_max = max(len(s) for s in shards)
        data = np.zeros((4, n_max, 4), np.float32)
        mask = np.zeros((4, n_max), np.float32)
        for i, s in enumerate(shards):
            data[i, :len(s)], mask[i, :len(s)] = s, 1.0
        split = ClientSplit(data, mask,
                            np.array([len(s) for s in shards]),
                            np.zeros((4, 1), np.int64))
        dr_src = DEM(3, init="separated",
                     chunk_size=CHUNK).run(sources, key=jax.random.key(0))
        dr_res = dem(jax.random.key(0), split, 3, init=1)
        assert bool(dr_src.converged)
        np.testing.assert_allclose(float(dr_src.log_likelihood),
                                   float(dr_res.log_likelihood), atol=5e-3)
        assert dr_src.comm.rounds == int(dr_src.n_rounds)

    def test_dem_rejects_pilot_init_on_sources(self, setup):
        x, _ = setup
        with pytest.raises(ValueError, match="pilot"):
            DEM(3, init="pilot").run([ArraySource(x)],
                                     key=jax.random.key(0))


class _WorkingSetSpy(DataSource):
    """Wraps a source; at every block boundary asserts that no live jax
    buffer has grown an O(N) leading axis. Block boundaries are exactly
    where a leaked materialization would be resident."""

    def __init__(self, inner: DataSource, max_rows: int):
        self._inner = inner
        self._max_rows = max_rows
        self.blocks_seen = 0

    @property
    def num_rows(self):
        return self._inner.num_rows

    @property
    def dim(self):
        return self._inner.dim

    @property
    def dtype(self):
        return self._inner.dtype

    def iter_blocks(self, chunk_size):
        for block in self._inner.iter_blocks(chunk_size):
            assert block.shape[0] <= chunk_size
            big = [a.shape for a in jax.live_arrays()
                   if a.ndim and a.shape[0] > self._max_rows]
            assert not big, f"O(N)-sized live buffers: {big}"
            self.blocks_seen += 1
            yield block


class TestMillionRowWorkingSet:
    def test_million_row_synthetic_fit_constant_memory(self):
        """Acceptance: fitting N=1M rows via SyntheticGMMSource completes
        with a peak working set independent of N (no live array ever holds
        more than a few chunks of rows) and recovers the planted mixture."""
        n, chunk = 1_000_000, 65536
        truth = GMM(jnp.array([0.4, 0.6]),
                    jnp.array([[-4.0, 0.0, 2.0, 1.0], [4.0, 1.0, -2.0, 0.0]]),
                    jnp.full((2, 4), 0.3))
        src = SyntheticGMMSource(truth, n, jax.random.key(11))
        spy = _WorkingSetSpy(src, max_rows=4 * chunk)
        res = fit_gmm(jax.random.key(0), spy, 2, chunk_size=chunk,
                      max_iter=5, tol=1e-3)
        assert spy.blocks_seen >= 2 * src.num_blocks(chunk)  # multi-pass
        assert bool(jnp.all(jnp.isfinite(res.gmm.means)))
        got = np.sort(np.asarray(res.gmm.means)[:, 0])
        np.testing.assert_allclose(got, [-4.0, 4.0], atol=0.1)
        got_w = np.sort(np.asarray(res.gmm.weights))
        np.testing.assert_allclose(got_w, [0.4, 0.6], atol=0.02)

    def test_materialize_is_the_opt_in_exception(self):
        """materialize() is the only O(N) affordance and it is explicit."""
        truth = GMM(jnp.array([1.0]), jnp.zeros((1, 2)), jnp.ones((1, 2)))
        src = SyntheticGMMSource(truth, 1024, jax.random.key(0))
        assert src.materialize(256).shape == (1024, 2)
