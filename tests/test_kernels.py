"""Pallas kernel validation: interpret-mode vs pure-jnp oracles across a
shape/dtype sweep (per-kernel allclose, as required)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as hst

from repro.kernels import ops, ref

SHAPES = [  # (N, d, K)
    (64, 4, 2),
    (256, 24, 30),       # the paper's MNIST setting
    (1000, 11, 15),      # VEHICLE
    (513, 84, 10),       # WADI, non-aligned N
    (100, 38, 10),       # SMD
    (2048, 128, 64),     # aligned everything
    (17, 3, 1),          # degenerate small
]


def make_inputs(rng, n, d, k, dtype=jnp.float32):
    x = jnp.asarray(rng.normal(0, 2, (n, d)), dtype)
    mu = jnp.asarray(rng.normal(0, 2, (k, d)), jnp.float32)
    var = jnp.asarray(rng.uniform(0.05, 3.0, (k, d)), jnp.float32)
    lw = jnp.asarray(np.log(rng.dirichlet(np.ones(k))), jnp.float32)
    return x, mu, var, lw


class TestGMMLogpdf:
    @pytest.mark.parametrize("n,d,k", SHAPES)
    def test_matches_ref(self, n, d, k):
        rng = np.random.default_rng(n * 31 + d * 7 + k)
        x, mu, var, lw = make_inputs(rng, n, d, k)
        out = ops.gmm_logpdf(x, mu, var, lw, interpret=True)
        exp = ref.gmm_logpdf_ref(x, mu, var, lw)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=2e-4, atol=2e-4)

    def test_no_log_weights(self):
        rng = np.random.default_rng(0)
        x, mu, var, _ = make_inputs(rng, 100, 8, 4)
        out = ops.gmm_logpdf(x, mu, var, None, interpret=True)
        exp = ref.gmm_logpdf_ref(x, mu, var, None)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=2e-4, atol=2e-4)

    def test_bfloat16_input(self):
        rng = np.random.default_rng(1)
        x, mu, var, lw = make_inputs(rng, 128, 16, 8, dtype=jnp.bfloat16)
        out = ops.gmm_logpdf(x, mu, var, lw, interpret=True)
        exp = ref.gmm_logpdf_ref(x.astype(jnp.float32), mu, var, lw)
        assert out.dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=0.05, atol=0.3)

    def test_block_shape_invariance(self):
        rng = np.random.default_rng(2)
        x, mu, var, lw = make_inputs(rng, 512, 24, 30)
        a = ops.gmm_logpdf(x, mu, var, lw, block_n=128, block_k=128,
                           interpret=True)
        b = ops.gmm_logpdf(x, mu, var, lw, block_n=512, block_k=256,
                           interpret=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


class TestEstepStats:
    @pytest.mark.parametrize("n,d,k", SHAPES)
    def test_matches_ref(self, n, d, k):
        rng = np.random.default_rng(n * 13 + d + k)
        x, mu, var, lw = make_inputs(rng, n, d, k)
        w = jnp.asarray(rng.uniform(0, 1, n), jnp.float32)
        s0, s1, s2, ll = ops.estep_stats(x, mu, var, lw, w, interpret=True)
        e0, e1, e2, el = ref.estep_stats_ref(x, mu, var, lw, w)
        np.testing.assert_allclose(np.asarray(s0), np.asarray(e0), rtol=1e-3,
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(e1), rtol=1e-3,
                                   atol=1e-3)
        np.testing.assert_allclose(np.asarray(s2), np.asarray(e2), rtol=1e-3,
                                   atol=1e-3)
        np.testing.assert_allclose(float(ll), float(el), rtol=1e-4)

    def test_unit_weights_default(self):
        rng = np.random.default_rng(3)
        x, mu, var, lw = make_inputs(rng, 200, 10, 5)
        s0, *_ = ops.estep_stats(x, mu, var, lw, None, interpret=True)
        np.testing.assert_allclose(float(jnp.sum(s0)), 200.0, rtol=1e-4)

    def test_multi_block_accumulation(self):
        """Accumulation across sequential grid steps must equal single block."""
        rng = np.random.default_rng(4)
        x, mu, var, lw = make_inputs(rng, 2048, 16, 8)
        a = ops.estep_stats(x, mu, var, lw, block_n=256, interpret=True)
        b = ops.estep_stats(x, mu, var, lw, block_n=2048, interpret=True)
        for u, v in zip(a, b):
            np.testing.assert_allclose(np.asarray(u), np.asarray(v),
                                       rtol=1e-4, atol=1e-3)


class TestKmeansAssign:
    @pytest.mark.parametrize("n,d,k", SHAPES)
    def test_matches_ref(self, n, d, k):
        rng = np.random.default_rng(n + d * 3 + k * 11)
        x, mu, _, _ = make_inputs(rng, n, d, k)
        ia, da = ops.kmeans_assign(x, mu, interpret=True)
        ie, de = ref.kmeans_assign_ref(x, mu)
        assert bool(jnp.all(ia == ie))
        np.testing.assert_allclose(np.asarray(da), np.asarray(de), rtol=1e-4,
                                   atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(n=hst.integers(1, 300), d=hst.integers(1, 40), k=hst.integers(1, 33),
       seed=hst.integers(0, 10**5))
def test_logpdf_property_sweep(n, d, k, seed):
    rng = np.random.default_rng(seed)
    x, mu, var, lw = make_inputs(rng, n, d, k)
    out = ops.gmm_logpdf(x, mu, var, lw, interpret=True)
    exp = ref.gmm_logpdf_ref(x, mu, var, lw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-3,
                               atol=1e-3)
