"""Serving-loop tests: batching, padding, determinism, budgets."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serve import Request, ServeEngine
from repro.models import init_params


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("internlm2-1.8b", "smoke")
    params = init_params(jax.random.key(0), cfg)
    return ServeEngine(cfg, params, max_batch=3, max_context=96)


def make_queue(n, rng, max_new=5):
    return [Request(i, rng.integers(0, 100, rng.integers(4, 17))
                    .astype(np.int32), max_new) for i in range(n)]


def test_all_requests_served(engine):
    rng = np.random.default_rng(0)
    queue = make_queue(7, rng)
    results = engine.serve(queue)
    assert sorted(r.rid for r in results) == list(range(7))
    assert all(len(r.tokens) == 5 for r in results)


def test_respects_token_budget(engine):
    rng = np.random.default_rng(1)
    queue = [Request(0, rng.integers(0, 100, 8).astype(np.int32), 2),
             Request(1, rng.integers(0, 100, 8).astype(np.int32), 7)]
    results = engine.serve(queue)
    by_rid = {r.rid: r for r in results}
    assert len(by_rid[0].tokens) == 2
    assert len(by_rid[1].tokens) == 7


def test_batching_deterministic_vs_solo(engine):
    """Greedy decode of a request must not depend on its batch peers
    (left-padding + causal masking correctness)."""
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, 100, 12).astype(np.int32)
    solo = engine.serve([Request(0, prompt, 4)])[0].tokens
    # same prompt packed with two other same-length requests (avoids
    # left-pad position-id differences, which shift RoPE phases)
    peers = [Request(1, rng.integers(0, 100, 12).astype(np.int32), 4),
             Request(2, prompt, 4),
             Request(3, rng.integers(0, 100, 12).astype(np.int32), 4)]
    batched = {r.rid: r.tokens for r in engine.serve(peers)}
    assert batched[2] == solo


def test_throughput_stats(engine):
    rng = np.random.default_rng(3)
    results = engine.serve(make_queue(4, rng))
    for r in results:
        assert r.ttft_s > 0 and r.latency_s >= r.ttft_s
