"""Parity and regression tests for the chunked k-means and streaming
scoring legs of the engine (DESIGN.md §6).

Claims under test, each load-bearing for constant-memory TrainGMM:
  1. kmeans returns assignments/inertia/cluster_sizes computed against the
     *returned* centers (regression: the loop body used to score the
     pre-update centers, skewing kmeans_multi's best-restart pick);
  2. chunked Lloyd sweeps == full-batch for any chunk size, including
     non-dividing ones and weighted/padded rows;
  3. label-stats init == the one-hot init it replaced, full-batch and
     chunked, diagonal and full covariance;
  4. streaming score/BIC/log_prob == the full-batch GMM methods;
  5. fit_gmm_bic model selection is chunking-invariant.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.em import (bic_streaming, fit_gmm_bic, init_from_kmeans,
                           label_stats, log_prob_chunked, score_streaming)
from repro.core.gmm import GMM
from repro.core.kmeans import _sq_dists, kmeans, kmeans_multi
from conftest import planted_gmm_data


def random_diag_gmm(rng, k, d):
    return GMM(jnp.asarray(rng.dirichlet(np.ones(k)), jnp.float32),
               jnp.asarray(rng.normal(0, 2, (k, d)), jnp.float32),
               jnp.asarray(rng.uniform(0.1, 2.0, (k, d)), jnp.float32))


class TestKMeansFinalStats:
    """Regression: returned stats must describe the returned centers."""

    def test_inertia_and_assignments_match_returned_centers(self):
        x, _, _ = planted_gmm_data(np.random.default_rng(0), n=700, k=3)
        xj = jnp.asarray(x)
        res = kmeans(jax.random.key(3), xj, 3)
        d2 = _sq_dists(xj, res.centers)
        np.testing.assert_allclose(float(res.inertia),
                                   float(jnp.sum(jnp.min(d2, axis=1))),
                                   rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(res.assignments),
                                      np.asarray(jnp.argmin(d2, axis=1)))

    def test_cluster_sizes_match_assignments(self):
        x, _, _ = planted_gmm_data(np.random.default_rng(1), n=600, k=4)
        w = jnp.asarray(np.random.default_rng(2).uniform(0.1, 1, 600),
                        jnp.float32)
        res = kmeans(jax.random.key(0), jnp.asarray(x), 4, sample_weight=w)
        expect = jax.ops.segment_sum(w, res.assignments, num_segments=4)
        np.testing.assert_allclose(np.asarray(res.cluster_sizes),
                                   np.asarray(expect), rtol=1e-5)

    def test_multi_restart_selection_uses_final_inertia(self):
        x, _, _ = planted_gmm_data(np.random.default_rng(3), n=800, k=3,
                                   spread=6.0, std=0.4, min_sep_sigma=8.0)
        xj = jnp.asarray(x)
        best = kmeans_multi(jax.random.key(1), xj, 3, n_init=5)
        # the selected restart's inertia must be reproducible from its
        # returned centers — the pre-fix code reported the previous sweep's
        d2 = _sq_dists(xj, best.centers)
        np.testing.assert_allclose(float(best.inertia),
                                   float(jnp.sum(jnp.min(d2, axis=1))),
                                   rtol=1e-5)


class TestChunkedKMeans:
    # dividing (250), non-dividing (333, 64), >N (2048) chunk sizes
    @pytest.mark.parametrize("chunk_size", [64, 250, 333, 2048])
    def test_chunk_size_invariance(self, chunk_size):
        x, _, _ = planted_gmm_data(np.random.default_rng(4), n=1000, k=3,
                                   spread=6.0, std=0.5, min_sep_sigma=8.0)
        xj = jnp.asarray(x)
        full = kmeans(jax.random.key(0), xj, 3)
        chunked = kmeans(jax.random.key(0), xj, 3, chunk_size=chunk_size)
        np.testing.assert_allclose(np.asarray(full.centers),
                                   np.asarray(chunked.centers),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(float(full.inertia),
                                   float(chunked.inertia), rtol=1e-4)
        np.testing.assert_array_equal(np.asarray(full.assignments),
                                      np.asarray(chunked.assignments))

    def test_weighted_and_padded_rows(self):
        """Zero-weight (padding) rows are invisible to the chunked sweep,
        exactly as they are to the full-batch one."""
        x, _, _ = planted_gmm_data(np.random.default_rng(5), n=800, k=2,
                                   spread=8.0, min_sep_sigma=8.0)
        xj = jnp.asarray(x)
        poisoned = xj.at[400:].set(1e3)   # garbage rows, weight 0
        w = jnp.asarray(np.r_[np.ones(400), np.zeros(400)], jnp.float32)
        full = kmeans(jax.random.key(0), poisoned, 2, sample_weight=w)
        chunked = kmeans(jax.random.key(0), poisoned, 2, sample_weight=w,
                         chunk_size=96)
        np.testing.assert_allclose(np.asarray(full.centers),
                                   np.asarray(chunked.centers),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(float(full.inertia),
                                   float(chunked.inertia), rtol=1e-4)
        ref = kmeans(jax.random.key(0), xj[:400], 2, chunk_size=96)
        np.testing.assert_allclose(
            np.sort(np.asarray(chunked.centers), 0),
            np.sort(np.asarray(ref.centers), 0), atol=0.3)

    def test_kmeans_multi_chunked(self):
        x, _, mus = planted_gmm_data(np.random.default_rng(6), n=1200, k=3,
                                     spread=6.0, std=0.4, min_sep_sigma=8.0)
        res = kmeans_multi(jax.random.key(0), jnp.asarray(x), 3, n_init=4,
                           chunk_size=500)
        np.testing.assert_allclose(np.sort(np.asarray(res.centers), axis=0),
                                   np.sort(mus, axis=0), atol=0.2)


class TestChunkedInit:
    @pytest.mark.parametrize("covariance_type", ["diag", "full"])
    def test_init_from_kmeans_chunk_invariance(self, covariance_type):
        x, _, _ = planted_gmm_data(np.random.default_rng(7), n=900, k=3,
                                   spread=6.0, std=0.5, min_sep_sigma=8.0)
        xj = jnp.asarray(x)
        w = jnp.asarray(np.random.default_rng(8).uniform(0.2, 1, 900),
                        jnp.float32)
        full = init_from_kmeans(jax.random.key(0), xj, 3, w, covariance_type)
        chunked = init_from_kmeans(jax.random.key(0), xj, 3, w,
                                   covariance_type, chunk_size=256)
        for name in ("weights", "means", "covs"):
            np.testing.assert_allclose(
                np.asarray(getattr(full, name)),
                np.asarray(getattr(chunked, name)),
                rtol=1e-4, atol=1e-4, err_msg=name)

    def test_label_stats_match_one_hot_reference(self):
        """The segment-sum stats equal the (N, K) one-hot contraction they
        replaced (the pre-engine init_from_kmeans formulation)."""
        rng = np.random.default_rng(9)
        x = jnp.asarray(rng.normal(0, 2, (257, 5)), jnp.float32)
        w = jnp.asarray(rng.uniform(0, 1, 257), jnp.float32)
        a = jnp.asarray(rng.integers(0, 4, 257), jnp.int32)
        for chunk in (None, 100):
            stats = label_stats(x, a, 4, w, "diag", chunk_size=chunk)
            resp = jax.nn.one_hot(a, 4, dtype=x.dtype) * w[:, None]
            np.testing.assert_allclose(np.asarray(stats.s0),
                                       np.asarray(jnp.sum(resp, 0)),
                                       rtol=1e-5, atol=1e-5)
            np.testing.assert_allclose(np.asarray(stats.s1),
                                       np.asarray(resp.T @ x),
                                       rtol=1e-4, atol=1e-4)
            np.testing.assert_allclose(np.asarray(stats.s2),
                                       np.asarray(resp.T @ (x * x)),
                                       rtol=1e-4, atol=1e-4)


class TestStreamingScoring:
    @pytest.mark.parametrize("chunk_size", [64, 333, 999, 4096])
    def test_score_and_bic_parity(self, chunk_size):
        rng = np.random.default_rng(10)
        gmm = random_diag_gmm(rng, 5, 7)
        x = jnp.asarray(rng.normal(0, 2, (1000, 7)), jnp.float32)
        w = jnp.asarray(rng.uniform(0, 1, 1000), jnp.float32)
        np.testing.assert_allclose(
            float(score_streaming(gmm, x, w, chunk_size=chunk_size)),
            float(gmm.score(x, w)), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(
            float(bic_streaming(gmm, x, w, chunk_size=chunk_size)),
            float(gmm.bic(x, w)), rtol=1e-4)

    def test_unweighted_bic_uses_row_count(self):
        rng = np.random.default_rng(11)
        gmm = random_diag_gmm(rng, 3, 4)
        x = jnp.asarray(rng.normal(0, 2, (501, 4)), jnp.float32)
        np.testing.assert_allclose(float(bic_streaming(gmm, x,
                                                       chunk_size=200)),
                                   float(gmm.bic(x)), rtol=1e-4)

    def test_full_covariance_falls_back_to_reference(self):
        rng = np.random.default_rng(12)
        k, d = 3, 4
        a = rng.normal(0, 1, (k, d, d))
        covs = a @ np.transpose(a, (0, 2, 1)) + 0.7 * np.eye(d)
        gmm = GMM(jnp.asarray(rng.dirichlet(np.ones(k)), jnp.float32),
                  jnp.asarray(rng.normal(0, 2, (k, d)), jnp.float32),
                  jnp.asarray(covs, jnp.float32))
        x = jnp.asarray(rng.normal(0, 2, (700, d)), jnp.float32)
        # "fused" must silently resolve to reference for full covariance
        np.testing.assert_allclose(
            float(score_streaming(gmm, x, chunk_size=128, backend="fused")),
            float(gmm.score(x)), rtol=1e-4, atol=1e-4)

    def test_log_prob_chunked_parity(self):
        rng = np.random.default_rng(13)
        gmm = random_diag_gmm(rng, 4, 6)
        x = jnp.asarray(rng.normal(0, 2, (777, 6)), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(log_prob_chunked(gmm, x, chunk_size=250)),
            np.asarray(gmm.log_prob(x)), rtol=1e-4, atol=1e-4)

    def test_log_prob_chunked_fused_interpret_parity(self):
        """The kernel-backed scoring path (interpret mode on CPU) matches
        the reference log density."""
        rng = np.random.default_rng(14)
        gmm = random_diag_gmm(rng, 3, 5)
        x = jnp.asarray(rng.normal(0, 2, (300, 5)), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(log_prob_chunked(gmm, x, chunk_size=128,
                                        backend="fused")),
            np.asarray(gmm.log_prob(x)), rtol=1e-4, atol=1e-4)


@pytest.mark.slow
class TestStreamingModelSelection:
    def test_fit_gmm_bic_chunking_invariant(self):
        x, _, _ = planted_gmm_data(np.random.default_rng(15), n=900, d=3,
                                   k=3, spread=6.0, std=0.5,
                                   min_sep_sigma=8.0)
        xj = jnp.asarray(x)
        full, bics_full = fit_gmm_bic(jax.random.key(0), xj, [2, 3],
                                      max_iter=60)
        chunked, bics_chunk = fit_gmm_bic(jax.random.key(0), xj, [2, 3],
                                          max_iter=60, chunk_size=256)
        assert min(bics_full, key=bics_full.get) == \
            min(bics_chunk, key=bics_chunk.get) == 3
        for k in bics_full:
            np.testing.assert_allclose(bics_chunk[k], bics_full[k],
                                       rtol=1e-3)
        np.testing.assert_allclose(np.asarray(full.gmm.means),
                                   np.asarray(chunked.gmm.means),
                                   rtol=1e-3, atol=1e-3)
