"""FedGenGMM end-to-end behaviour: one-shot aggregation tracks the
centralized model, works under heterogeneity, heterogeneous K_c, comm
accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (dem, fedgengmm, fit_gmm, partition)
from conftest import planted_gmm_data

# end-to-end fits: multi-second EM training loops on CPU
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(11)
    x, y, _ = planted_gmm_data(rng, n=3000, d=4, k=4, spread=5.0, std=0.6)
    return rng, x, y


class TestFedGen:
    def test_one_shot_close_to_centralized_iid(self, setup):
        rng, x, y = setup
        split = partition(np.random.default_rng(0), x, y, 6, "dirichlet", 100.0)
        fr = fedgengmm(jax.random.key(0), split, k_clients=4, k_global=4, h=80)
        bench = fit_gmm(jax.random.key(1), jnp.asarray(x), 4)
        ll_fed = float(fr.global_gmm.score(jnp.asarray(x)))
        ll_cen = float(bench.gmm.score(jnp.asarray(x)))
        assert ll_fed > ll_cen - 0.35, (ll_fed, ll_cen)

    def test_one_shot_close_to_centralized_noniid(self, setup):
        rng, x, y = setup
        split = partition(np.random.default_rng(1), x, y, 6, "dirichlet", 0.1)
        fr = fedgengmm(jax.random.key(0), split, k_clients=4, k_global=4, h=80)
        bench = fit_gmm(jax.random.key(1), jnp.asarray(x), 4)
        ll_fed = float(fr.global_gmm.score(jnp.asarray(x)))
        ll_cen = float(bench.gmm.score(jnp.asarray(x)))
        # paper claim: stable under heterogeneity
        assert ll_fed > ll_cen - 0.5, (ll_fed, ll_cen)

    def test_single_round(self, setup):
        rng, x, y = setup
        split = partition(np.random.default_rng(2), x, y, 4, "quantity", 2)
        fr = fedgengmm(jax.random.key(0), split, k_clients=3, k_global=4, h=50)
        assert fr.comm.rounds == 1

    def test_synthetic_size(self, setup):
        rng, x, y = setup
        split = partition(np.random.default_rng(3), x, y, 4, "dirichlet", 1.0)
        fr = fedgengmm(jax.random.key(0), split, k_clients=3, k_global=4, h=25)
        assert fr.synthetic.shape == (25 * 3 * 4, x.shape[1])  # H * sum K_c

    def test_heterogeneous_kc_via_bic(self, setup):
        rng, x, y = setup
        split = partition(np.random.default_rng(4), x, y, 3, "dirichlet", 1.0)
        fr = fedgengmm(jax.random.key(0), split, k_candidates=[2, 4],
                       k_global=4, h=40)
        assert all(g.n_components in (2, 4) for g in fr.local_gmms)
        assert bool(jnp.all(jnp.isfinite(fr.global_gmm.means)))

    def test_constrained_clients_larger_global(self, setup):
        """Fig. 5 setting: small local models, bigger global model."""
        rng, x, y = setup
        split = partition(np.random.default_rng(5), x, y, 6, "dirichlet", 0.2)
        fr = fedgengmm(jax.random.key(0), split, k_clients=2, k_global=8, h=80)
        assert fr.global_gmm.n_components == 8
        bench = fit_gmm(jax.random.key(1), jnp.asarray(x), 8)
        assert float(fr.global_gmm.score(jnp.asarray(x))) > \
            float(bench.gmm.score(jnp.asarray(x))) - 0.6

    def test_no_raw_data_in_uplink_accounting(self, setup):
        rng, x, y = setup
        split = partition(np.random.default_rng(6), x, y, 6, "dirichlet", 1.0)
        fr = fedgengmm(jax.random.key(0), split, k_clients=3, k_global=4, h=40)
        d = x.shape[1]
        per_client = 3 + 3 * d + 3 * d + 1  # weights+means+covs+size
        assert fr.comm.uplink_floats == 6 * per_client
        # far below shipping raw data
        assert fr.comm.uplink_floats < x.size // 10


class TestAgainstDEM:
    def test_fedgen_comparable_to_dem(self, setup):
        rng, x, y = setup
        split = partition(np.random.default_rng(7), x, y, 6, "dirichlet", 0.2)
        fr = fedgengmm(jax.random.key(0), split, k_clients=4, k_global=4, h=80)
        dr = dem(jax.random.key(1), split, 4, init=3)
        ll_fed = float(fr.global_gmm.score(jnp.asarray(x)))
        ll_dem = float(dr.global_gmm.score(jnp.asarray(x)))
        assert ll_fed > ll_dem - 0.5, (ll_fed, ll_dem)

    def test_fedgen_uses_fewer_rounds(self, setup):
        rng, x, y = setup
        split = partition(np.random.default_rng(8), x, y, 6, "dirichlet", 0.2)
        fr = fedgengmm(jax.random.key(0), split, k_clients=4, k_global=4, h=60)
        dr = dem(jax.random.key(1), split, 4, init=1)
        assert fr.comm.rounds == 1
        assert dr.comm.rounds > 1  # Table 4: order of magnitude more
