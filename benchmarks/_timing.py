"""Shared micro-benchmark timing harness.

One implementation for every bench module so the noise-mitigation scheme
(best-of-N, interleaving) can only evolve in one place and rows stay
comparable with the tracked BENCH_streaming.json trajectory.
"""
from __future__ import annotations

import time

import jax


def time_pair(fa, fb=None, iters: int = 10):
    """Interleaved best-of-iters wall time in us -> (us_a, us_b).

    min is robust to scheduler noise, and alternating the measurements
    means a bursty window (CPU steal on a small shared box) cannot land on
    one path's entire block and fake a slowdown — each path's min still
    finds its quiet windows. ``fb=None`` times a single function
    (us_b = inf).
    """
    jax.block_until_ready(fa())  # warmup/compile
    if fb is not None:
        jax.block_until_ready(fb())
    best_a = best_b = float("inf")
    for _ in range(iters):
        t0 = time.time()
        jax.block_until_ready(fa())
        best_a = min(best_a, time.time() - t0)
        if fb is not None:
            t0 = time.time()
            jax.block_until_ready(fb())
            best_b = min(best_b, time.time() - t0)
    return best_a * 1e6, best_b * 1e6


def time_one(fn, iters: int = 10) -> float:
    """Best-of-iters wall time of one function in us."""
    return time_pair(fn, None, iters)[0]
