"""Figure 5: constrained client models — small local K_c aggregated into a
larger global model (K=20), vs DEM restricted to K=K_c everywhere."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import eval_auc, load_quick
from repro.api import DEM, FedGenGMM, GMMEstimator
from repro.core import partition

DATASETS_Q = ["vehicle"]
DATASETS_FULL = ["mnist", "covertype", "rwhar", "vehicle", "smd"]
K_GLOBAL = 20


def run(quick: bool = True, seeds=(0,)) -> list[str]:
    rows = []
    kcs = [2, 5, 10, 20] if quick else [2, 5, 10, 15, 20]
    for name in (DATASETS_Q if quick else DATASETS_FULL):
        ds = load_quick(name, quick=quick)
        alpha = 0.2 if ds.scheme == "dirichlet" else 1
        import time
        for seed in seeds:
            rng = np.random.default_rng(seed)
            split = partition(rng, ds.x_train, ds.y_train, ds.n_clients,
                              ds.scheme, alpha)
            key = jax.random.key(seed)
            # non-federated benchmark at full K
            t0 = time.time()
            bench = GMMEstimator(K_GLOBAL).fit(
                np.asarray(ds.x_train),
                key=jax.random.fold_in(key, 99))
            rows.append(f"fig5_constrained/{name}/benchK20,"
                        f"{(time.time() - t0) * 1e6:.0f},"
                        f"{eval_auc(bench.gmm_, ds):.4f}")
            for kc in kcs:
                t0 = time.time()
                fr = FedGenGMM(k_clients=kc, k_global=K_GLOBAL, h=50).run(
                    split, key=jax.random.fold_in(key, kc))
                rows.append(f"fig5_constrained/{name}/Kc={kc}/fedgen,"
                            f"{(time.time() - t0) * 1e6:.0f},"
                            f"{eval_auc(fr.global_gmm, ds):.4f}")
                t0 = time.time()
                dr = DEM(kc, init="fed-kmeans").run(
                    split, key=jax.random.fold_in(key, 100 + kc))
                rows.append(f"fig5_constrained/{name}/Kc={kc}/dem3,"
                            f"{(time.time() - t0) * 1e6:.0f},"
                            f"{eval_auc(dr.global_gmm, ds):.4f}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
