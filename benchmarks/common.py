"""Shared harness for the paper-reproduction benchmarks.

Runs every method of §5.4 on one (dataset, heterogeneity) setting and
returns fitness scores (Eq. 2), anomaly AUC-PR (§5.8), and communication
accounting (Table 4). CPU-scale note: dataset sizes and repeat counts are
reduced vs the paper (band-2 simulation); the *relative* comparisons are
what is validated.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import DEM, FedGenGMM, FitConfig, GMMEstimator
from repro.core import partition
from repro.core.dem import INIT_SCHEME_NAMES
from repro.core.metrics import (anomaly_scores, auc_pr, auc_pr_for_model,
                                average_log_likelihood)
from repro.data import load

QUICK_SIZES = {  # n_train per dataset in quick (CI) mode
    "mnist": 4000, "covertype": 6000, "rwhar": 5000,
    "wadi": 5000, "vehicle": 6000, "smd": 6000,
}


def load_quick(name: str, seed: int = 0, quick: bool = True):
    kw = {"n_train": QUICK_SIZES[name]} if quick else {}
    return load(name, np.random.default_rng(seed), **kw)


def eval_auc(gmm, ds, chunk_size=None) -> float:
    return auc_pr_for_model(gmm, jnp.asarray(ds.x_test_in),
                            jnp.asarray(ds.x_test_ood),
                            chunk_size=chunk_size)


def eval_auc_local_mean(local_gmms, ds, chunk_size=None) -> float:
    """Local-models baseline: average the per-client scores (§5.4)."""
    s_in = np.mean([anomaly_scores(g, jnp.asarray(ds.x_test_in),
                                   chunk_size=chunk_size)
                    for g in local_gmms], axis=0)
    s_out = np.mean([anomaly_scores(g, jnp.asarray(ds.x_test_ood),
                                    chunk_size=chunk_size)
                     for g in local_gmms], axis=0)
    scores = np.concatenate([s_in, s_out])
    labels = np.concatenate([np.zeros(len(s_in)), np.ones(len(s_out))])
    return auc_pr(scores, labels)


def run_methods(ds, alpha: float, seed: int, *,
                k: Optional[int] = None,
                k_clients: Optional[int] = None,
                n_clients: Optional[int] = None,
                h: int = 50,
                chunk_size: Optional[int] = None,
                methods=("fedgen", "dem1", "dem2", "dem3", "local",
                         "central")) -> dict:
    """Returns {method: {loglik, auc_pr, rounds, seconds}}.

    ``chunk_size`` runs every method — training *and* scoring — through
    the streaming engine in O(chunk·K) memory (DESIGN.md §6): the
    memory-constrained edge-client configuration of Fig. 5.
    """
    k = k or ds.k_global
    k_clients = k_clients or k
    n_clients = n_clients or ds.n_clients
    rng = np.random.default_rng(seed)
    split = partition(rng, ds.x_train, ds.y_train, n_clients, ds.scheme,
                      alpha)
    xj = jnp.asarray(ds.x_train)
    key = jax.random.key(seed)
    cfg = FitConfig.from_legacy(chunk_size=chunk_size)
    out = {}

    def score(gmm):
        return average_log_likelihood(gmm, xj, chunk_size=chunk_size)

    local_gmms = None
    if "fedgen" in methods or "local" in methods:
        t0 = time.time()
        fr = FedGenGMM(k_clients=k_clients, k_global=k, h=h,
                       synthetic="resident", config=cfg).run(
            split, key=jax.random.fold_in(key, 0))
        if "fedgen" in methods:
            out["fedgen"] = {
                "loglik": score(fr.global_gmm),
                "auc_pr": eval_auc(fr.global_gmm, ds, chunk_size),
                "rounds": fr.comm.rounds,
                "uplink_floats": fr.comm.uplink_floats,
                "seconds": time.time() - t0,
            }
        local_gmms = fr.local_gmms
    if "local" in methods and local_gmms is not None:
        t0 = time.time()
        scores = [score(g) for g in local_gmms]
        out["local"] = {
            "loglik": float(np.mean(scores)),
            "auc_pr": eval_auc_local_mean(local_gmms, ds, chunk_size),
            "rounds": 0, "uplink_floats": 0,
            "seconds": time.time() - t0,
        }
    for init in (1, 2, 3):
        nm = f"dem{init}"
        if nm not in methods:
            continue
        t0 = time.time()
        dr = DEM(k, config=cfg.replace(init=INIT_SCHEME_NAMES[init])).run(
            split, key=jax.random.fold_in(key, 10 + init))
        out[nm] = {
            "loglik": score(dr.global_gmm),
            "auc_pr": eval_auc(dr.global_gmm, ds, chunk_size),
            "rounds": int(dr.n_rounds),
            "uplink_floats": dr.comm.uplink_floats,
            "seconds": time.time() - t0,
        }
    if "central" in methods:
        t0 = time.time()
        res = GMMEstimator(k, config=cfg).fit(
            xj, key=jax.random.fold_in(key, 99)).result_
        out["central"] = {
            "loglik": score(res.gmm),
            "auc_pr": eval_auc(res.gmm, ds, chunk_size),
            "rounds": 0, "uplink_floats": ds.x_train.size,
            "seconds": time.time() - t0,
        }
    return out


def csv_rows(experiment: str, dataset: str, alpha, results: dict,
             metric: str) -> list[str]:
    rows = []
    for method, r in results.items():
        name = f"{experiment}/{dataset}/alpha={alpha}/{method}"
        rows.append(f"{name},{r['seconds'] * 1e6:.0f},{r[metric]:.4f}")
    return rows
