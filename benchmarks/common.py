"""Shared harness for the paper-reproduction benchmarks.

Runs every method of §5.4 on one (dataset, heterogeneity) setting and
returns fitness scores (Eq. 2), anomaly AUC-PR (§5.8), and communication
accounting (Table 4). CPU-scale note: dataset sizes and repeat counts are
reduced vs the paper (band-2 simulation); the *relative* comparisons are
what is validated.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dem, fedgengmm, fit_gmm, partition
from repro.core.metrics import auc_pr, anomaly_scores
from repro.data import load

QUICK_SIZES = {  # n_train per dataset in quick (CI) mode
    "mnist": 4000, "covertype": 6000, "rwhar": 5000,
    "wadi": 5000, "vehicle": 6000, "smd": 6000,
}


def load_quick(name: str, seed: int = 0, quick: bool = True):
    kw = {"n_train": QUICK_SIZES[name]} if quick else {}
    return load(name, np.random.default_rng(seed), **kw)


def eval_auc(gmm, ds) -> float:
    s_in = anomaly_scores(gmm, jnp.asarray(ds.x_test_in))
    s_out = anomaly_scores(gmm, jnp.asarray(ds.x_test_ood))
    scores = np.concatenate([s_in, s_out])
    labels = np.concatenate([np.zeros(len(s_in)), np.ones(len(s_out))])
    return auc_pr(scores, labels)


def eval_auc_local_mean(local_gmms, ds) -> float:
    """Local-models baseline: average the per-client scores (§5.4)."""
    s_in = np.mean([anomaly_scores(g, jnp.asarray(ds.x_test_in))
                    for g in local_gmms], axis=0)
    s_out = np.mean([anomaly_scores(g, jnp.asarray(ds.x_test_ood))
                     for g in local_gmms], axis=0)
    scores = np.concatenate([s_in, s_out])
    labels = np.concatenate([np.zeros(len(s_in)), np.ones(len(s_out))])
    return auc_pr(scores, labels)


def run_methods(ds, alpha: float, seed: int, *,
                k: Optional[int] = None,
                k_clients: Optional[int] = None,
                n_clients: Optional[int] = None,
                h: int = 50,
                methods=("fedgen", "dem1", "dem2", "dem3", "local",
                         "central")) -> dict:
    """Returns {method: {loglik, auc_pr, rounds, seconds}}."""
    k = k or ds.k_global
    k_clients = k_clients or k
    n_clients = n_clients or ds.n_clients
    rng = np.random.default_rng(seed)
    split = partition(rng, ds.x_train, ds.y_train, n_clients, ds.scheme,
                      alpha)
    xj = jnp.asarray(ds.x_train)
    key = jax.random.key(seed)
    out = {}

    local_gmms = None
    if "fedgen" in methods or "local" in methods:
        t0 = time.time()
        fr = fedgengmm(jax.random.fold_in(key, 0), split,
                       k_clients=k_clients, k_global=k, h=h)
        if "fedgen" in methods:
            out["fedgen"] = {
                "loglik": float(fr.global_gmm.score(xj)),
                "auc_pr": eval_auc(fr.global_gmm, ds),
                "rounds": fr.comm.rounds,
                "uplink_floats": fr.comm.uplink_floats,
                "seconds": time.time() - t0,
            }
        local_gmms = fr.local_gmms
    if "local" in methods and local_gmms is not None:
        t0 = time.time()
        scores = [float(g.score(xj)) for g in local_gmms]
        out["local"] = {
            "loglik": float(np.mean(scores)),
            "auc_pr": eval_auc_local_mean(local_gmms, ds),
            "rounds": 0, "uplink_floats": 0,
            "seconds": time.time() - t0,
        }
    for init in (1, 2, 3):
        nm = f"dem{init}"
        if nm not in methods:
            continue
        t0 = time.time()
        dr = dem(jax.random.fold_in(key, 10 + init), split, k, init=init)
        out[nm] = {
            "loglik": float(dr.global_gmm.score(xj)),
            "auc_pr": eval_auc(dr.global_gmm, ds),
            "rounds": int(dr.n_rounds),
            "uplink_floats": dr.comm.uplink_floats,
            "seconds": time.time() - t0,
        }
    if "central" in methods:
        t0 = time.time()
        res = fit_gmm(jax.random.fold_in(key, 99), xj, k)
        out["central"] = {
            "loglik": float(res.gmm.score(xj)),
            "auc_pr": eval_auc(res.gmm, ds),
            "rounds": 0, "uplink_floats": ds.x_train.size,
            "seconds": time.time() - t0,
        }
    return out


def csv_rows(experiment: str, dataset: str, alpha, results: dict,
             metric: str) -> list[str]:
    rows = []
    for method, r in results.items():
        name = f"{experiment}/{dataset}/alpha={alpha}/{method}"
        rows.append(f"{name},{r['seconds'] * 1e6:.0f},{r[metric]:.4f}")
    return rows
