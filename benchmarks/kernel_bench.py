"""Kernel micro-benchmarks: XLA reference path timings on CPU (the Pallas
kernels themselves are TPU-targeted; interpret mode is correctness-only and
its timing is meaningless, so we report the oracle path + a one-shot
interpret-mode parity check).

Also benchmarks the *engine* stages end to end — E-step, k-means Lloyd
sweep, and BIC scoring, each as reference (full-batch jnp), fused (Pallas
kernel; real timing on TPU only), and chunked (lax.scan streaming
accumulator) — in one run, together with the (N, K)-block working set each
needs, so both the speedup and the memory ceiling of the streaming paths
are measurable.

``--dry-run`` (the CI bench-smoke lane) runs one tiny shape with a single
timing iteration and validates every emitted row against the
``name,us_per_call,derived`` CSV contract — execution coverage without
pretending the numbers mean anything."""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

try:  # package import (benchmarks/run.py)
    from benchmarks._timing import time_one as _time
    from benchmarks._timing import time_pair as _time_pair
except ImportError:  # documented standalone: python benchmarks/kernel_bench.py
    from _timing import time_one as _time
    from _timing import time_pair as _time_pair
from repro.api import FitConfig
from repro.api import bic as api_bic
from repro.core.em import e_step_stats, e_step_stats_chunked
from repro.core.gmm import GMM
from repro.core.kmeans import kmeans
from repro.kernels import ops, ref
from repro.kernels.estep_stats import DEFAULT_BLOCK_N

SHAPES = [(20000, 24, 30), (20000, 84, 10), (50000, 38, 10)]
SHAPES_DRY = [(2048, 24, 10)]
ENGINE_CHUNK = 4096


def validate_rows(rows: list[str]) -> None:
    """Every row must parse as ``name,us_per_call,derived`` with a numeric
    us column — the contract benchmarks/run.py's CSV consumers rely on."""
    problems = []
    for row in rows:
        parts = row.split(",")
        if len(parts) != 3:
            problems.append(f"expected 3 CSV fields: {row!r}")
            continue
        try:
            float(parts[1])
        except ValueError:
            problems.append(f"non-numeric us column: {row!r}")
    if problems:
        raise ValueError("kernel_bench row-format violations:\n  "
                         + "\n  ".join(problems))


def run(quick: bool = True, dry_run: bool = False) -> list[str]:
    shapes = SHAPES_DRY if dry_run else (SHAPES[:2] if quick else SHAPES)
    iters = 1 if dry_run else 10
    rows = []
    for n, d, k in shapes:
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        mu = jnp.asarray(rng.normal(size=(k, d)), jnp.float32)
        var = jnp.asarray(rng.uniform(0.1, 2, (k, d)), jnp.float32)
        lw = jnp.asarray(np.log(rng.dirichlet(np.ones(k))), jnp.float32)

        logpdf = jax.jit(ref.gmm_logpdf_ref)
        us = _time(lambda: logpdf(x, mu, var, lw), iters=iters)
        rows.append(f"kernel/gmm_logpdf_ref/N{n}d{d}K{k},{us:.0f},"
                    f"{2 * n * d * k * 2 / (us * 1e-6) / 1e9:.2f}")

        estep = jax.jit(ref.estep_stats_ref)
        us = _time(lambda: estep(x, mu, var, lw), iters=iters)
        rows.append(f"kernel/estep_stats_ref/N{n}d{d}K{k},{us:.0f},"
                    f"{4 * n * d * k * 2 / (us * 1e-6) / 1e9:.2f}")

        # interpret-mode parity (correctness, not speed)
        sub = x[:2048]
        a = ops.estep_stats(sub, mu, var, lw, interpret=True)
        b = ref.estep_stats_ref(sub, mu, var, lw)
        err = max(float(jnp.max(jnp.abs(u - v))) for u, v in zip(a, b))
        rows.append(f"kernel/estep_pallas_parity/N2048d{d}K{k},0,{err:.2e}")

        rows.extend(_engine_rows(x, mu, var, lw, n, d, k, iters))
        rows.extend(_kmeans_rows(x, n, d, k, iters))
        rows.extend(_scoring_rows(x, mu, var, lw, n, d, k, iters))
    if dry_run:
        validate_rows(rows)
        rows.append("# dry-run: row format OK, timings are placeholders")
    return rows


def _engine_rows(x, mu, var, lw, n, d, k, iters=10) -> list[str]:
    """reference vs fused vs chunked E-step engine, one shape.

    Columns: label, wall us, responsibility working set in MiB (the (N, K)
    matrix for the full-batch path, one (chunk, K) block for streaming; the
    fused kernel keeps it in VMEM tiles, reported as its (block_n, K)).
    """
    gmm = GMM(jnp.exp(lw), mu, var)
    on_tpu = jax.default_backend() == "tpu"
    mib = lambda rows_resident: rows_resident * k * 4 / 2**20

    engine_ref = jax.jit(
        lambda x: e_step_stats(gmm, x, estep_backend="reference"))
    us = _time(lambda: engine_ref(x), iters=iters)
    out = [f"engine/estep_reference/N{n}d{d}K{k},{us:.0f},{mib(n):.2f}"]

    engine_chunked = jax.jit(lambda x: e_step_stats_chunked(
        gmm, x, chunk_size=ENGINE_CHUNK, estep_backend="reference"))
    us = _time(lambda: engine_chunked(x), iters=iters)
    out.append(f"engine/estep_chunked_c{ENGINE_CHUNK}/N{n}d{d}K{k},"
               f"{us:.0f},{mib(ENGINE_CHUNK):.2f}")

    if on_tpu:
        engine_fused = jax.jit(
            lambda x: e_step_stats(gmm, x, estep_backend="fused"))
        us = _time(lambda: engine_fused(x), iters=iters)
        # the kernel's default block_n: its resident resp tile
        out.append(f"engine/estep_fused/N{n}d{d}K{k},{us:.0f},{mib(DEFAULT_BLOCK_N):.2f}")
    else:
        # CPU: interpret mode executes the kernel body in Python — parity
        # is already checked above, a timing would only mislead. Keep the
        # us column numeric (0 = not timed, like the parity rows).
        out.append(f"engine/estep_fused/N{n}d{d}K{k},0,skipped_not_tpu")
    return out


def _kmeans_rows(x, n, d, k, iters=10) -> list[str]:
    """Full-batch vs chunked Lloyd engine (fixed 10 sweeps, tol=0 so both
    run the same iteration count). Working-set column: the (rows, K)
    distance block each sweep materializes."""
    mib = lambda rows_resident: rows_resident * k * 4 / 2**20
    key = jax.random.key(0)
    us_full, us_chunk = _time_pair(
        lambda: kmeans(key, x, k, max_iter=10, tol=0.0).centers,
        lambda: kmeans(key, x, k, max_iter=10, tol=0.0,
                       chunk_size=ENGINE_CHUNK).centers, iters=iters)
    out = [f"engine/kmeans_full/N{n}d{d}K{k},{us_full:.0f},{mib(n):.2f}",
           f"engine/kmeans_chunked_c{ENGINE_CHUNK}/N{n}d{d}K{k},"
           f"{us_chunk:.0f},{mib(ENGINE_CHUNK):.2f}"]
    # interpret-mode parity of the Pallas assignment kernel (not a timing)
    sub = x[:2048]
    centers = x[:k]
    idx_p, d2_p = ops.kmeans_assign(sub, centers, interpret=True)
    from repro.core.kmeans import _sq_dists
    dref = _sq_dists(sub, centers)
    err = max(float(jnp.sum(idx_p != jnp.argmin(dref, 1))),
              float(jnp.max(jnp.abs(d2_p - jnp.min(dref, 1)))))
    out.append(f"kernel/kmeans_assign_parity/N2048d{d}K{k},0,{err:.2e}")
    return out


def _scoring_rows(x, mu, var, lw, n, d, k, iters=10) -> list[str]:
    """Full-batch GMM.bic vs streaming BIC (the per-candidate model
    selection cost of TrainGMM). Working-set column: the (rows, K)
    log-prob block."""
    gmm = GMM(jnp.exp(lw), mu, var)
    mib = lambda rows_resident: rows_resident * k * 4 / 2**20
    bic_full = jax.jit(lambda x: gmm.bic(x))
    bic_cfg = FitConfig(chunk_size=ENGINE_CHUNK, backend="reference")
    bic_chunk = jax.jit(lambda x: api_bic(gmm, x, config=bic_cfg))
    us_full, us_chunk = _time_pair(lambda: bic_full(x),
                                   lambda: bic_chunk(x), iters=iters)
    return [f"engine/bic_full/N{n}d{d}K{k},{us_full:.0f},{mib(n):.2f}",
            f"engine/bic_chunked_c{ENGINE_CHUNK}/N{n}d{d}K{k},"
            f"{us_chunk:.0f},{mib(ENGINE_CHUNK):.2f}"]


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--dry-run", action="store_true",
                        help="tiny-N row-format validation mode (CI "
                             "bench-smoke lane)")
    cli = parser.parse_args()
    for r in run(dry_run=cli.dry_run):
        print(r)
