"""Kernel micro-benchmarks: XLA reference path timings on CPU (the Pallas
kernels themselves are TPU-targeted; interpret mode is correctness-only and
its timing is meaningless, so we report the oracle path + a one-shot
interpret-mode parity check)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

SHAPES = [(20000, 24, 30), (20000, 84, 10), (50000, 38, 10)]


def _time(fn, iters=5):
    jax.block_until_ready(fn())  # warmup/compile
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn())
    return (time.time() - t0) / iters * 1e6


def run(quick: bool = True) -> list[str]:
    rows = []
    for n, d, k in (SHAPES[:2] if quick else SHAPES):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        mu = jnp.asarray(rng.normal(size=(k, d)), jnp.float32)
        var = jnp.asarray(rng.uniform(0.1, 2, (k, d)), jnp.float32)
        lw = jnp.asarray(np.log(rng.dirichlet(np.ones(k))), jnp.float32)

        logpdf = jax.jit(ref.gmm_logpdf_ref)
        us = _time(lambda: logpdf(x, mu, var, lw))
        rows.append(f"kernel/gmm_logpdf_ref/N{n}d{d}K{k},{us:.0f},"
                    f"{2 * n * d * k * 2 / (us * 1e-6) / 1e9:.2f}")

        estep = jax.jit(ref.estep_stats_ref)
        us = _time(lambda: estep(x, mu, var, lw))
        rows.append(f"kernel/estep_stats_ref/N{n}d{d}K{k},{us:.0f},"
                    f"{4 * n * d * k * 2 / (us * 1e-6) / 1e9:.2f}")

        # interpret-mode parity (correctness, not speed)
        sub = x[:2048]
        a = ops.estep_stats(sub, mu, var, lw, interpret=True)
        b = ref.estep_stats_ref(sub, mu, var, lw)
        err = max(float(jnp.max(jnp.abs(u - v))) for u, v in zip(a, b))
        rows.append(f"kernel/estep_pallas_parity/N2048d{d}K{k},0,{err:.2e}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
