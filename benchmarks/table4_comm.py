"""Table 4: communication rounds (and uplink volume) per method. Validates
the one-shot claim: FedGenGMM = 1 round; DEM = one-to-two orders more."""
from __future__ import annotations

from benchmarks.common import load_quick, run_methods

DATASETS_Q = ["covertype", "vehicle"]
DATASETS_FULL = ["mnist", "covertype", "rwhar", "wadi", "vehicle", "smd"]


def run(quick: bool = True, seeds=(0,)) -> list[str]:
    rows = []
    for name in (DATASETS_Q if quick else DATASETS_FULL):
        ds = load_quick(name, quick=quick)
        alpha = 0.2 if ds.scheme == "dirichlet" else 1
        for seed in seeds:
            res = run_methods(ds, alpha, seed,
                              methods=("fedgen", "dem1", "dem2", "dem3"))
            for m, r in res.items():
                rows.append(
                    f"table4_comm/{name}/{m},{r['seconds'] * 1e6:.0f},"
                    f"{r['rounds']}")
                rows.append(
                    f"table4_uplink/{name}/{m},{r['seconds'] * 1e6:.0f},"
                    f"{r['uplink_floats']}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
