"""Serving-engine latency/throughput/swap-pause — the DESIGN.md §10
continuous-batching claims as a tracked artifact.

A fitted diagonal GMM serves a stream of mixed-size scoring requests
through ``repro.serve.ScoringEngine``. The **sweep** section times each
slot-pool geometry (slots x rows_per_slot) on the SAME request stream,
reporting per-request submit-to-retire latency (p50/p99) and throughput
(requests/s and rows/s) — the batch-size/slot-count trade the one
compiled slab shape buys. The **swap** section re-runs the stream and
hot-swaps a second model mid-flight: it reports the drain-and-install
admission pause (``ScoringEngine.swap_pauses``) and proves the
protocol's consistency guarantee by COUNTING — every submitted request
must retire, tagged with exactly one of the two versions.

In full mode (standalone ``python benchmarks/serve_bench.py``) the
results are written to ``BENCH_serve.json`` (repo root):

    {"backend", "setting": {d, k, requests, rows_total},
     "sweep": [{slots, rows_per_slot, p50_ms, p99_ms, requests_per_s,
                rows_per_s, seconds}],
     "swap": {slots, rows_per_slot, swaps, pause_ms_mean, pause_ms_max,
              submitted, completed, dropped, versions_seen}}

Full mode FAILS (RuntimeError) if any request is dropped across the
mid-stream swap, if results arrive tagged with a version other than the
two that served, or if the best geometry's p99 latency exceeds
``P99_LIMIT_MS`` — the "bounded tail under continuous batching" claim,
guarded. Quick (CI) mode scales down and prints rows only; ``--dry-run``
shrinks to a tiny stream and *validates the report schema* instead of
recording timings — that is what the CI bench-smoke lane runs.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.api import GMMEstimator
from repro.serve import ScoreConfig, ScoreRequest, ScoringEngine

D, K = 8, 5
JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_serve.json"

# (slots, rows_per_slot) geometries swept on the same request stream
SWEEP_FULL = ((1, 256), (4, 256), (8, 256), (4, 1024), (8, 1024))
SWEEP_DRY = ((1, 32), (2, 32))
# request-size mix: mostly small online batches, a heavy tail that
# streams through its slot across micro-batches
REQ_SIZES_FULL = (16, 64, 200, 512, 3000)
REQ_SIZES_DRY = (4, 16, 40)
N_REQS_FULL, N_REQS_DRY = 400, 24
ARRIVALS_PER_STEP = 4          # open-loop-ish: submissions trickle in
P99_LIMIT_MS = 2000.0          # generous CPU bound; the guard is the tail
                               # staying bounded, not a specific machine

SWEEP_FIELDS = ("slots", "rows_per_slot", "p50_ms", "p99_ms",
                "requests_per_s", "rows_per_s", "seconds")
SWAP_FIELDS = ("slots", "rows_per_slot", "swaps", "pause_ms_mean",
               "pause_ms_max", "submitted", "completed", "dropped",
               "versions_seen")


def validate_report(report: dict) -> None:
    """Schema gate for the tracked JSON; raises ValueError listing every
    violation rather than stopping at the first."""
    problems = []
    for field in ("backend", "setting", "sweep", "swap"):
        if field not in report:
            problems.append(f"missing top-level field {field!r}")
    setting = report.get("setting", {})
    for field in ("d", "k", "requests", "rows_total"):
        if not isinstance(setting.get(field), int):
            problems.append(f"setting.{field} must be an int")
    sweep = report.get("sweep", [])
    if not isinstance(sweep, list) or not sweep:
        problems.append("sweep must be a non-empty list")
        sweep = []
    for i, row in enumerate(sweep):
        for field in ("slots", "rows_per_slot"):
            if not isinstance(row.get(field), int) or row.get(field) < 1:
                problems.append(f"sweep[{i}].{field} must be a positive "
                                f"int, got {row.get(field)!r}")
        for field in ("p50_ms", "p99_ms", "requests_per_s", "rows_per_s",
                      "seconds"):
            v = row.get(field)
            if not isinstance(v, (int, float)) or v < 0:
                problems.append(f"sweep[{i}].{field} must be a "
                                f"non-negative number, got {v!r}")
        if isinstance(row.get("p50_ms"), float) and \
                isinstance(row.get("p99_ms"), float) and \
                row["p99_ms"] < row["p50_ms"]:
            problems.append(f"sweep[{i}]: p99_ms < p50_ms")
    swap = report.get("swap", {})
    for field in ("swaps", "submitted", "completed", "dropped"):
        v = swap.get(field)
        if not isinstance(v, int) or v < 0:
            problems.append(f"swap.{field} must be a non-negative int, "
                            f"got {v!r}")
    for field in ("pause_ms_mean", "pause_ms_max"):
        v = swap.get(field)
        if not isinstance(v, (int, float)) or v < 0:
            problems.append(f"swap.{field} must be a non-negative "
                            f"number, got {v!r}")
    if not isinstance(swap.get("versions_seen"), list):
        problems.append("swap.versions_seen must be a list")
    if problems:
        raise ValueError("BENCH_serve.json schema violations:\n  "
                         + "\n  ".join(problems))


def _fit_models(rng: np.random.Generator):
    """Two distinct fitted models over the same features — the serving
    model and the mid-stream replacement."""
    x = np.concatenate([rng.normal(m, 1.0, (600, D))
                        for m in np.linspace(0.0, 8.0, K)]
                       ).astype(np.float32)
    gmm_a = GMMEstimator(k=K, seed=0).fit(x).gmm_
    gmm_b = GMMEstimator(k=K, seed=3).fit(x[::2] + 0.2).gmm_
    return gmm_a, gmm_b


def _request_stream(rng: np.random.Generator, sizes, n_reqs: int):
    picks = rng.choice(len(sizes), size=n_reqs)
    return [ScoreRequest(i, rng.normal(0.0, 4.0, (sizes[p], D)))
            for i, p in enumerate(picks)]


def _drive(eng: ScoringEngine, reqs, install_at=None, new_model=None):
    """Trickle the stream in (ARRIVALS_PER_STEP per micro-batch),
    optionally installing ``new_model`` after ``install_at`` submissions
    -> (results, wall_seconds)."""
    results, submitted = [], 0
    t0 = time.time()
    while submitted < len(reqs) or eng.pending_requests:
        for _ in range(ARRIVALS_PER_STEP):
            if submitted < len(reqs):
                eng.submit(reqs[submitted])
                submitted += 1
        if install_at is not None and submitted >= install_at:
            eng.install(new_model, 2)
            install_at = None
        results.extend(eng.step())
    return results, time.time() - t0


def _sweep_row(gmm, reqs, slots: int, rows_per_slot: int) -> dict:
    eng = ScoringEngine(gmm, ScoreConfig(slots=slots,
                                         rows_per_slot=rows_per_slot))
    _drive(eng, reqs[: 2 * slots])                 # warmup: compile
    eng2 = ScoringEngine(gmm, ScoreConfig(slots=slots,
                                          rows_per_slot=rows_per_slot))
    results, secs = _drive(eng2, reqs)
    lat_ms = np.array([r.latency_s for r in results]) * 1e3
    rows_total = int(sum(r.num_rows for r in results))
    return {
        "slots": slots,
        "rows_per_slot": rows_per_slot,
        "p50_ms": round(float(np.percentile(lat_ms, 50)), 3),
        "p99_ms": round(float(np.percentile(lat_ms, 99)), 3),
        "requests_per_s": round(len(results) / secs, 2),
        "rows_per_s": round(rows_total / secs, 1),
        "seconds": round(secs, 3),
    }


def _swap_section(gmm_a, gmm_b, reqs, slots: int,
                  rows_per_slot: int) -> dict:
    eng = ScoringEngine(gmm_a, ScoreConfig(slots=slots,
                                           rows_per_slot=rows_per_slot),
                        version=1)
    results, _ = _drive(eng, reqs, install_at=len(reqs) // 2,
                        new_model=gmm_b)
    pauses_ms = [p * 1e3 for p in eng.swap_pauses]
    return {
        "slots": slots,
        "rows_per_slot": rows_per_slot,
        "swaps": eng.swaps,
        "pause_ms_mean": round(float(np.mean(pauses_ms)), 3) if pauses_ms
        else 0.0,
        "pause_ms_max": round(float(np.max(pauses_ms)), 3) if pauses_ms
        else 0.0,
        "submitted": len(reqs),
        "completed": len(results),
        "dropped": len(reqs) - len(results),
        "versions_seen": sorted({r.model_version for r in results}),
    }


def run(quick: bool = True, dry_run: bool = False) -> list[str]:
    sweep_cfgs = SWEEP_DRY if dry_run else SWEEP_FULL
    sizes = REQ_SIZES_DRY if dry_run else REQ_SIZES_FULL
    n_reqs = N_REQS_DRY if dry_run else (
        N_REQS_FULL // 4 if quick else N_REQS_FULL)
    rng = np.random.default_rng(0)
    gmm_a, gmm_b = _fit_models(rng)
    reqs = _request_stream(rng, sizes, n_reqs)

    report = {
        "backend": jax.default_backend(),
        "setting": {"d": D, "k": K, "requests": n_reqs,
                    "rows_total": int(sum(r.num_rows for r in reqs)),
                    "request_sizes": list(sizes),
                    "arrivals_per_step": ARRIVALS_PER_STEP},
        "sweep": [],
        "swap": {},
    }
    rows = []
    for slots, rps in sweep_cfgs:
        row = _sweep_row(gmm_a, reqs, slots, rps)
        report["sweep"].append(row)
        rows.append(f"serve/slots{slots}x{rps}/req{n_reqs}d{D}K{K},"
                    f"{row['seconds'] / max(n_reqs, 1) * 1e6:.0f},"
                    f"p50={row['p50_ms']}ms p99={row['p99_ms']}ms "
                    f"{row['requests_per_s']}req/s "
                    f"{row['rows_per_s']:.0f}rows/s")

    swap_slots, swap_rps = sweep_cfgs[-1]
    swap = _swap_section(gmm_a, gmm_b, reqs, swap_slots, swap_rps)
    report["swap"] = swap
    rows.append(f"serve/hot_swap/slots{swap_slots}x{swap_rps},"
                f"{swap['pause_ms_mean'] * 1e3:.0f},"
                f"pause_max={swap['pause_ms_max']}ms "
                f"dropped={swap['dropped']} "
                f"versions={swap['versions_seen']}")

    validate_report(report)
    if not dry_run:
        # hard guards: the consistency claim and the bounded tail
        if swap["dropped"] != 0:
            raise RuntimeError(
                f"hot swap dropped {swap['dropped']} of "
                f"{swap['submitted']} requests — the drain-and-install "
                f"protocol guarantees zero")
        if not set(swap["versions_seen"]) <= {1, 2}:
            raise RuntimeError(
                f"results tagged with unknown model versions: "
                f"{swap['versions_seen']} (expected a subset of [1, 2])")
        best_p99 = min(row["p99_ms"] for row in report["sweep"])
        if best_p99 > P99_LIMIT_MS:
            raise RuntimeError(
                f"serving tail latency unbounded: best-geometry p99 is "
                f"{best_p99:.1f}ms (guard: <= {P99_LIMIT_MS}ms)")
    if dry_run:
        rows.append("# dry-run: report schema OK, numbers are placeholders")
        return rows
    if not quick:
        JSON_PATH.write_text(json.dumps(report, indent=2) + "\n")
    return rows


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--dry-run", action="store_true",
                        help="tiny-stream schema-validation mode (CI "
                             "bench-smoke lane): runs the sweep and the "
                             "mid-stream swap, validates the report "
                             "schema, writes nothing")
    cli = parser.parse_args()
    for r in run(quick=cli.dry_run, dry_run=cli.dry_run):
        print(r)
    if not cli.dry_run:
        print(f"# wrote {JSON_PATH}")
