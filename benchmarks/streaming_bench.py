"""Streaming-engine trajectory benchmark (DESIGN.md §6).

Times every stage of the constant-memory TrainGMM pipeline — k-means Lloyd
sweeps, init label statistics, the E-step, and BIC scoring — full-batch vs
chunked. In full mode (standalone, or ``BENCH_FULL=1 benchmarks/run.py``)
it also writes the results to ``BENCH_streaming.json`` (repo root) in
machine-readable form so the perf trajectory is tracked across PRs:

    {"stages": {stage: {"full_us", "chunked_us", "full_peak_bytes",
                        "chunked_peak_bytes", "slowdown"}}, ...}

Quick (CI) mode runs a scaled-down sweep and prints rows only — it never
touches the tracked JSON, so benchmark smoke runs don't dirty the working
tree or replace reference timings with noisy-machine numbers.

``peak_bytes`` is the analytic per-stage working set: the (rows, K) block
(distances / responsibilities / log-probs) for the Lloyd, E-step and BIC
stages, and the (rows, d) weighted-row block for the label statistics
(whose (N, K) one-hot no longer exists on either path). ``slowdown`` is
chunked/full wall time — the price of O(chunk·K) memory, tracked to stay
under 2x.
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

try:  # package import (benchmarks/run.py)
    from benchmarks._timing import time_one as _time
    from benchmarks._timing import time_pair as _time_pair
except ImportError:  # standalone: python benchmarks/streaming_bench.py
    from _timing import time_one as _time
    from _timing import time_pair as _time_pair
from repro.core.em import (bic_streaming, e_step_stats, init_from_kmeans,
                           label_stats)
from repro.core.gmm import GMM
from repro.core.kmeans import kmeans

N_FULL, N_QUICK, D, K = 100_000, 20_000, 16, 8
# 8192 amortizes CPU scan serialization to <2x full-batch wall time while
# keeping the per-stage working set at 8192·K·4 = 256 KiB (vs 3 MiB full
# at N=100k); on TPU the fused kernels re-tile each chunk internally.
CHUNK = 8192
JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_streaming.json"


def _stages(x, gmm, assignments):
    """{stage: (full_fn, chunked_fn, full_peak_bytes, chunked_peak_bytes)}.
    Data is a traced jit argument everywhere — a closed-over array would be
    constant-folded by XLA and the full-batch timings would be fiction."""
    n = x.shape[0]
    nk = lambda rows: rows * K * 4
    nd = lambda rows: rows * D * 4
    key = jax.random.key(0)
    lbl_full = jax.jit(lambda x, a: label_stats(x, a, K).s1)
    lbl_chunk = jax.jit(lambda x, a: label_stats(x, a, K,
                                                 chunk_size=CHUNK).s1)
    es_full = jax.jit(lambda x: e_step_stats(gmm, x).s1)
    es_chunk = jax.jit(lambda x: e_step_stats(gmm, x, chunk_size=CHUNK).s1)
    bic_full = jax.jit(lambda x: gmm.bic(x))
    bic_chunk = jax.jit(lambda x: bic_streaming(gmm, x, chunk_size=CHUNK))
    return {
        "kmeans_lloyd": (
            lambda: kmeans(key, x, K, max_iter=10, tol=0.0).centers,
            lambda: kmeans(key, x, K, max_iter=10, tol=0.0,
                           chunk_size=CHUNK).centers,
            nk(n), nk(CHUNK)),
        "init_label_stats": (
            lambda: lbl_full(x, assignments),
            lambda: lbl_chunk(x, assignments),
            nd(n), nd(CHUNK)),
        "em_estep": (
            lambda: es_full(x), lambda: es_chunk(x), nk(n), nk(CHUNK)),
        "bic_score": (
            lambda: bic_full(x), lambda: bic_chunk(x), nk(n), nk(CHUNK)),
    }


def run(quick: bool = True) -> list[str]:
    n = N_QUICK if quick else N_FULL
    rng = np.random.default_rng(0)
    mus = rng.normal(0, 5, (K, D)).astype(np.float32)
    comp = rng.integers(0, K, n)
    x = jnp.asarray(mus[comp] + rng.normal(0, 0.7, (n, D)).astype(np.float32))
    gmm = GMM(jnp.full((K,), 1.0 / K), jnp.asarray(mus),
              jnp.full((K, D), 0.5))
    assignments = jnp.asarray(comp, jnp.int32)

    report = {
        "backend": jax.default_backend(),
        "shape": {"n": n, "d": D, "k": K},
        "chunk_size": CHUNK,
        "stages": {},
    }
    rows = []
    for stage, (full_fn, chunked_fn, full_b, chunk_b) in _stages(
            x, gmm, assignments).items():
        full_us, chunked_us = _time_pair(full_fn, chunked_fn, iters=20)
        report["stages"][stage] = {
            "full_us": round(full_us),
            "chunked_us": round(chunked_us),
            "full_peak_bytes": full_b,
            "chunked_peak_bytes": chunk_b,
            "slowdown": round(chunked_us / full_us, 3),
        }
        rows.append(f"streaming/{stage}_full/N{n}d{D}K{K},{full_us:.0f},"
                    f"{full_b / 2**20:.2f}")
        rows.append(f"streaming/{stage}_chunked_c{CHUNK}/N{n}d{D}K{K},"
                    f"{chunked_us:.0f},{chunk_b / 2**20:.2f}")
    if not quick:
        # end-to-end streaming init (4-restart k-means + label stats)
        us = _time(lambda: init_from_kmeans(jax.random.key(1), x, K,
                                            chunk_size=CHUNK).means, iters=1)
        report["init_from_kmeans_chunked_us"] = round(us)
        JSON_PATH.write_text(json.dumps(report, indent=2) + "\n")
    return rows


if __name__ == "__main__":
    for r in run(quick=False):
        print(r)
    print(f"# wrote {JSON_PATH}")
