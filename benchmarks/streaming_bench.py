"""Streaming-engine trajectory benchmark (DESIGN.md §6/§7).

Times every stage of the constant-memory TrainGMM pipeline — k-means Lloyd
sweeps, init label statistics, the E-step, and BIC scoring — full-batch vs
chunked, plus the out-of-core E-step through each DataSource flavour
(resident-array-as-source, mmap ``.npy``, seeded synthetic stream). The
source rows answer ROADMAP follow-up (b): whether the host-side block loop
avoids the CPU ``lax.scan`` serialization cost that the resident chunked
path pays.

In full mode (standalone ``python benchmarks/streaming_bench.py``, or
``BENCH_FULL=1 benchmarks/run.py``) it also writes the results to
``BENCH_streaming.json`` (repo root) in machine-readable form so the perf
trajectory is tracked across PRs:

    {"stages": {stage: {"full_us", "chunked_us", "full_peak_bytes",
                        "chunked_peak_bytes", "slowdown"}},
     "sources": {"estep_full_us", "estep_scan_chunked_us",
                 "estep_scan2_chunked_us", "estep_array_source_us",
                 "estep_mmap_source_us", "estep_synthetic_source_us",
                 "estep_source_prefetch{0,1,2}_us", "source_vs_scan",
                 "source_vs_full", "synthetic_vs_array",
                 "chosen_prefetch_depth"}, ...}

Full mode additionally enforces the regression guards (``source_vs_full``
<= 2.0, ``synthetic_vs_array`` <= 1.5, ``init_from_kmeans_chunked_us``
< 500k, and the auto-chosen prefetch depth never being the slowest
measured depth) before writing the JSON.

Quick (CI) mode runs a scaled-down sweep and prints rows only — it never
touches the tracked JSON, so benchmark smoke runs don't dirty the working
tree or replace reference timings with noisy-machine numbers. ``--dry-run``
shrinks further (tiny N, single timing iteration — numbers are meaningless
by design) and instead *validates the report schema*, which is what the CI
bench-smoke lane runs: the bench can't silently rot even though no real
timing happens in CI.

``peak_bytes`` is the analytic per-stage working set: the (rows, K) block
(distances / responsibilities / log-probs) for the Lloyd, E-step and BIC
stages, and the (rows, d) weighted-row block for the label statistics
(whose (N, K) one-hot no longer exists on either path). ``slowdown`` is
chunked/full wall time — the price of O(chunk·K) memory, tracked to stay
under 2x.
"""
from __future__ import annotations

import argparse
import json
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

try:  # package import (benchmarks/run.py)
    from benchmarks._timing import time_one as _time
    from benchmarks._timing import time_pair as _time_pair
except ImportError:  # standalone: python benchmarks/streaming_bench.py
    from _timing import time_one as _time
    from _timing import time_pair as _time_pair
from repro.api import FitConfig
from repro.api import bic as api_bic
from repro.core.em import e_step_stats, init_from_kmeans, label_stats
from repro.core.gmm import GMM
from repro.core.kmeans import kmeans
from repro.data import sources
from repro.data.sources import ArraySource, NpyFileSource, SyntheticGMMSource

N_FULL, N_QUICK, N_DRY, D, K = 100_000, 20_000, 2_048, 16, 8
# 8192 amortizes CPU scan serialization to <2x full-batch wall time while
# keeping the per-stage working set at 8192·K·4 = 256 KiB (vs 3 MiB full
# at N=100k); on TPU the fused kernels re-tile each chunk internally.
CHUNK, CHUNK_DRY = 8192, 512
JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_streaming.json"

# {section: required keys} of the machine-readable report — the contract
# the CI dry-run enforces so downstream tooling (and the next perf PR) can
# rely on the JSON shape without re-reading this module.
REPORT_SCHEMA = {
    "stages": ("full_us", "chunked_us", "full_peak_bytes",
               "chunked_peak_bytes", "slowdown"),
    "sources": ("chunk_size", "estep_full_us", "estep_scan_chunked_us",
                "estep_scan2_chunked_us", "estep_array_source_us",
                "estep_mmap_source_us", "estep_synthetic_source_us",
                "estep_source_prefetch0_us", "estep_source_prefetch1_us",
                "estep_source_prefetch2_us", "source_vs_scan",
                "source_vs_full", "synthetic_vs_array",
                "chosen_prefetch_depth"),
}
STAGES = ("kmeans_lloyd", "init_label_stats", "em_estep", "bic_score")

# Full-mode regression guards: the ratios/outliers this PR drove down stay
# down, or the bench refuses to write the tracked JSON. (Quick/dry modes
# run on scaled shapes and noisy CI boxes — guards only apply to the
# committed full-mode numbers.)
SOURCE_VS_FULL_MAX = 2.0
# The seeded synthetic stream must stay near the resident-array source:
# the per-row fold_in/split/categorical/normal spelling put generation at
# ~3x the E-step itself (55.7ms vs 19.6ms); the tile-batched generator
# (sources._synth_block) holds the ratio under this.
SYNTHETIC_VS_ARRAY_MAX = 1.5
INIT_US_MAX = 500_000


def validate_report(report: dict) -> None:
    """Schema gate for the tracked JSON; raises ValueError listing every
    violation rather than stopping at the first."""
    problems = []
    for field in ("backend", "shape", "chunk_size", "stages", "sources"):
        if field not in report:
            problems.append(f"missing top-level field {field!r}")
    shape = report.get("shape", {})
    for field in ("n", "d", "k"):
        if not isinstance(shape.get(field), int):
            problems.append(f"shape.{field} must be an int")
    stages = report.get("stages", {})
    missing_stages = set(STAGES) - set(stages)
    if missing_stages:
        problems.append(f"missing stages: {sorted(missing_stages)}")
    for stage, row in stages.items():
        for field in REPORT_SCHEMA["stages"]:
            v = row.get(field)
            if not isinstance(v, (int, float)) or v < 0:
                problems.append(f"stages.{stage}.{field} must be a "
                                f"non-negative number, got {v!r}")
    sources = report.get("sources", {})
    for field in REPORT_SCHEMA["sources"]:
        v = sources.get(field)
        if not isinstance(v, (int, float)) or v < 0:
            problems.append(f"sources.{field} must be a non-negative "
                            f"number, got {v!r}")
    if problems:
        raise ValueError("BENCH_streaming.json schema violations:\n  "
                         + "\n  ".join(problems))


def _stages(x, gmm, assignments, chunk):
    """{stage: (full_fn, chunked_fn, full_peak_bytes, chunked_peak_bytes)}.
    Data is a traced jit argument everywhere — a closed-over array would be
    constant-folded by XLA and the full-batch timings would be fiction."""
    n = x.shape[0]
    nk = lambda rows: rows * K * 4
    nd = lambda rows: rows * D * 4
    key = jax.random.key(0)
    lbl_full = jax.jit(lambda x, a: label_stats(x, a, K).s1)
    lbl_chunk = jax.jit(lambda x, a: label_stats(x, a, K,
                                                 chunk_size=chunk).s1)
    es_full = jax.jit(lambda x: e_step_stats(gmm, x).s1)
    es_chunk = jax.jit(lambda x: e_step_stats(gmm, x, chunk_size=chunk).s1)
    bic_full = jax.jit(lambda x: gmm.bic(x))
    bic_cfg = FitConfig(chunk_size=chunk)
    bic_chunk = jax.jit(lambda x: api_bic(gmm, x, config=bic_cfg))
    return {
        "kmeans_lloyd": (
            lambda: kmeans(key, x, K, max_iter=10, tol=0.0).centers,
            lambda: kmeans(key, x, K, max_iter=10, tol=0.0,
                           chunk_size=chunk).centers,
            nk(n), nk(chunk)),
        "init_label_stats": (
            lambda: lbl_full(x, assignments),
            lambda: lbl_chunk(x, assignments),
            nd(n), nd(chunk)),
        "em_estep": (
            lambda: es_full(x), lambda: es_chunk(x), nk(n), nk(chunk)),
        "bic_score": (
            lambda: bic_full(x), lambda: bic_chunk(x), nk(n), nk(chunk)),
    }


def _source_section(x, gmm, chunk, iters, tmpdir):
    """Out-of-core E-step rows: the same reduction through each DataSource
    flavour vs the resident full-batch and lax.scan paths. The host block
    loop re-dispatches per block but never pays scan's serialized-carry
    cost — this comparison is what ROADMAP follow-up (b) tracks."""
    n = x.shape[0]
    npy = Path(tmpdir) / f"bench_rows_{n}.npy"
    np.save(npy, np.asarray(x))
    srcs = {
        "array": ArraySource(x),
        "mmap": NpyFileSource(npy),
        "synthetic": SyntheticGMMSource(gmm, n, jax.random.key(2)),
    }
    es_full = jax.jit(lambda x: e_step_stats(gmm, x).s1)
    es_scan = jax.jit(lambda x: e_step_stats(gmm, x, chunk_size=chunk).s1)
    es_scan2 = jax.jit(lambda x: e_step_stats(gmm, x, chunk_size=chunk,
                                              scan_width=2).s1)
    full_us = _time(lambda: es_full(x), iters=iters)
    scan_us = _time(lambda: es_scan(x), iters=iters)
    scan2_us = _time(lambda: es_scan2(x), iters=iters)
    section = {
        "chunk_size": chunk,
        "estep_full_us": round(full_us),
        "estep_scan_chunked_us": round(scan_us),
        "estep_scan2_chunked_us": round(scan2_us),
    }
    rows = []
    for name, src in srcs.items():
        us = _time(lambda: e_step_stats(gmm, src, chunk_size=chunk).s1,
                   iters=iters)
        section[f"estep_{name}_source_us"] = round(us)
        rows.append(f"streaming/estep_source_{name}_c{chunk}/N{n}d{D}K{K},"
                    f"{us:.0f},{chunk * K * 4 / 2**20:.2f}")
    # Prefetch-depth sweep over the array source: depth 0 = synchronous
    # block loop, 1/2 = producer thread keeping that many prepared blocks
    # ahead of compute. Depth is pinned via the module default so the rows
    # time exactly what library callers get at each setting.
    default_depth = sources.PREFETCH_DEPTH
    try:
        for depth in (0, 1, 2):
            sources.PREFETCH_DEPTH = depth
            us = _time(lambda: e_step_stats(gmm, srcs["array"],
                                            chunk_size=chunk).s1,
                       iters=iters)
            section[f"estep_source_prefetch{depth}_us"] = round(us)
            rows.append(
                f"streaming/estep_source_prefetch{depth}_c{chunk}/"
                f"N{n}d{D}K{K},{us:.0f},{chunk * K * 4 / 2**20:.2f}")
    finally:
        sources.PREFETCH_DEPTH = default_depth
    section["source_vs_scan"] = round(
        section["estep_array_source_us"] / max(scan_us, 1e-9), 3)
    section["source_vs_full"] = round(
        section["estep_array_source_us"] / max(full_us, 1e-9), 3)
    section["synthetic_vs_array"] = round(
        section["estep_synthetic_source_us"]
        / max(section["estep_array_source_us"], 1e-9), 3)
    # What default_prefetch_depth() picks on THIS host — recorded next to
    # the measured depth sweep so the auto heuristic is auditable against
    # the numbers it claims to optimize (guarded in full mode).
    section["chosen_prefetch_depth"] = sources.default_prefetch_depth()
    return section, rows


def run(quick: bool = True, dry_run: bool = False) -> list[str]:
    n = N_DRY if dry_run else (N_QUICK if quick else N_FULL)
    chunk = CHUNK_DRY if dry_run else CHUNK
    iters = 1 if dry_run else 20
    rng = np.random.default_rng(0)
    mus = rng.normal(0, 5, (K, D)).astype(np.float32)
    comp = rng.integers(0, K, n)
    x = jnp.asarray(mus[comp] + rng.normal(0, 0.7, (n, D)).astype(np.float32))
    gmm = GMM(jnp.full((K,), 1.0 / K), jnp.asarray(mus),
              jnp.full((K, D), 0.5))
    assignments = jnp.asarray(comp, jnp.int32)

    report = {
        "backend": jax.default_backend(),
        "shape": {"n": n, "d": D, "k": K},
        "chunk_size": chunk,
        "stages": {},
    }
    rows = []
    for stage, (full_fn, chunked_fn, full_b, chunk_b) in _stages(
            x, gmm, assignments, chunk).items():
        full_us, chunked_us = _time_pair(full_fn, chunked_fn, iters=iters)
        report["stages"][stage] = {
            "full_us": round(full_us),
            "chunked_us": round(chunked_us),
            "full_peak_bytes": full_b,
            "chunked_peak_bytes": chunk_b,
            "slowdown": round(chunked_us / full_us, 3),
        }
        rows.append(f"streaming/{stage}_full/N{n}d{D}K{K},{full_us:.0f},"
                    f"{full_b / 2**20:.2f}")
        rows.append(f"streaming/{stage}_chunked_c{chunk}/N{n}d{D}K{K},"
                    f"{chunked_us:.0f},{chunk_b / 2**20:.2f}")
    with tempfile.TemporaryDirectory() as tmpdir:
        report["sources"], src_rows = _source_section(x, gmm, chunk, iters,
                                                      tmpdir)
    rows.extend(src_rows)
    validate_report(report)
    if dry_run:
        rows.append("# dry-run: report schema OK, timings are placeholders")
        return rows
    if not quick:
        # end-to-end streaming init (4-restart k-means + label stats)
        us = _time(lambda: init_from_kmeans(jax.random.key(1), x, K,
                                            chunk_size=chunk).means, iters=1)
        report["init_from_kmeans_chunked_us"] = round(us)
        guard_violations = []
        if report["sources"]["source_vs_full"] > SOURCE_VS_FULL_MAX:
            guard_violations.append(
                f"source_vs_full {report['sources']['source_vs_full']} > "
                f"{SOURCE_VS_FULL_MAX} (host block loop regressed vs "
                f"full-batch)")
        if report["sources"]["synthetic_vs_array"] > SYNTHETIC_VS_ARRAY_MAX:
            guard_violations.append(
                f"synthetic_vs_array "
                f"{report['sources']['synthetic_vs_array']} > "
                f"{SYNTHETIC_VS_ARRAY_MAX} (the per-row generation "
                f"outlier is back)")
        depth_us = {d: report["sources"][f"estep_source_prefetch{d}_us"]
                    for d in (0, 1, 2)}
        chosen = report["sources"]["chosen_prefetch_depth"]
        if chosen in depth_us and depth_us[chosen] == max(depth_us.values()) \
                and len(set(depth_us.values())) > 1:
            guard_violations.append(
                f"chosen_prefetch_depth {chosen} is the slowest measured "
                f"depth ({depth_us}) — the auto heuristic picked wrong "
                f"on this host")
        if report["init_from_kmeans_chunked_us"] >= INIT_US_MAX:
            guard_violations.append(
                f"init_from_kmeans_chunked_us "
                f"{report['init_from_kmeans_chunked_us']} >= {INIT_US_MAX} "
                f"(the 6.3s init outlier is back)")
        if guard_violations:
            raise RuntimeError("streaming bench regression guard:\n  "
                               + "\n  ".join(guard_violations))
        JSON_PATH.write_text(json.dumps(report, indent=2) + "\n")
    return rows


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--dry-run", action="store_true",
                        help="tiny-N schema-validation mode (CI bench-smoke "
                             "lane): exercises every code path, validates "
                             "the report schema, writes nothing")
    cli = parser.parse_args()
    for r in run(quick=cli.dry_run, dry_run=cli.dry_run):
        print(r)
    if not cli.dry_run:
        print(f"# wrote {JSON_PATH}")
