"""Roofline analysis from the dry-run artifacts (§Roofline).

Three terms per (arch x shape x mesh), all in seconds/step/device:

    compute    = FLOPs_per_device / peak_FLOPs
    memory     = HBM_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / link_bw

FLOPs source: XLA's cost_analysis counts while-loop bodies ONCE (verified
experimentally), which silently drops the layer scan — so the compute and
memory terms use an ANALYTIC per-architecture model (standard matmul
accounting, validated against the unscanned-layer HLO numbers), and the raw
HLO numbers are reported alongside. Collective bytes come from the HLO walk
in launch/dryrun.py (while-loop trip counts multiplied back in).

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

from repro.configs import INPUT_SHAPES, get_config
from repro.models.transformer import ModelConfig

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link


# ----------------------------------------------------------------------
# Analytic FLOPs model
# ----------------------------------------------------------------------

def _attn_layer_flops(cfg: ModelConfig, ctx: float, window=None) -> float:
    """Per-token forward FLOPs for one attention layer (excl. FFN)."""
    d, hd = cfg.d_model, cfg.hd
    h, kv = cfg.n_heads, cfg.n_kv_heads
    proj = 2 * d * hd * (2 * h + 2 * kv)          # q,o (h) + k,v (kv)
    eff_ctx = min(ctx, window) if window else ctx
    scores = 2 * 2 * h * hd * eff_ctx             # qk^T + pv
    return proj + scores


def _ffn_flops(cfg: ModelConfig, dense_width=None) -> float:
    d = cfg.d_model
    if dense_width is not None:
        mult = 3 if cfg.gated_mlp else 2
        return 2 * d * dense_width * mult
    if cfg.moe is not None:
        m = cfg.moe
        mult = 3  # gated experts
        expert = m.top_k * 2 * d * m.d_ff * mult
        router = 2 * d * m.n_experts
        cap = m.group_size * m.top_k * m.capacity_factor / m.n_experts
        dispatch = 2 * 2 * m.n_experts * cap * d  # dispatch + combine
        shared = 2 * d * (m.n_shared * m.d_ff) * 3 if m.n_shared else 0
        return expert + router + dispatch + shared
    mult = 3 if cfg.gated_mlp else 2
    return 2 * d * cfg.d_ff * mult


def _rglru_flops(cfg: ModelConfig) -> float:
    d, dr = cfg.d_model, cfg.d_rnn
    return 2 * d * dr * 3 + 2 * dr * dr * 2 + 10 * dr


def _mlstm_flops(cfg: ModelConfig, ctx: float) -> float:
    d = cfg.d_model
    di = cfg.xlstm.n_heads * cfg.xlstm.head_dim
    return 2 * d * 2 * di + 3 * 2 * di * di + 2 * 2 * di * ctx + 2 * di * d


def _slstm_flops(cfg: ModelConfig) -> float:
    d = cfg.d_model
    up = max(256, (4 * d // 3 + 255) // 256 * 256)
    return 2 * d * 4 * d + 2 * d * 4 * (d // cfg.xlstm.n_heads) \
        + 2 * d * 2 * up + 2 * up * d


def forward_flops_per_token(cfg: ModelConfig, ctx: float,
                            decode: bool = False) -> float:
    """Forward FLOPs for one generated/processed token at context ``ctx``."""
    total = 0.0
    for i, lt in enumerate(cfg.layer_types()):
        if lt in ("attn", "dense_attn"):
            dense_w = None
            if i < cfg.first_k_dense:
                dense_w = cfg.first_dense_d_ff or cfg.d_ff
            total += _attn_layer_flops(cfg, ctx)
            total += _ffn_flops(cfg, dense_w)
            if cfg.n_enc_layers:  # cross attention
                d, hd, h, kvv = cfg.d_model, cfg.hd, cfg.n_heads, \
                    cfg.n_kv_heads
                total += 2 * d * hd * 2 * h + 2 * 2 * h * hd * \
                    (ctx / cfg.src_ratio)
        elif lt == "swa":
            total += _attn_layer_flops(cfg, ctx, cfg.window)
            total += _ffn_flops(cfg)
        elif lt == "local_attn":
            total += _attn_layer_flops(cfg, ctx, cfg.local_window)
            total += _ffn_flops(cfg)
        elif lt == "rglru":
            total += _rglru_flops(cfg) + _ffn_flops(cfg)
        elif lt == "mlstm":
            total += _mlstm_flops(cfg, 0 if decode else ctx)
        elif lt == "slstm":
            total += _slstm_flops(cfg)
    total += 2 * cfg.d_model * cfg.vocab_size       # vocab head
    return total


def encoder_flops(cfg: ModelConfig, src_len: int) -> float:
    per_tok = cfg.n_enc_layers * (_attn_layer_flops(cfg, src_len)
                                  + _ffn_flops(cfg))
    return per_tok * src_len


def step_flops(cfg: ModelConfig, shape_name: str) -> float:
    """Global (all-device) FLOPs for one step of this input shape."""
    sh = INPUT_SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]
    if kind == "train":
        # causal: avg context S/2; train = fwd + remat fwd + 2x bwd = 4x
        fwd = forward_flops_per_token(cfg, s / 2) * b * s
        if cfg.n_enc_layers:
            fwd += encoder_flops(cfg, s // cfg.src_ratio) * b
        if cfg.frontend == "vision":
            fwd += forward_flops_per_token(cfg, s / 2) * b * cfg.n_prefix
        return fwd * (4 if cfg.remat else 3)
    if kind == "prefill":
        fwd = forward_flops_per_token(cfg, s / 2) * b * s
        if cfg.n_enc_layers:
            fwd += encoder_flops(cfg, s // cfg.src_ratio) * b
        return fwd
    # decode: ONE token against ctx = s
    ctx = min(s, cfg.long_window) if kind == "decode_ring" else s
    return forward_flops_per_token(cfg, ctx, decode=True) * b


def n_params(cfg: ModelConfig, active_only: bool = False) -> float:
    """Analytic parameter count (active = MoE top-k + shared only)."""
    d, v = cfg.d_model, cfg.vocab_size
    total = 2 * v * d  # embed + head
    for i, lt in enumerate(cfg.layer_types()):
        if lt in ("attn", "dense_attn", "swa", "local_attn"):
            hd, h, kv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
            total += d * hd * (2 * h + 2 * kv)
            if cfg.n_enc_layers:
                total += d * hd * (2 * h + 2 * kv)  # cross attn
            if cfg.moe is not None and i >= cfg.first_k_dense:
                m = cfg.moe
                e = m.top_k if active_only else m.n_experts
                total += e * 3 * d * m.d_ff + d * m.n_experts
                total += (3 * d * m.n_shared * m.d_ff) if m.n_shared else 0
            else:
                w = cfg.first_dense_d_ff if i < cfg.first_k_dense and \
                    cfg.first_dense_d_ff else cfg.d_ff
                total += (3 if cfg.gated_mlp else 2) * d * w
        elif lt == "rglru":
            total += 3 * d * cfg.d_rnn + 2 * cfg.d_rnn ** 2 \
                + (3 if cfg.gated_mlp else 2) * d * cfg.d_ff
        elif lt == "mlstm":
            di = cfg.xlstm.n_heads * cfg.xlstm.head_dim
            total += 2 * d * di + 3 * di * di + di * d
        elif lt == "slstm":
            up = max(256, (4 * d // 3 + 255) // 256 * 256)
            total += 4 * d * d + 4 * d * (d // cfg.xlstm.n_heads) \
                + 3 * up * d
    if cfg.n_enc_layers:
        hd, h, kv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
        total += cfg.n_enc_layers * (d * hd * (2 * h + 2 * kv)
                                     + (3 if cfg.gated_mlp else 2)
                                     * d * cfg.d_ff)
    return total


# ----------------------------------------------------------------------
# Roofline assembly
# ----------------------------------------------------------------------

def analyze(record: dict) -> dict:
    cfg = get_config(record["arch"])
    devices = record["devices"]
    sh_name = record["shape"]
    sh = INPUT_SHAPES[sh_name]

    flops_global = step_flops(cfg, sh_name)
    flops_dev = flops_global / devices
    compute_t = flops_dev / PEAK_FLOPS

    # memory term: HLO bytes accessed (per device) — while-body-once caveat
    # makes this a LOWER bound; we also add the analytic param+cache bytes
    # which dominate the truth for most shapes.
    hlo_bytes = record["cost"]["bytes_accessed"]
    params_bytes = n_params(cfg) * 4 / devices
    kind = sh["kind"]
    if kind == "train":
        analytic_bytes = 3 * params_bytes  # read p, read grads, write p (opt)
    else:
        analytic_bytes = params_bytes / 2  # bf16 weights read once
    if kind.startswith("decode"):
        analytic_bytes += record["memory"]["argument_bytes"]  # cache read
    mem_bytes = max(hlo_bytes, analytic_bytes)
    memory_t = mem_bytes / HBM_BW

    coll_bytes = record["collective_bytes_total"]
    collective_t = coll_bytes / LINK_BW

    model_flops = 6 * n_params(cfg, active_only=True) * \
        sh["global_batch"] * sh["seq_len"] if kind == "train" else \
        2 * n_params(cfg, active_only=True) * sh["global_batch"] * \
        (sh["seq_len"] if kind == "prefill" else 1)

    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": collective_t}
    dominant = max(terms, key=terms.get)
    step_t = max(terms.values())
    return {
        "arch": record["arch"], "shape": sh_name, "mesh": record["mesh"],
        **{k: float(f"{v:.3e}") for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "flops_analytic_global": flops_global,
        "flops_hlo_raw_perdev": record["cost"]["flops"],
        "model_flops": model_flops,
        "useful_ratio": model_flops / max(flops_global, 1.0),
        "mfu_at_roofline": (flops_dev / step_t) / PEAK_FLOPS,
        "peak_gib": record["memory"]["peak_bytes"] / 2**30,
    }


def run(dryrun_dir="experiments/dryrun", mesh="singlepod") -> list[str]:
    rows = []
    recs = []
    for f in sorted(Path(dryrun_dir).glob(f"*__{mesh}.json")):
        r = analyze(json.loads(f.read_text()))
        recs.append(r)
        rows.append(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},0,"
            f"dominant={r['dominant']};compute={r['compute_s']:.2e}s;"
            f"memory={r['memory_s']:.2e}s;coll={r['collective_s']:.2e}s;"
            f"useful={r['useful_ratio']:.2f};mfu={r['mfu_at_roofline']:.3f}")
    out = Path(dryrun_dir).parent / f"roofline_{mesh}.json"
    out.write_text(json.dumps(recs, indent=2))
    return rows


def table(dryrun_dir="experiments/dryrun", mesh="singlepod"):
    print(f"{'arch':>20} {'shape':>12} {'compute':>9} {'memory':>9} "
          f"{'coll':>9} {'dom':>8} {'useful':>7} {'MFU@roof':>8} "
          f"{'peakGiB':>8}")
    for f in sorted(Path(dryrun_dir).glob(f"*__{mesh}.json")):
        r = analyze(json.loads(f.read_text()))
        print(f"{r['arch']:>20} {r['shape']:>12} {r['compute_s']:>9.2e} "
              f"{r['memory_s']:>9.2e} {r['collective_s']:>9.2e} "
              f"{r['dominant']:>8} {r['useful_ratio']:>7.2f} "
              f"{r['mfu_at_roofline']:>8.3f} {r['peak_gib']:>8.2f}")


if __name__ == "__main__":
    table(*sys.argv[1:])
