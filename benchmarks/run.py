"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Set BENCH_FULL=1 for paper-scale
settings (quick CPU-scale by default). The roofline rows appear only if the
dry-run artifacts exist (run ``python -m repro.launch.dryrun --all`` first).
"""
import os
import sys
import time
import traceback
from pathlib import Path


def main() -> None:
    quick = os.environ.get("BENCH_FULL", "0") != "1"
    from benchmarks import (ablation_h, fed_bench, fig2_global_fit,
                            fig3_anomaly, fig4_clients, fig5_constrained,
                            kernel_bench, serve_bench, streaming_bench,
                            table4_comm)
    # streaming_bench / fed_bench / serve_bench also refresh the
    # machine-readable trajectory files (BENCH_streaming.json /
    # BENCH_comm.json / BENCH_serve.json) when run standalone in full mode.
    modules = [fig2_global_fit, table4_comm, fig3_anomaly, fig4_clients,
               fig5_constrained, ablation_h, kernel_bench, streaming_bench,
               fed_bench, serve_bench]
    print("name,us_per_call,derived")
    ok = True
    for mod in modules:
        t0 = time.time()
        try:
            for row in mod.run(quick=quick):
                print(row, flush=True)
        except Exception:
            ok = False
            traceback.print_exc()
        print(f"# {mod.__name__}: {time.time() - t0:.0f}s", file=sys.stderr)
    # roofline (needs dry-run artifacts)
    if Path("experiments/dryrun").exists() and \
            any(Path("experiments/dryrun").glob("*.json")):
        from benchmarks import roofline
        for row in roofline.run():
            print(row, flush=True)
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
